//! Fig. 4 reproduction — the END-TO-END DRIVER (DESIGN.md §5).
//!
//! Full system on the real small workload: conditional latent diffusion of
//! the three letters H/K/U with classifier-free guidance, decoded to pixel
//! space, served through the batching coordinator:
//!
//!   requests → batcher → analog solver (simulated RRAM macro, read noise
//!   on) → latent samples → VAE decoder → images;  the same workload runs
//!   on the digital baseline (AOT PJRT artifacts) for the Fig. 4g/4h
//!   speed/energy comparison at matched quality.
//!
//! Run with: `cargo run --release --example letters_latent`

use std::sync::Arc;

use memdiff::coordinator::service::AnalogEngine;
use memdiff::coordinator::{Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::energy::model::{AnalogCost, Comparison, DigitalCost};
use memdiff::nn::{AnalogScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::rng::Rng;
use memdiff::util::stats;
use memdiff::vae::{DecoderWeights, PixelDecoder};

const LETTERS: [&str; 3] = ["H", "K", "U"];
const GUIDANCE: f32 = 2.0;
const N_PER_CLASS: usize = 500; // paper Fig. 4d: 500 samplings per condition

/// Per-class quality vs the *software baseline* (paper framing:
/// "equivalent generative quality to the software baseline"): KL between
/// generated points and a converged 512-step digital reference sampled at
/// the same guidance strength.
fn baseline_kl(samples: &[f32], reference: &[f32]) -> f64 {
    stats::kl_points(samples, reference, 20, 3.0)
}

fn ascii_image(img: &[f32], side: usize) {
    for r in 0..side {
        let row: String = (0..side)
            .map(|c| match img[r * side + c] {
                v if v > 0.4 => '#',
                v if v > 0.0 => '+',
                v if v > -0.5 => '.',
                _ => ' ',
            })
            .collect();
        println!("    {row}");
    }
}

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let weights = ScoreWeights::load(Meta::artifacts_dir().join("weights_cond.json"))?;
    let decoder = Arc::new(PixelDecoder::new(DecoderWeights::load(
        Meta::artifacts_dir().join("vae_decoder.json"))?));
    let mut rng = Rng::new(4242);

    println!("== Fig 4: conditional latent diffusion of letters H/K/U (CFG λ={GUIDANCE})");

    // ---- analog system through the full coordinator ----------------------
    let engine = Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(
            &weights, CellParams::default(), NoiseModel::ReadFast),
        meta.sched,
        4000,
    ));
    let service = Service::start(engine, Some(decoder.clone()), ServiceConfig {
        workers: 4,
        ..ServiceConfig::default()
    });

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..3)
        .map(|c| {
            service
                .submit(memdiff::coordinator::GenRequest {
                    id: 0,
                    task: TaskKind::Letter(c),
                    n_samples: N_PER_CLASS,
                    solver: SolverChoice::AnalogSde,
                    guidance: GUIDANCE,
                    decode: true,
                    trace: memdiff::obs::TraceId::mint(),
                })
                .unwrap()
        })
        .collect();
    let responses: Vec<_> = rxs.into_iter()
        .map(|rx| rx.recv().unwrap())
        .collect();
    let wall = t0.elapsed();

    // software-baseline reference: converged 512-step digital sampler at
    // the same guidance, per class (the distribution the paper's GPU
    // produces when given unlimited steps)
    let store = ArtifactStore::open_default()?;
    let mut references: Vec<Vec<f32>> = Vec::new();
    for c in 0..3 {
        let onehot: Vec<f32> = (0..64)
            .flat_map(|_| {
                let mut v = [0.0f32; 3];
                v[c] = 1.0;
                v
            })
            .collect();
        let mut pts = Vec::new();
        for _ in 0..((4 * N_PER_CLASS) / 64) {
            pts.extend(store.sample_digital(64, 512, true,
                                            Some((&onehot, GUIDANCE)), &mut rng)?);
        }
        references.push(pts);
    }

    println!("\n== Fig 4d: generated latent distributions (analog SDE, {N_PER_CLASS}/class)");
    let mut kl_analog = 0.0f64;
    for (c, resp) in responses.iter().enumerate() {
        let xs: Vec<f32> = resp.samples.iter().step_by(2).copied().collect();
        let ys: Vec<f32> = resp.samples.iter().skip(1).step_by(2).copied().collect();
        let kl = baseline_kl(&resp.samples, &references[c]);
        kl_analog = kl_analog.max(kl);
        println!(
            "  {} : mean ({:+.3}, {:+.3})  class center ({:+.3}, {:+.3})  \
             KL-vs-baseline={kl:.3}",
            LETTERS[c],
            stats::mean(&xs), stats::mean(&ys),
            meta.latent_class_means[c][0], meta.latent_class_means[c][1]
        );
    }

    println!("\n== Fig 4f: decoded images (first sample per condition)");
    for (c, resp) in responses.iter().enumerate() {
        println!("  letter {}:", LETTERS[c]);
        ascii_image(&resp.images.as_ref().unwrap()[..144], 12);
    }
    println!("\n  coordinator wall time for 3x{N_PER_CLASS} decoded samples: {wall:?}");
    println!("  metrics: {}", service.metrics.snapshot().report());
    service.shutdown();

    // ---- digital baseline via the AOT PJRT artifacts ---------------------
    println!("\n== Fig 4g/4h: digital baseline sweep (AOT artifacts, CFG baked in)");
    let mut matched_steps = None;
    println!("  steps | worst-class KL vs converged baseline (digital SDE)");
    for steps in [4usize, 8, 16, 32, 64, 128, 256] {
        let mut worst: f64 = 0.0;
        for c in 0..3 {
            let onehot: Vec<f32> = (0..64)
                .flat_map(|_| {
                    let mut v = [0.0f32; 3];
                    v[c] = 1.0;
                    v
                })
                .collect();
            let mut pts = Vec::new();
            for _ in 0..(N_PER_CLASS / 64 + 1) {
                let x = store.sample_digital(64, steps, true,
                                             Some((&onehot, GUIDANCE)), &mut rng)?;
                pts.extend(x);
            }
            pts.truncate(2 * N_PER_CLASS);
            worst = worst.max(baseline_kl(&pts, &references[c]));
        }
        println!("  {steps:5} | {worst:.3}");
        if matched_steps.is_none() && worst <= kl_analog * 1.05 {
            matched_steps = Some(steps);
        }
    }
    let steps = matched_steps.unwrap_or(256);
    let c = Comparison::of(&AnalogCost::conditional_projected(),
                           &DigitalCost::new(steps, 2));
    println!("  matched-quality digital steps = {steps} (x2 CFG evals)");
    println!("  speedup      = {:.1}x   (paper Fig 4g: 156.5x)", c.speedup);
    println!("  energy red.  = {:.1}%   (paper Fig 4h: 75.6%)",
             c.energy_reduction_pct);
    println!("  analog: {:.1} us, {:.2} uJ | digital: {:.1} us, {:.2} uJ",
             1e6 * c.analog_latency_s, 1e6 * c.analog_energy_j,
             1e6 * c.digital_latency_s, 1e6 * c.digital_energy_j);
    Ok(())
}
