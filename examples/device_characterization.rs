//! Fig. 2 reproduction: resistive-memory device & array characterization
//! on the behavioural simulator (DESIGN.md §3, substitution 1).
//!
//!  * 2c — 200-cycle quasi-static bipolar IV sweeps
//!  * 2d — 64 discernible linear conductance states
//!  * 2e — retention over 1e6 s with read-noise bands
//!  * 2f — 32×32 moon-and-star conductance pattern (write-verify)
//!  * 2g — array conductance error distribution at different times
//!
//! Run with: `cargo run --release --example device_characterization`

use memdiff::device::{Cell, Macro};
use memdiff::util::rng::Rng;
use memdiff::util::stats;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(2024);

    // ---- Fig. 2c ----------------------------------------------------------
    println!("== Fig 2c: quasi-static IV, 200 cycles (mean current at probe voltages)");
    let up: Vec<f32> = (0..60).map(|i| 1.5 * i as f32 / 59.0).collect();
    let dn: Vec<f32> = (0..60).map(|i| -1.5 * i as f32 / 59.0).collect();
    let mut cell = Cell::with_default(0.02);
    let mut i_set = Vec::new();
    let mut i_reset = Vec::new();
    for _ in 0..200 {
        let iu = cell.iv_sweep(&up, &mut rng);
        i_set.push(*iu.last().unwrap());
        let id = cell.iv_sweep(&dn, &mut rng);
        i_reset.push(*id.last().unwrap());
    }
    println!("  I(+1.5V): {:.4} ± {:.4} mA over 200 cycles",
             stats::mean(&i_set), stats::std(&i_set));
    println!("  I(-1.5V): {:.4} ± {:.4} mA",
             stats::mean(&i_reset), stats::std(&i_reset));
    println!("  cycle-to-cycle CV: {:.1}% (paper: highly uniform)",
             100.0 * stats::std(&i_set) / stats::mean(&i_set).abs());

    // ---- Fig. 2d ----------------------------------------------------------
    println!("\n== Fig 2d: 64 linear conductance states, programmed and read back");
    let mut max_overlap = 0usize;
    let mut prev_hi = f32::MIN;
    for k in 0..64 {
        let target = Cell::level_conductance(k);
        let mut c = Cell::with_default(0.05);
        c.program_verify(target, 0.0005, 2000, &mut rng);
        let reads: Vec<f32> = (0..200).map(|_| c.read(&mut rng)).collect();
        let (m, s) = (stats::mean(&reads) as f32, stats::std(&reads) as f32);
        if m - 2.0 * s < prev_hi {
            max_overlap += 1;
        }
        prev_hi = m + 2.0 * s;
        if k % 8 == 0 {
            println!("  level {k:2}: {m:.5} ± {s:.5} mS");
        }
    }
    println!("  levels with 2σ overlap vs neighbour: {max_overlap}/64 \
              (discernibility, paper: ≥64 states)");

    // ---- Fig. 2e ----------------------------------------------------------
    println!("\n== Fig 2e: retention of 8 states over 1e6 s");
    for k in (0..64).step_by(8) {
        let mut c = Cell::with_default(Cell::level_conductance(k));
        let g0 = c.conductance();
        let mut worst: f32 = 0.0;
        for _ in 0..6 {
            c.drift(10.0_f64.powi(1), &mut rng); // cumulative decades
            worst = worst.max((c.conductance() - g0).abs());
        }
        c.drift(1e6, &mut rng);
        println!("  level {k:2}: {g0:.4} -> {:.4} mS after 1e6 s (max excursion {worst:.4})",
                 c.conductance());
    }

    // ---- Fig. 2f ----------------------------------------------------------
    println!("\n== Fig 2f: 32x32 moon-and-star conductance pattern");
    let mut array = Macro::new(32, 32);
    let pattern = Macro::moon_star_pattern(32);
    let st = array.program(&pattern, 0.0015, 500, &mut rng);
    println!("  write-verify: {:.1} pulses/cell mean, {} failures, max |err| {:.4} mS",
             st.mean_pulses(), st.failures, st.max_error_ms());
    let snap = array.conductances();
    for r in 0..32 {
        let row: String = (0..32)
            .map(|c| if snap.get(r, c) > 0.06 { '#' } else { '.' })
            .collect();
        println!("  {row}");
    }

    // ---- Fig. 2g ----------------------------------------------------------
    println!("\n== Fig 2g: conductance relative-error distribution vs time");
    for (label, age_s) in [("t = 0", 0.0f64), ("t = 1e3 s", 1e3), ("t = 1e6 s", 1e6)] {
        if age_s > 0.0 {
            array.age(age_s, &mut rng);
        }
        let read = array.read_all(&mut rng);
        let errs: Vec<f32> = read
            .as_slice()
            .iter()
            .zip(pattern.as_slice())
            .map(|(r, t)| 100.0 * (r - t) / t)
            .collect();
        println!("  {label:10}: relative error mean {:+.3}% std {:.3}%",
                 stats::mean(&errs), stats::std(&errs));
    }
    println!("\nExpected shape (paper): Gaussian error distribution, no significant");
    println!("temporal variation — retention keeps states stable over 1e6 s.");
    Ok(())
}
