//! Fig. 3 reproduction: unconditional generation of the circular
//! distribution on the analog neural-differential-equation solver.
//!
//! Produces, as text/CSV on stdout:
//!  * 3b — histogram of target vs programmed weights (write-verify)
//!  * 3c — per-layer input-voltage histograms (clamping effect)
//!  * 3d — the 2-D score vector field at t = 0.5
//!  * 3e — time slices of 1000 samplings + two example trajectories
//!  * 3f/3g — speed & energy vs the digital baseline at matched quality
//!
//! Run with: `cargo run --release --example circular_generation`

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::diffusion::sampler::{DigitalSampler, SamplerMode};
use memdiff::energy::model::{AnalogCost, Comparison, DigitalCost};
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreNet, ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::stats;

fn histogram(label: &str, xs: &[f32], lo: f32, hi: f32, bins: usize) {
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let k = (((x - lo) / (hi - lo)) * bins as f32) as isize;
        counts[k.clamp(0, bins as isize - 1) as usize] += 1;
    }
    let max = counts.iter().copied().max().unwrap_or(1).max(1);
    println!("  {label}: [{lo:.2}, {hi:.2}] n={}", xs.len());
    for (k, &c) in counts.iter().enumerate() {
        let x = lo + (hi - lo) * (k as f32 + 0.5) / bins as f32;
        let bar = "#".repeat(c * 40 / max);
        println!("    {x:+.3} | {bar}");
    }
}

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(33);

    // ---- Fig. 3b: program the macro with write-verify, compare weights --
    println!("== Fig 3b: offline-optimized weights vs programmed conductance weights");
    // verify band 0.0005 mS ≈ half a conductance level — the paper's Fig. 2g
    // programming accuracy; Fig. 5e shows quality degrades beyond ~0.001
    let (net, pulses) = AnalogScoreNet::program_from_weights(
        &w, CellParams::default(), 0.0005, NoiseModel::ReadFast, &mut rng);
    println!("  write-verify used {pulses} pulses total");
    let (e1, e2, e3) = net.effective_weights();
    let target: Vec<f32> = w.w1.as_slice().iter()
        .chain(w.w2.as_slice()).chain(w.w3.as_slice()).copied().collect();
    let actual: Vec<f32> = e1.as_slice().iter()
        .chain(e2.as_slice()).chain(e3.as_slice()).copied().collect();
    let errs: Vec<f32> = target.iter().zip(&actual).map(|(t, a)| a - t).collect();
    println!("  weight deployment error: mean {:+.4}, std {:.4} (target std {:.4})",
             stats::mean(&errs), stats::std(&errs), stats::std(&target));
    histogram("target weights", &target, -3.5, 3.5, 17);

    // ---- Fig. 3c: layer input-voltage histograms under N(0,1) drive ------
    println!("\n== Fig 3c: input voltages per layer (clamp window [-2, 4])");
    let mut l1_in = Vec::new();
    let mut outs = Vec::new();
    let mut out = [0.0f32; 2];
    for _ in 0..2000 {
        let x = [rng.gaussian_f32(), rng.gaussian_f32()];
        l1_in.extend_from_slice(&x);
        net.eval(&x, rng.uniform() as f32, &[0.0, 0.0, 0.0], &mut out, &mut rng);
        outs.extend_from_slice(&out);
    }
    histogram("network input", &l1_in, -3.0, 5.0, 16);
    histogram("network output", &outs, -3.0, 5.0, 16);

    // ---- Fig. 3d: score vector field at t = 0.5 --------------------------
    println!("\n== Fig 3d: score vector field at t=0.5 (x, y, dx, dy)");
    println!("  x,y,sx,sy");
    for iy in (-2..=2).rev() {
        for ix in -2..=2 {
            let x = [ix as f32 * 0.75, iy as f32 * 0.75];
            net.eval(&x, 0.5, &[0.0, 0.0, 0.0], &mut out, &mut rng);
            // score = -net/sigma
            let sg = meta.sched.sigma(0.5) as f32;
            println!("  {:+.2},{:+.2},{:+.3},{:+.3}", x[0], x[1],
                     -out[0] / sg, -out[1] / sg);
        }
    }

    // ---- Fig. 3e: time slices of 1000 samplings + trajectories ----------
    // Quality sections use the calibrated deployment (exact conductances,
    // read noise on) — the write-noise sensitivity is Fig. 5's experiment.
    let net = AnalogScoreNet::from_conductances(
        &w, CellParams::default(), NoiseModel::ReadFast);
    println!("\n== Fig 3e: time slices (radius mean ± std across 1000 samplings)");
    let cfg = SolverConfig::new(SolverMode::Sde).with_schedule(meta.sched);
    let solver = AnalogSolver::new(&net, cfg);
    let mut slices: Vec<Vec<(f64, Vec<f32>)>> = Vec::new();
    for _ in 0..1000 {
        let mut x = [rng.gaussian_f32(), rng.gaussian_f32()];
        let mut trace = Vec::new();
        solver.solve_into(&mut x, &[], &mut rng, 400, &mut trace);
        slices.push(trace);
    }
    let n_slices = slices[0].len();
    for k in 0..n_slices {
        let t = slices[0][k].0;
        let radii: Vec<f32> = slices.iter()
            .map(|tr| {
                let p = &tr[k].1;
                (p[0] * p[0] + p[1] * p[1]).sqrt()
            })
            .collect();
        println!("  t={t:.2}: radius {:.3} ± {:.3}",
                 stats::mean(&radii), stats::std(&radii));
    }
    println!("  example trajectory (t, x1, x2):");
    for (t, p) in &slices[0] {
        println!("    {t:.2}, {:+.3}, {:+.3}", p[0], p[1]);
    }

    // ---- final distribution + quality ------------------------------------
    let gen = solver.solve_batch(2000, &[], &mut rng);
    let mut truth_rng = Rng::new(77);
    let truth = sample_circle(40_000, &mut truth_rng);
    let kl_analog = stats::kl_points(&gen, &truth, 24, 2.0);
    let radii: Vec<f32> = gen.chunks_exact(2)
        .map(|p| (p[0] * p[0] + p[1] * p[1]).sqrt()).collect();
    println!("\n  analog SDE: radius {:.3} ± {:.3}, KL = {kl_analog:.4}",
             stats::mean(&radii), stats::std(&radii));

    // ---- Fig. 3f/3g: matched-quality speed & energy comparison ----------
    println!("\n== Fig 3f/3g: speed & energy vs digital baseline at matched quality");
    let dig = DigitalScoreNet::new(w.clone());
    let sampler = DigitalSampler::new(&dig, SamplerMode::Sde).with_schedule(meta.sched);
    let mut matched_steps = None;
    println!("  steps | KL (digital SDE)");
    for steps in [4usize, 8, 16, 32, 64, 128, 256, 512] {
        let (pts, _) = sampler.sample_batch(2000, &[], steps, &mut rng);
        let kl = stats::kl_points(&pts, &truth, 24, 2.0);
        println!("  {steps:5} | {kl:.4}");
        if matched_steps.is_none() && kl <= kl_analog * 1.05 {
            matched_steps = Some(steps);
        }
    }
    let steps = matched_steps.unwrap_or(512);
    let analog_cost = AnalogCost::unconditional_projected();
    let digital_cost = DigitalCost::new(steps, 1);
    let c = Comparison::of(&analog_cost, &digital_cost);
    println!("  matched-quality digital steps = {steps}");
    println!("  speedup      = {:.1}x   (paper Fig 3f: 64.8x)", c.speedup);
    println!("  energy red.  = {:.1}%   (paper Fig 3g: 80.8%)",
             c.energy_reduction_pct);
    println!("  analog: {:.1} us, {:.2} uJ | digital: {:.1} us, {:.2} uJ",
             1e6 * c.analog_latency_s, 1e6 * c.analog_energy_j,
             1e6 * c.digital_latency_s, 1e6 * c.digital_energy_j);
    Ok(())
}
