//! Quickstart: load the AOT artifacts, start the generation service, and
//! sample the unconditional circular distribution three ways —
//! the analog closed-loop solver, the rust digital baseline, and the
//! AOT-compiled PJRT artifacts.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first).

use std::sync::Arc;

use memdiff::coordinator::service::{AnalogEngine, HloEngine, RustDigitalEngine};
use memdiff::coordinator::{Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::rng::Rng;
use memdiff::util::stats;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let weights = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    println!("memdiff quickstart — score net 2->{}x2->2, beta {}..{}",
             meta.hidden, meta.sched.beta_min, meta.sched.beta_max);

    let mut truth_rng = Rng::new(1234);
    let truth = sample_circle(40_000, &mut truth_rng);
    let n = 1000;

    // 1. the paper's system: time-continuous analog solver on the
    //    simulated resistive-memory macro (read noise on)
    let analog = Arc::new(AnalogEngine::new(
        AnalogScoreNet::from_conductances(
            &weights, CellParams::default(), NoiseModel::ReadFast),
        meta.sched,
        2000,
    ));
    let svc = Service::start(analog, None, ServiceConfig::default());
    let r = svc.generate(TaskKind::Circle, n, SolverChoice::AnalogSde, 0.0, false)?;
    println!(
        "analog SDE  : {} samples, modeled hw latency {:.1} us/sample, KL = {:.4}",
        n,
        1e6 * r.hw_latency_s / n as f64,
        stats::kl_points(&r.samples, &truth, 24, 2.0)
    );
    svc.shutdown();

    // 2. digital baseline in pure rust (what a CPU/GPU would iterate)
    let digital = Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(weights.clone()),
        sched: meta.sched,
    });
    let svc = Service::start(digital, None, ServiceConfig::default());
    let r = svc.generate(TaskKind::Circle, n,
                         SolverChoice::DigitalSde { steps: 200 }, 0.0, false)?;
    println!(
        "digital 200 : {} samples, modeled hw latency {:.1} us/sample, KL = {:.4}",
        n,
        1e6 * r.hw_latency_s / n as f64,
        stats::kl_points(&r.samples, &truth, 24, 2.0)
    );
    svc.shutdown();

    // 3. the AOT path: jax+pallas lowered to HLO text, executed via PJRT
    let store = ArtifactStore::open_default()?;
    println!("PJRT platform: {}", store.platform());
    let hlo = Arc::new(HloEngine { n_classes: store.meta().n_classes, store });
    let svc = Service::start(hlo, None, ServiceConfig::default());
    let r = svc.generate(TaskKind::Circle, n,
                         SolverChoice::DigitalSde { steps: 200 }, 0.0, false)?;
    println!(
        "hlo 200     : {} samples, wall {:.1} ms, KL = {:.4}",
        n,
        1e3 * r.wall_latency_s,
        stats::kl_points(&r.samples, &truth, 24, 2.0)
    );
    svc.shutdown();

    println!("ok");
    Ok(())
}
