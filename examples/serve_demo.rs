//! Deployment-router demo: mixed analog + digital traffic through ONE
//! routed service.
//!
//! Builds the paper-shaped two-backend deployment table — analog classes
//! on the analog-hardware simulator, digital classes on the rust baseline
//! — and fires conditional/unconditional requests of both solver families
//! at it from concurrent clients.  Each backend owns its own batcher lane
//! and workers, so the slow analog batches never head-of-line-block the
//! digital traffic; the metrics report shows the per-backend `backend=`
//! columns (queue depth, throughput, modeled hardware energy).
//!
//! Falls back to synthetic weights when the AOT artifacts are absent, so
//! this demo (and the CI smoke step that runs it) works on a fresh
//! checkout.  A second mini-deployment at the end requests the `hlo`
//! backend to demonstrate the Hlo→rust fallback chain: with the default
//! stub runtime the deployment degrades instead of failing startup, and
//! the degradation surfaces in the metrics (`degraded=` column).
//!
//! Run with: `cargo run --release --example serve_demo`

use std::sync::Arc;

use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::deploy::{self, BackendKind, DeployPlan};
use memdiff::coordinator::service::{AnalogEngine, Engine, HloEngine, RustDigitalEngine};
use memdiff::coordinator::{GenRequest, ServiceConfig, SolverChoice, TaskKind};
use memdiff::crossbar::NoiseModel;
use memdiff::data::Meta;
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::rng::Rng;
use memdiff::util::stats::Summary;
use memdiff::vae::{DecoderWeights, PixelDecoder};

const CLIENTS: usize = 6;
const REQUESTS_PER_CLIENT: usize = 12;
/// Analog solve window per sample, kept short so the demo stays snappy.
const DEMO_SUBSTEPS: usize = 250;

fn main() -> anyhow::Result<()> {
    // artifacts when built, synthetic fixture otherwise (CI smoke runs
    // this on a fresh checkout)
    let sched = Meta::load_default().map(|m| m.sched).unwrap_or_default();
    let weights = ScoreWeights::load(Meta::artifacts_dir().join("weights_cond.json"))
        .unwrap_or_else(|_| {
            println!("(artifacts absent: using the synthetic weight fixture)");
            ScoreWeights::synthetic(2, 48, 3, 2024)
        });
    let decoder = DecoderWeights::load(Meta::artifacts_dir().join("vae_decoder.json"))
        .ok()
        .map(|w| Arc::new(PixelDecoder::new(w)));
    let have_decoder = decoder.is_some();

    // the paper-shaped two-backend table: analog classes → analog
    // simulator, digital classes → rust baseline, two workers each
    let mut plan = DeployPlan::default();
    plan.set("analog_workers", "2")?;
    plan.set("rust_workers", "2")?;
    let mut factory = |kind: BackendKind, _weights: Option<&str>|
     -> anyhow::Result<Arc<dyn Engine>> {
        Ok(match kind {
            BackendKind::Analog => Arc::new(AnalogEngine::new(
                AnalogScoreNet::from_conductances(
                    &weights, CellParams::default(), NoiseModel::ReadFast),
                sched,
                DEMO_SUBSTEPS,
            )),
            BackendKind::Rust => Arc::new(RustDigitalEngine {
                net: DigitalScoreNet::new(weights.clone()),
                sched,
            }),
            BackendKind::Hlo => {
                let store = ArtifactStore::open_default()?;
                let n_classes = store.meta().n_classes;
                Arc::new(HloEngine { store, n_classes })
            }
        })
    };
    let service = Arc::new(deploy::start_deployed(
        &plan,
        &mut factory,
        decoder,
        ServiceConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch_samples: 64,
                linger: std::time::Duration::from_millis(2),
                ..BatcherConfig::default()
            },
            seed: 99,
            intra_threads: 0,
        },
    )?);

    println!(
        "serve_demo: {CLIENTS} clients x {REQUESTS_PER_CLIENT} mixed-family \
         requests, 2 workers/backend"
    );
    println!("deployment: {}", service.registry().route_summary());
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|cid| {
            let service = Arc::clone(&service);
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + cid as u64);
                let mut lat = Summary::new();
                let mut samples = 0usize;
                for k in 0..REQUESTS_PER_CLIENT {
                    let task = match rng.below(4) {
                        0 => TaskKind::Circle,
                        c => TaskKind::Letter(c - 1),
                    };
                    // both solver families through the one router
                    let solver = match (cid + k) % 4 {
                        0 => SolverChoice::AnalogOde,
                        1 => SolverChoice::AnalogSde,
                        2 => SolverChoice::DigitalOde { steps: 100 },
                        _ => SolverChoice::DigitalSde { steps: 100 },
                    };
                    let n = 1 + rng.below(12);
                    let t = std::time::Instant::now();
                    let rx = service
                        .submit(GenRequest {
                            id: 0,
                            task,
                            n_samples: n,
                            solver,
                            guidance: 2.0,
                            decode: have_decoder
                                && task.is_conditional()
                                && rng.uniform() < 0.3,
                            trace: memdiff::obs::TraceId::mint(),
                        })
                        .unwrap();
                    let resp = rx.recv().unwrap();
                    lat.record(t.elapsed().as_secs_f64());
                    samples += resp.samples.len() / 2;
                }
                (lat, samples)
            })
        })
        .collect();

    let mut total_samples = 0usize;
    let mut all_lat = Summary::new();
    for h in handles {
        let (lat, samples) = h.join().unwrap();
        total_samples += samples;
        all_lat.record(lat.p50());
    }
    let wall = t0.elapsed();
    println!(
        "served {} requests / {total_samples} samples in {wall:?} ({:.0} samples/s)",
        CLIENTS * REQUESTS_PER_CLIENT,
        total_samples as f64 / wall.as_secs_f64()
    );
    println!("client-side median latency (median across clients): {:.1} ms",
             1e3 * all_lat.p50());
    let snap = service.metrics.snapshot();
    println!("service metrics: {}", snap.report());
    assert_eq!(snap.backends.len(), 2, "two backends deployed");
    for b in &snap.backends {
        assert!(b.requests > 0, "backend {} must have served traffic", b.name);
        println!(
            "  backend {:>6}: {} requests, {} samples, mean batch latency {:.1} ms, \
             modeled hw energy {:.3e} J",
            b.name, b.requests, b.samples, 1e3 * b.mean_latency_s, b.hw_energy_j
        );
    }

    // programming-mode exclusion demo: reprogram while serving drains
    println!("\nmode-gate demo: entering programming mode (compute drains first)...");
    {
        let _prog = service.mode_gate.programming();
        println!("  in programming mode: macro exclusively held");
    }
    println!("  back in compute mode");

    // Hlo→rust fallback chain: ask for the PJRT backend; with the default
    // stub runtime (or absent artifacts) the digital classes degrade to
    // the rust engine at startup instead of failing the deployment
    println!("\nfallback demo: deployment table requests digital=hlo ...");
    let mut plan = DeployPlan::default();
    plan.apply_overrides("digital=hlo,rust_workers=1,analog_workers=1,hlo_workers=1")?;
    let fb = deploy::start_deployed(&plan, &mut factory, None, ServiceConfig {
        workers: 1,
        batcher: BatcherConfig {
            max_batch_samples: 64,
            linger: std::time::Duration::from_millis(1),
            ..BatcherConfig::default()
        },
        seed: 7,
        intra_threads: 0,
    })?;
    let resp = fb.generate(TaskKind::Circle, 4,
                           SolverChoice::DigitalOde { steps: 50 }, 0.0, false)?;
    assert_eq!(resp.samples.len(), 8);
    let snap = fb.metrics.snapshot();
    println!("  resolved routes: {}", fb.registry().route_summary());
    if snap.degraded.is_empty() {
        println!("  hlo runtime available: no degradation");
    } else {
        println!("  degraded as planned: {}", snap.degraded.join("; "));
    }
    println!("  fallback metrics: {}", snap.report());
    Ok(())
}
