//! Coordinator demo: mixed-task request load through the batching service.
//!
//! Spawns client threads firing conditional/unconditional generation
//! requests with random sizes and decode flags at the service, then prints
//! throughput, latency percentiles, and batch-fill metrics — the serving-
//! layer behaviour a deployment cares about.
//!
//! Run with: `cargo run --release --example serve_demo`

use std::sync::Arc;

use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::service::RustDigitalEngine;
use memdiff::coordinator::{GenRequest, Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::data::Meta;
use memdiff::nn::{DigitalScoreNet, ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::stats::Summary;
use memdiff::vae::{DecoderWeights, PixelDecoder};

const CLIENTS: usize = 8;
const REQUESTS_PER_CLIENT: usize = 24;

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let weights = ScoreWeights::load(Meta::artifacts_dir().join("weights_cond.json"))?;
    let decoder = Arc::new(PixelDecoder::new(DecoderWeights::load(
        Meta::artifacts_dir().join("vae_decoder.json"))?));

    let engine = Arc::new(RustDigitalEngine {
        net: DigitalScoreNet::new(weights),
        sched: meta.sched,
    });
    let service = Arc::new(Service::start(engine, Some(decoder), ServiceConfig {
        workers: 4,
        batcher: BatcherConfig {
            max_batch_samples: 64,
            linger: std::time::Duration::from_millis(2),
        },
        seed: 99,
        intra_threads: 0,
    }));

    println!("serve_demo: {CLIENTS} clients x {REQUESTS_PER_CLIENT} requests, 4 workers");
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|cid| {
            let service = Arc::clone(&service);
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + cid as u64);
                let mut lat = Summary::new();
                let mut samples = 0usize;
                for _ in 0..REQUESTS_PER_CLIENT {
                    let task = match rng.below(4) {
                        0 => TaskKind::Circle,
                        c => TaskKind::Letter(c - 1),
                    };
                    let solver = if rng.uniform() < 0.5 {
                        SolverChoice::DigitalSde { steps: 100 }
                    } else {
                        SolverChoice::DigitalOde { steps: 100 }
                    };
                    let n = 1 + rng.below(24);
                    let t = std::time::Instant::now();
                    let rx = service
                        .submit(GenRequest {
                            id: 0,
                            task,
                            n_samples: n,
                            solver,
                            guidance: 2.0,
                            decode: task.is_conditional() && rng.uniform() < 0.3,
                        })
                        .unwrap();
                    let resp = rx.recv().unwrap().unwrap();
                    lat.record(t.elapsed().as_secs_f64());
                    samples += resp.samples.len() / 2;
                }
                (lat, samples)
            })
        })
        .collect();

    let mut total_samples = 0usize;
    let mut all_lat = Summary::new();
    for h in handles {
        let (lat, samples) = h.join().unwrap();
        total_samples += samples;
        for q in [50.0, 99.0] {
            let _ = q; // per-client percentiles folded into the global summary
        }
        all_lat.record(lat.p50());
    }
    let wall = t0.elapsed();
    println!(
        "served {} requests / {total_samples} samples in {wall:?} ({:.0} samples/s)",
        CLIENTS * REQUESTS_PER_CLIENT,
        total_samples as f64 / wall.as_secs_f64()
    );
    println!("client-side median latency (median across clients): {:.1} ms",
             1e3 * all_lat.p50());
    println!("service metrics: {}", service.metrics.snapshot().report());

    // programming-mode exclusion demo: reprogram while serving drains
    println!("\nmode-gate demo: entering programming mode (compute drains first)...");
    {
        let _prog = service.mode_gate.programming();
        println!("  in programming mode: macro exclusively held");
    }
    println!("  back in compute mode");
    Ok(())
}
