//! Fig. 5 reproduction: robustness of score-based diffusion to analog
//! noise — write noise (programming error) and read noise (conductance
//! fluctuation), ODE vs SDE.
//!
//! Sweeps each noise magnitude, runs 1500 samplings per point through the
//! analog solver on the simulated macro, and reports generation KL — the
//! rows behind Fig. 5e and Fig. 5f.
//!
//! Run with: `cargo run --release --example noise_robustness`

use memdiff::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, ScoreWeights};
use memdiff::util::rng::Rng;
use memdiff::util::stats;

const N_SAMPLES: usize = 1500;

fn run_kl(net: &AnalogScoreNet, mode: SolverMode, sched: memdiff::diffusion::VpSchedule,
          truth: &[f32], rng: &mut Rng) -> f64 {
    let solver = AnalogSolver::new(net, SolverConfig::new(mode)
        .with_schedule(sched).with_substeps(1200));
    let gen = solver.solve_batch(N_SAMPLES, &[], rng);
    stats::kl_points(&gen, truth, 24, 2.0)
}

fn main() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    let w = ScoreWeights::load(Meta::artifacts_dir().join("weights_uncond.json"))?;
    let mut rng = Rng::new(555);
    let mut truth_rng = Rng::new(556);
    let truth = sample_circle(40_000, &mut truth_rng);

    // ---- Fig. 5b: write-verify pulse statistics ---------------------------
    println!("== Fig 5b: write-verify programming (pulses until in-band)");
    for tol in [0.0030f32, 0.0015, 0.0008] {
        let mut r = Rng::new(1);
        let (_, pulses) = AnalogScoreNet::program_from_weights(
            &w, CellParams::default(), tol, NoiseModel::Ideal, &mut r);
        println!("  verify band ±{:.4} mS: {pulses} total pulses for {} cells",
                 tol, 2 * 14 + 14 * 14 + 14 * 2);
    }

    // ---- Fig. 5c: read noise vs conductance --------------------------------
    println!("\n== Fig 5c: read-noise distribution vs mean conductance");
    for g in [0.02f32, 0.04, 0.06, 0.08, 0.10] {
        let cell = memdiff::device::Cell::with_default(g);
        let mut r = Rng::new(2);
        let reads: Vec<f32> = (0..20_000).map(|_| cell.read(&mut r) - g).collect();
        println!("  G = {g:.2} mS: fluctuation std = {:.5} mS ({:.2}% of G)",
                 stats::std(&reads), 100.0 * stats::std(&reads) / g as f64);
    }

    // ---- Fig. 5e/f: KL vs noise magnitude, ODE vs SDE ----------------------
    println!("\n== Fig 5e/f: generation quality vs noise magnitude");
    println!("  kind  | magnitude | KL (ODE) | KL (SDE)");

    // read-noise sweep: fraction of conductance
    for frac in [0.0f32, 0.005, 0.01, 0.02, 0.05, 0.10] {
        let params = CellParams { read_noise_frac: frac, ..CellParams::default() };
        let noise = if frac == 0.0 { NoiseModel::Ideal } else { NoiseModel::ReadFast };
        let net = AnalogScoreNet::from_conductances(&w, params, noise);
        let kl_ode = run_kl(&net, SolverMode::Ode, meta.sched, &truth, &mut rng);
        let kl_sde = run_kl(&net, SolverMode::Sde, meta.sched, &truth, &mut rng);
        println!("  read  | {frac:9.3} | {kl_ode:8.4} | {kl_sde:8.4}");
    }

    // write-noise sweep: programming-band width (residual error std)
    for tol in [0.0004f32, 0.0008, 0.0015, 0.003, 0.006] {
        let params = CellParams { read_noise_frac: 0.0, ..CellParams::default() };
        let mut prog_rng = Rng::new(7);
        let (net, _) = AnalogScoreNet::program_from_weights(
            &w, params, tol, NoiseModel::Ideal, &mut prog_rng);
        let kl_ode = run_kl(&net, SolverMode::Ode, meta.sched, &truth, &mut rng);
        let kl_sde = run_kl(&net, SolverMode::Sde, meta.sched, &truth, &mut rng);
        println!("  write | {tol:9.4} | {kl_ode:8.4} | {kl_sde:8.4}");
    }

    println!("\nExpected shape (paper Fig. 5e/f): KL flat for small noise, rising");
    println!("for large write noise; SDE more robust to read noise than ODE");
    println!("(read fluctuation ≈ the Wiener term the SDE already integrates).");
    Ok(())
}
