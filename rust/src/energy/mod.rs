//! Latency & energy models behind the paper's headline comparisons
//! (Fig. 3f/3g for unconditional, Fig. 4g/4h for conditional generation).

pub mod model;

pub use model::{AnalogCost, DigitalCost, Comparison};
