//! Analog vs digital latency/energy accounting (DESIGN.md §3, subst. 3–4).
//!
//! **Analog side** — component-based power model of the projected fully
//! integrated system (the paper's comparison target, Methods):
//! crossbar static dissipation `Σ V²·G`, op-amp quiescent power (OPAx171
//! class), AD633 multipliers, and DAC/driver overhead.  Energy per sample
//! is `P_total × T_solve` with the projected `T_solve = 20 µs`.
//! Peripheral counts are charged **per macro** from the actual bank grid
//! ([`score_path_peripherals`]): a layer wider than one 32×32 array pays
//! for every extra summing amplifier and row-fanout buffer its sharding
//! ([`crate::crossbar::BankedCrossbarLayer`]) physically requires.
//!
//! **Digital side** — the "state-of-the-art GPU scaled to the same
//! technology node" baseline (paper ref. 73): a per-step cost
//! `t_step = launch overhead + MACs/throughput`, `e_step` dominated by the
//! effective per-step energy at this tiny network size.  For a 2→14→14→2
//! MLP the kernel-launch overhead dominates — which is precisely the
//! paper's argument for why iterative digital sampling is slow.
//!
//! Every constant is documented at its definition; the benches print the
//! resulting ratios next to the paper's (64.8× / 156.5× speed,
//! 80.8% / 75.6% energy) so EXPERIMENTS.md can report paper-vs-measured.

/// Projected fully-integrated solve window (paper: 20 µs/sample).
pub const T_SOLVE_PROJECTED_S: f64 = 20e-6;
/// PCB demonstrator solve window (paper: 1 s/sample).
pub const T_SOLVE_PCB_S: f64 = 1.0;

/// Op-amp quiescent power: OPA171-class, 475 µA × ±6 V rails ≈ 5.7 mW.
pub const P_OPAMP_W: f64 = 5.7e-3;
/// AD633-class analog multiplier, integrated-scale estimate.
pub const P_MULT_W: f64 = 35e-3;
/// 12-bit DAC + driver per channel.
pub const P_DAC_W: f64 = 2.0e-3;
/// Mean crossbar cell static power: (0.1 V)² × 0.06 mS = 0.6 µW.
pub const P_CELL_W: f64 = 0.6e-6;

/// Digital baseline per-step wall time: kernel launch + dispatch overhead
/// dominates a 2→14→14→2 MLP on an accelerator (~5–10 µs per launch is
/// typical; we use 10 µs to model launch + DtoH of the tiny state).
pub const T_STEP_DIGITAL_S: f64 = 10e-6;
/// Digital baseline per-inference energy, scaled to the comparison basis
/// of the paper's ref. 73 (eDRAM-CIM @ ISSCC'21): effective ~288 nJ per
/// network inference at this size (accelerator static power over t_step
/// dominates the picojoule-scale MAC energy).
pub const E_STEP_DIGITAL_J: f64 = 288e-9;

/// Per-macro peripheral inventory of a (possibly banked) score path.
///
/// The counts scale with the **actual bank grid** of each layer
/// (`ceil(rows/32) × ceil(cols/32)` macros), not with one assumed macro:
///
/// * one TIA per physical output column — partial sums down a column of
///   tiles meet a single TIA bank, so row-sharding adds no TIAs;
/// * one shared-negative-weight summing amplifier **per macro** (the
///   row-shared fixed conductance is a per-array structure);
/// * one input buffer per extra driven copy of a row — a row that spans
///   `tc` tile-columns must be driven into `tc` macros, and only the first
///   copy comes free from the source, so `rows·(tc−1)` buffers per layer.
#[derive(Debug, Clone, Default)]
pub struct ScorePathPeripherals {
    /// Programmed crossbar cells.
    pub n_cells: usize,
    /// Macros (banks) across all layers.
    pub n_banks: usize,
    /// Column TIAs across all layers.
    pub n_tias: usize,
    /// Row-fanout input buffers across all layers.
    pub n_row_buffers: usize,
}

/// Peripheral inventory for layers of the given logical shapes, tiled on
/// 32×32 macros exactly as [`crate::crossbar::BankedCrossbarLayer`] does.
pub fn score_path_peripherals(shapes: &[(usize, usize)]) -> ScorePathPeripherals {
    const MACRO_DIM: usize = crate::device::array::MACRO_DIM;
    let mut p = ScorePathPeripherals::default();
    for &(rows, cols) in shapes {
        let tile_rows = rows.div_ceil(MACRO_DIM);
        let tile_cols = cols.div_ceil(MACRO_DIM);
        p.n_cells += rows * cols;
        p.n_banks += tile_rows * tile_cols;
        p.n_tias += cols;
        p.n_row_buffers += rows * (tile_cols - 1);
    }
    p
}

/// Analog system cost for one sampling.
#[derive(Debug, Clone)]
pub struct AnalogCost {
    /// Number of programmed crossbar cells in the score path.
    pub n_cells: usize,
    /// TIAs + summing/inverting amps + integrator op-amps.
    pub n_opamps: usize,
    /// Analog multipliers in the feedback path.
    pub n_mults: usize,
    /// DAC channels (time embedding, condition, noise).
    pub n_dacs: usize,
    /// Solve window in seconds.
    pub t_solve_s: f64,
}

/// The paper's score-net layer shapes (2→14→14→2).
const PAPER_SHAPES: [(usize, usize); 3] = [(2, 14), (14, 14), (14, 2)];

impl AnalogCost {
    /// Projected system for an arbitrary (possibly banked) score path:
    /// peripherals are charged **per macro** from the actual bank grid —
    /// TIAs per physical column, one summing amp per bank, row-fanout
    /// buffers for extra tile-columns — plus `dim` integrators, `dim`
    /// output inverters, `2·dim` multipliers (f/g paths) and the
    /// time-embedding (2) + noise (`dim`) DAC channels.
    pub fn projected_for_layers(shapes: &[(usize, usize)], dim: usize) -> Self {
        let p = score_path_peripherals(shapes);
        AnalogCost {
            n_cells: p.n_cells,
            n_opamps: p.n_tias + p.n_banks + p.n_row_buffers + dim + dim,
            n_mults: 2 * dim,
            n_dacs: 2 + dim,
            t_solve_s: T_SOLVE_PROJECTED_S,
        }
    }

    /// Conditional (classifier-free-guidance) system for an arbitrary
    /// score path: the score hardware is duplicated (conditional +
    /// unconditional branches run concurrently), integrators/inverters are
    /// shared, plus `dim` CFG combine amps and `n_classes` condition-DAC
    /// channels.
    pub fn conditional_for_layers(shapes: &[(usize, usize)], dim: usize,
                                  n_classes: usize) -> Self {
        let p = score_path_peripherals(shapes);
        AnalogCost {
            n_cells: 2 * p.n_cells,
            // two score paths + shared integrators/inverters + CFG combine
            n_opamps: 2 * (p.n_tias + p.n_banks + p.n_row_buffers)
                + dim
                + dim
                + dim,
            n_mults: 2 * dim,
            n_dacs: 2 + dim + n_classes,
            t_solve_s: T_SOLVE_PROJECTED_S,
        }
    }

    /// The unconditional circle system (Fig. 3): 3-layer 2→14→14→2 net.
    /// Every layer fits one macro, so this reduces to the paper's counts:
    /// 30 TIAs (14+14+2) + 3 shared-negative-weight summing amps +
    /// 2 integrators + 2 output inverters; 4 multipliers (2 dims × f/g
    /// paths); DACs: time embedding (2 chan) + noise (2).
    pub fn unconditional_projected() -> Self {
        Self::projected_for_layers(&PAPER_SHAPES, 2)
    }

    /// The conditional latent-diffusion system (Fig. 4): classifier-free
    /// guidance evaluates conditional + unconditional scores concurrently
    /// (duplicated score path on hardware), plus condition-embedding DACs
    /// and the CFG combine amps.
    pub fn conditional_projected() -> Self {
        Self::conditional_for_layers(&PAPER_SHAPES, 2, 3)
    }

    /// Same systems at PCB timing (1 s solve) — the demonstrator numbers.
    pub fn at_pcb_timing(mut self) -> Self {
        self.t_solve_s = T_SOLVE_PCB_S;
        self
    }

    /// Total static power (W).
    pub fn power_w(&self) -> f64 {
        self.n_cells as f64 * P_CELL_W
            + self.n_opamps as f64 * P_OPAMP_W
            + self.n_mults as f64 * P_MULT_W
            + self.n_dacs as f64 * P_DAC_W
    }

    /// Latency of one sampling (s): the solve window plus pre-charge.
    pub fn latency_s(&self) -> f64 {
        self.t_solve_s + 0.02 * self.t_solve_s // 2% pre-charge overhead
    }

    /// Energy of one sampling (J).
    pub fn energy_j(&self) -> f64 {
        self.power_w() * self.latency_s()
    }
}

/// Digital baseline cost for one sampling at `n_steps` with
/// `evals_per_step` network inferences per step (2 for CFG, 2 for Heun).
#[derive(Debug, Clone)]
pub struct DigitalCost {
    pub n_steps: usize,
    pub evals_per_step: usize,
}

impl DigitalCost {
    pub fn new(n_steps: usize, evals_per_step: usize) -> Self {
        DigitalCost { n_steps, evals_per_step }
    }

    pub fn n_inferences(&self) -> usize {
        self.n_steps * self.evals_per_step
    }

    pub fn latency_s(&self) -> f64 {
        self.n_inferences() as f64 * T_STEP_DIGITAL_S
    }

    pub fn energy_j(&self) -> f64 {
        self.n_inferences() as f64 * E_STEP_DIGITAL_J
    }
}

/// Paper-style comparison row.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub speedup: f64,
    pub energy_reduction_pct: f64,
    pub analog_latency_s: f64,
    pub digital_latency_s: f64,
    pub analog_energy_j: f64,
    pub digital_energy_j: f64,
}

impl Comparison {
    pub fn of(analog: &AnalogCost, digital: &DigitalCost) -> Self {
        let al = analog.latency_s();
        let dl = digital.latency_s();
        let ae = analog.energy_j();
        let de = digital.energy_j();
        Comparison {
            speedup: dl / al,
            energy_reduction_pct: 100.0 * (1.0 - ae / de),
            analog_latency_s: al,
            digital_latency_s: dl,
            analog_energy_j: ae,
            digital_energy_j: de,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projected_unconditional_matches_paper_scale() {
        let a = AnalogCost::unconditional_projected();
        // paper: 20 µs, 7.2 µJ per sample
        assert!((a.latency_s() - 20.4e-6).abs() < 1e-6);
        let e = a.energy_j();
        assert!(
            (5e-6..10e-6).contains(&e),
            "energy {e} J should be ~7 µJ (paper: 7.2 µJ)"
        );
    }

    #[test]
    fn paper_speedup_shape_unconditional() {
        // at the paper's implied matched-quality step count (~130 Euler
        // steps), the speedup lands near 64.8×
        let a = AnalogCost::unconditional_projected();
        let d = DigitalCost::new(130, 1);
        let c = Comparison::of(&a, &d);
        assert!(
            (40.0..95.0).contains(&c.speedup),
            "speedup {} should bracket the paper's 64.8x",
            c.speedup
        );
        assert!(
            (60.0..95.0).contains(&c.energy_reduction_pct),
            "energy reduction {}% should bracket the paper's 80.8%",
            c.energy_reduction_pct
        );
    }

    #[test]
    fn paper_speedup_shape_conditional() {
        // CFG doubles inferences per step: ~160 steps × 2 evals
        let a = AnalogCost::conditional_projected();
        let d = DigitalCost::new(160, 2);
        let c = Comparison::of(&a, &d);
        assert!(
            (100.0..220.0).contains(&c.speedup),
            "speedup {} should bracket the paper's 156.5x",
            c.speedup
        );
        assert!(
            (55.0..90.0).contains(&c.energy_reduction_pct),
            "energy reduction {}% should bracket the paper's 75.6%",
            c.energy_reduction_pct
        );
    }

    #[test]
    fn pcb_timing_is_seconds_scale() {
        let a = AnalogCost::unconditional_projected().at_pcb_timing();
        assert!(a.latency_s() > 1.0);
        // PCB energy correspondingly large — the projection is the win
        assert!(a.energy_j() > 0.3);
    }

    #[test]
    fn digital_cost_scales_linearly() {
        let d1 = DigitalCost::new(100, 1);
        let d2 = DigitalCost::new(200, 1);
        assert!((d2.latency_s() / d1.latency_s() - 2.0).abs() < 1e-12);
        assert!((d2.energy_j() / d1.energy_j() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn peripherals_scale_with_bank_grid() {
        // one-macro layers: the paper's exact counts
        let p = score_path_peripherals(&[(2, 14), (14, 14), (14, 2)]);
        assert_eq!(p.n_cells, 252);
        assert_eq!(p.n_banks, 3);
        assert_eq!(p.n_tias, 30);
        assert_eq!(p.n_row_buffers, 0);

        // a 2→64→64→2 net shards onto 2+4+2 = 8 macros
        let shapes = [(2usize, 64usize), (64, 64), (64, 2)];
        let w = score_path_peripherals(&shapes);
        assert_eq!(w.n_banks, 2 + 4 + 2);
        assert_eq!(w.n_tias, 64 + 64 + 2);
        // row fanout: 2·(2−1) + 64·(2−1) + 64·0
        assert_eq!(w.n_row_buffers, 2 + 64);
        assert_eq!(w.n_cells, 2 * 64 + 64 * 64 + 64 * 2);

        // the cost model charges every extra macro: more banks ⇒ more
        // op-amps ⇒ more power than a single-macro-per-layer assumption
        let wide = AnalogCost::projected_for_layers(&shapes, 2);
        assert_eq!(wide.n_opamps, 130 + 8 + 66 + 2 + 2);
        let naive = AnalogCost {
            n_opamps: 130 + 3 + 2 + 2, // one summing amp per layer, no fanout
            ..wide.clone()
        };
        assert!(wide.power_w() > naive.power_w());
    }

    #[test]
    fn conditional_hardware_larger_than_unconditional() {
        let u = AnalogCost::unconditional_projected();
        let c = AnalogCost::conditional_projected();
        assert!(c.power_w() > u.power_w());
        assert!(c.n_cells == 2 * u.n_cells);
    }
}
