//! Minimal JSON parser + writer (no `serde` in the offline vendor set).
//!
//! Covers the full JSON grammar the artifact files use: objects, arrays,
//! strings (with escapes), numbers, booleans, null.  The parser is a
//! straightforward recursive-descent over bytes; artifact files are ≤ a few
//! MB so no streaming is needed.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `{"shape": [...], "data": [...]}` -> (shape, flat f32 data) — the
    /// array layout `aot.py` emits.  None if the data length does not
    /// match the shape's element count.
    pub fn as_tensor(&self) -> Option<(Vec<usize>, Vec<f32>)> {
        let shape = self
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()?;
        let data = self
            .get("data")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<Vec<_>>>()?;
        let n: usize = shape.iter().product();
        if data.len() != n.max(if shape.is_empty() { 1 } else { n }) {
            return None;
        }
        Some((shape, data))
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{s}'")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(
                        |_| self.err("invalid utf-8"),
                    )?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn tensor_layout() {
        let j = Json::parse(r#"{"shape": [2, 2], "data": [1, 2, 3, 4.5]}"#).unwrap();
        let (shape, data) = j.as_tensor().unwrap();
        assert_eq!(shape, vec![2, 2]);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.5]);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"n":-7}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"a\" :  1 , \"b\": [ ] } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("b").unwrap().as_arr().unwrap().len(), 0);
    }
}
