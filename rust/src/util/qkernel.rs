//! Conductance-quantized i8 MVM lane.
//!
//! The 180 nm macro never computes in f32: cells hold one of
//! [`N_LEVELS`](crate::crossbar::N_LEVELS) discrete conductance states and
//! the DAC drives quantized read voltages.  This lane makes the simulator
//! compute the same way — and gets ~4× more weights per cache line plus
//! i8×i8→i32 SIMD dot products in the bargain:
//!
//! * **Weights** are stored as their conductance *level index*
//!   (`level = round((g − G_LO)/step)` ∈ `0..64`, one byte per cell),
//!   transposed per tile so each output column's dot product is a
//!   contiguous byte run.
//! * **Inputs** are quantized symmetrically to DAC bit-width over the
//!   voltage clamp window: `q = round(v / IN_SCALE)` with
//!   `IN_SCALE = V_CLAMP_HI / 127`, so the full clamp range
//!   `[-2, 4]` maps into i8 without saturation.
//! * **Accumulation** is exact integer math, so the quant lane is bitwise
//!   deterministic across every [`KernelBackend`] by construction; all the
//!   quantization error is introduced at the two `round` sites above.
//!
//! Reconstruction folds the differential-pair epilogue into the dequant:
//! the f32 path computes `gain·(Σ v·g − G_FIXED·Σ v)` per column, which
//! under quantization becomes
//!
//! ```text
//! out[c] = gain · IN_SCALE · (step · acc[c] + (G_LO − G_FIXED) · Σ q)
//! acc[c] = Σ_r q[r] · level[r][c]        (i32)
//! ```
//!
//! — the per-tile-column TIA `gain` is exactly the one the f32 path uses,
//! so the quant lane rides the existing gain machinery unchanged.

use super::simd::KernelBackend;
use super::tensor::Mat;
use crate::crossbar::{G_CELL_HI_MS, G_CELL_LO_MS, G_FIXED_MS, N_LEVELS};

/// Input LSB: the DAC window's largest magnitude over the i8 range.
/// `V_CLAMP_HI = 4.0` dominates `|V_CLAMP_LO| = 2.0`, so `4/127` covers the
/// whole clamp window with `q ∈ [-64, 127]`.
pub const IN_SCALE: f32 = crate::V_CLAMP_HI / 127.0;

/// Conductance LSB of the macro's 64 linear states (mS).
#[inline]
pub fn level_step_ms() -> f32 {
    (G_CELL_HI_MS - G_CELL_LO_MS) / (N_LEVELS - 1) as f32
}

/// Quantize one input row to DAC codes, returning `Σ q` (needed by the
/// dequant epilogue for both the `G_LO` level offset and the differential
/// `G_FIXED` column).  Values are clamped defensively — serving inputs are
/// already voltage-clamped upstream.
#[inline]
pub fn quantize_inputs(v: &[f32], q: &mut [i8]) -> i32 {
    debug_assert_eq!(v.len(), q.len());
    let inv = 1.0 / IN_SCALE;
    let mut sum = 0i32;
    for (qv, &x) in q.iter_mut().zip(v) {
        let t = (x * inv).round().clamp(-128.0, 127.0) as i32;
        *qv = t as i8;
        sum += t;
    }
    sum
}

/// Dequantized differential readout: writes
/// `out[c] = gain · IN_SCALE · (step·acc[c] + (G_LO − G_FIXED)·sumq)`.
#[inline]
pub fn dequant_into(acc: &[i32], sumq: i32, gain: f32, out: &mut [f32]) {
    debug_assert_eq!(acc.len(), out.len());
    let step = level_step_ms();
    let base = (G_CELL_LO_MS - G_FIXED_MS) * sumq as f32;
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = gain * (IN_SCALE * (step * a as f32 + base));
    }
}

/// A conductance block captured as level indices, transposed for
/// contiguous per-column dot products.  Built once at program time (and on
/// every `refresh_cache` after aging/reprogramming) from the same
/// conductance cache the f32 path reads.
#[derive(Clone)]
pub struct QuantBank {
    k: usize,
    n: usize,
    /// n×k: `levels_t[c*k + r]` = level index of cell (r, c), 0..=63.
    levels_t: Vec<u8>,
}

impl QuantBank {
    /// `g`: k×n conductances in mS.  Programmed targets are already
    /// level-snapped by the mapper; off-level values (drifted or
    /// write-verified-within-tolerance cells) round to the nearest level.
    pub fn from_conductances(g: &Mat) -> Self {
        let (k, n) = g.shape();
        let inv = 1.0 / level_step_ms();
        let max_level = (N_LEVELS - 1) as f32;
        let mut levels_t = vec![0u8; n * k];
        for r in 0..k {
            let row = g.row(r);
            for (c, &gv) in row.iter().enumerate() {
                levels_t[c * k + r] =
                    ((gv - G_CELL_LO_MS) * inv).round().clamp(0.0, max_level) as u8;
            }
        }
        QuantBank { k, n, levels_t }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Bytes held by the level store (bench/report accounting).
    pub fn bytes(&self) -> usize {
        self.levels_t.len()
    }

    /// `acc[c] += Σ_r q[r] · level[r][c]` — integer-exact on every backend,
    /// so dispatch here is purely a speed choice.
    pub fn accum(&self, q: &[i8], acc: &mut [i32], backend: KernelBackend) {
        assert_eq!(q.len(), self.k, "input length vs bank rows");
        assert_eq!(acc.len(), self.n, "acc length vs bank cols");
        match backend {
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    unsafe { accum_avx2(&self.levels_t, q, acc, self.k) };
                    return;
                }
                #[cfg(not(target_arch = "x86_64"))]
                accum_scalar(&self.levels_t, q, acc, self.k)
            }
            KernelBackend::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    unsafe { accum_neon(&self.levels_t, q, acc, self.k) };
                    return;
                }
                #[cfg(not(target_arch = "aarch64"))]
                accum_scalar(&self.levels_t, q, acc, self.k)
            }
            KernelBackend::Scalar => accum_scalar(&self.levels_t, q, acc, self.k),
        }
    }

    /// One full quantized forward for a batch against this block: quantize
    /// each lane, integer-accumulate, dequantize with a uniform `gain`.
    /// Convenience for the monolithic layer and the digital quant net; the
    /// banked layer drives [`accum`](Self::accum) directly so one input
    /// quantization is shared across every bank of a lane.
    pub fn forward_batch(&self, v_in: &[f32], out: &mut [f32], batch: usize,
                         gain: f32, backend: KernelBackend) {
        debug_assert_eq!(v_in.len(), batch * self.k);
        debug_assert_eq!(out.len(), batch * self.n);
        let mut q = vec![0i8; self.k];
        let mut acc = vec![0i32; self.n];
        for (vrow, orow) in v_in.chunks_exact(self.k).zip(out.chunks_exact_mut(self.n)) {
            let sumq = quantize_inputs(vrow, &mut q);
            acc.iter_mut().for_each(|a| *a = 0);
            self.accum(&q, &mut acc, backend);
            dequant_into(&acc, sumq, gain, orow);
        }
    }
}

fn accum_scalar(levels_t: &[u8], q: &[i8], acc: &mut [i32], k: usize) {
    for (av, col) in acc.iter_mut().zip(levels_t.chunks_exact(k)) {
        let mut s = 0i32;
        for (&lv, &qv) in col.iter().zip(q) {
            s += (lv as i32) * (qv as i32);
        }
        *av += s;
    }
}

/// # Safety
/// AVX2 must be available; `levels_t.len() == acc.len()·k`, `q.len() == k`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accum_avx2(levels_t: &[u8], q: &[i8], acc: &mut [i32], k: usize) {
    use std::arch::x86_64::*;
    let kv = k / 32 * 32;
    let ones = _mm256_set1_epi16(1);
    let qp = q.as_ptr();
    for (c, av) in acc.iter_mut().enumerate() {
        let col = levels_t.as_ptr().add(c * k);
        let mut accv = _mm256_setzero_si256();
        let mut l = 0usize;
        while l < kv {
            let lv = _mm256_loadu_si256(col.add(l) as *const __m256i);
            let qv = _mm256_loadu_si256(qp.add(l) as *const __m256i);
            // u8×i8 pairwise → i16: |pair sum| ≤ 2·63·128 = 16128 < i16::MAX,
            // so the saturating maddubs can never actually saturate here
            let prod = _mm256_maddubs_epi16(lv, qv);
            accv = _mm256_add_epi32(accv, _mm256_madd_epi16(prod, ones));
            l += 32;
        }
        let hi = _mm256_extracti128_si256::<1>(accv);
        let lo = _mm256_castsi256_si128(accv);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_srli_si128::<8>(s));
        let s = _mm_add_epi32(s, _mm_srli_si128::<4>(s));
        let mut sum = _mm_cvtsi128_si32(s);
        while l < k {
            sum += (*col.add(l) as i32) * (*qp.add(l) as i32);
            l += 1;
        }
        *av += sum;
    }
}

/// # Safety
/// `levels_t.len() == acc.len()·k`, `q.len() == k`.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn accum_neon(levels_t: &[u8], q: &[i8], acc: &mut [i32], k: usize) {
    use std::arch::aarch64::*;
    let kv = k / 8 * 8;
    let qp = q.as_ptr();
    for (c, av) in acc.iter_mut().enumerate() {
        let col = levels_t.as_ptr().add(c * k);
        let mut accv = vdupq_n_s32(0);
        let mut l = 0usize;
        while l < kv {
            // u8 levels ≤ 63 widen losslessly into i16
            let lv = vreinterpretq_s16_u16(vmovl_u8(vld1_u8(col.add(l))));
            let qv = vmovl_s8(vld1_s8(qp.add(l)));
            accv = vmlal_s16(accv, vget_low_s16(lv), vget_low_s16(qv));
            accv = vmlal_s16(accv, vget_high_s16(lv), vget_high_s16(qv));
            l += 8;
        }
        let mut sum = vaddvq_s32(accv);
        while l < k {
            sum += (*col.add(l) as i32) * (*qp.add(l) as i32);
            l += 1;
        }
        *av += sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::mapper;
    use crate::util::simd;

    fn level_grid(k: usize, n: usize, seed: usize) -> Mat {
        // conductances exactly on levels, spread over the whole window
        let step = level_step_ms();
        Mat::from_fn(k, n, |r, c| {
            let lv = (r * 31 + c * 7 + seed) % N_LEVELS;
            G_CELL_LO_MS + step * lv as f32
        })
    }

    #[test]
    fn input_quantization_error_is_half_lsb() {
        let v: Vec<f32> = (0..64)
            .map(|i| crate::V_CLAMP_LO + (crate::V_CLAMP_HI - crate::V_CLAMP_LO) * i as f32 / 63.0)
            .collect();
        let mut q = vec![0i8; v.len()];
        let sumq = quantize_inputs(&v, &mut q);
        assert_eq!(sumq, q.iter().map(|&x| x as i32).sum::<i32>());
        for (&x, &qq) in v.iter().zip(&q) {
            assert!((x - qq as f32 * IN_SCALE).abs() <= 0.5 * IN_SCALE + 1e-6,
                    "{x} vs code {qq}");
        }
    }

    #[test]
    fn scalar_accum_matches_naive() {
        let (k, n) = (37usize, 9);
        let g = level_grid(k, n, 3);
        let bank = QuantBank::from_conductances(&g);
        let q: Vec<i8> = (0..k).map(|i| ((i * 23 % 191) as i32 - 64) as i8).collect();
        let mut acc = vec![1i32; n]; // nonzero start: accum must add, not overwrite
        bank.accum(&q, &mut acc, KernelBackend::Scalar);
        for (c, &got) in acc.iter().enumerate() {
            let step = level_step_ms();
            let want: i32 = (0..k)
                .map(|r| {
                    let lv = ((g.get(r, c) - G_CELL_LO_MS) / step).round() as i32;
                    lv * q[r] as i32
                })
                .sum();
            assert_eq!(got, want + 1, "col {c}");
        }
    }

    #[test]
    fn every_backend_is_integer_identical() {
        // ragged k exercises every SIMD tail
        for k in [1usize, 7, 8, 31, 32, 33, 64, 97] {
            let n = 5usize;
            let g = level_grid(k, n, k);
            let bank = QuantBank::from_conductances(&g);
            let q: Vec<i8> = (0..k).map(|i| ((i * 41 % 255) as i32 - 128) as i8).collect();
            let mut want = vec![0i32; n];
            bank.accum(&q, &mut want, KernelBackend::Scalar);
            for b in simd::available() {
                let mut got = vec![0i32; n];
                bank.accum(&q, &mut got, b);
                assert_eq!(got, want, "backend {b} k={k}");
            }
        }
    }

    #[test]
    fn dequant_matches_f32_epilogue_on_exact_codes() {
        // inputs exactly on DAC codes + conductances exactly on levels:
        // the quant lane must agree with the f32 differential readout to
        // float rounding
        let (k, n) = (16usize, 6);
        let g = level_grid(k, n, 1);
        let bank = QuantBank::from_conductances(&g);
        let v: Vec<f32> = (0..k).map(|i| (i as i32 - 8) as f32 * IN_SCALE).collect();
        let gain = 3.7f32;
        let mut out = vec![0.0f32; n];
        bank.forward_batch(&v, &mut out, 1, gain, KernelBackend::Scalar);
        for c in 0..n {
            let o: f32 = (0..k).map(|r| v[r] * g.get(r, c)).sum();
            let neg: f32 = G_FIXED_MS * v.iter().sum::<f32>();
            let want = gain * (o - neg);
            assert!((out[c] - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "col {c}: {} vs {want}", out[c]);
        }
    }

    #[test]
    fn mapper_targets_roundtrip_to_levels() {
        // the mapper's quantized targets must hit level indices exactly
        let w = Mat::from_fn(12, 10, |r, c| ((r * 10 + c) as f32 * 0.37).sin() * 0.04);
        let m = mapper::map_layer(&w);
        let bank = QuantBank::from_conductances(&m.g_target);
        let step = level_step_ms();
        for r in 0..12 {
            for c in 0..10 {
                let lv = bank.levels_t[c * bank.k + r] as f32;
                let back = G_CELL_LO_MS + step * lv;
                assert!((back - m.g_target.get(r, c)).abs() < 1e-6);
            }
        }
    }
}
