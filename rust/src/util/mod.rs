//! Self-contained substrates: PRNG, JSON, dense tensors, statistics,
//! and a property-testing harness.
//!
//! The offline build environment vendors only the `xla` dependency chain,
//! so the usual ecosystem crates (`rand`, `serde`, `proptest`, `criterion`)
//! are reimplemented here at the scale this project needs.  Each module is
//! small, tested, and used by the simulator and coordinator layers.

pub mod bench;
pub mod json;
pub mod ptest;
pub mod qkernel;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod tensor;

pub use rng::Rng;
pub use simd::{KernelBackend, KernelMode};
pub use tensor::Mat;
