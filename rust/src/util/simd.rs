//! Runtime kernel dispatch for the MVM hot path.
//!
//! Every sampler substep funnels through the GEMM kernels in
//! [`super::tensor`]; this module picks *which* implementation runs them:
//!
//! | backend  | arch      | selected when                                   |
//! |----------|-----------|-------------------------------------------------|
//! | `scalar` | any       | always available (the parity oracle)            |
//! | `avx2`   | `x86_64`  | `is_x86_feature_detected!("avx2")` + `"fma"`    |
//! | `neon`   | `aarch64` | always (NEON is baseline on aarch64)            |
//!
//! Detection runs once and is cached; the result can be forced with
//! `RUST_PALLAS_KERNEL=scalar|avx2|neon` (an unavailable forced backend
//! silently falls back to the best detected one, so a config written on an
//! x86 box still boots on ARM).  Tests and benches can also flip the
//! process-global backend with [`set_active`] or bypass the global entirely
//! through the `*_with` entry points in [`super::tensor`].
//!
//! ## Bitwise contract
//!
//! The f32 kernels here are **order-preserving**: they vectorize over the
//! output-column axis with separate multiply and add instructions (never a
//! fused `fmadd`), walk the shared-`k` axis in the same ascending order as
//! the scalar kernels, and apply the identical zero-skip conditions — so
//! every output element sees the exact float-op sequence of the scalar
//! path and `scalar`/`avx2`/`neon` are bitwise interchangeable on all
//! `Ideal`-mode parity suites.  The one exception is the transposed-B
//! dot-product kernel (`matmul_tb_into`), which reduces over `k` with FMA
//! accumulators + a horizontal sum: faster, but a different accumulation
//! order, and therefore only used where callers compare with a tolerance
//! (no serving forward path goes through it).
//!
//! The column-strip width the SIMD kernels block over is autotuned once at
//! first use (candidates timed on a representative shape, cached, exposed
//! via [`tile_info`] and overridable with `RUST_PALLAS_KERNEL_TILE`); the
//! strip width cannot change any output bit — per-element accumulation
//! order is strip-invariant — so autotune results may differ across hosts
//! without breaking determinism.

use std::str::FromStr;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Env var forcing the process-global kernel backend.
pub const KERNEL_ENV: &str = "RUST_PALLAS_KERNEL";
/// Env var forcing the SIMD column-strip width (skips autotune).
pub const KERNEL_TILE_ENV: &str = "RUST_PALLAS_KERNEL_TILE";

/// Which microkernel implementation services the f32/quant MVM entry points.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// Portable 4-row-blocked kernels — always available, the parity oracle.
    Scalar,
    /// 8-wide AVX2 (x86_64; FMA used only on the tolerance-tested tb path).
    Avx2,
    /// 4-wide NEON (aarch64).
    Neon,
}

impl KernelBackend {
    pub const ALL: [KernelBackend; 3] =
        [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Neon];

    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Neon => "neon",
        }
    }

    /// Can this backend actually run on the current host?
    pub fn is_available(self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelBackend::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    fn from_u8(v: u8) -> KernelBackend {
        match v {
            1 => KernelBackend::Avx2,
            2 => KernelBackend::Neon,
            _ => KernelBackend::Scalar,
        }
    }
}

impl FromStr for KernelBackend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelBackend::Scalar),
            "avx2" => Ok(KernelBackend::Avx2),
            "neon" => Ok(KernelBackend::Neon),
            other => Err(format!("unknown kernel backend '{other}' (scalar|avx2|neon)")),
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Numeric lane served by a score net / crossbar layer: full-precision f32
/// or the conductance-quantized i8 path ([`super::qkernel`]).  This is the
/// per-backend `[service] kernel` / `[deploy] <backend>_kernel` knob —
/// orthogonal to [`KernelBackend`], which picks the instruction set both
/// lanes run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    #[default]
    F32,
    /// Weights snapped to the macro's 64 conductance levels, inputs to DAC
    /// bit-width, i8×i8→i32 accumulation — active only under
    /// `NoiseModel::Ideal` (the noise models are conductance-domain f32).
    Quant,
}

impl KernelMode {
    pub fn name(self) -> &'static str {
        match self {
            KernelMode::F32 => "f32",
            KernelMode::Quant => "quant",
        }
    }
}

impl FromStr for KernelMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" => Ok(KernelMode::F32),
            "quant" | "i8" => Ok(KernelMode::Quant),
            other => Err(format!("unknown kernel mode '{other}' (f32|quant)")),
        }
    }
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

const ACTIVE_UNSET: u8 = u8::MAX;
static ACTIVE: AtomicU8 = AtomicU8::new(ACTIVE_UNSET);

/// Best backend the host supports.
pub fn detect() -> KernelBackend {
    if KernelBackend::Avx2.is_available() {
        KernelBackend::Avx2
    } else if KernelBackend::Neon.is_available() {
        KernelBackend::Neon
    } else {
        KernelBackend::Scalar
    }
}

fn initial() -> KernelBackend {
    match std::env::var(KERNEL_ENV) {
        Ok(s) if !s.trim().is_empty() => match s.parse::<KernelBackend>() {
            Ok(b) if b.is_available() => b,
            _ => detect(),
        },
        _ => detect(),
    }
}

/// The process-global backend every undecorated tensor entry point uses.
/// Resolved once from `RUST_PALLAS_KERNEL` (falling back to detection);
/// the resolution race is benign — both sides compute the same value.
#[inline]
pub fn active() -> KernelBackend {
    match ACTIVE.load(Ordering::Relaxed) {
        ACTIVE_UNSET => {
            let b = initial();
            ACTIVE.store(b as u8, Ordering::Relaxed);
            b
        }
        v => KernelBackend::from_u8(v),
    }
}

/// Force the process-global backend (test/bench hook — serving code should
/// use the env var).  Returns `false` (and changes nothing) if the backend
/// is not available on this host.
pub fn set_active(b: KernelBackend) -> bool {
    if !b.is_available() {
        return false;
    }
    ACTIVE.store(b as u8, Ordering::Relaxed);
    true
}

/// Every backend that can run on this host (always starts with `Scalar`),
/// for in-process dispatch-sweep tests and benches.
pub fn available() -> Vec<KernelBackend> {
    KernelBackend::ALL
        .iter()
        .copied()
        .filter(|b| b.is_available())
        .collect()
}

// ---------------------------------------------------------------------------
// Column-strip autotune
// ---------------------------------------------------------------------------

/// Row-block depth shared by the scalar and SIMD f32 kernels.
pub const ROW_BLOCK: usize = 4;
const TILE_CANDIDATES: [usize; 4] = [32, 64, 128, 256];
const TILE_DEFAULT: usize = 128;

static COL_TILE: OnceLock<usize> = OnceLock::new();

/// The autotuned column-strip width (elements of `n` the SIMD kernels keep
/// resident per pass over `k`).  Cached after the first call; affects cache
/// behaviour only, never results.
pub fn col_tile() -> usize {
    *COL_TILE.get_or_init(|| {
        if let Ok(s) = std::env::var(KERNEL_TILE_ENV) {
            if let Ok(t) = s.trim().parse::<usize>() {
                if t >= 8 {
                    return t;
                }
            }
        }
        autotune(active())
    })
}

/// `(row_block, col_tile)` actually in use — recorded into bench output.
pub fn tile_info() -> (usize, usize) {
    (ROW_BLOCK, col_tile())
}

fn autotune(backend: KernelBackend) -> usize {
    if backend == KernelBackend::Scalar {
        return TILE_DEFAULT; // scalar kernel does not strip-mine
    }
    // Representative hot shape: a 64-lane batch against a hidden-sized
    // square panel.  Time each candidate (best of 3 after one warmup) and
    // keep the fastest; ties go to the smaller strip (less L1 pressure).
    let (m, k, n) = (64usize, 96, 96);
    let a: Vec<f32> = (0..m * k).map(|i| ((i % 83) as f32) * 0.011 - 0.4).collect();
    let b: Vec<f32> = (0..k * n).map(|i| ((i % 67) as f32) * 0.013 - 0.4).collect();
    let mut c = vec![0.0f32; m * n];
    let mut best = (f64::INFINITY, TILE_DEFAULT);
    for &tile in &TILE_CANDIDATES {
        let mut best_rep = f64::INFINITY;
        for rep in 0..4 {
            let t0 = std::time::Instant::now();
            for _ in 0..4 {
                run_tiled(backend, &a, &b, &mut c, m, k, n, tile);
            }
            let dt = t0.elapsed().as_secs_f64();
            if rep > 0 {
                best_rep = best_rep.min(dt);
            }
        }
        if best_rep < best.0 {
            best = (best_rep, tile);
        }
    }
    std::hint::black_box(&c);
    best.1
}

fn run_tiled(backend: KernelBackend, a: &[f32], b: &[f32], c: &mut [f32],
             m: usize, k: usize, n: usize, tile: usize) {
    match backend {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => unsafe { x86::matmul_into(a, b, c, m, k, n, tile) },
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => unsafe { arm::matmul_into(a, b, c, m, k, n, tile) },
        _ => super::tensor::matmul_into_with(KernelBackend::Scalar, a, b, c, m, k, n),
    }
}

// ---------------------------------------------------------------------------
// AVX2 f32 kernels (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use std::arch::x86_64::*;

    /// c += a(m×k)·b(k×n), strip-mined over `tile` columns.  Mirrors the
    /// scalar 4-row-blocked kernel operation for operation — separate
    /// `mul`+`add` (never `fmadd`), ascending `l`, identical zero-skips —
    /// so it is bitwise equal to the scalar path.
    ///
    /// # Safety
    /// Caller guarantees AVX2 is available and the slice lengths match
    /// `(m·k, k·n, m·n)`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32],
                              m: usize, k: usize, n: usize, tile: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let cp = c.as_mut_ptr();
        let tile = tile.max(8);
        let mut i = 0usize;
        while i + 4 <= m {
            let a0 = ap.add(i * k);
            let a1 = ap.add((i + 1) * k);
            let a2 = ap.add((i + 2) * k);
            let a3 = ap.add((i + 3) * k);
            let c0 = cp.add(i * n);
            let c1 = cp.add((i + 1) * n);
            let c2 = cp.add((i + 2) * n);
            let c3 = cp.add((i + 3) * n);
            let mut j0 = 0usize;
            while j0 < n {
                let j1 = (j0 + tile).min(n);
                let jv = j0 + (j1 - j0) / 8 * 8;
                for l in 0..k {
                    let v0 = *a0.add(l);
                    let v1 = *a1.add(l);
                    let v2 = *a2.add(l);
                    let v3 = *a3.add(l);
                    if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                        continue;
                    }
                    let brow = bp.add(l * n);
                    let w0 = _mm256_set1_ps(v0);
                    let w1 = _mm256_set1_ps(v1);
                    let w2 = _mm256_set1_ps(v2);
                    let w3 = _mm256_set1_ps(v3);
                    let mut j = j0;
                    while j < jv {
                        let bv = _mm256_loadu_ps(brow.add(j));
                        _mm256_storeu_ps(
                            c0.add(j),
                            _mm256_add_ps(_mm256_loadu_ps(c0.add(j)), _mm256_mul_ps(w0, bv)),
                        );
                        _mm256_storeu_ps(
                            c1.add(j),
                            _mm256_add_ps(_mm256_loadu_ps(c1.add(j)), _mm256_mul_ps(w1, bv)),
                        );
                        _mm256_storeu_ps(
                            c2.add(j),
                            _mm256_add_ps(_mm256_loadu_ps(c2.add(j)), _mm256_mul_ps(w2, bv)),
                        );
                        _mm256_storeu_ps(
                            c3.add(j),
                            _mm256_add_ps(_mm256_loadu_ps(c3.add(j)), _mm256_mul_ps(w3, bv)),
                        );
                        j += 8;
                    }
                    while j < j1 {
                        let bv = *brow.add(j);
                        *c0.add(j) += v0 * bv;
                        *c1.add(j) += v1 * bv;
                        *c2.add(j) += v2 * bv;
                        *c3.add(j) += v3 * bv;
                        j += 1;
                    }
                }
                j0 = j1;
            }
            i += 4;
        }
        let nv = n / 8 * 8;
        while i < m {
            let ai = ap.add(i * k);
            let ci = cp.add(i * n);
            for l in 0..k {
                let v = *ai.add(l);
                if v == 0.0 {
                    continue;
                }
                let brow = bp.add(l * n);
                let w = _mm256_set1_ps(v);
                let mut j = 0usize;
                while j < nv {
                    _mm256_storeu_ps(
                        ci.add(j),
                        _mm256_add_ps(_mm256_loadu_ps(ci.add(j)),
                                      _mm256_mul_ps(w, _mm256_loadu_ps(brow.add(j)))),
                    );
                    j += 8;
                }
                while j < n {
                    *ci.add(j) += v * *brow.add(j);
                    j += 1;
                }
            }
            i += 1;
        }
    }

    /// Bank-sharded strided accumulate (see `tensor::matmul_block_accum`).
    /// Single-row loop with the scalar kernel's per-element zero-skip;
    /// order-preserving like `matmul_into` (banks are ≤32 wide, so no
    /// strip-mining).
    ///
    /// # Safety
    /// AVX2 available; offsets/strides in bounds as asserted by the
    /// dispatching wrapper.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn matmul_block_accum(a: &[f32], a_stride: usize, a_off: usize,
                                     b: &[f32], c: &mut [f32], c_stride: usize,
                                     c_off: usize, m: usize, k: usize, n: usize) {
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let cp = c.as_mut_ptr();
        let nv = n / 8 * 8;
        for i in 0..m {
            let arow = ap.add(i * a_stride + a_off);
            let crow = cp.add(i * c_stride + c_off);
            for l in 0..k {
                let v = *arow.add(l);
                if v == 0.0 {
                    continue;
                }
                let brow = bp.add(l * n);
                let w = _mm256_set1_ps(v);
                let mut j = 0usize;
                while j < nv {
                    _mm256_storeu_ps(
                        crow.add(j),
                        _mm256_add_ps(_mm256_loadu_ps(crow.add(j)),
                                      _mm256_mul_ps(w, _mm256_loadu_ps(brow.add(j)))),
                    );
                    j += 8;
                }
                while j < n {
                    *crow.add(j) += v * *brow.add(j);
                    j += 1;
                }
            }
        }
    }

    /// c = a(m×k)·Bᵀ(n×k) dot-product kernel with FMA accumulators and a
    /// horizontal reduction — NOT order-preserving (callers compare with a
    /// tolerance; no serving forward path uses it).
    ///
    /// # Safety
    /// AVX2+FMA available; slice lengths `(m·k, n·k, m·n)`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_tb_into(a: &[f32], bt: &[f32], c: &mut [f32],
                                 m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(bt.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        let kv = k / 8 * 8;
        for i in 0..m {
            let arow = a.as_ptr().add(i * k);
            let crow = c.as_mut_ptr().add(i * n);
            for j in 0..n {
                let brow = bt.as_ptr().add(j * k);
                let mut acc = _mm256_setzero_ps();
                let mut l = 0usize;
                while l < kv {
                    acc = _mm256_fmadd_ps(_mm256_loadu_ps(arow.add(l)),
                                          _mm256_loadu_ps(brow.add(l)), acc);
                    l += 8;
                }
                let hi = _mm256_extractf128_ps::<1>(acc);
                let lo = _mm256_castps256_ps128(acc);
                let s = _mm_add_ps(lo, hi);
                let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
                let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
                let mut sum = _mm_cvtss_f32(s);
                while l < k {
                    sum += *arow.add(l) * *brow.add(l);
                    l += 1;
                }
                *crow.add(j) = sum;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// NEON f32 kernels (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
pub(crate) mod arm {
    use std::arch::aarch64::*;

    /// c += a(m×k)·b(k×n); order-preserving NEON mirror of the scalar
    /// kernel (separate `vmul`+`vadd`, ascending `l`, identical zero-skips).
    ///
    /// # Safety
    /// Slice lengths must match `(m·k, k·n, m·n)`.
    #[target_feature(enable = "neon")]
    pub unsafe fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32],
                              m: usize, k: usize, n: usize, tile: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let cp = c.as_mut_ptr();
        let tile = tile.max(4);
        let mut i = 0usize;
        while i + 4 <= m {
            let a0 = ap.add(i * k);
            let a1 = ap.add((i + 1) * k);
            let a2 = ap.add((i + 2) * k);
            let a3 = ap.add((i + 3) * k);
            let c0 = cp.add(i * n);
            let c1 = cp.add((i + 1) * n);
            let c2 = cp.add((i + 2) * n);
            let c3 = cp.add((i + 3) * n);
            let mut j0 = 0usize;
            while j0 < n {
                let j1 = (j0 + tile).min(n);
                let jv = j0 + (j1 - j0) / 4 * 4;
                for l in 0..k {
                    let v0 = *a0.add(l);
                    let v1 = *a1.add(l);
                    let v2 = *a2.add(l);
                    let v3 = *a3.add(l);
                    if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                        continue;
                    }
                    let brow = bp.add(l * n);
                    let w0 = vdupq_n_f32(v0);
                    let w1 = vdupq_n_f32(v1);
                    let w2 = vdupq_n_f32(v2);
                    let w3 = vdupq_n_f32(v3);
                    let mut j = j0;
                    while j < jv {
                        let bv = vld1q_f32(brow.add(j));
                        vst1q_f32(c0.add(j), vaddq_f32(vld1q_f32(c0.add(j)), vmulq_f32(w0, bv)));
                        vst1q_f32(c1.add(j), vaddq_f32(vld1q_f32(c1.add(j)), vmulq_f32(w1, bv)));
                        vst1q_f32(c2.add(j), vaddq_f32(vld1q_f32(c2.add(j)), vmulq_f32(w2, bv)));
                        vst1q_f32(c3.add(j), vaddq_f32(vld1q_f32(c3.add(j)), vmulq_f32(w3, bv)));
                        j += 4;
                    }
                    while j < j1 {
                        let bv = *brow.add(j);
                        *c0.add(j) += v0 * bv;
                        *c1.add(j) += v1 * bv;
                        *c2.add(j) += v2 * bv;
                        *c3.add(j) += v3 * bv;
                        j += 1;
                    }
                }
                j0 = j1;
            }
            i += 4;
        }
        let nv = n / 4 * 4;
        while i < m {
            let ai = ap.add(i * k);
            let ci = cp.add(i * n);
            for l in 0..k {
                let v = *ai.add(l);
                if v == 0.0 {
                    continue;
                }
                let brow = bp.add(l * n);
                let w = vdupq_n_f32(v);
                let mut j = 0usize;
                while j < nv {
                    vst1q_f32(ci.add(j),
                              vaddq_f32(vld1q_f32(ci.add(j)), vmulq_f32(w, vld1q_f32(brow.add(j)))));
                    j += 4;
                }
                while j < n {
                    *ci.add(j) += v * *brow.add(j);
                    j += 1;
                }
            }
            i += 1;
        }
    }

    /// Bank-sharded strided accumulate; order-preserving (see the AVX2
    /// twin for the contract).
    ///
    /// # Safety
    /// Offsets/strides in bounds as asserted by the dispatching wrapper.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn matmul_block_accum(a: &[f32], a_stride: usize, a_off: usize,
                                     b: &[f32], c: &mut [f32], c_stride: usize,
                                     c_off: usize, m: usize, k: usize, n: usize) {
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let cp = c.as_mut_ptr();
        let nv = n / 4 * 4;
        for i in 0..m {
            let arow = ap.add(i * a_stride + a_off);
            let crow = cp.add(i * c_stride + c_off);
            for l in 0..k {
                let v = *arow.add(l);
                if v == 0.0 {
                    continue;
                }
                let brow = bp.add(l * n);
                let w = vdupq_n_f32(v);
                let mut j = 0usize;
                while j < nv {
                    vst1q_f32(crow.add(j),
                              vaddq_f32(vld1q_f32(crow.add(j)),
                                        vmulq_f32(w, vld1q_f32(brow.add(j)))));
                    j += 4;
                }
                while j < n {
                    *crow.add(j) += v * *brow.add(j);
                    j += 1;
                }
            }
        }
    }

    /// Transposed-B dot-product kernel with FMA + horizontal reduction —
    /// NOT order-preserving (tolerance-tested callers only).
    ///
    /// # Safety
    /// Slice lengths `(m·k, n·k, m·n)`.
    #[target_feature(enable = "neon")]
    pub unsafe fn matmul_tb_into(a: &[f32], bt: &[f32], c: &mut [f32],
                                 m: usize, k: usize, n: usize) {
        let kv = k / 4 * 4;
        for i in 0..m {
            let arow = a.as_ptr().add(i * k);
            let crow = c.as_mut_ptr().add(i * n);
            for j in 0..n {
                let brow = bt.as_ptr().add(j * k);
                let mut acc = vdupq_n_f32(0.0);
                let mut l = 0usize;
                while l < kv {
                    acc = vfmaq_f32(acc, vld1q_f32(arow.add(l)), vld1q_f32(brow.add(l)));
                    l += 4;
                }
                let mut sum = vaddvq_f32(acc);
                while l < k {
                    sum += *arow.add(l) * *brow.add(l);
                    l += 1;
                }
                *crow.add(j) = sum;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available() {
        assert!(KernelBackend::Scalar.is_available());
        let avail = available();
        assert_eq!(avail[0], KernelBackend::Scalar);
        assert!(avail.contains(&detect()));
    }

    #[test]
    fn backend_names_roundtrip() {
        for b in KernelBackend::ALL {
            assert_eq!(b.name().parse::<KernelBackend>().unwrap(), b);
        }
        assert!("pentium".parse::<KernelBackend>().is_err());
        assert_eq!("f32".parse::<KernelMode>().unwrap(), KernelMode::F32);
        assert_eq!("quant".parse::<KernelMode>().unwrap(), KernelMode::Quant);
        assert!("fp8".parse::<KernelMode>().is_err());
    }

    #[test]
    fn set_active_refuses_unavailable() {
        for b in KernelBackend::ALL {
            if !b.is_available() {
                assert!(!set_active(b));
            }
        }
        // restore/confirm a real backend is active either way
        assert!(set_active(detect()));
        assert!(active().is_available());
    }

    #[test]
    fn tile_info_is_sane() {
        let (rb, ct) = tile_info();
        assert_eq!(rb, ROW_BLOCK);
        assert!(ct >= 8, "column strip too small: {ct}");
    }
}
