//! Minimal dense f32 matrix type for the analog simulator.
//!
//! Row-major, contiguous, no views — the score networks here are 2→14→14→2
//! and the macros are 32×32, so simplicity and cache behaviour beat
//! generality.  The hot-path matmuls in [`crate::crossbar`] operate on raw
//! slices from this type.

use std::fmt;

/// Row-major dense matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// Zero-filled rows × cols.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Constant fill.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Wrap an existing buffer (len must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/buffer mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// self (m×k) @ other (k×n) -> (m×n).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        matmul_into(
            self.as_slice(),
            other.as_slice(),
            out.as_mut_slice(),
            self.rows,
            self.cols,
            other.cols,
        );
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }

    /// Elementwise map (copy).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Max |a - b| over all entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

/// Inner matmul over raw slices: c += a(m×k) @ b(k×n). `c` must be zeroed by
/// the caller when a fresh product is wanted.  ikj loop order — streams `b`
/// and `c` rows sequentially, which is the cache-friendly order for the
/// small-k regime here.
#[inline]
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (l, &aval) in arow.iter().enumerate() {
            if aval == 0.0 {
                continue;
            }
            let brow = &b[l * n..(l + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aval * bv;
            }
        }
    }
}

/// y = x (1×k) @ b (k×n) + bias, writing into y.
#[inline]
pub fn vecmat_bias_into(x: &[f32], b: &[f32], bias: &[f32], y: &mut [f32]) {
    let k = x.len();
    let n = y.len();
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    y.copy_from_slice(bias);
    for (l, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let brow = &b[l * n..(l + 1) * n];
        for (yv, &bv) in y.iter_mut().zip(brow) {
            *yv += xv * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| if r == c { 1.0 } else { 0.0 });
        let b = Mat::from_fn(3, 2, |r, c| (r * 2 + c) as f32);
        assert_eq!(a.matmul(&b), b);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Mat::from_fn(4, 3, |r, c| (r + c) as f32);
        let b = Mat::from_fn(3, 5, |r, c| (r as f32) - (c as f32));
        let c = a.matmul(&b);
        // verify one entry by hand: c[1][2] = sum_k a[1][k] b[k][2]
        let want: f32 = (0..3).map(|k| ((1 + k) as f32) * ((k as f32) - 2.0)).sum();
        assert_eq!(c.get(1, 2), want);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn vecmat_bias() {
        let b = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = [1.0f32, -1.0];
        let bias = [0.5f32, 0.5, 0.5];
        let mut y = [0.0f32; 3];
        vecmat_bias_into(&x, b.as_slice(), &bias, &mut y);
        assert_eq!(y, [-2.5, -2.5, -2.5]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn map_and_diff() {
        let a = Mat::full(2, 2, 2.0);
        let b = a.map(|x| x * x);
        assert_eq!(b.as_slice(), &[4.0; 4]);
        assert_eq!(a.max_abs_diff(&b), 2.0);
    }
}
