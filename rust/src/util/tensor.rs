//! Minimal dense f32 matrix type + the dispatched MVM kernels for the
//! analog simulator.
//!
//! Row-major, contiguous, no views — the score networks here are 2→14→14→2
//! and the macros are 32×32, so simplicity and cache behaviour beat
//! generality.  The hot-path matmuls in [`crate::crossbar`] operate on raw
//! slices from this type.
//!
//! The batched execution lane (B concurrent samples advanced per timestep)
//! turns the per-sample vector·matrix products into B×k · k×n GEMMs:
//! [`matmul_into`] runs a 4-row-blocked kernel so each weight row loaded
//! from memory feeds four output lanes, [`matmul_bias_into`] fuses the
//! per-row bias broadcast, and [`matmul_tb_into`] is the transposed-B
//! dot-product fast path for tall-k shapes.
//!
//! ## Kernel dispatch
//!
//! Each public kernel resolves to a [`KernelBackend`]
//! (scalar / AVX2 / NEON, see [`super::simd`]) — the undecorated entry
//! points use the process-global backend ([`simd::active`], forced with
//! `RUST_PALLAS_KERNEL`), while the `*_with` variants take an explicit
//! backend for parity sweeps and benches.  Determinism contract:
//!
//! | kernel                | cross-backend bitwise? | why                        |
//! |-----------------------|------------------------|----------------------------|
//! | `matmul_into`         | yes                    | order-preserving (mul+add) |
//! | `matmul_bias_into`    | yes                    | delegates to `matmul_into` |
//! | `matmul_block_accum`  | yes                    | order-preserving (mul+add) |
//! | `vecmat_bias_into`    | yes (scalar only)      | single-row, never SIMD     |
//! | `matmul_tb_into`      | **no** (tolerance)     | FMA + horizontal reduction |
//!
//! Per-output-element accumulation order on the order-preserving kernels
//! is identical to the single-vector [`vecmat_bias_into`] path, which
//! keeps the batched lane bitwise equal to the scalar lane under
//! `NoiseModel::Ideal` (asserted by the parity suite) on *every* backend.

use std::fmt;

use super::simd::{self, KernelBackend};

/// Row-major dense matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// Zero-filled rows × cols.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Constant fill.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Wrap an existing buffer (len must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/buffer mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// self (m×k) @ other (k×n) -> (m×n).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        matmul_into(
            self.as_slice(),
            other.as_slice(),
            out.as_mut_slice(),
            self.rows,
            self.cols,
            other.cols,
        );
        out
    }

    /// Transposed copy.  Cache-blocked: both source rows and destination
    /// rows stay resident per 32×32 block instead of the naive per-element
    /// `get` walk that strides the whole destination every row — this sits
    /// on the [`matmul_tb_into`] setup path.
    pub fn transpose(&self) -> Mat {
        const TB: usize = 32;
        let (r, c) = (self.rows, self.cols);
        let mut out = vec![0.0f32; r * c];
        let mut i0 = 0;
        while i0 < r {
            let i1 = (i0 + TB).min(r);
            let mut j0 = 0;
            while j0 < c {
                let j1 = (j0 + TB).min(c);
                for i in i0..i1 {
                    let src = &self.data[i * c + j0..i * c + j1];
                    for (j, &v) in (j0..j1).zip(src) {
                        out[j * r + i] = v;
                    }
                }
                j0 = j1;
            }
            i0 = i1;
        }
        Mat { rows: c, cols: r, data: out }
    }

    /// Elementwise map (copy).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Max |a - b| over all entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

/// Inner matmul over raw slices: c += a(m×k) @ b(k×n). `c` must be zeroed by
/// the caller when a fresh product is wanted.  Dispatches to the
/// process-global [`KernelBackend`]; see the module docs for the bitwise
/// contract (this kernel is order-preserving on every backend).
#[inline]
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_into_with(simd::active(), a, b, c, m, k, n);
}

/// [`matmul_into`] on an explicit backend (parity sweeps / benches).
/// An unavailable backend falls back to scalar, which computes the same
/// bits by the order-preserving contract.
pub fn matmul_into_with(backend: KernelBackend, a: &[f32], b: &[f32], c: &mut [f32],
                        m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let _t = crate::obs::phase(crate::obs::Phase::Gemm);
    match backend {
        KernelBackend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if backend.is_available() {
                // SAFETY: avx2 confirmed available; lengths asserted above.
                unsafe { simd::x86::matmul_into(a, b, c, m, k, n, simd::col_tile()) };
                return;
            }
            matmul_into_scalar(a, b, c, m, k, n)
        }
        KernelBackend::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is baseline on aarch64; lengths asserted above.
                unsafe { simd::arm::matmul_into(a, b, c, m, k, n, simd::col_tile()) };
                return;
            }
            #[cfg(not(target_arch = "aarch64"))]
            matmul_into_scalar(a, b, c, m, k, n)
        }
        KernelBackend::Scalar => matmul_into_scalar(a, b, c, m, k, n),
    }
}

/// The portable 4-row-blocked kernel — the parity oracle every SIMD path
/// must match bit for bit.  ikj loop order streams `b` and `c` rows
/// sequentially (the cache-friendly order for the small-k regime here);
/// rows of `a` are processed in blocks of four so each `b` row loaded from
/// memory feeds four output lanes.  The per-row accumulation order over `l`
/// is unchanged from the single-row kernel, so each output element sees the
/// identical float-op sequence as [`vecmat_bias_into`] minus the bias
/// (blocked lanes add exact ±0.0 terms where the single-row kernel skips,
/// which cannot change any sum).  All row walks are pre-split
/// `chunks_exact` iterators — no per-iteration bounds slicing.
fn matmul_into_scalar(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    let mut i = 0;
    while i + 4 <= m {
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        let block = &mut c[i * n..(i + 4) * n];
        let (c0, rest) = block.split_at_mut(n);
        let (c1, rest) = rest.split_at_mut(n);
        let (c2, c3) = rest.split_at_mut(n);
        for ((((&v0, &v1), &v2), &v3), brow) in
            a0.iter().zip(a1).zip(a2).zip(a3).zip(b.chunks_exact(n))
        {
            if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                continue;
            }
            for ((((w0, w1), w2), w3), &bv) in c0
                .iter_mut()
                .zip(c1.iter_mut())
                .zip(c2.iter_mut())
                .zip(c3.iter_mut())
                .zip(brow)
            {
                *w0 += v0 * bv;
                *w1 += v1 * bv;
                *w2 += v2 * bv;
                *w3 += v3 * bv;
            }
        }
        i += 4;
    }
    for (arow, crow) in a[i * k..].chunks_exact(k).zip(c[i * n..].chunks_exact_mut(n)) {
        for (&aval, brow) in arow.iter().zip(b.chunks_exact(n)) {
            if aval == 0.0 {
                continue;
            }
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aval * bv;
            }
        }
    }
}

/// c = a(m×k) @ b(k×n) + bias (broadcast over rows), writing into `c`.
/// The batched counterpart of [`vecmat_bias_into`]: every output row sees
/// the same bias-then-accumulate float-op order as the single-vector path,
/// so the two are bitwise interchangeable per lane (on every backend).
#[inline]
pub fn matmul_bias_into(a: &[f32], b: &[f32], bias: &[f32], c: &mut [f32],
                        m: usize, k: usize, n: usize) {
    matmul_bias_into_with(simd::active(), a, b, bias, c, m, k, n);
}

/// [`matmul_bias_into`] on an explicit backend.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_into_with(backend: KernelBackend, a: &[f32], b: &[f32], bias: &[f32],
                             c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(c.len(), m * n);
    for crow in c.chunks_exact_mut(n) {
        crow.copy_from_slice(bias);
    }
    matmul_into_with(backend, a, b, c, m, k, n);
}

/// c = a(m×k) @ B(k×n) where `bt` stores B *transposed* (n×k): dot-product
/// inner loop.  The fast path when B is reused across many calls with a
/// tall k — each output element is one contiguous dot product, keeping both
/// streams sequential.  Overwrites `c` (no accumulate).
///
/// **Not order-preserving across backends**: the SIMD paths reduce with
/// FMA accumulators + a horizontal sum, so compare with a tolerance.  No
/// serving forward path goes through this kernel.
#[inline]
pub fn matmul_tb_into(a: &[f32], bt: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_tb_into_with(simd::active(), a, bt, c, m, k, n);
}

/// [`matmul_tb_into`] on an explicit backend.
pub fn matmul_tb_into_with(backend: KernelBackend, a: &[f32], bt: &[f32], c: &mut [f32],
                           m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let _t = crate::obs::phase(crate::obs::Phase::Gemm);
    match backend {
        KernelBackend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if backend.is_available() {
                // SAFETY: avx2+fma confirmed available; lengths asserted.
                unsafe { simd::x86::matmul_tb_into(a, bt, c, m, k, n) };
                return;
            }
            matmul_tb_into_scalar(a, bt, c, m, k, n)
        }
        KernelBackend::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is baseline on aarch64; lengths asserted.
                unsafe { simd::arm::matmul_tb_into(a, bt, c, m, k, n) };
                return;
            }
            #[cfg(not(target_arch = "aarch64"))]
            matmul_tb_into_scalar(a, bt, c, m, k, n)
        }
        KernelBackend::Scalar => matmul_tb_into_scalar(a, bt, c, m, k, n),
    }
}

fn matmul_tb_into_scalar(a: &[f32], bt: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    let _ = m;
    for (arow, crow) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        for (cv, brow) in crow.iter_mut().zip(bt.chunks_exact(k)) {
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
}

/// Bank-sharded GEMM block: accumulate `c[i, c_off..c_off+n] +=
/// a[i, a_off..a_off+k] @ b(k×n)` for `m` lanes, where `a` rows have stride
/// `a_stride` and `c` rows have stride `c_stride` (both row-major with the
/// block starting at the given column offset).  This is the one-GEMM-per-
/// bank kernel of the macro-bank sharding subsystem
/// ([`crate::crossbar::bank`]): each bank contributes its row-slice ×
/// column-slice product directly into the shared output scratch, so for a
/// fixed output element the accumulation order over the logical rows `r`
/// is ascending — identical to the monolithic [`matmul_into`] path, which
/// keeps banked `Ideal` evaluation bitwise equal to the monolithic oracle.
/// Order-preserving on every backend.
///
/// Zero-valued `a` entries are skipped; with all-positive `b` (conductances)
/// and accumulators that never go negative-zero, skipping versus adding an
/// exact ±0.0 term cannot change any output bit.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn matmul_block_accum(a: &[f32], a_stride: usize, a_off: usize,
                          b: &[f32], c: &mut [f32], c_stride: usize,
                          c_off: usize, m: usize, k: usize, n: usize) {
    matmul_block_accum_with(simd::active(), a, a_stride, a_off, b, c, c_stride, c_off, m, k, n);
}

/// [`matmul_block_accum`] on an explicit backend.
#[allow(clippy::too_many_arguments)]
pub fn matmul_block_accum_with(backend: KernelBackend, a: &[f32], a_stride: usize,
                               a_off: usize, b: &[f32], c: &mut [f32], c_stride: usize,
                               c_off: usize, m: usize, k: usize, n: usize) {
    debug_assert!(a_off + k <= a_stride);
    debug_assert!(c_off + n <= c_stride);
    debug_assert!(a.len() >= (m.saturating_sub(1)) * a_stride + a_off + k);
    debug_assert!(c.len() >= (m.saturating_sub(1)) * c_stride + c_off + n);
    debug_assert_eq!(b.len(), k * n);
    match backend {
        KernelBackend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if backend.is_available() {
                // SAFETY: avx2 confirmed available; bounds asserted above.
                unsafe {
                    simd::x86::matmul_block_accum(a, a_stride, a_off, b, c, c_stride,
                                                  c_off, m, k, n)
                };
                return;
            }
            matmul_block_accum_scalar(a, a_stride, a_off, b, c, c_stride, c_off, m, k, n)
        }
        KernelBackend::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is baseline on aarch64; bounds asserted above.
                unsafe {
                    simd::arm::matmul_block_accum(a, a_stride, a_off, b, c, c_stride,
                                                  c_off, m, k, n)
                };
                return;
            }
            #[cfg(not(target_arch = "aarch64"))]
            matmul_block_accum_scalar(a, a_stride, a_off, b, c, c_stride, c_off, m, k, n)
        }
        KernelBackend::Scalar => {
            matmul_block_accum_scalar(a, a_stride, a_off, b, c, c_stride, c_off, m, k, n)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn matmul_block_accum_scalar(a: &[f32], a_stride: usize, a_off: usize,
                             b: &[f32], c: &mut [f32], c_stride: usize,
                             c_off: usize, m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * a_stride + a_off..i * a_stride + a_off + k];
        let crow = &mut c[i * c_stride + c_off..i * c_stride + c_off + n];
        for (&aval, brow) in arow.iter().zip(b.chunks_exact(n)) {
            if aval == 0.0 {
                continue;
            }
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aval * bv;
            }
        }
    }
}

/// Grow-only scratch helper for the batch lanes: ensure `buf` holds at
/// least `len` elements and return the `len`-prefix.  Contents are NOT
/// cleared — callers fully overwrite.  Amortizes to zero allocation once a
/// buffer has seen its steady-state batch size.
#[inline]
pub fn scratch_slice(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    &mut buf[..len]
}

/// y = x (1×k) @ b (k×n) + bias, writing into y.  Always scalar — the
/// single-vector path is the accumulation-order reference the batched
/// kernels preserve.
#[inline]
pub fn vecmat_bias_into(x: &[f32], b: &[f32], bias: &[f32], y: &mut [f32]) {
    let k = x.len();
    let n = y.len();
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    y.copy_from_slice(bias);
    for (&xv, brow) in x.iter().zip(b.chunks_exact(n)) {
        if xv == 0.0 {
            continue;
        }
        for (yv, &bv) in y.iter_mut().zip(brow) {
            *yv += xv * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| if r == c { 1.0 } else { 0.0 });
        let b = Mat::from_fn(3, 2, |r, c| (r * 2 + c) as f32);
        assert_eq!(a.matmul(&b), b);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Mat::from_fn(4, 3, |r, c| (r + c) as f32);
        let b = Mat::from_fn(3, 5, |r, c| (r as f32) - (c as f32));
        let c = a.matmul(&b);
        // verify one entry by hand: c[1][2] = sum_k a[1][k] b[k][2]
        let want: f32 = (0..3).map(|k| ((1 + k) as f32) * ((k as f32) - 2.0)).sum();
        assert_eq!(c.get(1, 2), want);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(3, 5, |r, c| (r * 5 + c) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_blocked_matches_naive_on_odd_shapes() {
        // shapes straddling the 32-block boundary exercise every edge block
        for (r, c) in [(1usize, 1usize), (7, 33), (33, 7), (32, 32), (40, 65)] {
            let a = Mat::from_fn(r, c, |i, j| (i * c + j) as f32 * 0.5 - 3.0);
            let t = a.transpose();
            assert_eq!(t.shape(), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.get(j, i), a.get(i, j), "({i},{j}) of {r}x{c}");
                }
            }
        }
    }

    #[test]
    fn vecmat_bias() {
        let b = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = [1.0f32, -1.0];
        let bias = [0.5f32, 0.5, 0.5];
        let mut y = [0.0f32; 3];
        vecmat_bias_into(&x, b.as_slice(), &bias, &mut y);
        assert_eq!(y, [-2.5, -2.5, -2.5]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn map_and_diff() {
        let a = Mat::full(2, 2, 2.0);
        let b = a.map(|x| x * x);
        assert_eq!(b.as_slice(), &[4.0; 4]);
        assert_eq!(a.max_abs_diff(&b), 2.0);
    }

    /// Reference single-row kernel for cross-checking the blocked path.
    fn matmul_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + l] * b[l * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn blocked_kernel_matches_reference_all_remainders() {
        // m = 1..9 exercises full 4-row blocks plus 0..3-row remainders
        for m in 1..=9usize {
            let (k, n) = (5, 6);
            let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
            let mut c = vec![0.0f32; m * n];
            matmul_into(&a, &b, &mut c, m, k, n);
            let want = matmul_ref(&a, &b, m, k, n);
            for (got, want) in c.iter().zip(&want) {
                assert!((got - want).abs() < 1e-5, "m={m}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn blocked_kernel_handles_zero_rows() {
        // zero inputs in some lanes must not perturb the others
        let (m, k, n) = (6usize, 4, 3);
        let mut a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.1 - 1.0).collect();
        for v in a[k..2 * k].iter_mut() {
            *v = 0.0; // lane 1 entirely zero
        }
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32) - 5.0).collect();
        let mut c = vec![0.0f32; m * n];
        matmul_into(&a, &b, &mut c, m, k, n);
        assert_eq!(&c[n..2 * n], &[0.0, 0.0, 0.0]);
        let want = matmul_ref(&a, &b, m, k, n);
        for (got, want) in c.iter().zip(&want) {
            assert!((got - want).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_bias_matches_per_row_vecmat() {
        let (m, k, n) = (7usize, 4, 5);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.23).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.71).cos()).collect();
        let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 1.0).collect();
        let mut c = vec![0.0f32; m * n];
        matmul_bias_into(&a, &b, &bias, &mut c, m, k, n);
        let mut y = vec![0.0f32; n];
        for i in 0..m {
            vecmat_bias_into(&a[i * k..(i + 1) * k], &b, &bias, &mut y);
            // bitwise: identical accumulation order per output element
            assert_eq!(&c[i * n..(i + 1) * n], y.as_slice(), "row {i}");
        }
    }

    #[test]
    fn transposed_b_path_matches_row_major() {
        let a = Mat::from_fn(6, 8, |r, c| ((r * 8 + c) as f32 * 0.13).sin());
        let b = Mat::from_fn(8, 4, |r, c| ((r * 4 + c) as f32 * 0.29).cos());
        let bt = b.transpose();
        let want = a.matmul(&b);
        let mut c = vec![0.0f32; 6 * 4];
        matmul_tb_into(a.as_slice(), bt.as_slice(), &mut c, 6, 8, 4);
        for (got, want) in c.iter().zip(want.as_slice()) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn block_accum_tiling_matches_full_matmul_bitwise() {
        // split a (m×k)·(k×n) product into 2×2 blocks of b and accumulate
        // bank-style: must equal the monolithic kernel bit for bit
        let (m, k, n) = (5usize, 7, 9);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.31).sin()).collect();
        // strictly positive "conductances" like the crossbar cache
        let b: Vec<f32> = (0..k * n).map(|i| 0.02 + 0.08 * ((i as f32 * 0.17).sin().abs())).collect();
        let mut want = vec![0.0f32; m * n];
        matmul_into(&a, &b, &mut want, m, k, n);

        let mut got = vec![0.0f32; m * n];
        let (k0, n0) = (4usize, 5usize); // ragged 2×2 tile grid
        for (r0, kb) in [(0usize, k0), (k0, k - k0)] {
            for (c0, nb) in [(0usize, n0), (n0, n - n0)] {
                // bank-local copy of b's (r0..r0+kb, c0..c0+nb) block
                let sub: Vec<f32> = (0..kb * nb)
                    .map(|i| b[(r0 + i / nb) * n + c0 + i % nb])
                    .collect();
                matmul_block_accum(&a, k, r0, &sub, &mut got, n, c0, m, kb, nb);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn every_backend_is_bitwise_on_order_preserving_kernels() {
        // ragged shapes exercise the 4-row remainder and every SIMD tail
        for &(m, k, n) in &[(1usize, 3usize, 2usize), (4, 8, 8), (5, 7, 9),
                            (9, 17, 33), (12, 32, 40), (6, 96, 70)] {
            let a: Vec<f32> = (0..m * k)
                .map(|i| if i % 11 == 0 { 0.0 } else { (i as f32 * 0.37).sin() })
                .collect();
            let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
            let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.3 - 1.0).collect();
            let mut want = vec![0.1f32; m * n];
            matmul_into_with(KernelBackend::Scalar, &a, &b, &mut want, m, k, n);
            let mut want_bias = vec![0.0f32; m * n];
            matmul_bias_into_with(KernelBackend::Scalar, &a, &b, &bias, &mut want_bias, m, k, n);
            for backend in super::simd::available() {
                let mut got = vec![0.1f32; m * n];
                matmul_into_with(backend, &a, &b, &mut got, m, k, n);
                assert_eq!(got, want, "matmul_into {backend} {m}x{k}x{n}");
                let mut got_bias = vec![0.0f32; m * n];
                matmul_bias_into_with(backend, &a, &b, &bias, &mut got_bias, m, k, n);
                assert_eq!(got_bias, want_bias, "matmul_bias_into {backend} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn every_backend_is_bitwise_on_block_accum() {
        let (m, k, n) = (5usize, 39, 23);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.31).sin()).collect();
        let b: Vec<f32> = (0..k * n)
            .map(|i| 0.02 + 0.08 * ((i as f32 * 0.17).sin().abs()))
            .collect();
        // bank-local copy of b's rows 2..19 × cols 3..14 block
        let tile: Vec<f32> = (0..17 * 11)
            .map(|i| b[(2 + i / 11) * n + 3 + i % 11])
            .collect();
        let mut want = vec![0.0f32; m * n];
        matmul_block_accum_with(KernelBackend::Scalar, &a, k, 2, &tile, &mut want,
                                n, 3, m, 17, 11);
        for backend in super::simd::available() {
            let mut got = vec![0.0f32; m * n];
            matmul_block_accum_with(backend, &a, k, 2, &tile, &mut got, n, 3, m, 17, 11);
            assert_eq!(got, want, "block_accum {backend}");
        }
    }

    #[test]
    fn tb_path_agrees_across_backends_within_tolerance() {
        // FMA + horizontal reduction reassociates: tolerance, not bitwise
        let (m, k, n) = (6usize, 37, 5);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.13).sin()).collect();
        let bt: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.29).cos()).collect();
        let mut want = vec![0.0f32; m * n];
        matmul_tb_into_with(KernelBackend::Scalar, &a, &bt, &mut want, m, k, n);
        for backend in super::simd::available() {
            let mut got = vec![0.0f32; m * n];
            matmul_tb_into_with(backend, &a, &bt, &mut got, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "tb {backend}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn scratch_slice_grows_and_reuses() {
        let mut buf = Vec::new();
        assert_eq!(scratch_slice(&mut buf, 4).len(), 4);
        buf[2] = 7.0;
        // shrink request returns prefix without reallocating or clearing
        let s = scratch_slice(&mut buf, 2);
        assert_eq!(s.len(), 2);
        assert_eq!(buf.len(), 4);
        assert_eq!(buf[2], 7.0);
        assert_eq!(scratch_slice(&mut buf, 8).len(), 8);
    }
}
