//! Statistics: moments, 2-D histograms, KL divergence (the paper's quality
//! metric, Eq. 8), and latency percentile summaries for the coordinator.

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// 2-D histogram over a square domain [-lim, lim]^2.
#[derive(Debug, Clone)]
pub struct Hist2d {
    pub bins: usize,
    pub lim: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Hist2d {
    pub fn new(bins: usize, lim: f64) -> Self {
        Hist2d { bins, lim, counts: vec![0; bins * bins], total: 0 }
    }

    /// Bin index for a coordinate; out-of-range values clamp to edge bins
    /// (they carry probability mass that must not be silently dropped).
    #[inline]
    fn idx(&self, v: f64) -> usize {
        let u = (v + self.lim) / (2.0 * self.lim);
        ((u * self.bins as f64) as isize).clamp(0, self.bins as isize - 1) as usize
    }

    /// Accumulate one 2-D point.
    pub fn add(&mut self, x: f64, y: f64) {
        let (i, j) = (self.idx(x), self.idx(y));
        self.counts[i * self.bins + j] += 1;
        self.total += 1;
    }

    /// Accumulate interleaved 2-D points [x0, y0, x1, y1, ...].
    pub fn add_points(&mut self, pts: &[f32]) {
        assert!(pts.len() % 2 == 0);
        for p in pts.chunks_exact(2) {
            self.add(p[0] as f64, p[1] as f64);
        }
    }

    /// Smoothed probability per bin (additive epsilon, normalized).
    pub fn probs(&self, eps: f64) -> Vec<f64> {
        let denom = self.total as f64 + eps * self.counts.len() as f64;
        self.counts.iter().map(|&c| (c as f64 + eps) / denom).collect()
    }
}

/// KL(P || Q) between two histograms over the same binning (paper Eq. 8).
/// Additive smoothing keeps empty bins finite — same convention as the
/// python-side `aot.kl_hist2d` gate, so the two sides cross-check.
pub fn kl_divergence(p: &Hist2d, q: &Hist2d, eps: f64) -> f64 {
    assert_eq!(p.bins, q.bins);
    assert_eq!(p.counts.len(), q.counts.len());
    let pp = p.probs(eps);
    let qq = q.probs(eps);
    pp.iter()
        .zip(&qq)
        .map(|(&a, &b)| if a > 0.0 { a * (a / b).ln() } else { 0.0 })
        .sum()
}

/// Convenience: KL between two interleaved 2-D point sets.
pub fn kl_points(gen: &[f32], truth: &[f32], bins: usize, lim: f64) -> f64 {
    let mut hp = Hist2d::new(bins, lim);
    let mut hq = Hist2d::new(bins, lim);
    hp.add_points(truth);
    hq.add_points(gen);
    kl_divergence(&hp, &hq, 1e-3)
}

/// Percentile (nearest-rank) of an unsorted sample, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

// ---------------------------------------------------------------------
// Log-bucketed histogram substrate (shared with `crate::obs::registry`)
//
// Buckets are geometric with `LOG_BUCKETS_PER_OCTAVE` subdivisions per
// power of two, spanning 2^LOG_MIN_EXP .. 2^LOG_MAX_EXP, plus a zero/
// underflow bucket below and an overflow bucket above.  A value's bucket
// is found from its log2, so a quantile read off a bucket representative
// (the geometric midpoint) is within a factor of 2^(1/16) ≈ ±4.4% of the
// true value — the quantile-error bound of everything built on this.

/// Subdivisions per octave (power of two). 8 → bucket width 2^(1/8) ≈ 1.09.
pub const LOG_BUCKETS_PER_OCTAVE: usize = 8;
/// Smallest resolved magnitude: 2^-30 ≈ 1e-9 (sub-nanosecond latencies
/// collapse into the zero bucket).
pub const LOG_MIN_EXP: i32 = -30;
/// Largest resolved magnitude: 2^30 ≈ 1e9.
pub const LOG_MAX_EXP: i32 = 30;
/// Total bucket count: zero/underflow + geometric range + overflow.
pub const LOG_BUCKETS: usize =
    (LOG_MAX_EXP - LOG_MIN_EXP) as usize * LOG_BUCKETS_PER_OCTAVE + 2;

/// Bucket index of a value (NaN and v ≤ 2^LOG_MIN_EXP land in bucket 0).
#[inline]
pub fn log_bucket_index(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    let pos = (v.log2() - LOG_MIN_EXP as f64) * LOG_BUCKETS_PER_OCTAVE as f64;
    if pos < 0.0 {
        0
    } else {
        // +1 past the underflow bucket; everything ≥ 2^LOG_MAX_EXP overflows
        (pos.floor() as usize + 1).min(LOG_BUCKETS - 1)
    }
}

/// Inclusive upper edge of bucket `i` (`+Inf` for the overflow bucket) —
/// the `le` boundary of the Prometheus exposition.
pub fn log_bucket_upper(i: usize) -> f64 {
    if i == 0 {
        (LOG_MIN_EXP as f64).exp2()
    } else if i >= LOG_BUCKETS - 1 {
        f64::INFINITY
    } else {
        (LOG_MIN_EXP as f64 + i as f64 / LOG_BUCKETS_PER_OCTAVE as f64).exp2()
    }
}

/// Representative value of bucket `i` (geometric midpoint; 0 for the
/// zero bucket) — what quantile queries report.
pub fn log_bucket_repr(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else if i >= LOG_BUCKETS - 1 {
        (LOG_MAX_EXP as f64).exp2()
    } else {
        (LOG_MIN_EXP as f64 + (i as f64 - 0.5) / LOG_BUCKETS_PER_OCTAVE as f64).exp2()
    }
}

/// Online latency/throughput summary for coordinator metrics.
///
/// **Constant memory**: one fixed bucket array plus four scalars, no
/// matter how many samples are recorded (the previous implementation kept
/// every sample in a `Vec<f64>`, which grew without bound under sustained
/// serving).  `count`/`mean`/`sum`/`max` are exact; `p50`/`p99` are read
/// from the log-bucketed histogram and carry its ±4.4% relative-error
/// bound (see [`LOG_BUCKETS_PER_OCTAVE`]).
#[derive(Debug, Clone)]
pub struct Summary {
    count: u64,
    sum: f64,
    max: f64,
    buckets: Box<[u64; LOG_BUCKETS]>,
}

impl Default for Summary {
    fn default() -> Self {
        Summary::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            count: 0,
            sum: 0.0,
            max: f64::NAN,
            buckets: Box::new([0u64; LOG_BUCKETS]),
        }
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.max = if self.max.is_nan() { v } else { self.max.max(v) };
        self.buckets[log_bucket_index(v)] += 1;
    }

    pub fn count(&self) -> usize {
        self.count as usize
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Approximate percentile (nearest-rank over the bucket counts),
    /// q in [0, 100]; within ±4.4% of the true value.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((q / 100.0) * (self.count as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return log_bucket_repr(i);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(90.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Total of all recorded values (0 when empty — unlike `mean`, a sum
    /// over nothing is well-defined).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// The raw bucket counts (index ↔ [`log_bucket_upper`] edges), for
    /// histogram export.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mean_std_known() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn hist_bins_cover_domain() {
        let mut h = Hist2d::new(4, 1.0);
        h.add(-0.99, -0.99);
        h.add(0.99, 0.99);
        h.add(0.0, 0.0);
        assert_eq!(h.total, 3);
        assert_eq!(h.counts[0], 1); // bottom-left
        assert_eq!(h.counts[15], 1); // top-right
    }

    #[test]
    fn hist_clamps_outliers() {
        let mut h = Hist2d::new(4, 1.0);
        h.add(100.0, -100.0);
        assert_eq!(h.total, 1);
        assert_eq!(h.counts.iter().sum::<u64>(), 1);
    }

    #[test]
    fn kl_identical_is_zero() {
        let mut rng = Rng::new(0);
        let pts: Vec<f32> = (0..20_000).map(|_| rng.gaussian_f32()).collect();
        let kl = kl_points(&pts, &pts, 16, 3.0);
        assert!(kl.abs() < 1e-12, "kl={kl}");
    }

    #[test]
    fn kl_same_distribution_small() {
        let mut rng = Rng::new(1);
        let a: Vec<f32> = (0..40_000).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..40_000).map(|_| rng.gaussian_f32()).collect();
        let kl = kl_points(&a, &b, 16, 3.0);
        assert!(kl < 0.02, "kl={kl}");
    }

    #[test]
    fn kl_detects_mismatch() {
        let mut rng = Rng::new(2);
        let narrow: Vec<f32> = (0..20_000).map(|_| 0.3 * rng.gaussian_f32()).collect();
        let wide: Vec<f32> = (0..20_000).map(|_| rng.gaussian_f32()).collect();
        let kl = kl_points(&narrow, &wide, 16, 3.0);
        assert!(kl > 0.3, "kl={kl}");
    }

    #[test]
    fn kl_asymmetry() {
        let mut rng = Rng::new(3);
        let narrow: Vec<f32> = (0..20_000).map(|_| 0.3 * rng.gaussian_f32()).collect();
        let wide: Vec<f32> = (0..20_000).map(|_| rng.gaussian_f32()).collect();
        let a = kl_points(&narrow, &wide, 16, 3.0);
        let b = kl_points(&wide, &narrow, 16, 3.0);
        assert!((a - b).abs() > 1e-3);
    }

    #[test]
    fn percentile_ranks() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for i in 1..=10 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-12);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn summary_memory_is_bounded_and_quantiles_hold_error_bound() {
        let mut s = Summary::new();
        let mut rng = Rng::new(9);
        // 200k samples: the old Vec-backed Summary would hold 1.6 MB here;
        // the bucketed one is a fixed array regardless of volume.
        for _ in 0..200_000 {
            let v = (rng.uniform() * 4.0).exp2() * 1e-3; // 1ms..16ms
            s.record(v.max(1e-6));
        }
        assert!(std::mem::size_of_val(&*s.buckets) == LOG_BUCKETS * 8);
        let bound = (1.0f64 / (2.0 * LOG_BUCKETS_PER_OCTAVE as f64)).exp2();
        for q in [50.0, 90.0, 99.0] {
            let est = s.percentile(q);
            assert!(est > 0.0 && est <= s.max() * bound, "q{q} est {est}");
        }
        // p50 of a known uniform set stays within the documented ±4.4%
        let mut t = Summary::new();
        for i in 1..=1000 {
            t.record(i as f64);
        }
        let p50 = t.p50();
        assert!((p50 / 500.0 - 1.0).abs() < 1.0 / LOG_BUCKETS_PER_OCTAVE as f64,
                "p50={p50}");
        assert_eq!(t.count(), 1000);
        assert!((t.sum() - 500_500.0).abs() < 1e-6);
    }

    #[test]
    fn log_buckets_are_monotone_and_cover() {
        // index is monotone in v and upper edges are honest bounds
        let mut prev = 0usize;
        let mut v = 1e-10f64;
        while v < 1e10 {
            let i = log_bucket_index(v);
            assert!(i >= prev, "index regressed at {v}");
            assert!(v <= log_bucket_upper(i) || i == LOG_BUCKETS - 1);
            if i > 0 && i < LOG_BUCKETS - 1 {
                assert!(v > log_bucket_upper(i - 1) * 0.999_999);
            }
            prev = i;
            v *= 1.07;
        }
        assert_eq!(log_bucket_index(0.0), 0);
        assert_eq!(log_bucket_index(-1.0), 0);
        assert_eq!(log_bucket_index(f64::NAN), 0);
        assert_eq!(log_bucket_index(f64::INFINITY), LOG_BUCKETS - 1);
        assert_eq!(log_bucket_upper(LOG_BUCKETS - 1), f64::INFINITY);
        assert_eq!(log_bucket_repr(0), 0.0);
    }
}
