//! Statistics: moments, 2-D histograms, KL divergence (the paper's quality
//! metric, Eq. 8), and latency percentile summaries for the coordinator.

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// 2-D histogram over a square domain [-lim, lim]^2.
#[derive(Debug, Clone)]
pub struct Hist2d {
    pub bins: usize,
    pub lim: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Hist2d {
    pub fn new(bins: usize, lim: f64) -> Self {
        Hist2d { bins, lim, counts: vec![0; bins * bins], total: 0 }
    }

    /// Bin index for a coordinate; out-of-range values clamp to edge bins
    /// (they carry probability mass that must not be silently dropped).
    #[inline]
    fn idx(&self, v: f64) -> usize {
        let u = (v + self.lim) / (2.0 * self.lim);
        ((u * self.bins as f64) as isize).clamp(0, self.bins as isize - 1) as usize
    }

    /// Accumulate one 2-D point.
    pub fn add(&mut self, x: f64, y: f64) {
        let (i, j) = (self.idx(x), self.idx(y));
        self.counts[i * self.bins + j] += 1;
        self.total += 1;
    }

    /// Accumulate interleaved 2-D points [x0, y0, x1, y1, ...].
    pub fn add_points(&mut self, pts: &[f32]) {
        assert!(pts.len() % 2 == 0);
        for p in pts.chunks_exact(2) {
            self.add(p[0] as f64, p[1] as f64);
        }
    }

    /// Smoothed probability per bin (additive epsilon, normalized).
    pub fn probs(&self, eps: f64) -> Vec<f64> {
        let denom = self.total as f64 + eps * self.counts.len() as f64;
        self.counts.iter().map(|&c| (c as f64 + eps) / denom).collect()
    }
}

/// KL(P || Q) between two histograms over the same binning (paper Eq. 8).
/// Additive smoothing keeps empty bins finite — same convention as the
/// python-side `aot.kl_hist2d` gate, so the two sides cross-check.
pub fn kl_divergence(p: &Hist2d, q: &Hist2d, eps: f64) -> f64 {
    assert_eq!(p.bins, q.bins);
    assert_eq!(p.counts.len(), q.counts.len());
    let pp = p.probs(eps);
    let qq = q.probs(eps);
    pp.iter()
        .zip(&qq)
        .map(|(&a, &b)| if a > 0.0 { a * (a / b).ln() } else { 0.0 })
        .sum()
}

/// Convenience: KL between two interleaved 2-D point sets.
pub fn kl_points(gen: &[f32], truth: &[f32], bins: usize, lim: f64) -> f64 {
    let mut hp = Hist2d::new(bins, lim);
    let mut hq = Hist2d::new(bins, lim);
    hp.add_points(truth);
    hq.add_points(gen);
    kl_divergence(&hp, &hq, 1e-3)
}

/// Percentile (nearest-rank) of an unsorted sample, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Online latency/throughput summary for coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NAN, f64::max)
    }

    /// Total of all recorded values (0 when empty — unlike `mean`, a sum
    /// over nothing is well-defined).
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mean_std_known() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn hist_bins_cover_domain() {
        let mut h = Hist2d::new(4, 1.0);
        h.add(-0.99, -0.99);
        h.add(0.99, 0.99);
        h.add(0.0, 0.0);
        assert_eq!(h.total, 3);
        assert_eq!(h.counts[0], 1); // bottom-left
        assert_eq!(h.counts[15], 1); // top-right
    }

    #[test]
    fn hist_clamps_outliers() {
        let mut h = Hist2d::new(4, 1.0);
        h.add(100.0, -100.0);
        assert_eq!(h.total, 1);
        assert_eq!(h.counts.iter().sum::<u64>(), 1);
    }

    #[test]
    fn kl_identical_is_zero() {
        let mut rng = Rng::new(0);
        let pts: Vec<f32> = (0..20_000).map(|_| rng.gaussian_f32()).collect();
        let kl = kl_points(&pts, &pts, 16, 3.0);
        assert!(kl.abs() < 1e-12, "kl={kl}");
    }

    #[test]
    fn kl_same_distribution_small() {
        let mut rng = Rng::new(1);
        let a: Vec<f32> = (0..40_000).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..40_000).map(|_| rng.gaussian_f32()).collect();
        let kl = kl_points(&a, &b, 16, 3.0);
        assert!(kl < 0.02, "kl={kl}");
    }

    #[test]
    fn kl_detects_mismatch() {
        let mut rng = Rng::new(2);
        let narrow: Vec<f32> = (0..20_000).map(|_| 0.3 * rng.gaussian_f32()).collect();
        let wide: Vec<f32> = (0..20_000).map(|_| rng.gaussian_f32()).collect();
        let kl = kl_points(&narrow, &wide, 16, 3.0);
        assert!(kl > 0.3, "kl={kl}");
    }

    #[test]
    fn kl_asymmetry() {
        let mut rng = Rng::new(3);
        let narrow: Vec<f32> = (0..20_000).map(|_| 0.3 * rng.gaussian_f32()).collect();
        let wide: Vec<f32> = (0..20_000).map(|_| rng.gaussian_f32()).collect();
        let a = kl_points(&narrow, &wide, 16, 3.0);
        let b = kl_points(&wide, &narrow, 16, 3.0);
        assert!((a - b).abs() > 1e-3);
    }

    #[test]
    fn percentile_ranks() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for i in 1..=10 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 10);
        assert!((s.mean() - 5.5).abs() < 1e-12);
        assert_eq!(s.max(), 10.0);
    }
}
