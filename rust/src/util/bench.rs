//! Micro-benchmark harness (no `criterion` in the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed iterations with outlier-robust statistics, and a `report` printer
//! whose rows mirror the paper's tables (see `benches/`).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

/// Time `f` adaptively: warm up, then run enough iterations to fill
/// ~`budget_ms` of wall clock (min 10 iters), reporting robust stats.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < Duration::from_millis(budget_ms / 5 + 1) {
        f();
        warm_iters += 1;
    }
    // estimate per-iter cost from warmup to size the timed run
    let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    let target_iters = ((budget_ms as f64 / 1000.0 / per_iter) as u64).clamp(10, 1_000_000);

    let mut times = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean: total / target_iters as u32,
        p50: times[times.len() / 2],
        min: times[0],
        max: times[times.len() - 1],
    }
}

/// Pretty-print one result row.
pub fn report(r: &BenchResult) {
    println!(
        "{:<44} {:>10} iters   mean {:>12?}   p50 {:>12?}   min {:>12?}",
        r.name, r.iters, r.mean, r.p50, r.min
    );
}

/// Section header for a bench table.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// A labelled table row for paper-shaped outputs (speedups, KL, energy).
pub fn row(cols: &[&str]) {
    println!("{}", cols.join(" | "));
}

/// Write a flat `{name: number}` JSON object — the machine-readable bench
/// artifact (`BENCH_*.json`) the perf trajectory is tracked from across
/// PRs.  Non-finite values are emitted as `null` to keep the file valid.
pub fn write_json(path: &str, fields: &[(&str, f64)]) -> std::io::Result<()> {
    let mut s = String::from("{\n");
    for (i, (k, v)) in fields.iter().enumerate() {
        let val = if v.is_finite() {
            format!("{v}")
        } else {
            "null".to_string()
        };
        let sep = if i + 1 == fields.len() { "" } else { "," };
        s.push_str(&format!("  \"{k}\": {val}{sep}\n"));
    }
    s.push_str("}\n");
    std::fs::write(path, s)?;
    println!("  wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let r = bench("noop-ish", 20, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 10);
        assert!(r.min <= r.p50 && r.p50 <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn write_json_emits_valid_object() {
        let dir = std::env::temp_dir();
        let path = dir.join("memdiff_bench_json_test.json");
        let path = path.to_str().unwrap();
        write_json(path, &[("a", 1.5), ("nan", f64::NAN), ("b", 2.0)]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        std::fs::remove_file(path).ok();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(parsed.get("a").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(parsed.get("b").and_then(|v| v.as_f64()), Some(2.0));
        assert!(parsed.get("nan").is_some(), "null field must still parse");
    }
}
