//! Property-testing harness (no `proptest` in the offline vendor set).
//!
//! Seeded generator combinators + a runner that reports the failing seed so
//! any counterexample is reproducible with `PTEST_SEED=<n> cargo test`.
//! Used by the coordinator invariant tests (no request lost/duplicated,
//! batch bounds, FIFO ordering) and the crossbar linearity properties.

use crate::util::rng::Rng;

/// Number of cases per property (override with PTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("PTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `cases` seeded inputs produced by `gen`.
/// Panics with the failing case index + seed on first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let cases = default_cases();
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (PTEST_SEED={seed}):\n{input:#?}"
            );
        }
    }
}

/// Like [`check`] but the property returns Result with a message.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (PTEST_SEED={seed}): {msg}\n{input:#?}"
            );
        }
    }
}

// ---- generator helpers ------------------------------------------------------

/// Vec of gaussians with random length in [1, max_len].
pub fn gen_gaussian_vec(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len);
    rng.gaussian_vec(n)
}

/// Random usize in [lo, hi].
pub fn gen_range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("abs nonneg", |r| r.gaussian_f32(), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_seed() {
        check("always false", |r| r.below(10), |_| false);
    }

    #[test]
    fn gen_helpers_in_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = gen_gaussian_vec(&mut rng, 17);
            assert!((1..=17).contains(&v.len()));
            let x = gen_range(&mut rng, 3, 9);
            assert!((3..=9).contains(&x));
        }
    }
}
