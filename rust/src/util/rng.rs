//! Deterministic PRNG: xoshiro256++ with Box–Muller Gaussian sampling.
//!
//! Used everywhere randomness appears — device noise, Wiener increments,
//! workload generation — so every experiment in EXPERIMENTS.md is exactly
//! reproducible from its seed.  Streams can be `split()` to decorrelate
//! subsystems (read noise vs. write noise vs. sampling noise) without
//! sharing state across threads.

/// xoshiro256++ PRNG (Blackman & Vigna), plus Gaussian helpers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

/// splitmix64 — seeds the xoshiro state from a single u64.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Rejection-free (modulo bias negligible
    /// for n << 2^64, asserted in debug).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via the Marsaglia polar method (cached pair).
    /// Chosen over Box–Muller for the hot path: no sin/cos, only one
    /// ln + sqrt per pair, at a ~27% rejection rate (§Perf iteration 2).
    #[inline]
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Standard normal as f32 (the simulator's working precision).
    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fill a slice with standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32();
        }
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_gaussian(&mut v);
        v
    }

    /// Derive an independent stream (distinct seed path).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 400_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let skew = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.03, "skew={skew}");
    }

    #[test]
    fn gaussian_tail_mass() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let beyond2 = (0..n).filter(|_| r.gaussian().abs() > 2.0).count();
        let frac = beyond2 as f64 / n as f64;
        assert!((frac - 0.0455).abs() < 0.01, "frac={frac}"); // 2-sigma ≈ 4.55%
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut a = Rng::new(5);
        let mut b = a.split();
        let xa: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
        // crude correlation check on low bits
        let same = xa.iter().zip(&xb).filter(|(x, y)| (**x ^ **y) & 1 == 0).count();
        assert!((20..=44).contains(&same), "same={same}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
