//! Macro-bank sharding: one logical weight matrix across a grid of ≤32×32
//! 1T1R macros ("banks"), the scaling substrate for layers wider than one
//! physical array.
//!
//! The paper's in-memory computing unit is a single 32×32 macro
//! ([`crate::device::array::Macro`]); anything larger must be *tiled*.
//! [`BankedCrossbarLayer`] makes that tiling a first-class subsystem:
//!
//! * **Grid** — a `rows×cols` logical matrix becomes a
//!   `ceil(rows/32) × ceil(cols/32)` grid of banks (ragged edge tiles keep
//!   their true size).  Banks are stored row-major.
//! * **Per-bank RNG streams** — every bank owns an independent noise
//!   stream ([`crate::util::rng::Rng::split`]), so device read/write noise
//!   is uncorrelated across physical arrays, as in multi-array resistive
//!   memory systems (cf. arXiv:2404.09613's per-array noise).  The
//!   streams are layer state (one mutex per bank), not caller state: noisy
//!   draws depend only on the bank's own call history — deterministic per
//!   (seed, call sequence), like a physical array whose noise keeps
//!   evolving — independent of *which thread* evaluates the bank, which is
//!   what makes the bank-parallel path below deterministic in the noisy
//!   modes too (`Ideal`, the bitwise-parity serving mode, never draws).
//! * **Deterministic bank-parallel execution** — `forward`/`forward_batch`
//!   fork over the [`crate::exec`] pool under an [`exec::Ctx`]
//!   ([`BankedCrossbarLayer::set_exec`]).  Two decompositions, both
//!   bitwise equal to the serial path at any thread count:
//!   *banks* — one task per tile-column into disjoint per-column scratch,
//!   tile-rows folded in ascending (monolithic) order, then a bit-exact
//!   copy into the shared output; *lanes* — one task per contiguous chunk
//!   of batch lanes writing its own slice of the output (noise-free path
//!   only; per-bank draws are lane-ordered and must stay on one task).
//!   `Auto` picks per call from the grid, batch and pool size.
//! * **Per-tile-column TIA gains** — partial sums flow *down a column of
//!   tiles* in the current domain and meet one TIA bank at the bottom, so
//!   every tile-column gets its own gain from the existing
//!   [`super::mapper`].  When a layer is *programmed* this adapts each
//!   column block's gain to its own weight range (finer 64-level
//!   quantization than one global gain); when deployed
//!   [`BankedCrossbarLayer::from_conductances`] the gain is uniform and
//!   the banked layer is bitwise-identical to the monolithic
//!   [`CrossbarLayer`] oracle under `Ideal` evaluation.
//! * **Partial-sum aggregation** — `forward`/`forward_batch` run **one
//!   GEMM per bank per step**
//!   ([`crate::util::tensor::matmul_block_accum`]), accumulating straight
//!   into the shared output scratch.  For a fixed output element the
//!   accumulation order over logical rows is ascending — identical to the
//!   monolithic fast path — which is what makes the bitwise parity hold.
//! * **Tile-major `ReadPerCell`** — the exact device walk reads each cell
//!   *once per call* from the bank's stream and applies it to every lane
//!   (the B-lane burst is faster than the read-noise bandwidth, so the
//!   fluctuation is frozen within a call), amortizing cell reads over the
//!   batch instead of re-walking the array per lane.
//! * **Per-bank stats** — write-verify programming aggregates
//!   [`ProgramStats`] per bank ([`BankStat`]), and every MVM sweep bumps a
//!   per-bank read counter; [`BankedCrossbarLayer::report`] snapshots both
//!   for the serving metrics ([`crate::coordinator::metrics`]) and the
//!   energy model charges peripherals per macro
//!   ([`crate::energy::model`]).
//!
//! [`ScoreLayer`] is the dispatch layer the score networks build on: it
//! auto-selects banked execution whenever a matrix exceeds [`MACRO_DIM`]
//! and keeps the monolithic [`CrossbarLayer`] as the parity oracle
//! (forceable either way via [`Banking`] for the parity suite).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::layer::CrossbarLayer;
use super::mapper;
use super::noise::NoiseModel;
use super::G_FIXED_MS;
use crate::device::array::{DriftStats, Macro, ProgramStats, MACRO_DIM};
use crate::device::cell::{Cell, CellParams};
use crate::exec::{self, lane_chunk_lens, lane_plan, ParStrategy, Shards};
use crate::util::qkernel::{self, QuantBank};
use crate::util::rng::Rng;
use crate::util::simd::{self, KernelMode};
use crate::util::tensor::{matmul_block_accum, Mat};

/// Write-verify pulse budget per cell (same as the monolithic layer).
const PROGRAM_MAX_PULSES: usize = 500;

/// Per-bank deployment + runtime statistics.
#[derive(Debug, Clone, Default)]
pub struct BankStat {
    /// Grid position (tile row, tile column).
    pub tile_row: usize,
    pub tile_col: usize,
    /// Physical tile size (≤ 32×32; edge tiles may be ragged).
    pub rows: usize,
    pub cols: usize,
    /// TIA gain of this bank's tile-column.
    pub gain: f32,
    /// Mean write-verify pulses per cell (0 for direct deployment).
    pub mean_pulses: f64,
    /// Cells that failed to verify within the pulse budget.
    pub failures: usize,
    /// Max |G − target| in mS after programming.
    pub max_error_ms: f32,
    /// MVM sweeps served (scalar forward = 1, batched forward = B lanes).
    pub reads: u64,
}

/// Bank topology + per-bank stats of one logical layer, as surfaced to the
/// service metrics.  `banks` is empty for a monolithic (oracle) layer.
#[derive(Debug, Clone, Default)]
pub struct BankReport {
    /// Layer index within the network.
    pub layer: usize,
    /// Logical matrix shape.
    pub rows: usize,
    pub cols: usize,
    /// Tile grid shape.
    pub tile_rows: usize,
    pub tile_cols: usize,
    /// Layer-level MVM sweep total — live on both substrates (the
    /// monolithic layer keeps its own counter), so the serving metrics
    /// never show a stalled-looking zero under traffic.
    pub reads: u64,
    /// Per-bank stats, row-major; empty = monolithic layer.
    pub banks: Vec<BankStat>,
}

impl BankReport {
    pub fn n_banks(&self) -> usize {
        self.tile_rows * self.tile_cols
    }

    pub fn is_banked(&self) -> bool {
        !self.banks.is_empty()
    }

    pub fn total_reads(&self) -> u64 {
        self.reads
    }

    pub fn total_failures(&self) -> usize {
        self.banks.iter().map(|b| b.failures).sum()
    }

    /// One-line summary for the metrics report.
    pub fn summary(&self) -> String {
        format!(
            "L{}:{}x{}{}(reads={})",
            self.layer,
            self.tile_rows,
            self.tile_cols,
            if self.is_banked() { "" } else { "*" },
            self.total_reads(),
        )
    }
}

/// Drift of one bank against its programmed baseline (health monitor).
#[derive(Debug, Clone, Default)]
pub struct BankDrift {
    pub tile_row: usize,
    pub tile_col: usize,
    pub drift: DriftStats,
}

/// Drift of one logical layer: the aggregate over its banks (or the
/// whole monolithic array), plus the per-bank breakdown when banked.
#[derive(Debug, Clone, Default)]
pub struct LayerDrift {
    /// Layer index within the network.
    pub layer: usize,
    pub drift: DriftStats,
    /// Per-bank drift, row-major; empty for a monolithic layer.
    pub banks: Vec<BankDrift>,
}

/// One bank: a ≤32×32 macro plus its placement and conductance cache.
#[derive(Debug)]
struct Bank {
    tile: Macro,
    /// Logical offsets of this tile's top-left cell.
    row0: usize,
    col0: usize,
    /// Flattened conductance cache of this tile (refreshed after
    /// programming / aging) — the `b` operand of the per-bank GEMM.
    g_local: Mat,
    /// Drift baseline: the conductances at the last (re)program.  The
    /// health monitor's estimator compares the live tile against this.
    g_target: Mat,
    /// Conductance-quantized (i8) view of this tile, present only under
    /// [`KernelMode::Quant`]; rebuilt with `g_local` so it can never go
    /// stale across aging / reprogramming.
    q_local: Option<QuantBank>,
    /// Programming summary (reads are tracked separately, lock-free).
    stat: BankStat,
}

/// A logical weight matrix sharded across a grid of macro banks.
///
/// See the module docs for the semantics; the key invariant is that under
/// uniform gains and `Ideal` evaluation this layer is bitwise-identical to
/// the monolithic [`CrossbarLayer`] built from the same conductances.
pub struct BankedCrossbarLayer {
    rows: usize,
    cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    /// Banks in row-major tile order; bank (ti, tj) covers logical rows
    /// [ti·32, …) × cols [tj·32, …).
    banks: Vec<Bank>,
    /// Per-tile-column TIA gains (len = tile_cols).
    col_gains: Vec<f32>,
    /// Flattened logical conductance view (diagnostics / effective
    /// weights; the hot path uses the per-bank caches).
    g_cache: Mat,
    read_noise_frac: f32,
    /// Per-bank noise streams (bank order), one mutex per bank so the
    /// `&self` compute path stays `Sync` and bank tasks running on
    /// different pool threads never contend — or share — a stream.
    streams: Vec<Mutex<Rng>>,
    /// Per-bank MVM sweep counters.
    reads: Vec<AtomicU64>,
    /// Parallel-execution context (strategy + pool handle).
    exec: exec::Ctx,
    /// MVM kernel lane (`F32` GEMM or conductance-quantized i8); the i8
    /// lane serves `Ideal` sweeps only and falls back to f32 otherwise.
    kernel: KernelMode,
}

/// Per-call execution plan for one forward sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Plan {
    Serial,
    /// One task per tile-column (all noise modes).
    Banks,
    /// N contiguous lane-chunk tasks (noise-free path only).
    Lanes(usize),
}

impl BankedCrossbarLayer {
    /// Map `weights` (n_in × n_out) onto the bank grid and program every
    /// tile with write-verify from its own stream.  Each tile-column gets
    /// its own TIA gain from the mapper.  Returns the layer plus the
    /// layer-level aggregate stats (per-bank summaries are retained in the
    /// banks and surfaced via [`Self::report`]).
    pub fn program(weights: &Mat, params: CellParams, tol_ms: f32,
                   rng: &mut Rng) -> (Self, ProgramStats) {
        let (rows, cols) = weights.shape();
        let tile_rows = rows.div_ceil(MACRO_DIM);
        let tile_cols = cols.div_ceil(MACRO_DIM);

        // per-tile-column mapping: one TIA bank per column of tiles
        let mut col_gains = Vec::with_capacity(tile_cols);
        let mut col_targets = Vec::with_capacity(tile_cols);
        for tj in 0..tile_cols {
            let c0 = tj * MACRO_DIM;
            let bc = (cols - c0).min(MACRO_DIM);
            let sub = Mat::from_fn(rows, bc, |r, c| weights.get(r, c0 + c));
            let gain = mapper::required_gain(&sub);
            col_targets
                .push(mapper::quantize(&mapper::weight_to_conductance(&sub, gain)));
            col_gains.push(gain);
        }

        let n_banks = tile_rows * tile_cols;
        let mut banks = Vec::with_capacity(n_banks);
        let mut streams = Vec::with_capacity(n_banks);
        let mut agg = ProgramStats::default();
        for ti in 0..tile_rows {
            for tj in 0..tile_cols {
                let r0 = ti * MACRO_DIM;
                let c0 = tj * MACRO_DIM;
                let br = (rows - r0).min(MACRO_DIM);
                let bc = (cols - c0).min(MACRO_DIM);
                let mut stream = rng.split(); // per-bank RNG stream
                let mut tile = Macro::with_params(br, bc, params.clone());
                let targets =
                    Mat::from_fn(br, bc, |r, c| col_targets[tj].get(r0 + r, c));
                let st = tile.program(&targets, tol_ms, PROGRAM_MAX_PULSES,
                                      &mut stream);
                let stat = BankStat {
                    tile_row: ti,
                    tile_col: tj,
                    rows: br,
                    cols: bc,
                    gain: col_gains[tj],
                    mean_pulses: st.mean_pulses(),
                    failures: st.failures,
                    max_error_ms: st.max_error_ms(),
                    reads: 0,
                };
                agg.failures += st.failures;
                agg.pulses.extend(st.pulses);
                agg.abs_errors_ms.extend(st.abs_errors_ms);
                let g_target = tile.conductances();
                banks.push(Bank {
                    tile,
                    row0: r0,
                    col0: c0,
                    g_local: Mat::zeros(br, bc),
                    g_target,
                    q_local: None,
                    stat,
                });
                streams.push(stream);
            }
        }
        let read_noise_frac = params.read_noise_frac;
        let mut layer = BankedCrossbarLayer {
            rows,
            cols,
            tile_rows,
            tile_cols,
            banks,
            col_gains,
            g_cache: Mat::zeros(rows, cols),
            read_noise_frac,
            streams: streams.into_iter().map(Mutex::new).collect(),
            reads: (0..n_banks).map(|_| AtomicU64::new(0)).collect(),
            exec: exec::Ctx::default(),
            kernel: KernelMode::F32,
        };
        layer.refresh_cache();
        (layer, agg)
    }

    /// Deploy *exact* conductances onto the bank grid with one uniform
    /// gain — the configuration that is bitwise-identical to the
    /// monolithic oracle under `Ideal` evaluation.  `stream_seed` seeds
    /// the per-bank noise streams (deterministic per seed).
    pub fn from_conductances(g: &Mat, gain: f32, params: CellParams,
                             stream_seed: u64) -> Self {
        let (rows, cols) = g.shape();
        let tile_rows = rows.div_ceil(MACRO_DIM);
        let tile_cols = cols.div_ceil(MACRO_DIM);
        let n_banks = tile_rows * tile_cols;
        let mut base = Rng::new(stream_seed ^ 0xBA2C_51DE_CAFE_F00D);
        let mut banks = Vec::with_capacity(n_banks);
        let mut streams = Vec::with_capacity(n_banks);
        for ti in 0..tile_rows {
            for tj in 0..tile_cols {
                let r0 = ti * MACRO_DIM;
                let c0 = tj * MACRO_DIM;
                let br = (rows - r0).min(MACRO_DIM);
                let bc = (cols - c0).min(MACRO_DIM);
                let mut tile = Macro::with_params(br, bc, params.clone());
                for r in 0..br {
                    for c in 0..bc {
                        // direct state injection (deployment shortcut,
                        // equivalent to a zero-tolerance verify)
                        *tile.cell_mut(r, c) =
                            Cell::new(g.get(r0 + r, c0 + c), params.clone());
                    }
                }
                let g_target = tile.conductances();
                banks.push(Bank {
                    tile,
                    row0: r0,
                    col0: c0,
                    g_local: Mat::zeros(br, bc),
                    g_target,
                    q_local: None,
                    stat: BankStat {
                        tile_row: ti,
                        tile_col: tj,
                        rows: br,
                        cols: bc,
                        gain,
                        ..BankStat::default()
                    },
                });
                streams.push(base.split());
            }
        }
        let read_noise_frac = params.read_noise_frac;
        let mut layer = BankedCrossbarLayer {
            rows,
            cols,
            tile_rows,
            tile_cols,
            banks,
            col_gains: vec![gain; tile_cols],
            g_cache: Mat::zeros(rows, cols),
            read_noise_frac,
            streams: streams.into_iter().map(Mutex::new).collect(),
            reads: (0..n_banks).map(|_| AtomicU64::new(0)).collect(),
            exec: exec::Ctx::default(),
            kernel: KernelMode::F32,
        };
        layer.refresh_cache();
        layer
    }

    /// Set the execution context (parallel strategy + pool handle) the
    /// forward paths run under.  Any context yields bitwise-identical
    /// outputs — only wall time changes.
    pub fn set_exec(&mut self, exec: exec::Ctx) {
        self.exec = exec;
    }

    /// Select the MVM kernel lane.  [`KernelMode::Quant`] builds every
    /// bank's i8 conductance view immediately (and keeps it fresh through
    /// [`Self::refresh_cache`]); [`KernelMode::F32`] drops the views.
    pub fn set_kernel(&mut self, kernel: KernelMode) {
        self.kernel = kernel;
        for bank in &mut self.banks {
            bank.q_local = match kernel {
                KernelMode::Quant => {
                    Some(QuantBank::from_conductances(&bank.g_local))
                }
                KernelMode::F32 => None,
            };
        }
    }

    /// Active MVM kernel lane.
    pub fn kernel(&self) -> KernelMode {
        self.kernel
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Tile grid shape (tile_rows, tile_cols).
    pub fn grid(&self) -> (usize, usize) {
        (self.tile_rows, self.tile_cols)
    }

    pub fn n_banks(&self) -> usize {
        self.banks.len()
    }

    /// Total programmed cells (energy model input).
    pub fn n_cells(&self) -> usize {
        self.rows * self.cols
    }

    /// Per-tile-column TIA gains.
    pub fn col_gains(&self) -> &[f32] {
        &self.col_gains
    }

    /// Rebuild the per-bank and flattened conductance caches (and, under
    /// [`KernelMode::Quant`], each bank's i8 view — the quantized lane can
    /// never serve stale conductances after aging or reprogramming).
    pub fn refresh_cache(&mut self) {
        for bank in &mut self.banks {
            let (br, bc) = (bank.tile.rows(), bank.tile.cols());
            for r in 0..br {
                for c in 0..bc {
                    let gv = bank.tile.cell(r, c).conductance();
                    bank.g_local.set(r, c, gv);
                    self.g_cache.set(bank.row0 + r, bank.col0 + c, gv);
                }
            }
            if self.kernel == KernelMode::Quant {
                bank.q_local = Some(QuantBank::from_conductances(&bank.g_local));
            }
        }
    }

    /// Effective realized weight matrix: per-tile-column
    /// `gain_tj · (G − G_FIXED)`.
    pub fn effective_weights(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| {
            self.col_gains[c / MACRO_DIM] * (self.g_cache.get(r, c) - G_FIXED_MS)
        })
    }

    /// Analog forward for one lane; see [`Self::forward_batch`].  Device
    /// noise comes from the per-bank streams, so the caller `rng` is
    /// untouched (kept for signature parity with [`CrossbarLayer`]).
    pub fn forward(&self, v_in: &[f32], out: &mut [f32], noise: NoiseModel,
                   rng: &mut Rng) {
        self.forward_batch(v_in, out, 1, noise, rng);
    }

    /// Batched analog forward: `v_in` holds `batch` lane-contiguous input
    /// rows, `out` receives `batch` output rows.  One GEMM per bank per
    /// step (`Ideal`), a fused per-bank mean+variance sweep with one
    /// column Gaussian per (bank, lane) from the bank's own stream
    /// (`ReadFast`), or a tile-major exact device walk reading each cell
    /// once per call (`ReadPerCell`).  All modes accumulate into the
    /// shared output scratch and finish with the per-lane shared-negative-
    /// weight + per-tile-column TIA epilogue.
    pub fn forward_batch(&self, v_in: &[f32], out: &mut [f32], batch: usize,
                         noise: NoiseModel, _rng: &mut Rng) {
        assert_eq!(v_in.len(), batch * self.rows);
        assert_eq!(out.len(), batch * self.cols);
        // conductance-quantized lane: DAC-quantized inputs against the
        // per-bank i8 level views, i32 partial sums folded across the tile
        // column (integer adds — exact, so order and chunking can never
        // change a bit), TIA epilogue folded into the dequant.  Noisy
        // modes need per-cell float conductances and stay on f32.
        if noise == NoiseModel::Ideal && self.kernel == KernelMode::Quant {
            self.forward_quant_batch(v_in, out, batch);
            for ctr in &self.reads {
                ctr.fetch_add(batch as u64, Ordering::Relaxed);
            }
            return;
        }
        out.fill(0.0);
        match self.plan(batch, noise) {
            Plan::Serial => {
                for tj in 0..self.tile_cols {
                    self.accumulate_column(tj, v_in, out, self.cols,
                                           tj * MACRO_DIM, batch, noise);
                }
            }
            Plan::Banks => self.run_bank_parallel(v_in, out, batch, noise),
            Plan::Lanes(nt) => self.run_lane_parallel(v_in, out, batch, nt),
        }
        for ctr in &self.reads {
            ctr.fetch_add(batch as u64, Ordering::Relaxed);
        }
        // per-lane epilogue: the single summing amplifier per macro
        // computes G_FIXED·Σv once per lane; each tile-column's TIA bank
        // applies its own gain.  Same float-op order as the monolithic
        // epilogue, so uniform gains stay bitwise equal.
        for (vrow, orow) in v_in
            .chunks_exact(self.rows)
            .zip(out.chunks_exact_mut(self.cols))
        {
            let v_sum: f32 = vrow.iter().sum();
            let neg = G_FIXED_MS * v_sum;
            for (chunk, &gain) in
                orow.chunks_mut(MACRO_DIM).zip(self.col_gains.iter())
            {
                for o in chunk.iter_mut() {
                    *o = gain * (*o - neg);
                }
            }
        }
    }

    /// Quantized `Ideal` sweep: lane-chunk parallel when the exec context
    /// allows (integer accumulation is exact, so any chunking is bitwise
    /// identical to serial), serial otherwise.
    fn forward_quant_batch(&self, v_in: &[f32], out: &mut [f32], batch: usize) {
        let nt = self.exec.lane_tasks(batch, batch * self.rows * self.cols);
        if nt > 1 {
            let (chunk, nt) = lane_plan(batch, nt);
            let lens = lane_chunk_lens(batch, self.cols, chunk, nt);
            let shards = Shards::new(out, lens);
            self.exec.run(nt, &|i| {
                let oc = shards.take(i);
                let lanes = oc.len() / self.cols;
                let lane0 = i * chunk;
                let vin = &v_in[lane0 * self.rows..(lane0 + lanes) * self.rows];
                self.quant_lanes(vin, oc, lanes);
            });
        } else {
            self.quant_lanes(v_in, out, batch);
        }
    }

    /// Quantized sweep over `lanes` contiguous lanes: quantize each input
    /// row to DAC codes **once**, fold every bank of a tile column into a
    /// shared i32 accumulator (ascending tile-row order — irrelevant for
    /// exactness, kept for symmetry with the f32 path), then dequantize
    /// with that tile column's TIA gain.  The shared-negative-weight term
    /// rides the dequant epilogue via the lane's total code sum.
    fn quant_lanes(&self, v_in: &[f32], out: &mut [f32], lanes: usize) {
        let backend = simd::active();
        let mut q = vec![0i8; self.rows];
        let mut acc = [0i32; MACRO_DIM];
        debug_assert_eq!(v_in.len(), lanes * self.rows);
        for (vrow, orow) in v_in
            .chunks_exact(self.rows)
            .zip(out.chunks_exact_mut(self.cols))
        {
            let sumq = qkernel::quantize_inputs(vrow, &mut q);
            for tj in 0..self.tile_cols {
                let bc = self.col_width(tj);
                let c0 = tj * MACRO_DIM;
                acc[..bc].fill(0);
                for ti in 0..self.tile_rows {
                    let bank = &self.banks[ti * self.tile_cols + tj];
                    let qb = bank
                        .q_local
                        .as_ref()
                        .expect("quant kernel selected without i8 cache");
                    qb.accum(&q[bank.row0..bank.row0 + qb.k()], &mut acc[..bc],
                             backend);
                }
                qkernel::dequant_into(&acc[..bc], sumq, self.col_gains[tj],
                                      &mut orow[c0..c0 + bc]);
            }
        }
    }

    /// Pick the execution plan for one forward sweep.  Every plan yields
    /// bitwise-identical output; the choice only affects wall time.
    fn plan(&self, batch: usize, noise: NoiseModel) -> Plan {
        let threads = self.exec.threads();
        if threads <= 1 {
            return Plan::Serial;
        }
        // lane chunking re-orders nothing in the noise-free path, but noisy
        // modes draw per (bank, lane) in lane order from the bank streams —
        // splitting lanes across tasks would split those sequences, so the
        // noisy modes stay on the bank (tile-column) axis
        let lanes_ok = noise == NoiseModel::Ideal && batch >= 2;
        let banks_ok = self.tile_cols >= 2;
        match self.exec.strategy {
            ParStrategy::Serial => Plan::Serial,
            ParStrategy::Lanes if lanes_ok => Plan::Lanes(threads.min(batch)),
            ParStrategy::Lanes | ParStrategy::Banks if banks_ok => Plan::Banks,
            ParStrategy::Lanes | ParStrategy::Banks => Plan::Serial,
            ParStrategy::Auto => {
                if self.rows * self.cols * batch < exec::MIN_PAR_WORK {
                    Plan::Serial
                } else if lanes_ok && batch >= 2 * threads {
                    Plan::Lanes(threads)
                } else if banks_ok {
                    Plan::Banks
                } else if lanes_ok {
                    Plan::Lanes(threads.min(batch))
                } else {
                    Plan::Serial
                }
            }
        }
    }

    /// Accumulate one tile-column's partial sums into `dst`, whose rows
    /// are `dst_stride` apart with the column block starting at `dst_off`
    /// (the shared output for the serial/lane paths, a private scratch
    /// block for the bank-parallel path).
    ///
    /// Banks fold in **ascending tile-row order**, so for every output
    /// element the accumulation runs over logical rows 0..rows ascending —
    /// the monolithic [`CrossbarLayer`] order.  That single invariant is
    /// what makes serial, bank-parallel and lane-parallel execution
    /// bitwise interchangeable.  Noisy draws come from each bank's own
    /// stream ([`Self::fast_bank`]/[`Self::per_cell_bank`]), so the
    /// sequences are identical no matter which task runs the column.
    fn accumulate_column(&self, tj: usize, v_in: &[f32], dst: &mut [f32],
                         dst_stride: usize, dst_off: usize, batch: usize,
                         noise: NoiseModel) {
        for ti in 0..self.tile_rows {
            let idx = ti * self.tile_cols + tj;
            match noise {
                NoiseModel::Ideal => {
                    let bank = &self.banks[idx];
                    let (br, bc) = bank.g_local.shape();
                    matmul_block_accum(v_in, self.rows, bank.row0,
                                       bank.g_local.as_slice(), dst,
                                       dst_stride, dst_off, batch, br, bc);
                }
                NoiseModel::ReadFast => {
                    self.fast_bank(idx, v_in, dst, dst_stride, dst_off, batch)
                }
                NoiseModel::ReadPerCell => {
                    self.per_cell_bank(idx, v_in, dst, dst_stride, dst_off,
                                       batch)
                }
            }
        }
    }

    /// Fused mean+variance sweep for one bank: exact per-cell column
    /// moments `frac²·Σ_r (v·G)²` with one Gaussian per (lane, column)
    /// drawn from the bank's own stream — noise independent across
    /// physical arrays, variances adding to the monolithic column total.
    fn fast_bank(&self, idx: usize, v_in: &[f32], dst: &mut [f32],
                 dst_stride: usize, dst_off: usize, batch: usize) {
        let bank = &self.banks[idx];
        let frac = self.read_noise_frac;
        let mut stream = self.streams[idx].lock().unwrap();
        let (br, bc) = bank.g_local.shape();
        let gl = bank.g_local.as_slice();
        let mut var = [0.0f32; MACRO_DIM];
        for b in 0..batch {
            let vrow =
                &v_in[b * self.rows + bank.row0..b * self.rows + bank.row0 + br];
            let orow =
                &mut dst[b * dst_stride + dst_off..b * dst_stride + dst_off + bc];
            var[..bc].fill(0.0);
            for (r, &v) in vrow.iter().enumerate() {
                if v == 0.0 {
                    continue;
                }
                let grow = &gl[r * bc..(r + 1) * bc];
                for ((o, vc), &gc) in orow.iter_mut().zip(var.iter_mut()).zip(grow)
                {
                    let term = v * gc;
                    *o += term;
                    *vc += term * term;
                }
            }
            for (o, vc) in orow.iter_mut().zip(var[..bc].iter()) {
                *o += frac * vc.sqrt() * stream.gaussian_f32();
            }
        }
    }

    /// Tile-major exact device walk for one bank: each cell is read **once
    /// per call** from the bank's stream and the draw serves every lane
    /// (the burst is faster than the read-noise bandwidth), amortizing the
    /// walk over the batch.  With zero read noise this is bitwise equal to
    /// the `Ideal` path (same accumulation order).
    fn per_cell_bank(&self, idx: usize, v_in: &[f32], dst: &mut [f32],
                     dst_stride: usize, dst_off: usize, batch: usize) {
        let bank = &self.banks[idx];
        let mut stream = self.streams[idx].lock().unwrap();
        let (br, bc) = (bank.tile.rows(), bank.tile.cols());
        for r in 0..br {
            for c in 0..bc {
                let gv = bank.tile.cell(r, c).read(&mut stream);
                for b in 0..batch {
                    let v = v_in[b * self.rows + bank.row0 + r];
                    if v != 0.0 {
                        dst[b * dst_stride + dst_off + c] += v * gv;
                    }
                }
            }
        }
    }

    /// Physical width of tile-column `tj` (ragged at the right edge).
    #[inline]
    fn col_width(&self, tj: usize) -> usize {
        (self.cols - tj * MACRO_DIM).min(MACRO_DIM)
    }

    /// One pool task per tile-column, each into a disjoint contiguous
    /// scratch block, then a fixed-order **bit-exact copy** (never a float
    /// add) into the shared output.  Because a column task folds its
    /// tile-rows in the monolithic order, the copied bits equal what the
    /// serial path would have produced in place.
    fn run_bank_parallel(&self, v_in: &[f32], out: &mut [f32], batch: usize,
                         noise: NoiseModel) {
        // one scratch allocation per call (batch × cols); only this plan
        // pays it — the serial and lane paths write straight into `out`
        let mut scratch = vec![0.0f32; batch * self.cols];
        {
            let shards = Shards::new(
                &mut scratch,
                (0..self.tile_cols).map(|tj| batch * self.col_width(tj)),
            );
            self.exec.run(self.tile_cols, &|tj| {
                let block = shards.take(tj);
                self.accumulate_column(tj, v_in, block, self.col_width(tj), 0,
                                       batch, noise);
            });
        }
        let mut off = 0usize;
        for tj in 0..self.tile_cols {
            let bc = self.col_width(tj);
            let c0 = tj * MACRO_DIM;
            for b in 0..batch {
                out[b * self.cols + c0..b * self.cols + c0 + bc]
                    .copy_from_slice(&scratch[off + b * bc..off + (b + 1) * bc]);
            }
            off += batch * bc;
        }
    }

    /// Lane-chunk tasks (noise-free path only): each task owns a
    /// contiguous run of output lanes and folds every tile-column serially
    /// for them — each output element is produced whole by one task with
    /// the serial accumulation order, so no reduction exists at all.
    fn run_lane_parallel(&self, v_in: &[f32], out: &mut [f32], batch: usize,
                         n_tasks: usize) {
        let (chunk, n_tasks) = lane_plan(batch, n_tasks);
        let lens = lane_chunk_lens(batch, self.cols, chunk, n_tasks);
        let shards = Shards::new(out, lens);
        self.exec.run(n_tasks, &|i| {
            let oc = shards.take(i);
            let lanes = oc.len() / self.cols;
            let lane0 = i * chunk;
            let vin = &v_in[lane0 * self.rows..(lane0 + lanes) * self.rows];
            for tj in 0..self.tile_cols {
                self.accumulate_column(tj, vin, oc, self.cols, tj * MACRO_DIM,
                                       lanes, NoiseModel::Ideal);
            }
        });
    }

    /// Age all banks (each from its own stream), then refresh the caches.
    pub fn age(&mut self, dt_s: f64) {
        for (bank, stream) in self.banks.iter_mut().zip(self.streams.iter_mut())
        {
            bank.tile.age(dt_s, stream.get_mut().unwrap());
        }
        self.refresh_cache();
    }

    /// Per-bank drift since the last (re)program, plus the layer
    /// aggregate: live tile conductances vs the programmed baseline.
    pub fn drift_stats(&self, layer: usize) -> LayerDrift {
        let mut agg = DriftStats::default();
        let banks: Vec<BankDrift> = self
            .banks
            .iter()
            .map(|b| {
                let drift = b.tile.drift_from(&b.g_target);
                agg.merge(&drift);
                BankDrift {
                    tile_row: b.stat.tile_row,
                    tile_col: b.stat.tile_col,
                    drift,
                }
            })
            .collect();
        LayerDrift { layer, drift: agg, banks }
    }

    /// Re-run write-verify on every bank toward its programmed baseline
    /// (each bank pulses from its own stream — deterministic per layer
    /// seed), refresh the caches, and re-baseline the drift estimator at
    /// the achieved state.  Per-bank [`BankStat`] programming summaries
    /// are updated in place.
    pub fn reprogram(&mut self, tol_ms: f32) -> ProgramStats {
        let mut agg = ProgramStats::default();
        for (bank, stream) in self.banks.iter_mut().zip(self.streams.iter_mut())
        {
            let rng = stream.get_mut().unwrap();
            let st = bank
                .tile
                .program(&bank.g_target, tol_ms, PROGRAM_MAX_PULSES, rng);
            bank.stat.mean_pulses = st.mean_pulses();
            bank.stat.failures = st.failures;
            bank.stat.max_error_ms = st.max_error_ms();
            bank.g_target = bank.tile.conductances();
            agg.merge(st);
        }
        self.refresh_cache();
        agg
    }

    /// Snapshot topology + per-bank program/read stats.
    pub fn report(&self, layer: usize) -> BankReport {
        let banks: Vec<BankStat> = self
            .banks
            .iter()
            .zip(self.reads.iter())
            .map(|(b, reads)| {
                let mut s = b.stat.clone();
                s.reads = reads.load(Ordering::Relaxed);
                s
            })
            .collect();
        BankReport {
            layer,
            rows: self.rows,
            cols: self.cols,
            tile_rows: self.tile_rows,
            tile_cols: self.tile_cols,
            reads: banks.iter().map(|b| b.reads).sum(),
            banks,
        }
    }
}

/// Which substrate a score-net layer deploys on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Banking {
    /// Banked whenever the matrix exceeds one macro, monolithic otherwise.
    Auto,
    /// Always the monolithic [`CrossbarLayer`] (the parity oracle).
    ForceMonolithic,
    /// Always [`BankedCrossbarLayer`] (exercises 1×1 grids too).
    ForceBanked,
}

/// One score-net layer on either substrate.  The monolithic arm is the
/// parity oracle; the banked arm is the scaling substrate.
pub enum ScoreLayer {
    Mono(CrossbarLayer),
    Banked(BankedCrossbarLayer),
}

impl ScoreLayer {
    /// Does a matrix of this shape exceed one 32×32 macro?
    pub fn exceeds_macro(rows: usize, cols: usize) -> bool {
        rows > MACRO_DIM || cols > MACRO_DIM
    }

    fn pick(banking: Banking, rows: usize, cols: usize) -> bool {
        match banking {
            Banking::Auto => Self::exceeds_macro(rows, cols),
            Banking::ForceMonolithic => false,
            Banking::ForceBanked => true,
        }
    }

    /// Deploy exact conductances; `stream_seed` feeds the banked arm's
    /// per-bank noise streams.
    pub fn from_conductances(g: &Mat, gain: f32, params: CellParams,
                             stream_seed: u64, banking: Banking) -> Self {
        let (rows, cols) = g.shape();
        if Self::pick(banking, rows, cols) {
            ScoreLayer::Banked(BankedCrossbarLayer::from_conductances(
                g, gain, params, stream_seed,
            ))
        } else {
            ScoreLayer::Mono(CrossbarLayer::from_conductances(g, gain, params))
        }
    }

    /// Program weights with write-verify on the selected substrate.
    pub fn program(weights: &Mat, params: CellParams, tol_ms: f32,
                   rng: &mut Rng, banking: Banking) -> (Self, ProgramStats) {
        let (rows, cols) = weights.shape();
        if Self::pick(banking, rows, cols) {
            let (l, st) = BankedCrossbarLayer::program(weights, params, tol_ms, rng);
            (ScoreLayer::Banked(l), st)
        } else {
            let (l, st) = CrossbarLayer::program(weights, params, tol_ms, rng);
            (ScoreLayer::Mono(l), st)
        }
    }

    pub fn is_banked(&self) -> bool {
        matches!(self, ScoreLayer::Banked(_))
    }

    /// Set the execution context on either substrate (outputs are
    /// context-invariant bit for bit; only wall time changes).
    pub fn set_exec(&mut self, exec: crate::exec::Ctx) {
        match self {
            ScoreLayer::Mono(l) => l.set_exec(exec),
            ScoreLayer::Banked(l) => l.set_exec(exec),
        }
    }

    /// Select the MVM kernel lane on either substrate (the i8 lane serves
    /// `Ideal` sweeps; noisy modes fall back to f32 transparently).
    pub fn set_kernel(&mut self, kernel: KernelMode) {
        match self {
            ScoreLayer::Mono(l) => l.set_kernel(kernel),
            ScoreLayer::Banked(l) => l.set_kernel(kernel),
        }
    }

    /// Active MVM kernel lane.
    pub fn kernel(&self) -> KernelMode {
        match self {
            ScoreLayer::Mono(l) => l.kernel(),
            ScoreLayer::Banked(l) => l.kernel(),
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        match self {
            ScoreLayer::Mono(l) => l.shape(),
            ScoreLayer::Banked(l) => l.shape(),
        }
    }

    pub fn n_cells(&self) -> usize {
        match self {
            ScoreLayer::Mono(l) => l.n_cells(),
            ScoreLayer::Banked(l) => l.n_cells(),
        }
    }

    pub fn effective_weights(&self) -> Mat {
        match self {
            ScoreLayer::Mono(l) => l.effective_weights(),
            ScoreLayer::Banked(l) => l.effective_weights(),
        }
    }

    pub fn forward(&self, v_in: &[f32], out: &mut [f32], noise: NoiseModel,
                   rng: &mut Rng) {
        match self {
            ScoreLayer::Mono(l) => l.forward(v_in, out, noise, rng),
            ScoreLayer::Banked(l) => l.forward(v_in, out, noise, rng),
        }
    }

    pub fn forward_batch(&self, v_in: &[f32], out: &mut [f32], batch: usize,
                         noise: NoiseModel, rng: &mut Rng) {
        match self {
            ScoreLayer::Mono(l) => l.forward_batch(v_in, out, batch, noise, rng),
            ScoreLayer::Banked(l) => l.forward_batch(v_in, out, batch, noise, rng),
        }
    }

    /// Age the substrate; the monolithic arm draws from `rng`, the banked
    /// arm from its per-bank streams.
    pub fn age(&mut self, dt_s: f64, rng: &mut Rng) {
        match self {
            ScoreLayer::Mono(l) => l.age(dt_s, rng),
            ScoreLayer::Banked(l) => l.age(dt_s),
        }
    }

    /// Drift since the last (re)program on either substrate.  The banked
    /// arm includes the per-bank breakdown; the monolithic arm reports
    /// the array aggregate only.
    pub fn drift_report(&self, layer: usize) -> LayerDrift {
        match self {
            ScoreLayer::Mono(l) => LayerDrift {
                layer,
                drift: l.drift_stats(),
                banks: Vec::new(),
            },
            ScoreLayer::Banked(l) => l.drift_stats(layer),
        }
    }

    /// Write-verify recovery toward the programmed baseline.  The
    /// monolithic arm pulses from `rng`; the banked arm from its
    /// per-bank streams (deterministic per layer seed).
    pub fn reprogram(&mut self, tol_ms: f32, rng: &mut Rng) -> ProgramStats {
        match self {
            ScoreLayer::Mono(l) => l.reprogram(tol_ms, rng),
            ScoreLayer::Banked(l) => l.reprogram(tol_ms),
        }
    }

    /// Bank topology report; monolithic layers report their implicit grid
    /// and layer-level read count, with no per-bank stats (`banks` empty).
    pub fn report(&self, layer: usize) -> BankReport {
        match self {
            ScoreLayer::Mono(l) => {
                let (rows, cols) = l.shape();
                BankReport {
                    layer,
                    rows,
                    cols,
                    tile_rows: rows.div_ceil(MACRO_DIM),
                    tile_cols: cols.div_ceil(MACRO_DIM),
                    reads: l.reads(),
                    banks: Vec::new(),
                }
            }
            ScoreLayer::Banked(l) => l.report(layer),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn quiet() -> CellParams {
        CellParams { read_noise_frac: 0.0, ..CellParams::default() }
    }

    fn test_weights(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| 0.7 * rng.gaussian_f32())
    }

    #[test]
    fn grid_shapes_cover_ragged_edges() {
        let w = test_weights(40, 70, 1);
        let mut rng = Rng::new(2);
        let (layer, _) = BankedCrossbarLayer::program(&w, quiet(), 0.0005, &mut rng);
        assert_eq!(layer.grid(), (2, 3));
        assert_eq!(layer.n_banks(), 6);
        let rep = layer.report(0);
        assert_eq!(rep.banks.len(), 6);
        // ragged edge tiles keep their true size
        assert_eq!((rep.banks[5].rows, rep.banks[5].cols), (8, 6));
        assert_eq!((rep.banks[0].rows, rep.banks[0].cols), (32, 32));
    }

    #[test]
    fn per_column_gains_tighten_quantization() {
        // column block 0 has small weights, block 1 large: per-tile-column
        // gains must differ and the small block must quantize finer than a
        // single global gain would allow
        let mut rng = Rng::new(3);
        let w = Mat::from_fn(8, 40, |_, c| {
            let scale: f32 = if c < 32 { 0.05 } else { 2.0 };
            scale * rng.gaussian_f32()
        });
        let (layer, _) = BankedCrossbarLayer::program(&w, quiet(), 0.0002, &mut rng);
        let gains = layer.col_gains();
        assert_eq!(gains.len(), 2);
        assert!(gains[0] < 0.2 * gains[1],
                "small block must get a much smaller gain: {gains:?}");
        let we = layer.effective_weights();
        // small-block deployment error stays at the small block's scale
        let mut max_err = 0.0f32;
        for r in 0..8 {
            for c in 0..32 {
                max_err = max_err.max((we.get(r, c) - w.get(r, c)).abs());
            }
        }
        assert!(max_err < 0.03, "small-block error {max_err}");
    }

    #[test]
    fn programming_aggregates_per_bank_stats() {
        let w = test_weights(40, 40, 5);
        let mut rng = Rng::new(6);
        let (layer, agg) = BankedCrossbarLayer::program(&w, quiet(), 0.0012, &mut rng);
        assert_eq!(agg.pulses.len() + agg.failures, 40 * 40);
        let rep = layer.report(2);
        assert_eq!(rep.layer, 2);
        assert_eq!(rep.n_banks(), 4);
        for b in &rep.banks {
            assert!(b.mean_pulses > 0.0, "write-verify must pulse");
            assert!(b.max_error_ms <= agg.max_error_ms() + 1e-9);
        }
    }

    #[test]
    fn banked_matches_monolithic_bitwise_when_ideal() {
        for (rows, cols) in [(8, 8), (16, 70), (70, 16), (40, 70)] {
            let w = test_weights(rows, cols, 7 + rows as u64);
            let m = mapper::map_layer(&w);
            let mono =
                CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet());
            let banked = BankedCrossbarLayer::from_conductances(
                &m.g_target, m.gain, quiet(), 11,
            );
            let mut rng = Rng::new(8);
            let v: Vec<f32> = (0..rows).map(|i| (i as f32 * 0.37).sin()).collect();
            let mut a = vec![0.0f32; cols];
            let mut b = vec![0.0f32; cols];
            mono.forward(&v, &mut a, NoiseModel::Ideal, &mut rng);
            banked.forward(&v, &mut b, NoiseModel::Ideal, &mut rng);
            assert_eq!(a, b, "{rows}x{cols} scalar");
            let batch = 5;
            let vb: Vec<f32> =
                (0..batch * rows).map(|i| (i as f32 * 0.13).cos()).collect();
            let mut ab = vec![0.0f32; batch * cols];
            let mut bb = vec![0.0f32; batch * cols];
            mono.forward_batch(&vb, &mut ab, batch, NoiseModel::Ideal, &mut rng);
            banked.forward_batch(&vb, &mut bb, batch, NoiseModel::Ideal, &mut rng);
            assert_eq!(ab, bb, "{rows}x{cols} batched");
        }
    }

    #[test]
    fn quiet_read_per_cell_equals_ideal() {
        // zero read noise: the tile-major device walk must reproduce the
        // per-bank GEMM bit for bit (same accumulation order)
        let w = test_weights(40, 40, 9);
        let m = mapper::map_layer(&w);
        let layer = BankedCrossbarLayer::from_conductances(
            &m.g_target, m.gain, quiet(), 13,
        );
        let mut rng = Rng::new(10);
        let batch = 3;
        let vb: Vec<f32> = (0..batch * 40).map(|_| rng.gaussian_f32()).collect();
        let mut ideal = vec![0.0f32; batch * 40];
        let mut walk = vec![0.0f32; batch * 40];
        layer.forward_batch(&vb, &mut ideal, batch, NoiseModel::Ideal, &mut rng);
        layer.forward_batch(&vb, &mut walk, batch, NoiseModel::ReadPerCell,
                            &mut rng);
        assert_eq!(ideal, walk);
    }

    #[test]
    fn read_fast_bank_noise_matches_monolithic_moments() {
        let w = test_weights(40, 40, 11);
        let m = mapper::map_layer(&w);
        let params = CellParams::default(); // 1% read noise
        let mono = CrossbarLayer::from_conductances(&m.g_target, m.gain,
                                                    params.clone());
        let banked = BankedCrossbarLayer::from_conductances(
            &m.g_target, m.gain, params, 17,
        );
        let v: Vec<f32> = (0..40).map(|i| 0.3 + 0.01 * i as f32).collect();
        let n = 3000;
        let mut rng = Rng::new(12);
        let mut out = vec![0.0f32; 40];
        let mut col0_mono = Vec::with_capacity(n);
        let mut col0_bank = Vec::with_capacity(n);
        for _ in 0..n {
            mono.forward(&v, &mut out, NoiseModel::ReadFast, &mut rng);
            col0_mono.push(out[0]);
            banked.forward(&v, &mut out, NoiseModel::ReadFast, &mut rng);
            col0_bank.push(out[0]);
        }
        let (m1, s1) = (stats::mean(&col0_mono), stats::std(&col0_mono));
        let (m2, s2) = (stats::mean(&col0_bank), stats::std(&col0_bank));
        assert!((m1 - m2).abs() < 0.02 * m1.abs().max(0.1), "means {m1} vs {m2}");
        assert!((s1 - s2).abs() / s1.max(1e-9) < 0.15, "stds {s1} vs {s2}");
        assert!(s1 > 0.0);
    }

    #[test]
    fn bank_streams_decorrelate_bank_noise() {
        // two banks in one tile-row: with identical conductances and
        // inputs, their noisy column outputs must differ (independent
        // per-bank streams)
        let g = Mat::full(8, 64, 0.06);
        let layer = BankedCrossbarLayer::from_conductances(
            &g, 1.0, CellParams::default(), 19,
        );
        let mut rng = Rng::new(13);
        let v = vec![1.0f32; 8];
        let mut out = vec![0.0f32; 64];
        layer.forward(&v, &mut out, NoiseModel::ReadFast, &mut rng);
        assert_ne!(&out[..32], &out[32..],
                   "bank noise must be independent per array");
    }

    #[test]
    fn read_counters_track_sweeps() {
        let g = Mat::full(8, 40, 0.06);
        let layer = BankedCrossbarLayer::from_conductances(&g, 1.0, quiet(), 23);
        let mut rng = Rng::new(14);
        let v = vec![0.5f32; 8];
        let mut out = vec![0.0f32; 40];
        layer.forward(&v, &mut out, NoiseModel::Ideal, &mut rng);
        let vb = vec![0.5f32; 4 * 8];
        let mut outb = vec![0.0f32; 4 * 40];
        layer.forward_batch(&vb, &mut outb, 4, NoiseModel::Ideal, &mut rng);
        let rep = layer.report(0);
        assert_eq!(rep.banks.len(), 2);
        for b in &rep.banks {
            assert_eq!(b.reads, 5, "1 scalar + 4 batched lanes");
        }
        assert_eq!(rep.total_reads(), 10);
    }

    #[test]
    fn score_layer_auto_picks_substrate() {
        let small = test_weights(8, 8, 15);
        let wide = test_weights(8, 48, 16);
        let mut rng = Rng::new(17);
        let (l1, _) = ScoreLayer::program(&small, quiet(), 0.001, &mut rng,
                                          Banking::Auto);
        let (l2, _) = ScoreLayer::program(&wide, quiet(), 0.001, &mut rng,
                                          Banking::Auto);
        assert!(!l1.is_banked());
        assert!(l2.is_banked());
        assert_eq!(l2.report(1).n_banks(), 2);
        // mono report: implicit grid, no per-bank stats, live layer reads
        let r1 = l1.report(0);
        assert_eq!(r1.n_banks(), 1);
        assert!(!r1.is_banked());
        assert_eq!(r1.total_reads(), 0);
        let vin = [0.1f32; 8];
        let mut out = vec![0.0f32; 8];
        l1.forward(&vin, &mut out, NoiseModel::Ideal, &mut rng);
        let vinb = [0.1f32; 3 * 8];
        let mut outb = vec![0.0f32; 3 * 8];
        l1.forward_batch(&vinb, &mut outb, 3, NoiseModel::Ideal, &mut rng);
        assert_eq!(l1.report(0).total_reads(), 4,
                   "monolithic read counter must stay live");
    }

    #[test]
    fn forced_parallel_plans_stay_bitwise_equal() {
        use crate::exec::{Ctx, ParStrategy, Pool};
        use std::sync::Arc;
        // 40x70 → 2x3 ragged grid; compare serial vs forced Banks vs forced
        // Lanes on a 3-thread pool, per noise mode, with fresh layers so the
        // per-bank streams start from the same state
        let w = test_weights(40, 70, 77);
        let m = mapper::map_layer(&w);
        let pool = Arc::new(Pool::new(3));
        let build = |ctx: Ctx| {
            let mut l = BankedCrossbarLayer::from_conductances(
                &m.g_target, m.gain, CellParams::default(), 29,
            );
            l.set_exec(ctx);
            l
        };
        let batch = 5;
        let vb: Vec<f32> =
            (0..batch * 40).map(|i| (i as f32 * 0.19).sin()).collect();
        for noise in
            [NoiseModel::Ideal, NoiseModel::ReadFast, NoiseModel::ReadPerCell]
        {
            let mut rng = Rng::new(30);
            let mut want = vec![0.0f32; batch * 70];
            build(Ctx::serial()).forward_batch(&vb, &mut want, batch, noise,
                                               &mut rng);
            for strategy in [ParStrategy::Banks, ParStrategy::Lanes] {
                let layer = build(Ctx::with_pool(strategy, pool.clone()));
                let mut got = vec![0.0f32; batch * 70];
                layer.forward_batch(&vb, &mut got, batch, noise, &mut rng);
                assert_eq!(got, want, "{noise:?} under {strategy:?}");
            }
        }
    }

    #[test]
    fn per_bank_drift_tracks_age_and_reprogram_clears() {
        let w = test_weights(40, 40, 51);
        let mut rng = Rng::new(52);
        let (mut layer, _) =
            BankedCrossbarLayer::program(&w, quiet(), 0.0015, &mut rng);
        // fresh program: baseline == achieved state, drift exactly zero
        let d0 = layer.drift_stats(1);
        assert_eq!(d0.layer, 1);
        assert_eq!(d0.banks.len(), 4);
        assert_eq!(d0.drift.cells, 40 * 40);
        assert_eq!(d0.drift.sum_abs_ms, 0.0);
        // age from the per-bank streams: every bank shows positive drift
        layer.age(1e12);
        let d1 = layer.drift_stats(1);
        assert!(d1.drift.mean_abs_ms() > 1e-4, "{}", d1.drift.mean_abs_ms());
        for b in &d1.banks {
            assert!(b.drift.mean_abs_ms() > 0.0,
                    "bank r{}c{} must drift", b.tile_row, b.tile_col);
        }
        // recovery: write-verify back to baseline, estimator re-zeroed
        let ps = layer.reprogram(0.0015);
        assert_eq!(ps.pulses.len() + ps.failures, 40 * 40);
        assert_eq!(layer.drift_stats(1).drift.sum_abs_ms, 0.0);
    }

    #[test]
    fn banked_aging_is_deterministic_per_stream_seed() {
        // same seed → identical drift trajectories; different seed → not
        let g = test_weights(40, 40, 53).map(|v| 0.04 + 0.02 * v.abs().min(1.0));
        let mut a = BankedCrossbarLayer::from_conductances(&g, 1.0, quiet(), 99);
        let mut b = BankedCrossbarLayer::from_conductances(&g, 1.0, quiet(), 99);
        let mut c = BankedCrossbarLayer::from_conductances(&g, 1.0, quiet(), 100);
        a.age(1e9);
        b.age(1e9);
        c.age(1e9);
        assert_eq!(a.effective_weights().as_slice(),
                   b.effective_weights().as_slice(),
                   "same stream seed must reproduce drift exactly");
        assert_ne!(a.effective_weights().as_slice(),
                   c.effective_weights().as_slice());
    }

    #[test]
    fn score_layer_drift_report_covers_both_substrates() {
        let small = test_weights(8, 8, 54);
        let wide = test_weights(8, 48, 55);
        let mut rng = Rng::new(56);
        let (mut mono, _) =
            ScoreLayer::program(&small, quiet(), 0.001, &mut rng, Banking::Auto);
        let (mut banked, _) =
            ScoreLayer::program(&wide, quiet(), 0.001, &mut rng, Banking::Auto);
        assert!(mono.drift_report(0).banks.is_empty());
        assert_eq!(banked.drift_report(1).banks.len(), 2);
        mono.age(1e12, &mut rng);
        banked.age(1e12, &mut rng);
        assert!(mono.drift_report(0).drift.mean_abs_ms() > 0.0);
        assert!(banked.drift_report(1).drift.mean_abs_ms() > 0.0);
        let _ = mono.reprogram(0.0015, &mut rng);
        let _ = banked.reprogram(0.0015, &mut rng);
        assert_eq!(mono.drift_report(0).drift.sum_abs_ms, 0.0);
        assert_eq!(banked.drift_report(1).drift.sum_abs_ms, 0.0);
    }

    #[test]
    fn aging_preserves_window_and_refreshes_cache() {
        let w = test_weights(40, 40, 18);
        let mut rng = Rng::new(19);
        let (mut layer, _) =
            BankedCrossbarLayer::program(&w, quiet(), 0.001, &mut rng);
        let before = layer.effective_weights();
        layer.age(1e6);
        let after = layer.effective_weights();
        assert!(before.max_abs_diff(&after) > 0.0, "drift must show in cache");
        for tj in 0..2 {
            let gain = layer.col_gains()[tj];
            for r in 0..40 {
                for c in (tj * 32)..((tj * 32 + 32).min(40)) {
                    let wv = after.get(r, c) / gain + G_FIXED_MS;
                    assert!((0.02 - 1e-5..=0.10 + 1e-5).contains(&wv));
                }
            }
        }
    }

    #[test]
    fn banked_quant_matches_monolithic_quant_bitwise() {
        // integer partial sums fold across tile rows exactly, so the
        // banked i8 lane must reproduce the monolithic i8 lane bit for
        // bit on every grid shape — including ragged edges
        for (rows, cols) in [(8, 8), (16, 70), (70, 16), (40, 70), (96, 96)] {
            let w = test_weights(rows, cols, 61 + rows as u64);
            let m = mapper::map_layer(&w);
            let mut mono =
                CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet());
            mono.set_kernel(KernelMode::Quant);
            let mut banked = BankedCrossbarLayer::from_conductances(
                &m.g_target, m.gain, quiet(), 11,
            );
            banked.set_kernel(KernelMode::Quant);
            let batch = 5;
            let mut rng = Rng::new(62);
            let vb: Vec<f32> =
                (0..batch * rows).map(|i| (i as f32 * 0.23).sin()).collect();
            let mut a = vec![0.0f32; batch * cols];
            let mut b = vec![0.0f32; batch * cols];
            mono.forward_batch(&vb, &mut a, batch, NoiseModel::Ideal, &mut rng);
            banked.forward_batch(&vb, &mut b, batch, NoiseModel::Ideal,
                                 &mut rng);
            assert_eq!(a, b, "{rows}x{cols} quant banked vs mono");
        }
    }

    #[test]
    fn banked_quant_is_plan_invariant_and_noisy_modes_fall_back() {
        use crate::exec::{Ctx, Pool};
        use std::sync::Arc;
        let w = test_weights(40, 70, 63);
        let m = mapper::map_layer(&w);
        let pool = Arc::new(Pool::new(3));
        let build = |ctx: Ctx| {
            let mut l = BankedCrossbarLayer::from_conductances(
                &m.g_target, m.gain, quiet(), 31,
            );
            l.set_kernel(KernelMode::Quant);
            l.set_exec(ctx);
            l
        };
        let batch = 7;
        let vb: Vec<f32> =
            (0..batch * 40).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut rng = Rng::new(64);
        let mut want = vec![0.0f32; batch * 70];
        build(Ctx::serial()).forward_batch(&vb, &mut want, batch,
                                           NoiseModel::Ideal, &mut rng);
        for strategy in [ParStrategy::Lanes, ParStrategy::Banks, ParStrategy::Auto]
        {
            let layer = build(Ctx::with_pool(strategy, pool.clone()));
            let mut got = vec![0.0f32; batch * 70];
            layer.forward_batch(&vb, &mut got, batch, NoiseModel::Ideal,
                                &mut rng);
            assert_eq!(got, want, "quant lane under {strategy:?}");
        }
        // noisy modes ignore the i8 lane: quiet ReadPerCell is the f32
        // device walk, bitwise equal to the f32 Ideal path, not the
        // quantized one
        let layer = build(Ctx::serial());
        let mut f32_ideal = vec![0.0f32; batch * 70];
        let f32_layer = BankedCrossbarLayer::from_conductances(
            &m.g_target, m.gain, quiet(), 31,
        );
        f32_layer.forward_batch(&vb, &mut f32_ideal, batch, NoiseModel::Ideal,
                                &mut rng);
        let mut walk = vec![0.0f32; batch * 70];
        layer.forward_batch(&vb, &mut walk, batch, NoiseModel::ReadPerCell,
                            &mut rng);
        assert_eq!(walk, f32_ideal, "noisy fallback must stay on f32");
    }

    #[test]
    fn banked_quant_error_respects_per_column_gains() {
        // two tile columns at very different weight scales: the i8 lane
        // dequantizes each through its own TIA gain, so the error in the
        // small-scale block must track the *small* gain.  Bound: per
        // output element, input DAC rounding contributes
        // gain·(IN_SCALE/2)·Σ_r|g−G_FIXED| and conductance re-snap (tol
        // < half a level step, so codes round back to their targets)
        // contributes gain·tol·Σ_r|v̂|.
        let tol = 0.0005f32;
        assert!(tol < qkernel::level_step_ms() / 2.0);
        let mut rng = Rng::new(65);
        let w = Mat::from_fn(8, 40, |_, c| {
            let scale: f32 = if c < 32 { 0.05 } else { 2.0 };
            scale * rng.gaussian_f32()
        });
        let (mut layer, _) =
            BankedCrossbarLayer::program(&w, quiet(), tol, &mut rng);
        let batch = 4;
        let vb: Vec<f32> =
            (0..batch * 8).map(|i| 0.25 + 0.05 * (i % 13) as f32).collect();
        let mut f32_out = vec![0.0f32; batch * 40];
        layer.forward_batch(&vb, &mut f32_out, batch, NoiseModel::Ideal,
                            &mut rng);
        layer.set_kernel(KernelMode::Quant);
        let mut q_out = vec![0.0f32; batch * 40];
        layer.forward_batch(&vb, &mut q_out, batch, NoiseModel::Ideal, &mut rng);
        let mut q = vec![0i8; 8];
        for b in 0..batch {
            let vrow = &vb[b * 8..(b + 1) * 8];
            qkernel::quantize_inputs(vrow, &mut q);
            let vhat_abs: f32 =
                q.iter().map(|&c| (qkernel::IN_SCALE * c as f32).abs()).sum();
            for c in 0..40 {
                let gain = layer.col_gains()[c / MACRO_DIM];
                let g_abs: f32 = (0..8)
                    .map(|r| (layer.g_cache.get(r, c) - G_FIXED_MS).abs())
                    .sum();
                let bound = gain
                    * ((qkernel::IN_SCALE / 2.0) * g_abs + tol * vhat_abs)
                    * 1.05
                    + 1e-5;
                let err = (q_out[b * 40 + c] - f32_out[b * 40 + c]).abs();
                assert!(err <= bound,
                        "lane {b} col {c}: err {err} > bound {bound}");
            }
        }
        // per-column gains really differ, so the bound above is two-scale
        assert!(layer.col_gains()[0] < 0.2 * layer.col_gains()[1]);
    }

    #[test]
    fn banked_quant_cache_follows_age_and_reprogram() {
        let w = test_weights(40, 40, 67);
        let mut rng = Rng::new(68);
        let (mut layer, _) =
            BankedCrossbarLayer::program(&w, quiet(), 0.0005, &mut rng);
        layer.set_kernel(KernelMode::Quant);
        let v: Vec<f32> = (0..40).map(|i| 0.4 + 0.01 * i as f32).collect();
        let mut fresh = vec![0.0f32; 40];
        layer.forward(&v, &mut fresh, NoiseModel::Ideal, &mut rng);
        layer.age(1e12);
        let mut aged = vec![0.0f32; 40];
        layer.forward(&v, &mut aged, NoiseModel::Ideal, &mut rng);
        assert_ne!(fresh, aged, "i8 views must track drifted conductances");
        layer.reprogram(0.0005);
        let mut back = vec![0.0f32; 40];
        layer.forward(&v, &mut back, NoiseModel::Ideal, &mut rng);
        let worst = fresh
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst < 0.2, "reprogram must pull the i8 lane back: {worst}");
    }
}
