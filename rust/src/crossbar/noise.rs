//! Noise configuration for analog crossbar evaluation.
//!
//! Three fidelity levels trade simulation cost for physical detail; the
//! integration tests assert that the fast statistical model matches the
//! per-cell model's first two moments, so benches can use the fast path
//! without changing the science.

/// How conductance noise is injected during an MVM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NoiseModel {
    /// No noise: true programmed conductances (idealized reference).
    Ideal,
    /// Per-cell instantaneous read noise (exact device-level model):
    /// every cell's conductance is re-sampled on every query.
    ReadPerCell,
    /// Statistically equivalent column-level noise: one Gaussian per
    /// output column with variance `frac² · Σ_r (v_r · G_rc)²` — same mean
    /// and variance as [`NoiseModel::ReadPerCell`] at a fraction of the
    /// cost (one RNG draw per column instead of per cell).
    ReadFast,
}

impl NoiseModel {
    pub fn is_noisy(self) -> bool {
        !matches!(self, NoiseModel::Ideal)
    }
}
