//! One logical crossbar layer: a weight matrix mapped onto ≤32×32 macro
//! tiles, evaluated with the differential-pair + TIA semantics of the
//! paper's Fig. 2h:
//!
//! ```text
//! out_c = gain · Σ_r  v_r · (G_rc − G_FIXED)
//!       = gain · ( Σ_r v_r·G_rc  −  G_FIXED · Σ_r v_r )
//! ```
//!
//! The second term is the row-shared negative weight realized by a single
//! summing amplifier per macro (50% cell saving) — we compute it exactly
//! that way so the hardware structure is visible in the code.
//!
//! Large logical matrices are split into row/column tiles of at most
//! [`MACRO_DIM`]; partial sums across row tiles accumulate at the TIA
//! input node, as in a multi-macro bank.
//!
//! ## Scalar vs batched path
//!
//! [`CrossbarLayer::forward`] evaluates one input vector — the
//! hardware-faithful single-solve view.  [`CrossbarLayer::forward_batch`]
//! evaluates B input lanes against the same conductance cache in one
//! blocked GEMM (`Ideal`), or one fused mean+variance sweep per lane
//! (`ReadFast`, preserving the exact per-cell `frac²·Σ(v·G)²` column
//! moments), or one tile-major device sweep reading each cell once per
//! call (`ReadPerCell`), with the shared-negative-weight subtraction and
//! TIA gain applied per lane afterwards.  Choose `forward` for single
//! trajectories and device-physics studies; choose `forward_batch`
//! whenever the caller already holds B concurrent states — the serving
//! coordinator's coalesced batches route here so the model is amortized
//! over all lanes.  Under
//! `Ideal` the two paths are bitwise identical per lane; under `ReadFast`
//! they are statistically identical (same column moments, different RNG
//! draw order) — both asserted by the batched-parity suite.

use std::sync::atomic::{AtomicU64, Ordering};

use super::mapper::{map_layer, Mapping};
use super::noise::NoiseModel;
use super::G_FIXED_MS;
use crate::device::array::{DriftStats, Macro, ProgramStats, MACRO_DIM};
use crate::device::cell::{CellParams, G_HI_MS, G_LO_MS};
use crate::exec::{self, lane_chunk_lens, lane_plan, Shards};
use crate::util::qkernel::{self, QuantBank};
use crate::util::rng::Rng;
use crate::util::simd::{self, KernelMode};
use crate::util::tensor::{matmul_into, Mat};

/// A weight matrix deployed on macro tiles.
pub struct CrossbarLayer {
    rows: usize,
    cols: usize,
    gain: f32,
    /// Tiles in row-major tile order; tile (ti, tj) covers
    /// rows [ti*32, ...) × cols [tj*32, ...).
    tiles: Vec<Macro>,
    tile_rows: usize,
    tile_cols: usize,
    /// Cached programmed conductances (flattened logical matrix) for the
    /// fast path — refreshed after programming / aging.
    g_cache: Mat,
    /// Conductance baseline the drift estimator compares against: the
    /// state at the last (re)program.  Re-baselined by [`Self::reprogram`]
    /// so write-verify residuals live in `ProgramStats`, not the drift
    /// gauges.
    g_target: Mat,
    /// Read-noise fraction used by the fast statistical model.
    read_noise_frac: f32,
    /// MVM sweeps served (scalar forward = 1, batched forward = B lanes)
    /// — the monolithic counterpart of the banked per-bank counters, so
    /// the serving metrics stay live on either substrate.
    reads: AtomicU64,
    /// Parallel-execution context: the noise-free batched GEMM lane-chunks
    /// over the pool (the "too small to bank" scaling axis).
    exec: exec::Ctx,
    /// Numeric lane: f32 (default) or the conductance-quantized i8 path.
    /// Quant applies only under `NoiseModel::Ideal` — the noise models are
    /// conductance-domain f32 and keep their own paths.
    kernel: KernelMode,
    /// Level-index cache for the quant lane, rebuilt with `g_cache` on
    /// every `refresh_cache`.  `Some` iff `kernel == Quant`.
    q_cache: Option<QuantBank>,
}

impl CrossbarLayer {
    /// Map `weights` (n_in × n_out) onto macros and program them with
    /// write-verify.  Returns the layer and the aggregate programming stats
    /// (write-noise residuals included — this is the Fig. 5e "write noise"
    /// path).
    pub fn program(weights: &Mat, params: CellParams, tol_ms: f32,
                   rng: &mut Rng) -> (Self, ProgramStats) {
        let Mapping { g_target, gain } = map_layer(weights);
        let (rows, cols) = weights.shape();
        let tile_rows = rows.div_ceil(MACRO_DIM);
        let tile_cols = cols.div_ceil(MACRO_DIM);
        let mut tiles = Vec::with_capacity(tile_rows * tile_cols);
        let mut agg = ProgramStats::default();
        for ti in 0..tile_rows {
            for tj in 0..tile_cols {
                let r0 = ti * MACRO_DIM;
                let c0 = tj * MACRO_DIM;
                let tr = (rows - r0).min(MACRO_DIM);
                let tc = (cols - c0).min(MACRO_DIM);
                let mut m = Macro::with_params(tr, tc, params.clone());
                let sub = Mat::from_fn(tr, tc, |r, c| g_target.get(r0 + r, c0 + c));
                let st = m.program(&sub, tol_ms, 500, rng);
                agg.pulses.extend(st.pulses);
                agg.failures += st.failures;
                agg.abs_errors_ms.extend(st.abs_errors_ms);
                tiles.push(m);
            }
        }
        let read_noise_frac = params.read_noise_frac;
        let mut layer = CrossbarLayer {
            rows,
            cols,
            gain,
            tiles,
            tile_rows,
            tile_cols,
            g_cache: Mat::zeros(rows, cols),
            g_target: Mat::zeros(rows, cols),
            read_noise_frac,
            reads: AtomicU64::new(0),
            exec: exec::Ctx::default(),
            kernel: KernelMode::F32,
            q_cache: None,
        };
        layer.refresh_cache();
        layer.g_target = layer.g_cache.clone();
        (layer, agg)
    }

    /// Build a layer with *exact* conductances (no programming error) —
    /// used when the deployment should match the python artifacts bit-for-
    /// bit and for the noise-ablation baselines.
    pub fn from_conductances(g: &Mat, gain: f32, params: CellParams) -> Self {
        let (rows, cols) = g.shape();
        let tile_rows = rows.div_ceil(MACRO_DIM);
        let tile_cols = cols.div_ceil(MACRO_DIM);
        let mut tiles = Vec::new();
        for ti in 0..tile_rows {
            for tj in 0..tile_cols {
                let r0 = ti * MACRO_DIM;
                let c0 = tj * MACRO_DIM;
                let tr = (rows - r0).min(MACRO_DIM);
                let tc = (cols - c0).min(MACRO_DIM);
                let mut m = Macro::with_params(tr, tc, params.clone());
                for r in 0..tr {
                    for c in 0..tc {
                        // direct state injection (test/deployment shortcut,
                        // equivalent to a zero-tolerance verify)
                        *m.cell_mut(r, c) = crate::device::cell::Cell::new(
                            g.get(r0 + r, c0 + c),
                            params.clone(),
                        );
                    }
                }
                tiles.push(m);
            }
        }
        let read_noise_frac = params.read_noise_frac;
        let mut layer = CrossbarLayer {
            rows,
            cols,
            gain,
            tiles,
            tile_rows,
            tile_cols,
            g_cache: Mat::zeros(rows, cols),
            g_target: Mat::zeros(rows, cols),
            read_noise_frac,
            reads: AtomicU64::new(0),
            exec: exec::Ctx::default(),
            kernel: KernelMode::F32,
            q_cache: None,
        };
        layer.refresh_cache();
        layer.g_target = layer.g_cache.clone();
        layer
    }

    /// Set the execution context; outputs are context-invariant bit for
    /// bit (only the noise-free batched GEMM forks, over lane chunks).
    pub fn set_exec(&mut self, exec: exec::Ctx) {
        self.exec = exec;
    }

    /// Select the numeric lane ([`KernelMode::Quant`] builds the level
    /// cache immediately; switching back to f32 drops it).  Quant only
    /// changes `Ideal`-mode evaluation.
    pub fn set_kernel(&mut self, kernel: KernelMode) {
        self.kernel = kernel;
        self.q_cache = match kernel {
            KernelMode::Quant => Some(QuantBank::from_conductances(&self.g_cache)),
            KernelMode::F32 => None,
        };
    }

    pub fn kernel(&self) -> KernelMode {
        self.kernel
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn gain(&self) -> f32 {
        self.gain
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Total programmed cells (for the energy model).
    pub fn n_cells(&self) -> usize {
        self.rows * self.cols
    }

    /// MVM sweeps served so far (scalar = 1 each, batched = B lanes each).
    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    /// Rebuild the flattened conductance cache from the tiles (and the
    /// quant-lane level cache when that lane is active — aging and
    /// reprogramming route here, so the i8 view can never go stale).
    pub fn refresh_cache(&mut self) {
        for ti in 0..self.tile_rows {
            for tj in 0..self.tile_cols {
                let m = &self.tiles[ti * self.tile_cols + tj];
                let (r0, c0) = (ti * MACRO_DIM, tj * MACRO_DIM);
                for r in 0..m.rows() {
                    for c in 0..m.cols() {
                        self.g_cache.set(r0 + r, c0 + c, m.cell(r, c).conductance());
                    }
                }
            }
        }
        if self.kernel == KernelMode::Quant {
            self.q_cache = Some(QuantBank::from_conductances(&self.g_cache));
        }
    }

    /// Effective weight matrix currently realized (gain·(G − G_FIXED)).
    pub fn effective_weights(&self) -> Mat {
        self.g_cache.map(|g| self.gain * (g - G_FIXED_MS))
    }

    /// Analog forward: `v_in` (len n_in, already in voltage units) →
    /// `out` (len n_out).  The caller applies the protective input clamp;
    /// this method implements MVM + shared-negative-weight subtraction +
    /// TIA gain.
    pub fn forward(&self, v_in: &[f32], out: &mut [f32], noise: NoiseModel,
                   rng: &mut Rng) {
        assert_eq!(v_in.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        self.reads.fetch_add(1, Ordering::Relaxed);
        match noise {
            NoiseModel::ReadPerCell => self.forward_per_cell(v_in, out, rng),
            NoiseModel::Ideal => self.forward_fast(v_in, out, 0.0, rng),
            NoiseModel::ReadFast => {
                self.forward_fast(v_in, out, self.read_noise_frac, rng)
            }
        }
        // shared negative weight: one summing amplifier computes
        // G_FIXED · Σ v and subtracts it from every column current
        let v_sum: f32 = v_in.iter().sum();
        let neg = G_FIXED_MS * v_sum;
        for o in out.iter_mut() {
            *o = self.gain * (*o - neg);
        }
    }

    /// Batched analog forward: `v_in` holds `batch` input lanes of length
    /// `n_in` (row-major, lane-contiguous), `out` receives `batch` lanes of
    /// length `n_out`.  One GEMM against the conductance cache (`Ideal`) or
    /// one fused mean+variance sweep per lane (`ReadFast`), followed by the
    /// batched shared-negative-weight subtraction — the single summing
    /// amplifier per macro serves every lane, so its `G_FIXED·Σv` term is
    /// computed per lane from the same cached conductances.
    /// `ReadPerCell` runs the tile-major device sweep
    /// ([`Self::forward_per_cell_batch`]): cell reads amortize over the
    /// batch instead of re-walking the array per lane.
    pub fn forward_batch(&self, v_in: &[f32], out: &mut [f32], batch: usize,
                         noise: NoiseModel, rng: &mut Rng) {
        assert_eq!(v_in.len(), batch * self.rows);
        assert_eq!(out.len(), batch * self.cols);
        self.reads.fetch_add(batch as u64, Ordering::Relaxed);
        if matches!(noise, NoiseModel::Ideal) && self.kernel == KernelMode::Quant {
            if let Some(qb) = &self.q_cache {
                // the differential epilogue is folded into the dequant, so
                // the quant lane returns fully-formed outputs
                self.forward_quant_batch(qb, v_in, out, batch);
                return;
            }
        }
        match noise {
            // exact device path, tile-major: every cell is read once per
            // call and the draw serves all lanes (the B-lane burst is
            // faster than the read-noise bandwidth, so the fluctuation is
            // frozen within a call) — amortizes the device walk over the
            // batch instead of re-walking the array per lane
            NoiseModel::ReadPerCell => {
                self.forward_per_cell_batch(v_in, out, batch, rng)
            }
            NoiseModel::Ideal => {
                self.forward_fast_batch(v_in, out, batch, 0.0, rng)
            }
            NoiseModel::ReadFast => self.forward_fast_batch(
                v_in, out, batch, self.read_noise_frac, rng,
            ),
        }
        // batched shared negative weight + TIA gain, per lane (same float
        // ops as the scalar epilogue so Ideal stays bitwise equal)
        for (vrow, orow) in v_in
            .chunks_exact(self.rows)
            .zip(out.chunks_exact_mut(self.cols))
        {
            let v_sum: f32 = vrow.iter().sum();
            let neg = G_FIXED_MS * v_sum;
            for o in orow.iter_mut() {
                *o = self.gain * (*o - neg);
            }
        }
    }

    /// Conductance-quantized batched forward: per lane, quantize the
    /// inputs to DAC codes, run the i8×i8→i32 dot products against the
    /// level cache, and dequantize with the TIA gain.  Integer
    /// accumulation makes the result bitwise invariant to both the kernel
    /// backend and the lane-chunk plan, so the same deterministic
    /// fork-join as the f32 GEMM applies without further ceremony.
    fn forward_quant_batch(&self, qb: &QuantBank, v_in: &[f32], out: &mut [f32],
                           batch: usize) {
        let _t = crate::obs::phase(crate::obs::Phase::Gemm);
        let (k, n) = (self.rows, self.cols);
        let gain = self.gain;
        let nt = self.exec.lane_tasks(batch, batch * k * n);
        if nt > 1 {
            let (chunk, nt) = lane_plan(batch, nt);
            let shards = Shards::new(out, lane_chunk_lens(batch, n, chunk, nt));
            self.exec.run(nt, &|i| {
                let oc = shards.take(i);
                let lanes = oc.len() / n;
                let a = &v_in[i * chunk * k..(i * chunk + lanes) * k];
                quant_lanes(qb, a, oc, lanes, gain);
            });
        } else {
            quant_lanes(qb, v_in, out, batch, gain);
        }
    }

    /// Batched statistical path: one blocked GEMM when noise-free, or a
    /// fused per-lane mean+variance sweep reproducing the scalar
    /// [`Self::forward_fast`] moments (one column Gaussian per lane).
    fn forward_fast_batch(&self, v_in: &[f32], out: &mut [f32], batch: usize,
                          frac: f32, rng: &mut Rng) {
        out.fill(0.0);
        let g = self.g_cache.as_slice();
        let (k, n) = (self.rows, self.cols);
        if frac == 0.0 {
            // noise-free GEMM: lane-chunk over the pool when the context
            // says so.  Each chunk's per-element accumulation order is the
            // serial order (row blocks are independent), so any task count
            // is bitwise identical to the single matmul_into call.
            let nt = self.exec.lane_tasks(batch, batch * k * n);
            if nt > 1 {
                let (chunk, nt) = lane_plan(batch, nt);
                let shards = Shards::new(out, lane_chunk_lens(batch, n, chunk, nt));
                self.exec.run(nt, &|i| {
                    let oc = shards.take(i);
                    let lanes = oc.len() / n;
                    let a = &v_in[i * chunk * k..(i * chunk + lanes) * k];
                    matmul_into(a, g, oc, lanes, k, n);
                });
            } else {
                matmul_into(v_in, g, out, batch, k, n);
            }
            return;
        }
        let mut var_stack = [0.0f32; MACRO_DIM * 4];
        let mut var_heap = Vec::new();
        let var: &mut [f32] = if n <= var_stack.len() {
            &mut var_stack[..n]
        } else {
            var_heap.resize(n, 0.0);
            &mut var_heap
        };
        for (vrow, orow) in v_in.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
            var.fill(0.0);
            for (r, &v) in vrow.iter().enumerate() {
                if v == 0.0 {
                    continue;
                }
                let grow = &g[r * n..(r + 1) * n];
                for ((o, vc), &gc) in
                    orow.iter_mut().zip(var.iter_mut()).zip(grow)
                {
                    let term = v * gc;
                    *o += term;
                    *vc += term * term;
                }
            }
            for (o, vc) in orow.iter_mut().zip(var.iter()) {
                *o += frac * vc.sqrt() * rng.gaussian_f32();
            }
        }
    }

    /// Batched exact device path, tile-major: one noisy read per cell per
    /// call, applied to every lane.  Per-lane partial sums are buffered
    /// per tile and then added to the output, preserving the scalar
    /// [`Self::forward_per_cell`] per-element float-op order — so with
    /// zero read noise the two paths agree bitwise, and with noise the
    /// per-lane moments match (lanes share the per-call draw, which is the
    /// frozen-fluctuation burst model).
    fn forward_per_cell_batch(&self, v_in: &[f32], out: &mut [f32],
                              batch: usize, rng: &mut Rng) {
        out.fill(0.0);
        let mut tile_acc = vec![0.0f32; batch * MACRO_DIM];
        for ti in 0..self.tile_rows {
            let r0 = ti * MACRO_DIM;
            for tj in 0..self.tile_cols {
                let m = &self.tiles[ti * self.tile_cols + tj];
                let c0 = tj * MACRO_DIM;
                let (tr, tc) = (m.rows(), m.cols());
                tile_acc[..batch * tc].fill(0.0);
                for r in 0..tr {
                    for c in 0..tc {
                        let gv = m.cell(r, c).read(rng);
                        for b in 0..batch {
                            let v = v_in[b * self.rows + r0 + r];
                            if v != 0.0 {
                                tile_acc[b * tc + c] += v * gv;
                            }
                        }
                    }
                }
                for b in 0..batch {
                    let orow =
                        &mut out[b * self.cols + c0..b * self.cols + c0 + tc];
                    for (o, &a) in
                        orow.iter_mut().zip(&tile_acc[b * tc..(b + 1) * tc])
                    {
                        *o += a;
                    }
                }
            }
        }
    }

    /// Exact device-level path: every cell re-read with noise.
    fn forward_per_cell(&self, v_in: &[f32], out: &mut [f32], rng: &mut Rng) {
        out.fill(0.0);
        let mut tile_out = [0.0f32; MACRO_DIM];
        for ti in 0..self.tile_rows {
            let r0 = ti * MACRO_DIM;
            for tj in 0..self.tile_cols {
                let m = &self.tiles[ti * self.tile_cols + tj];
                let c0 = tj * MACRO_DIM;
                m.mvm(&v_in[r0..r0 + m.rows()], &mut tile_out[..m.cols()], rng);
                for c in 0..m.cols() {
                    out[c0 + c] += tile_out[c];
                }
            }
        }
    }

    /// Fast statistical path: ideal MVM against the cache plus one
    /// column-level Gaussian with the exact per-cell variance
    /// `frac² Σ_r (v_r G_rc)²` (see [`NoiseModel::ReadFast`]).
    ///
    /// Intentionally NOT implemented as `forward_fast_batch(.., 1, ..)`:
    /// the scalar and batched lanes stay independent implementations so
    /// the parity suite cross-checks one against the other.
    fn forward_fast(&self, v_in: &[f32], out: &mut [f32], frac: f32,
                    rng: &mut Rng) {
        out.fill(0.0);
        let g = self.g_cache.as_slice();
        let n = self.cols;
        if frac == 0.0 {
            for (r, &v) in v_in.iter().enumerate() {
                if v == 0.0 {
                    continue;
                }
                let grow = &g[r * n..(r + 1) * n];
                for (o, &gc) in out.iter_mut().zip(grow) {
                    *o += v * gc;
                }
            }
            return;
        }
        // accumulate mean and variance in one pass; iterator zips keep the
        // inner loop bounds-check-free so it auto-vectorizes (§Perf: this
        // rewrite cut ReadFast eval time vs the indexed version)
        let mut var_stack = [0.0f32; MACRO_DIM * 4];
        let mut var_heap;
        let var: &mut [f32] = if n <= var_stack.len() {
            &mut var_stack[..n]
        } else {
            var_heap = vec![0.0f32; n];
            &mut var_heap
        };
        for (r, &v) in v_in.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let grow = &g[r * n..(r + 1) * n];
            for ((o, vc), &gc) in out.iter_mut().zip(var.iter_mut()).zip(grow) {
                let term = v * gc;
                *o += term;
                *vc += term * term;
            }
        }
        for (o, vc) in out.iter_mut().zip(var.iter()) {
            *o += frac * vc.sqrt() * rng.gaussian_f32();
        }
    }

    /// Age all tiles (retention experiments), then refresh the cache.
    pub fn age(&mut self, dt_s: f64, rng: &mut Rng) {
        for t in &mut self.tiles {
            t.age(dt_s, rng);
        }
        self.refresh_cache();
    }

    /// Drift since the last (re)program: live conductances vs the
    /// programmed baseline, aggregated over all tiles.
    pub fn drift_stats(&self) -> DriftStats {
        let mut agg = DriftStats::default();
        for ti in 0..self.tile_rows {
            for tj in 0..self.tile_cols {
                let m = &self.tiles[ti * self.tile_cols + tj];
                let (r0, c0) = (ti * MACRO_DIM, tj * MACRO_DIM);
                let sub = Mat::from_fn(m.rows(), m.cols(), |r, c| {
                    self.g_target.get(r0 + r, c0 + c)
                });
                agg.merge(&m.drift_from(&sub));
            }
        }
        agg
    }

    /// Re-run write-verify toward the programmed baseline (drift
    /// recovery), refresh the cache, and re-baseline the drift estimator
    /// at the achieved state — so residual write error shows up in the
    /// returned [`ProgramStats`], not as permanent drift.
    pub fn reprogram(&mut self, tol_ms: f32, rng: &mut Rng) -> ProgramStats {
        let mut agg = ProgramStats::default();
        for ti in 0..self.tile_rows {
            for tj in 0..self.tile_cols {
                let m = &mut self.tiles[ti * self.tile_cols + tj];
                let (r0, c0) = (ti * MACRO_DIM, tj * MACRO_DIM);
                let sub = Mat::from_fn(m.rows(), m.cols(), |r, c| {
                    self.g_target
                        .get(r0 + r, c0 + c)
                        .clamp(G_LO_MS, G_HI_MS)
                });
                agg.merge(m.program(&sub, tol_ms, 500, rng));
            }
        }
        self.refresh_cache();
        self.g_target = self.g_cache.clone();
        agg
    }
}

/// Run the quant lane over `lanes` contiguous input/output rows.  Small
/// per-task scratch (one i8 row + one i32 accumulator) — amortized over
/// every lane of the chunk.
fn quant_lanes(qb: &QuantBank, v_in: &[f32], out: &mut [f32], lanes: usize, gain: f32) {
    let backend = simd::active();
    let (k, n) = (qb.k(), qb.n());
    debug_assert_eq!(v_in.len(), lanes * k);
    debug_assert_eq!(out.len(), lanes * n);
    let mut q = vec![0i8; k];
    let mut acc = vec![0i32; n];
    for (vrow, orow) in v_in.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        let sumq = qkernel::quantize_inputs(vrow, &mut q);
        acc.iter_mut().for_each(|a| *a = 0);
        qb.accum(&q, &mut acc, backend);
        qkernel::dequant_into(&acc, sumq, gain, orow);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn quiet_params() -> CellParams {
        CellParams { read_noise_frac: 0.0, ..CellParams::default() }
    }

    fn test_weights(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| 0.8 * rng.gaussian_f32())
    }

    #[test]
    fn ideal_forward_matches_weight_matmul() {
        let w = test_weights(14, 14, 1);
        let mut rng = Rng::new(2);
        let (layer, _) = CrossbarLayer::program(&w, quiet_params(), 0.0002, &mut rng);
        let v: Vec<f32> = (0..14).map(|i| 0.1 * i as f32 - 0.5).collect();
        let mut out = vec![0.0f32; 14];
        layer.forward(&v, &mut out, NoiseModel::Ideal, &mut rng);
        // compare against the *effective* (programmed) weights — exact
        let we = layer.effective_weights();
        for c in 0..14 {
            let want: f32 = (0..14).map(|r| v[r] * we.get(r, c)).sum();
            assert!((out[c] - want).abs() < 1e-4, "col {c}: {} vs {want}", out[c]);
        }
        // and close to the requested weights (within programming tolerance)
        assert!(w.max_abs_diff(&we) < 0.15, "{}", w.max_abs_diff(&we));
    }

    #[test]
    fn from_conductances_is_exact() {
        let w = test_weights(6, 9, 3);
        let m = super::super::mapper::map_layer(&w);
        let layer =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        let we = layer.effective_weights();
        let qstep = m.gain * (0.08) / 63.0;
        assert!(w.max_abs_diff(&we) <= 0.5 * qstep + 1e-6);
    }

    #[test]
    fn tiling_splits_large_matrices() {
        let w = test_weights(40, 70, 5);
        let mut rng = Rng::new(6);
        let (layer, _) = CrossbarLayer::program(&w, quiet_params(), 0.0005, &mut rng);
        assert_eq!(layer.n_tiles(), 2 * 3); // ceil(40/32) x ceil(70/32)
        let v: Vec<f32> = (0..40).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut out = vec![0.0f32; 70];
        layer.forward(&v, &mut out, NoiseModel::Ideal, &mut rng);
        let we = layer.effective_weights();
        for c in [0usize, 31, 32, 69] {
            let want: f32 = (0..40).map(|r| v[r] * we.get(r, c)).sum();
            assert!((out[c] - want).abs() < 1e-3, "col {c}");
        }
    }

    #[test]
    fn fast_noise_matches_per_cell_moments() {
        let w = test_weights(14, 14, 7);
        let params = CellParams::default(); // 1% read noise
        let mut rng = Rng::new(8);
        let (layer, _) = CrossbarLayer::program(&w, params, 0.0005, &mut rng);
        let v: Vec<f32> = (0..14).map(|i| 0.2 * (i as f32 - 7.0) / 7.0 + 0.3).collect();

        let n = 4000;
        let mut col0_per_cell = Vec::with_capacity(n);
        let mut col0_fast = Vec::with_capacity(n);
        let mut out = vec![0.0f32; 14];
        for _ in 0..n {
            layer.forward(&v, &mut out, NoiseModel::ReadPerCell, &mut rng);
            col0_per_cell.push(out[0]);
            layer.forward(&v, &mut out, NoiseModel::ReadFast, &mut rng);
            col0_fast.push(out[0]);
        }
        let (m1, s1) = (stats::mean(&col0_per_cell), stats::std(&col0_per_cell));
        let (m2, s2) = (stats::mean(&col0_fast), stats::std(&col0_fast));
        assert!((m1 - m2).abs() < 0.02 * m1.abs().max(0.1), "means {m1} vs {m2}");
        assert!((s1 - s2).abs() / s1.max(1e-9) < 0.15, "stds {s1} vs {s2}");
        assert!(s1 > 0.0);
    }

    #[test]
    fn forward_batch_matches_scalar_bitwise_when_ideal() {
        let w = test_weights(14, 14, 21);
        let m = super::super::mapper::map_layer(&w);
        let layer =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        let batch = 6;
        let mut rng = Rng::new(22);
        let v: Vec<f32> = (0..batch * 14).map(|_| rng.gaussian_f32()).collect();
        let mut batched = vec![0.0f32; batch * 14];
        layer.forward_batch(&v, &mut batched, batch, NoiseModel::Ideal, &mut rng);
        let mut scalar = vec![0.0f32; 14];
        for b in 0..batch {
            layer.forward(&v[b * 14..(b + 1) * 14], &mut scalar,
                          NoiseModel::Ideal, &mut rng);
            assert_eq!(&batched[b * 14..(b + 1) * 14], scalar.as_slice(),
                       "lane {b}");
        }
    }

    #[test]
    fn forward_batch_read_fast_matches_scalar_moments() {
        let w = test_weights(14, 14, 23);
        let params = CellParams::default(); // 1% read noise
        let mut rng = Rng::new(24);
        let (layer, _) = CrossbarLayer::program(&w, params, 0.0005, &mut rng);
        let v: Vec<f32> = (0..14).map(|i| 0.15 * (i as f32 - 7.0) / 7.0 + 0.2).collect();
        let batch = 8;
        let vb: Vec<f32> = (0..batch).flat_map(|_| v.iter().copied()).collect();

        let trials = 600;
        let mut col0_scalar = Vec::with_capacity(trials * batch);
        let mut col0_batch = Vec::with_capacity(trials * batch);
        let mut out = vec![0.0f32; 14];
        let mut outb = vec![0.0f32; batch * 14];
        for _ in 0..trials {
            for _ in 0..batch {
                layer.forward(&v, &mut out, NoiseModel::ReadFast, &mut rng);
                col0_scalar.push(out[0]);
            }
            layer.forward_batch(&vb, &mut outb, batch, NoiseModel::ReadFast,
                                &mut rng);
            for b in 0..batch {
                col0_batch.push(outb[b * 14]);
            }
        }
        let (m1, s1) = (stats::mean(&col0_scalar), stats::std(&col0_scalar));
        let (m2, s2) = (stats::mean(&col0_batch), stats::std(&col0_batch));
        assert!((m1 - m2).abs() < 0.02 * m1.abs().max(0.1), "means {m1} vs {m2}");
        assert!((s1 - s2).abs() / s1.max(1e-9) < 0.15, "stds {s1} vs {s2}");
        assert!(s1 > 0.0);
    }

    #[test]
    fn forward_batch_per_cell_tile_sweep_matches_scalar_when_quiet() {
        let w = test_weights(10, 8, 25);
        let mut rng = Rng::new(26);
        let (layer, _) = CrossbarLayer::program(&w, quiet_params(), 0.0005, &mut rng);
        let batch = 3;
        let v: Vec<f32> = (0..batch * 10).map(|_| rng.gaussian_f32()).collect();
        let mut batched = vec![0.0f32; batch * 8];
        // quiet params ⇒ both walks are deterministic, so the tile-major
        // batched sweep must equal the scalar per-lane walk exactly
        layer.forward_batch(&v, &mut batched, batch, NoiseModel::ReadPerCell,
                            &mut rng);
        let mut scalar = vec![0.0f32; 8];
        for b in 0..batch {
            layer.forward(&v[b * 10..(b + 1) * 10], &mut scalar,
                          NoiseModel::ReadPerCell, &mut rng);
            assert_eq!(&batched[b * 8..(b + 1) * 8], scalar.as_slice());
        }
    }

    #[test]
    fn lane_chunked_ideal_batch_matches_serial_bitwise() {
        use crate::exec::{Ctx, ParStrategy, Pool};
        use std::sync::Arc;
        let w = test_weights(14, 14, 31);
        let m = super::super::mapper::map_layer(&w);
        let mut serial =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        serial.set_exec(Ctx::serial());
        let mut par =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        par.set_exec(Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(4))));
        let mut rng = Rng::new(32);
        // batch 7 over 4 tasks exercises ragged lane chunks
        for batch in [2usize, 4, 7] {
            let v: Vec<f32> = (0..batch * 14).map(|_| rng.gaussian_f32()).collect();
            let mut a = vec![0.0f32; batch * 14];
            let mut b = vec![0.0f32; batch * 14];
            serial.forward_batch(&v, &mut a, batch, NoiseModel::Ideal, &mut rng);
            par.forward_batch(&v, &mut b, batch, NoiseModel::Ideal, &mut rng);
            assert_eq!(a, b, "batch {batch}");
        }
    }

    #[test]
    fn drift_estimator_tracks_age_and_reprogram_rebaselines() {
        let w = test_weights(20, 12, 41);
        let mut rng = Rng::new(42);
        let (mut layer, _) = CrossbarLayer::program(&w, quiet_params(), 0.0015, &mut rng);
        // freshly programmed: estimator sits exactly at zero
        let st0 = layer.drift_stats();
        assert_eq!(st0.cells, 20 * 12);
        assert_eq!(st0.sum_abs_ms, 0.0);
        // retention interval registers as positive drift
        layer.age(1e12, &mut rng);
        let st1 = layer.drift_stats();
        assert!(st1.mean_abs_ms() > 1e-4, "mean {}", st1.mean_abs_ms());
        // write-verify recovery returns residuals and zeroes the estimator
        let ps = layer.reprogram(0.0015, &mut rng);
        assert_eq!(ps.pulses.len() + ps.failures, 20 * 12);
        let st2 = layer.drift_stats();
        assert_eq!(st2.sum_abs_ms, 0.0, "reprogram must re-baseline");
        // and the realized weights moved back toward the original request
        assert!(w.max_abs_diff(&layer.effective_weights()) < 0.2);
    }

    #[test]
    fn from_conductances_starts_with_zero_drift() {
        let w = test_weights(6, 9, 43);
        let m = super::super::mapper::map_layer(&w);
        let layer =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        assert_eq!(layer.drift_stats().sum_abs_ms, 0.0);
    }

    #[test]
    fn negative_weight_subtraction_exact() {
        // all-G_FIXED conductances == zero weights: output must be 0 for any input
        let g = Mat::full(5, 4, G_FIXED_MS);
        let layer = CrossbarLayer::from_conductances(&g, 3.0, quiet_params());
        let mut rng = Rng::new(9);
        let v = [0.7f32, -1.0, 0.3, 2.0, -0.2];
        let mut out = vec![0.0f32; 4];
        layer.forward(&v, &mut out, NoiseModel::Ideal, &mut rng);
        for &o in &out {
            assert!(o.abs() < 1e-5, "{o}");
        }
    }

    #[test]
    fn linearity_property() {
        // forward(a·v1 + b·v2) == a·forward(v1) + b·forward(v2) (ideal mode)
        let w = test_weights(10, 8, 11);
        let m = super::super::mapper::map_layer(&w);
        let layer =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        crate::util::ptest::check_msg(
            "crossbar linearity",
            |rng: &mut Rng| {
                let v1 = rng.gaussian_vec(10);
                let v2 = rng.gaussian_vec(10);
                let a = rng.gaussian_f32();
                let b = rng.gaussian_f32();
                (v1, v2, a, b)
            },
            |(v1, v2, a, b)| {
                let mut rng = Rng::new(0);
                let mut o1 = vec![0.0f32; 8];
                let mut o2 = vec![0.0f32; 8];
                let mut o3 = vec![0.0f32; 8];
                let vc: Vec<f32> =
                    v1.iter().zip(v2).map(|(x, y)| a * x + b * y).collect();
                layer.forward(v1, &mut o1, NoiseModel::Ideal, &mut rng);
                layer.forward(v2, &mut o2, NoiseModel::Ideal, &mut rng);
                layer.forward(&vc, &mut o3, NoiseModel::Ideal, &mut rng);
                for c in 0..8 {
                    let want = a * o1[c] + b * o2[c];
                    if (o3[c] - want).abs() > 1e-3 * (1.0 + want.abs()) {
                        return Err(format!("col {c}: {} vs {want}", o3[c]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quant_lane_error_is_bounded_by_input_lsb() {
        // level-snapped targets ⇒ zero weight-quantization error, so the
        // only quant-vs-f32 deviation is the input DAC rounding, which has
        // the exact per-column bound  gain · (LSB/2) · Σ_r |g_rc − G_FIXED|
        let w = test_weights(14, 14, 51);
        let m = super::super::mapper::map_layer(&w);
        let f32_layer =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        let mut q_layer =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        q_layer.set_kernel(KernelMode::Quant);
        assert_eq!(q_layer.kernel(), KernelMode::Quant);
        let batch = 6;
        let mut rng = Rng::new(52);
        let v: Vec<f32> = (0..batch * 14).map(|_| rng.gaussian_f32()).collect();
        let mut want = vec![0.0f32; batch * 14];
        f32_layer.forward_batch(&v, &mut want, batch, NoiseModel::Ideal, &mut rng);
        let mut got = vec![0.0f32; batch * 14];
        q_layer.forward_batch(&v, &mut got, batch, NoiseModel::Ideal, &mut rng);
        let half_lsb = 0.5 * qkernel::IN_SCALE;
        for c in 0..14 {
            let bound: f32 = m.gain
                * half_lsb
                * (0..14).map(|r| (m.g_target.get(r, c) - G_FIXED_MS).abs()).sum::<f32>();
            for b in 0..batch {
                let (g, w) = (got[b * 14 + c], want[b * 14 + c]);
                assert!((g - w).abs() <= bound * 1.05 + 1e-4,
                        "lane {b} col {c}: {g} vs {w} (bound {bound})");
            }
        }
    }

    #[test]
    fn quant_lane_is_bitwise_chunk_invariant() {
        use crate::exec::{Ctx, ParStrategy, Pool};
        use std::sync::Arc;
        let w = test_weights(14, 14, 53);
        let m = super::super::mapper::map_layer(&w);
        let mut serial =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        serial.set_kernel(KernelMode::Quant);
        serial.set_exec(Ctx::serial());
        let mut par =
            CrossbarLayer::from_conductances(&m.g_target, m.gain, quiet_params());
        par.set_kernel(KernelMode::Quant);
        par.set_exec(Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(4))));
        let mut rng = Rng::new(54);
        for batch in [2usize, 4, 7] {
            let v: Vec<f32> = (0..batch * 14).map(|_| rng.gaussian_f32()).collect();
            let mut a = vec![0.0f32; batch * 14];
            let mut b = vec![0.0f32; batch * 14];
            serial.forward_batch(&v, &mut a, batch, NoiseModel::Ideal, &mut rng);
            par.forward_batch(&v, &mut b, batch, NoiseModel::Ideal, &mut rng);
            assert_eq!(a, b, "batch {batch}");
        }
    }

    #[test]
    fn quant_cache_follows_age_and_reprogram() {
        // after aging, the quant lane must see the drifted conductances
        // (refresh_cache rebuilds the level cache), not the programmed ones
        let w = test_weights(10, 8, 55);
        let mut rng = Rng::new(56);
        let (mut layer, _) = CrossbarLayer::program(&w, quiet_params(), 0.0005, &mut rng);
        layer.set_kernel(KernelMode::Quant);
        let v: Vec<f32> = (0..10).map(|i| 0.2 * (i as f32 - 5.0) / 5.0 + 0.1).collect();
        let mut fresh = vec![0.0f32; 8];
        layer.forward_batch(&v, &mut fresh, 1, NoiseModel::Ideal, &mut rng);
        layer.age(1e12, &mut rng);
        let mut aged = vec![0.0f32; 8];
        layer.forward_batch(&v, &mut aged, 1, NoiseModel::Ideal, &mut rng);
        assert_ne!(fresh, aged, "year-scale drift must move quant outputs");
        layer.reprogram(0.0005, &mut rng);
        let mut healed = vec![0.0f32; 8];
        layer.forward_batch(&v, &mut healed, 1, NoiseModel::Ideal, &mut rng);
        let worst = fresh.iter().zip(&healed).map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst < 0.2, "reprogram must pull quant outputs back: {worst}");
    }
}
