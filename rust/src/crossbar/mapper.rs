//! Weight ↔ conductance mapping (rust mirror of `python/compile/analog.py`).
//!
//! Contract shared with the python side and asserted in integration tests:
//!
//! ```text
//! W = tia_gain * (G_mem - G_FIXED),   G_mem ∈ [0.02, 0.10] mS
//! ```
//!
//! Each layer gets its own TIA gain — the smallest that fits the layer's
//! weights into the window, maximizing conductance-range usage and thus
//! minimizing 64-level quantization error.

use super::{G_CELL_HI_MS, G_CELL_LO_MS, G_FIXED_MS, N_LEVELS};
use crate::util::tensor::Mat;

/// Negative / positive weight headroom in conductance units (mS).
pub const W_NEG_MAX: f32 = G_FIXED_MS - G_CELL_LO_MS; // 0.03
pub const W_POS_MAX: f32 = G_CELL_HI_MS - G_FIXED_MS; // 0.05

/// A complete layer mapping: target conductances + the gain that inverts it.
#[derive(Debug, Clone)]
pub struct Mapping {
    pub g_target: Mat,
    pub gain: f32,
}

/// Smallest TIA gain that fits every weight of `w` into the window.
pub fn required_gain(w: &Mat) -> f32 {
    let mut g = 1e-6f32;
    for &x in w.as_slice() {
        if x > 0.0 {
            g = g.max(x / W_POS_MAX);
        } else {
            g = g.max(-x / W_NEG_MAX);
        }
    }
    g
}

/// W → G_mem (mS), clipped into the programmable window.
pub fn weight_to_conductance(w: &Mat, gain: f32) -> Mat {
    w.map(|x| (x / gain + G_FIXED_MS).clamp(G_CELL_LO_MS, G_CELL_HI_MS))
}

/// Snap conductances to the macro's 64 linear states (Fig. 2d).
pub fn quantize(g: &Mat) -> Mat {
    let step = (G_CELL_HI_MS - G_CELL_LO_MS) / (N_LEVELS - 1) as f32;
    g.map(|x| G_CELL_LO_MS + ((x - G_CELL_LO_MS) / step).round() * step)
}

/// Inverse mapping (used to quantify deployment error).
pub fn conductance_to_weight(g: &Mat, gain: f32) -> Mat {
    g.map(|x| gain * (x - G_FIXED_MS))
}

/// Full mapping of one weight matrix: per-layer gain + quantized targets.
pub fn map_layer(w: &Mat) -> Mapping {
    let gain = required_gain(w);
    Mapping { g_target: quantize(&weight_to_conductance(w, gain)), gain }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;
    use crate::util::rng::Rng;

    #[test]
    fn gain_fits_window() {
        ptest::check(
            "mapped conductances in window",
            |rng: &mut Rng| {
                let r = 1 + rng.below(20);
                let c = 1 + rng.below(20);
                let scale = rng.uniform_range(0.01, 10.0) as f32;
                Mat::from_fn(r, c, |_, _| scale * rng.gaussian_f32())
            },
            |w| {
                let g = weight_to_conductance(w, required_gain(w));
                g.as_slice()
                    .iter()
                    .all(|&x| (G_CELL_LO_MS - 1e-6..=G_CELL_HI_MS + 1e-6).contains(&x))
            },
        );
    }

    #[test]
    fn roundtrip_within_half_quant_step() {
        ptest::check_msg(
            "quantized roundtrip error bounded",
            |rng: &mut Rng| {
                let scale = rng.uniform_range(0.05, 5.0) as f32;
                Mat::from_fn(8, 8, |_, _| scale * rng.gaussian_f32())
            },
            |w| {
                let m = map_layer(w);
                let w2 = conductance_to_weight(&m.g_target, m.gain);
                let qstep = m.gain * (G_CELL_HI_MS - G_CELL_LO_MS) / (N_LEVELS - 1) as f32;
                let err = w.max_abs_diff(&w2);
                if err <= 0.5 * qstep + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("err {err} > half step {}", 0.5 * qstep))
                }
            },
        );
    }

    #[test]
    fn quantize_produces_at_most_64_levels() {
        let g = Mat::from_fn(40, 40, |r, c| {
            G_CELL_LO_MS + (G_CELL_HI_MS - G_CELL_LO_MS) * ((r * 40 + c) as f32 / 1599.0)
        });
        let q = quantize(&g);
        let mut levels: Vec<i64> = q
            .as_slice()
            .iter()
            .map(|&x| (x * 1e7).round() as i64)
            .collect();
        levels.sort();
        levels.dedup();
        assert!(levels.len() <= N_LEVELS);
    }

    #[test]
    fn zero_weight_maps_to_g_fixed() {
        let w = Mat::zeros(3, 3);
        let g = weight_to_conductance(&w, 1.0);
        for &x in g.as_slice() {
            assert!((x - G_FIXED_MS).abs() < 1e-7);
        }
    }

    #[test]
    fn asymmetric_headroom_respected() {
        // max negative weight maps to floor, max positive to ceiling
        let w = Mat::from_vec(1, 2, vec![-W_NEG_MAX, W_POS_MAX]);
        let gain = required_gain(&w);
        assert!((gain - 1.0).abs() < 1e-5);
        let g = weight_to_conductance(&w, gain);
        assert!((g.get(0, 0) - G_CELL_LO_MS).abs() < 1e-6);
        assert!((g.get(0, 1) - G_CELL_HI_MS).abs() < 1e-6);
    }
}
