//! Analog crossbar compute layer: weight mapping, differential-pair MVM
//! with TIA readout, and tiling of logical matrices onto 32×32 macros —
//! either inside one monolithic [`CrossbarLayer`] (the parity oracle) or
//! sharded across a grid of macro banks ([`bank::BankedCrossbarLayer`])
//! with per-bank RNG streams and per-tile-column TIA gains.
//!
//! This is the rust mirror of the L1 Pallas kernel semantics
//! (`python/compile/kernels/crossbar.py` / `ref.py`): the three
//! implementations are cross-checked by the integration tests.

pub mod bank;
pub mod layer;
pub mod mapper;
pub mod noise;

pub use bank::{BankDrift, BankReport, BankStat, BankedCrossbarLayer, Banking,
               LayerDrift, ScoreLayer};
pub use layer::CrossbarLayer;
pub use mapper::{conductance_to_weight, required_gain, weight_to_conductance, Mapping};
pub use noise::NoiseModel;

/// Shared negative-weight conductance: 20 kΩ → 0.05 mS (paper Fig. 2h).
pub const G_FIXED_MS: f32 = 0.05;
/// Programmable cell window (paper Fig. 2d).
pub const G_CELL_LO_MS: f32 = 0.02;
pub const G_CELL_HI_MS: f32 = 0.10;
/// ≥64 discernible linear conductance states.
pub const N_LEVELS: usize = 64;
