//! Deployment router: request classes mapped onto named backends.
//!
//! The paper's evaluation runs *different solvers on different substrates*
//! — the analog integrator for speed/energy, the digital sampler as the
//! quality baseline — so a deployment must serve both side by side.  This
//! module is the table that makes that routable:
//!
//! * [`BackendKind`] — the three engine implementations a deployment can
//!   name (`analog` simulator, `rust` digital baseline, `hlo` PJRT
//!   artifacts).
//! * [`DeployPlan`] — the config-driven class→backend table (`[deploy]`
//!   section / `--deploy` CLI overrides) plus per-backend worker counts.
//! * [`EngineRegistry`] — the resolved runtime table the [`Service`]
//!   facade consults on every submit: request class → backend index →
//!   that backend's batcher lane and worker allotment.
//! * [`build_registry`] — constructs each backend the plan needs via a
//!   caller-supplied factory, with a **fallback chain**: if the `hlo`
//!   backend fails to construct (the default `pjrt_vendored` stub errors,
//!   or the AOT artifacts are absent), its classes degrade to the `rust`
//!   digital engine and the [`Degradation`] is recorded in `Metrics`
//!   rather than failing startup.
//!
//! Flow of one request: `GenRequest::class()` → registry route → that
//! backend's lane ([`super::batcher::LaneSet`]) → coalesced per-class
//! batch → one of the backend's own workers → `Engine::generate`.  Lanes
//! are per-backend, so a slow analog batch can never head-of-line-block
//! digital traffic.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::anyhow;

use super::request::{RequestClass, SolverFamily};
use super::service::{Engine, Service, ServiceConfig};
use crate::util::KernelMode;
use crate::vae::PixelDecoder;

/// The engine implementations a deployment table can name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Rust analog-hardware simulator ([`super::service::AnalogEngine`]).
    Analog,
    /// Pure-rust digital baseline ([`super::service::RustDigitalEngine`]).
    Rust,
    /// AOT PJRT artifacts ([`super::service::HloEngine`]).
    Hlo,
}

impl BackendKind {
    /// Every kind, in a fixed order ([`Self::index`] indexes it).
    pub const ALL: [BackendKind; 3] =
        [BackendKind::Analog, BackendKind::Rust, BackendKind::Hlo];

    /// Dense index into [`Self::ALL`].
    pub fn index(&self) -> usize {
        match self {
            BackendKind::Analog => 0,
            BackendKind::Rust => 1,
            BackendKind::Hlo => 2,
        }
    }

    /// Stable name used by config values, CLI flags and metrics labels.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Analog => "analog",
            BackendKind::Rust => "rust",
            BackendKind::Hlo => "hlo",
        }
    }

    /// Whether this engine implementation can execute the given solver
    /// family (engines reject the wrong family at `generate` time; the
    /// plan validates earlier, at assignment time).
    pub fn serves(&self, family: SolverFamily) -> bool {
        match self {
            BackendKind::Analog => family == SolverFamily::Analog,
            BackendKind::Rust | BackendKind::Hlo => {
                family == SolverFamily::Digital
            }
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "analog" => Ok(BackendKind::Analog),
            "rust" => Ok(BackendKind::Rust),
            "hlo" => Ok(BackendKind::Hlo),
            other => {
                Err(format!("unknown backend {other:?} (expected analog|rust|hlo)"))
            }
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The config-driven deployment table: which backend serves each request
/// class, and how many service workers each backend gets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeployPlan {
    /// Backend per class, indexed by [`RequestClass::index`].
    route: [BackendKind; 4],
    /// Workers per backend, indexed by [`BackendKind::index`];
    /// 0 = use the service-wide default ([`ServiceConfig::workers`]).
    workers: [usize; 3],
    /// Lane queue bound (samples) per backend, indexed by
    /// [`BackendKind::index`]; 0 = the service-wide
    /// `[service] queue_depth`.  A slow backend can run a shallower
    /// shed-early queue than the rest of the deployment.
    queue: [usize; 3],
    /// Score-weight path per backend, indexed by [`BackendKind::index`]
    /// (`<backend>_weights` keys).  `None` = the engine factory's
    /// default.  This is the groundwork for per-class model variants: a
    /// wide high-accuracy net and a narrow low-latency net can sit
    /// behind different backends of one deployment.
    weights: [Option<String>; 3],
    /// MVM kernel lane per backend (`<backend>_kernel` keys), indexed by
    /// [`BackendKind::index`].  Seeded by `[service] kernel`, so one
    /// deployment can serve the f32 and conductance-quantized lanes side
    /// by side (e.g. `analog_kernel = quant` with `rust` on f32).
    kernel: [KernelMode; 3],
}

impl Default for DeployPlan {
    /// Paper-shaped default: analog classes on the analog simulator,
    /// digital classes on the rust baseline (the stub-safe choice).
    fn default() -> Self {
        DeployPlan {
            route: [
                BackendKind::Analog,
                BackendKind::Analog,
                BackendKind::Rust,
                BackendKind::Rust,
            ],
            workers: [0; 3],
            queue: [0; 3],
            weights: [None, None, None],
            kernel: [KernelMode::F32; 3],
        }
    }
}

impl DeployPlan {
    pub fn backend_for(&self, class: RequestClass) -> BackendKind {
        self.route[class.index()]
    }

    /// Configured worker count for a backend (0 = service default).
    pub fn workers_for(&self, kind: BackendKind) -> usize {
        self.workers[kind.index()]
    }

    /// Configured lane queue bound for a backend (0 = service default).
    pub fn queue_for(&self, kind: BackendKind) -> usize {
        self.queue[kind.index()]
    }

    /// Configured weight path for a backend (`None` = factory default).
    pub fn weights_for(&self, kind: BackendKind) -> Option<&str> {
        self.weights[kind.index()].as_deref()
    }

    /// Configured MVM kernel lane for a backend.
    pub fn kernel_for(&self, kind: BackendKind) -> KernelMode {
        self.kernel[kind.index()]
    }

    /// Seed every backend's kernel lane (the `[service] kernel` default;
    /// applied before the `[deploy]` section so `<backend>_kernel` keys
    /// override it).
    pub fn set_base_kernel(&mut self, kernel: KernelMode) {
        self.kernel = [kernel; 3];
    }

    /// Apply one `key = value` entry.  Keys:
    ///
    /// * `analog` / `digital` — backend for the whole solver family;
    /// * `analog_uncond` / `analog_cond` / `digital_uncond` /
    ///   `digital_cond` — backend for one class;
    /// * `analog_workers` / `rust_workers` / `hlo_workers` — per-backend
    ///   worker count (0 = service default);
    /// * `<backend>_queue` — per-backend lane queue bound in samples
    ///   (0 = the service-wide `[service] queue_depth`);
    /// * `<backend>_weights` — per-backend score-weight path (for `hlo`,
    ///   an artifacts directory), overriding the factory default;
    /// * `<backend>_kernel` — per-backend MVM kernel lane (`f32` |
    ///   `quant`), overriding the `[service] kernel` default.
    ///
    /// Family compatibility is validated here, at assignment time: an
    /// analog class can only run on the analog engine, a digital class on
    /// `rust` or `hlo`.
    pub fn set(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        let key = key.trim();
        if let Some(backend) = key.strip_suffix("_workers") {
            let kind: BackendKind = backend
                .parse()
                .map_err(|e| anyhow!("[deploy] {key}: {e}"))?;
            let n: usize = value.trim().parse().map_err(|_| {
                anyhow!("[deploy] {key} = {value:?}: expected a worker count")
            })?;
            self.workers[kind.index()] = n;
            return Ok(());
        }
        if let Some(backend) = key.strip_suffix("_queue") {
            let kind: BackendKind = backend
                .parse()
                .map_err(|e| anyhow!("[deploy] {key}: {e}"))?;
            let n: usize = value.trim().parse().map_err(|_| {
                anyhow!("[deploy] {key} = {value:?}: expected a queue depth \
                         in samples")
            })?;
            self.queue[kind.index()] = n;
            return Ok(());
        }
        if let Some(backend) = key.strip_suffix("_weights") {
            let kind: BackendKind = backend
                .parse()
                .map_err(|e| anyhow!("[deploy] {key}: {e}"))?;
            let path = value.trim();
            if path.is_empty() {
                return Err(anyhow!("[deploy] {key}: expected a weight path"));
            }
            self.weights[kind.index()] = Some(path.to_string());
            return Ok(());
        }
        if let Some(backend) = key.strip_suffix("_kernel") {
            let kind: BackendKind = backend
                .parse()
                .map_err(|e| anyhow!("[deploy] {key}: {e}"))?;
            let mode: KernelMode = value
                .trim()
                .parse()
                .map_err(|e| anyhow!("[deploy] {key} = {value:?}: {e}"))?;
            self.kernel[kind.index()] = mode;
            return Ok(());
        }
        let kind: BackendKind = value
            .parse()
            .map_err(|e| anyhow!("[deploy] {key} = {value:?}: {e}"))?;
        let classes: Vec<RequestClass> = match key {
            "analog" | "digital" => {
                let family = if key == "analog" {
                    SolverFamily::Analog
                } else {
                    SolverFamily::Digital
                };
                RequestClass::ALL
                    .into_iter()
                    .filter(|c| c.family == family)
                    .collect()
            }
            _ => match RequestClass::ALL.into_iter().find(|c| c.name() == key) {
                Some(c) => vec![c],
                None => {
                    return Err(anyhow!(
                        "[deploy] unknown key {key:?} (expected analog, digital, \
                         a class name like digital_cond, or <backend>_workers / \
                         <backend>_queue / <backend>_weights / <backend>_kernel)"
                    ))
                }
            },
        };
        for class in classes {
            if !kind.serves(class.family) {
                return Err(anyhow!(
                    "[deploy] {key} = {value:?}: backend {kind} cannot serve \
                     {class} (wrong solver family)"
                ));
            }
            self.route[class.index()] = kind;
        }
        Ok(())
    }

    /// Apply a comma-separated `key=value` override list (the `--deploy`
    /// CLI flag), e.g. `digital=hlo,digital_cond=rust,rust_workers=4`.
    pub fn apply_overrides(&mut self, spec: &str) -> anyhow::Result<()> {
        for pair in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow!("--deploy {pair:?}: expected key=value"))?;
            self.set(k, v)?;
        }
        Ok(())
    }

    /// The distinct backends this plan routes to, in [`BackendKind::ALL`]
    /// order (so `rust` is always constructed before `hlo` can need it as
    /// a fallback).
    pub fn backends_needed(&self) -> Vec<BackendKind> {
        BackendKind::ALL
            .into_iter()
            .filter(|k| self.route.contains(k))
            .collect()
    }

    /// One-line class→backend summary for logs.
    pub fn summary(&self) -> String {
        RequestClass::ALL
            .iter()
            .map(|c| format!("{c}->{}", self.backend_for(*c)))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// A class rerouted at startup because its planned backend failed to
/// construct (the Hlo→rust fallback chain).
#[derive(Debug, Clone)]
pub struct Degradation {
    pub class: RequestClass,
    pub from: BackendKind,
    pub to: BackendKind,
    pub reason: String,
}

impl std::fmt::Display for Degradation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}->{}", self.class, self.from, self.to)
    }
}

/// A named backend: an engine plus its worker allotment and lane bound.
pub struct Backend {
    pub name: String,
    pub engine: Arc<dyn Engine>,
    /// Worker threads dedicated to this backend's lane
    /// (0 = [`ServiceConfig::workers`]).
    pub workers: usize,
    /// Lane queue bound in samples (0 = the service-wide
    /// `BatcherConfig::queue_depth`).
    pub queue_depth: usize,
}

/// The resolved runtime routing table: named backends plus the class→
/// backend map the [`Service`] facade consults on every submit.
#[derive(Default)]
pub struct EngineRegistry {
    backends: Vec<Backend>,
    route: HashMap<RequestClass, usize>,
}

impl EngineRegistry {
    pub fn new() -> Self {
        EngineRegistry::default()
    }

    /// One backend named `default` serving every class — the thin
    /// single-engine deployment [`Service::start`] wraps for back-compat.
    pub fn single(engine: Arc<dyn Engine>) -> Self {
        let mut reg = EngineRegistry::new();
        reg.add_backend("default", engine, 0).unwrap();
        for class in RequestClass::ALL {
            reg.route_class(class, "default").unwrap();
        }
        reg
    }

    /// Register a backend; names must be unique.  Returns its index.
    /// Lane queue bound defaults to the service-wide depth; use
    /// [`Self::add_backend_cfg`] to override it.
    pub fn add_backend(&mut self, name: impl Into<String>,
                       engine: Arc<dyn Engine>, workers: usize)
                       -> anyhow::Result<usize> {
        self.add_backend_cfg(name, engine, workers, 0)
    }

    /// [`Self::add_backend`] with an explicit lane queue bound in samples
    /// (0 = the service-wide `BatcherConfig::queue_depth`).
    pub fn add_backend_cfg(&mut self, name: impl Into<String>,
                           engine: Arc<dyn Engine>, workers: usize,
                           queue_depth: usize) -> anyhow::Result<usize> {
        let name = name.into();
        if self.backends.iter().any(|b| b.name == name) {
            return Err(anyhow!("backend {name:?} registered twice"));
        }
        self.backends.push(Backend { name, engine, workers, queue_depth });
        Ok(self.backends.len() - 1)
    }

    /// Route a request class to a registered backend by name.
    pub fn route_class(&mut self, class: RequestClass, name: &str)
                       -> anyhow::Result<()> {
        let idx = self
            .backends
            .iter()
            .position(|b| b.name == name)
            .ok_or_else(|| anyhow!("no backend named {name:?} registered"))?;
        self.route.insert(class, idx);
        Ok(())
    }

    /// Route both classes (conditional and unconditional) of a solver
    /// family to a registered backend by name.
    pub fn route_family(&mut self, family: SolverFamily, name: &str)
                        -> anyhow::Result<()> {
        for class in
            RequestClass::ALL.into_iter().filter(|c| c.family == family)
        {
            self.route_class(class, name)?;
        }
        Ok(())
    }

    /// Backend index serving `class`, if routed.
    pub fn backend_index(&self, class: RequestClass) -> Option<usize> {
        self.route.get(&class).copied()
    }

    pub fn backend(&self, idx: usize) -> &Backend {
        &self.backends[idx]
    }

    pub fn backends(&self) -> &[Backend] {
        &self.backends
    }

    pub fn n_backends(&self) -> usize {
        self.backends.len()
    }

    pub fn names(&self) -> Vec<String> {
        self.backends.iter().map(|b| b.name.clone()).collect()
    }

    /// One-line class→backend summary of the *resolved* routes.
    pub fn route_summary(&self) -> String {
        let mut classes: Vec<RequestClass> = self.route.keys().copied().collect();
        classes.sort_by_key(|c| c.index());
        classes
            .into_iter()
            .map(|c| format!("{c}->{}", self.backends[self.route[&c]].name))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Engine constructor the deployment layer calls per [`BackendKind`].
/// The second argument is the plan's `<backend>_weights` path override
/// (`None` = the factory's default weights; for `hlo`, an artifacts
/// directory).  Fallible so a missing runtime (the `pjrt_vendored`
/// stub) or missing artifacts surface as a degradation instead of a
/// panic.
pub type BackendFactory<'a> =
    dyn FnMut(BackendKind, Option<&str>) -> anyhow::Result<Arc<dyn Engine>> + 'a;

/// Build the runtime registry a plan describes, constructing each needed
/// backend via `factory`.  The **fallback chain**: a failed `hlo`
/// construction degrades its classes to the `rust` digital engine
/// (constructing it on demand if the plan didn't already need it) and
/// returns the [`Degradation`]s for the metrics; any other construction
/// failure aborts startup.  The replacement lane absorbs the failed
/// backend's explicit worker allotment when it exceeds rust's own, so
/// provisioned capacity isn't silently dropped with the degradation.
pub fn build_registry(plan: &DeployPlan, factory: &mut BackendFactory<'_>)
                      -> anyhow::Result<(EngineRegistry, Vec<Degradation>)> {
    let mut reg = EngineRegistry::new();
    let mut built: HashMap<BackendKind, usize> = HashMap::new();
    let mut degradations: Vec<Degradation> = Vec::new();
    // resolved class→kind map, updated when a backend degrades
    let mut resolved: [BackendKind; 4] =
        std::array::from_fn(|i| plan.backend_for(RequestClass::ALL[i]));

    // `backends_needed` yields `rust` before `hlo`, so when the fallback
    // fires, the rust engine either already exists or is built right here
    for kind in plan.backends_needed() {
        match factory(kind, plan.weights_for(kind)) {
            Ok(engine) => {
                let idx = reg.add_backend_cfg(
                    kind.name(), engine,
                    plan.workers_for(kind), plan.queue_for(kind))?;
                built.insert(kind, idx);
            }
            Err(e) if kind == BackendKind::Hlo => {
                let reason = format!("{e:#}");
                let hlo_workers = plan.workers_for(BackendKind::Hlo);
                let hlo_queue = plan.queue_for(BackendKind::Hlo);
                match built.get(&BackendKind::Rust).copied() {
                    Some(idx) => {
                        // rust already serves its own classes and now
                        // absorbs the hlo traffic too: keep the larger
                        // *explicit* allotment (0 = service default is
                        // left alone — this layer has no basis to resize
                        // a default).  Same for the lane bound: absorbed
                        // traffic keeps the deeper provisioned queue.
                        let w = &mut reg.backends[idx].workers;
                        if *w > 0 && hlo_workers > *w {
                            *w = hlo_workers;
                        }
                        let q = &mut reg.backends[idx].queue_depth;
                        if *q > 0 && hlo_queue > *q {
                            *q = hlo_queue;
                        }
                    }
                    None => {
                        let engine = factory(
                            BackendKind::Rust,
                            plan.weights_for(BackendKind::Rust),
                        )
                        .map_err(|re| {
                            anyhow!(
                                "hlo backend failed ({reason}) and the rust \
                                 fallback failed too: {re:#}"
                            )
                        })?;
                        // this lane exists only to absorb the hlo classes:
                        // it inherits the larger allotment so provisioned
                        // capacity isn't silently dropped
                        let workers =
                            plan.workers_for(BackendKind::Rust).max(hlo_workers);
                        let queue =
                            plan.queue_for(BackendKind::Rust).max(hlo_queue);
                        let idx = reg.add_backend_cfg(
                            BackendKind::Rust.name(), engine, workers, queue)?;
                        built.insert(BackendKind::Rust, idx);
                    }
                }
                for (i, class) in RequestClass::ALL.into_iter().enumerate() {
                    if resolved[i] == BackendKind::Hlo {
                        resolved[i] = BackendKind::Rust;
                        degradations.push(Degradation {
                            class,
                            from: BackendKind::Hlo,
                            to: BackendKind::Rust,
                            reason: reason.clone(),
                        });
                    }
                }
            }
            Err(e) => {
                return Err(e.context(format!(
                    "constructing the {} backend (no fallback for this kind)",
                    kind.name()
                )))
            }
        }
    }

    for (i, class) in RequestClass::ALL.into_iter().enumerate() {
        reg.route_class(class, resolved[i].name())?;
    }
    Ok((reg, degradations))
}

/// One-call deployment: build the registry from `plan` (with the Hlo→rust
/// fallback chain), start the routed [`Service`], and record any
/// degradations in its [`super::Metrics`].
pub fn start_deployed(plan: &DeployPlan, factory: &mut BackendFactory<'_>,
                      decoder: Option<Arc<PixelDecoder>>, cfg: ServiceConfig)
                      -> anyhow::Result<Service> {
    let (registry, degradations) = build_registry(plan, factory)?;
    let service = Service::start_routed(registry, decoder, cfg);
    for d in &degradations {
        service.metrics.record_degradation(d.to_string());
    }
    Ok(service)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::TagEngine;

    fn class(name: &str) -> RequestClass {
        RequestClass::ALL.into_iter().find(|c| c.name() == name).unwrap()
    }

    #[test]
    fn default_plan_routes_families() {
        let plan = DeployPlan::default();
        assert_eq!(plan.backend_for(class("analog_uncond")), BackendKind::Analog);
        assert_eq!(plan.backend_for(class("analog_cond")), BackendKind::Analog);
        assert_eq!(plan.backend_for(class("digital_uncond")), BackendKind::Rust);
        assert_eq!(plan.backend_for(class("digital_cond")), BackendKind::Rust);
        assert_eq!(plan.backends_needed(),
                   vec![BackendKind::Analog, BackendKind::Rust]);
    }

    #[test]
    fn plan_keys_parse_and_validate() {
        let mut plan = DeployPlan::default();
        plan.set("digital", "hlo").unwrap();
        assert_eq!(plan.backend_for(class("digital_cond")), BackendKind::Hlo);
        plan.set("digital_cond", "rust").unwrap();
        assert_eq!(plan.backend_for(class("digital_cond")), BackendKind::Rust);
        assert_eq!(plan.backend_for(class("digital_uncond")), BackendKind::Hlo);
        plan.set("rust_workers", "4").unwrap();
        assert_eq!(plan.workers_for(BackendKind::Rust), 4);
        plan.set("analog_queue", "96").unwrap();
        assert_eq!(plan.queue_for(BackendKind::Analog), 96);
        assert_eq!(plan.queue_for(BackendKind::Rust), 0, "others untouched");
        plan.set("rust_weights", "custom/weights_narrow.json").unwrap();
        assert_eq!(plan.weights_for(BackendKind::Rust),
                   Some("custom/weights_narrow.json"));
        assert_eq!(plan.weights_for(BackendKind::Analog), None);
        plan.set("analog_kernel", "quant").unwrap();
        assert_eq!(plan.kernel_for(BackendKind::Analog), KernelMode::Quant);
        assert_eq!(plan.kernel_for(BackendKind::Rust), KernelMode::F32,
                   "others untouched");
        plan.set("analog_kernel", "f32").unwrap();
        assert_eq!(plan.kernel_for(BackendKind::Analog), KernelMode::F32);
        // family mismatches rejected at assignment time
        assert!(plan.set("analog", "rust").is_err());
        assert!(plan.set("digital", "analog").is_err());
        assert!(plan.set("digital_uncond", "analog").is_err());
        // junk rejected
        assert!(plan.set("teleport", "analog").is_err());
        assert!(plan.set("digital", "gpu").is_err());
        assert!(plan.set("rust_workers", "many").is_err());
        assert!(plan.set("gpu_queue", "8").is_err());
        assert!(plan.set("rust_queue", "deep").is_err());
        assert!(plan.set("analog_weights", "  ").is_err());
        assert!(plan.set("analog_kernel", "f16").is_err());
        assert!(plan.set("gpu_kernel", "quant").is_err());
    }

    #[test]
    fn base_kernel_seeds_then_per_backend_overrides() {
        let mut plan = DeployPlan::default();
        plan.set_base_kernel(KernelMode::Quant);
        for kind in BackendKind::ALL {
            assert_eq!(plan.kernel_for(kind), KernelMode::Quant);
        }
        plan.apply_overrides("rust_kernel=f32").unwrap();
        assert_eq!(plan.kernel_for(BackendKind::Rust), KernelMode::F32);
        assert_eq!(plan.kernel_for(BackendKind::Analog), KernelMode::Quant);
    }

    #[test]
    fn cli_overrides_apply_in_order() {
        let mut plan = DeployPlan::default();
        plan.apply_overrides("digital=hlo,digital_cond=rust,analog_workers=2")
            .unwrap();
        assert_eq!(plan.backend_for(class("digital_uncond")), BackendKind::Hlo);
        assert_eq!(plan.backend_for(class("digital_cond")), BackendKind::Rust);
        assert_eq!(plan.workers_for(BackendKind::Analog), 2);
        assert!(plan.apply_overrides("digital").is_err());
        assert_eq!(plan.summary(),
                   "analog_uncond->analog,analog_cond->analog,\
                    digital_uncond->hlo,digital_cond->rust");
    }

    #[test]
    fn registry_routes_and_rejects_duplicates() {
        let mut reg = EngineRegistry::new();
        reg.add_backend("a", Arc::new(TagEngine(1.0)), 1).unwrap();
        reg.add_backend("b", Arc::new(TagEngine(2.0)), 2).unwrap();
        assert!(reg.add_backend("a", Arc::new(TagEngine(3.0)), 1).is_err());
        reg.route_class(class("analog_uncond"), "a").unwrap();
        reg.route_class(class("digital_uncond"), "b").unwrap();
        assert!(reg.route_class(class("digital_cond"), "zzz").is_err());
        assert_eq!(reg.backend_index(class("analog_uncond")), Some(0));
        assert_eq!(reg.backend_index(class("digital_uncond")), Some(1));
        assert_eq!(reg.backend_index(class("digital_cond")), None);
        assert_eq!(reg.backend(1).workers, 2);
        assert_eq!(reg.route_summary(),
                   "analog_uncond->a,digital_uncond->b");
    }

    #[test]
    fn single_registry_serves_every_class() {
        let reg = EngineRegistry::single(Arc::new(TagEngine(7.0)));
        assert_eq!(reg.n_backends(), 1);
        for class in RequestClass::ALL {
            assert_eq!(reg.backend_index(class), Some(0));
        }
    }

    #[test]
    fn build_registry_happy_path() {
        let plan = DeployPlan::default();
        let mut calls = Vec::new();
        let (reg, degs) = build_registry(&plan, &mut |kind, _weights| {
            calls.push(kind);
            Ok(Arc::new(TagEngine(kind.index() as f32)) as Arc<dyn Engine>)
        })
        .unwrap();
        assert_eq!(calls, vec![BackendKind::Analog, BackendKind::Rust]);
        assert!(degs.is_empty());
        assert_eq!(reg.n_backends(), 2);
        assert_eq!(reg.backend_index(class("digital_cond")), Some(1));
    }

    #[test]
    fn hlo_failure_degrades_to_rust() {
        let mut plan = DeployPlan::default();
        plan.apply_overrides("digital=hlo,hlo_workers=8").unwrap();
        // plan needs only analog + hlo: the fallback must construct rust
        // on demand
        let (reg, degs) = build_registry(&plan, &mut |kind, _weights| match kind {
            BackendKind::Hlo => Err(anyhow!("stub runtime")),
            k => Ok(Arc::new(TagEngine(k.index() as f32)) as Arc<dyn Engine>),
        })
        .unwrap();
        assert_eq!(degs.len(), 2, "both digital classes degrade");
        for d in &degs {
            assert_eq!(d.from, BackendKind::Hlo);
            assert_eq!(d.to, BackendKind::Rust);
            assert!(d.reason.contains("stub runtime"));
        }
        let rust_idx = reg
            .backends()
            .iter()
            .position(|b| b.name == "rust")
            .expect("rust fallback backend registered");
        assert_eq!(reg.backend_index(class("digital_uncond")), Some(rust_idx));
        assert_eq!(reg.backend_index(class("digital_cond")), Some(rust_idx));
        assert_eq!(reg.backend(rust_idx).workers, 8,
                   "fallback lane inherits the hlo worker allotment");
    }

    #[test]
    fn hlo_degradation_bumps_existing_rust_allotment() {
        let mut plan = DeployPlan::default();
        plan.apply_overrides(
            "digital_uncond=rust,digital_cond=hlo,rust_workers=2,hlo_workers=6",
        )
        .unwrap();
        let (reg, degs) = build_registry(&plan, &mut |kind, _weights| match kind {
            BackendKind::Hlo => Err(anyhow!("stub runtime")),
            k => Ok(Arc::new(TagEngine(k.index() as f32)) as Arc<dyn Engine>),
        })
        .unwrap();
        assert_eq!(degs.len(), 1);
        let rust = reg
            .backends()
            .iter()
            .find(|b| b.name == "rust")
            .unwrap();
        assert_eq!(rust.workers, 6,
                   "explicit rust allotment grows to the absorbed hlo one");
    }

    #[test]
    fn build_registry_passes_weight_paths_and_queue_bounds() {
        let mut plan = DeployPlan::default();
        plan.apply_overrides(
            "rust_weights=narrow.json,analog_queue=64,rust_queue=32")
            .unwrap();
        let mut seen: Vec<(BackendKind, Option<String>)> = Vec::new();
        let (reg, degs) = build_registry(&plan, &mut |kind, weights| {
            seen.push((kind, weights.map(String::from)));
            Ok(Arc::new(TagEngine(0.0)) as Arc<dyn Engine>)
        })
        .unwrap();
        assert!(degs.is_empty());
        assert_eq!(seen, vec![
            (BackendKind::Analog, None),
            (BackendKind::Rust, Some("narrow.json".into())),
        ], "factory receives each backend's configured weight path");
        assert_eq!(reg.backends()[0].queue_depth, 64);
        assert_eq!(reg.backends()[1].queue_depth, 32);
    }

    #[test]
    fn hlo_fallback_absorbs_queue_bound_not_weights() {
        let mut plan = DeployPlan::default();
        plan.apply_overrides("digital=hlo,hlo_queue=96,hlo_weights=hlo_dir")
            .unwrap();
        let mut rust_weights_seen: Option<Option<String>> = None;
        let (reg, degs) = build_registry(&plan, &mut |kind, weights| match kind {
            BackendKind::Hlo => Err(anyhow!("stub runtime")),
            k => {
                if k == BackendKind::Rust {
                    rust_weights_seen = Some(weights.map(String::from));
                }
                Ok(Arc::new(TagEngine(k.index() as f32)) as Arc<dyn Engine>)
            }
        })
        .unwrap();
        assert_eq!(degs.len(), 2);
        let rust =
            reg.backends().iter().find(|b| b.name == "rust").unwrap();
        assert_eq!(rust.queue_depth, 96,
                   "on-demand fallback lane inherits the hlo queue bound");
        assert_eq!(rust_weights_seen, Some(None),
                   "fallback builds rust with RUST weights (hlo's path names \
                    an artifacts dir, not score weights)");
    }

    #[test]
    fn non_hlo_failure_aborts_startup() {
        let plan = DeployPlan::default();
        let err = build_registry(&plan, &mut |kind, _weights| match kind {
            BackendKind::Analog => Err(anyhow!("no weights")),
            k => Ok(Arc::new(TagEngine(k.index() as f32)) as Arc<dyn Engine>),
        })
        .unwrap_err();
        assert!(format!("{err:#}").contains("analog backend"));
    }

    #[test]
    fn hlo_failure_with_failing_rust_fallback_aborts() {
        let mut plan = DeployPlan::default();
        plan.set("digital", "hlo").unwrap();
        let err = build_registry(&plan, &mut |kind, _weights| match kind {
            BackendKind::Analog => {
                Ok(Arc::new(TagEngine(0.0)) as Arc<dyn Engine>)
            }
            _ => Err(anyhow!("nothing works")),
        })
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fallback failed too"), "{msg}");
    }
}
