//! Request/response types of the generation service.

/// Which generative task a request targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Unconditional 2-D circle (paper Fig. 3).
    Circle,
    /// Conditional letter generation in VAE latent space (paper Fig. 4);
    /// the payload is the class index (0=H, 1=K, 2=U).
    Letter(usize),
}

impl TaskKind {
    /// One-hot condition vector (empty classes → zeros).
    pub fn onehot(&self, n_classes: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n_classes];
        if let TaskKind::Letter(c) = self {
            v[*c] = 1.0;
        }
        v
    }

    pub fn is_conditional(&self) -> bool {
        matches!(self, TaskKind::Letter(_))
    }

    /// Parse the stable task names shared by the CLI and the wire
    /// protocol (`circle`, or a letter class `h`/`k`/`u`).
    pub fn from_name(s: &str) -> Option<TaskKind> {
        match s {
            "circle" => Some(TaskKind::Circle),
            "h" | "H" => Some(TaskKind::Letter(0)),
            "k" | "K" => Some(TaskKind::Letter(1)),
            "u" | "U" => Some(TaskKind::Letter(2)),
            _ => None,
        }
    }

    /// Inverse of [`Self::from_name`] — the stable wire/persistence name.
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Circle => "circle",
            TaskKind::Letter(0) => "h",
            TaskKind::Letter(1) => "k",
            TaskKind::Letter(_) => "u",
        }
    }
}

/// Solver substrate family — the first routing axis of the deployment
/// layer (the paper runs the two families on different hardware).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SolverFamily {
    /// Continuous-time analog integrator (the resistive-memory substrate).
    Analog,
    /// Discrete-step digital sampler (rust baseline or PJRT artifacts).
    Digital,
}

/// Request class: the unit the deployment router maps onto a backend —
/// solver family × conditional/unconditional.  Every request resolves to
/// exactly one class, and requests sharing a [`GenRequest::batch_key`]
/// always share a class (the key folds in both the condition and the
/// solver), so routing by class never splits a coalescible batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RequestClass {
    pub family: SolverFamily,
    pub conditional: bool,
}

impl RequestClass {
    /// Every class, in a fixed order ([`Self::index`] indexes it).
    pub const ALL: [RequestClass; 4] = [
        RequestClass { family: SolverFamily::Analog, conditional: false },
        RequestClass { family: SolverFamily::Analog, conditional: true },
        RequestClass { family: SolverFamily::Digital, conditional: false },
        RequestClass { family: SolverFamily::Digital, conditional: true },
    ];

    /// Dense index into [`Self::ALL`] (deployment tables are arrays).
    pub fn index(&self) -> usize {
        let fam = match self.family {
            SolverFamily::Analog => 0,
            SolverFamily::Digital => 2,
        };
        fam + self.conditional as usize
    }

    /// Stable name used by `[deploy]` config keys and metrics labels.
    pub fn name(&self) -> &'static str {
        match (self.family, self.conditional) {
            (SolverFamily::Analog, false) => "analog_uncond",
            (SolverFamily::Analog, true) => "analog_cond",
            (SolverFamily::Digital, false) => "digital_uncond",
            (SolverFamily::Digital, true) => "digital_cond",
        }
    }
}

impl std::fmt::Display for RequestClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which solver executes the request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolverChoice {
    /// Time-continuous closed-loop analog solver, ODE mode (the paper's
    /// probability-flow configuration).
    AnalogOde,
    /// Analog solver, reverse-SDE mode (noise DAC on).
    AnalogSde,
    /// Digital baseline via the AOT PJRT artifacts, Euler, given steps.
    DigitalOde { steps: usize },
    DigitalSde { steps: usize },
}

impl SolverChoice {
    pub fn is_analog(&self) -> bool {
        matches!(self, SolverChoice::AnalogOde | SolverChoice::AnalogSde)
    }

    /// Parse the stable solver names shared by the CLI and the wire
    /// protocol; `steps` applies to the digital solvers only.
    pub fn from_name(s: &str, steps: usize) -> Option<SolverChoice> {
        match s {
            "analog-ode" => Some(SolverChoice::AnalogOde),
            "analog-sde" => Some(SolverChoice::AnalogSde),
            "euler" => Some(SolverChoice::DigitalOde { steps }),
            "euler-sde" => Some(SolverChoice::DigitalSde { steps }),
            _ => None,
        }
    }

    /// Inverse of [`Self::from_name`] — the stable wire/persistence name
    /// (pair it with [`Self::steps`] to round-trip digital choices).
    pub fn name(&self) -> &'static str {
        match self {
            SolverChoice::AnalogOde => "analog-ode",
            SolverChoice::AnalogSde => "analog-sde",
            SolverChoice::DigitalOde { .. } => "euler",
            SolverChoice::DigitalSde { .. } => "euler-sde",
        }
    }

    /// Step count of a digital choice (None for the analog solvers).
    pub fn steps(&self) -> Option<usize> {
        match self {
            SolverChoice::DigitalOde { steps } | SolverChoice::DigitalSde { steps } => {
                Some(*steps)
            }
            _ => None,
        }
    }

    /// Substrate family this choice executes on (the routing axis).
    pub fn family(&self) -> SolverFamily {
        if self.is_analog() {
            SolverFamily::Analog
        } else {
            SolverFamily::Digital
        }
    }

    /// Batching key: requests sharing it may ride the same batch.
    pub fn batch_key(&self) -> u64 {
        match self {
            SolverChoice::AnalogOde => 1,
            SolverChoice::AnalogSde => 2,
            SolverChoice::DigitalOde { steps } => 1000 + *steps as u64,
            SolverChoice::DigitalSde { steps } => 2_000_000 + *steps as u64,
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub task: TaskKind,
    pub n_samples: usize,
    pub solver: SolverChoice,
    /// CFG guidance strength for conditional tasks.
    pub guidance: f32,
    /// Decode latents to 12×12 pixel images (letters task).
    pub decode: bool,
    /// Trace identity minted at ingress ([`TraceId::NONE`] for internal
    /// synthetic requests); rides the request through every layer so
    /// span events correlate into one timeline.
    pub trace: crate::obs::TraceId,
}

impl GenRequest {
    /// The class the deployment router maps onto a backend.  Coarser than
    /// [`Self::batch_key`]: many keys per class, never the reverse.
    pub fn class(&self) -> RequestClass {
        RequestClass {
            family: self.solver.family(),
            conditional: self.task.is_conditional(),
        }
    }

    /// Batching key: same condition + solver (+decode flag) may coalesce.
    pub fn batch_key(&self) -> u64 {
        let cond = match self.task {
            TaskKind::Circle => 0u64,
            TaskKind::Letter(c) => 1 + c as u64,
        };
        cond ^ (self.solver.batch_key() << 8) ^ ((self.decode as u64) << 63)
            ^ ((self.guidance.to_bits() as u64) << 20)
    }
}

/// The service's answer.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    /// Interleaved 2-D samples (n_samples × dim).
    pub samples: Vec<f32>,
    /// Decoded images (n_samples × 144) when requested.
    pub images: Option<Vec<f32>>,
    /// End-to-end latency in seconds (wall clock of the simulator).
    pub wall_latency_s: f64,
    /// Modeled hardware latency (analog solve window / digital steps).
    pub hw_latency_s: f64,
    /// Modeled hardware energy (J) — charges the engine's actual deployed
    /// topology (per-macro peripherals) for analog engines.
    pub hw_energy_j: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn onehot_encoding() {
        assert_eq!(TaskKind::Circle.onehot(3), vec![0.0, 0.0, 0.0]);
        assert_eq!(TaskKind::Letter(1).onehot(3), vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn batch_keys_separate_conditions() {
        let base = GenRequest {
            id: 0,
            task: TaskKind::Letter(0),
            n_samples: 10,
            solver: SolverChoice::DigitalOde { steps: 100 },
            guidance: 2.0,
            decode: false,
            trace: crate::obs::TraceId::NONE,
        };
        let other_class = GenRequest { task: TaskKind::Letter(1), ..base.clone() };
        let other_steps = GenRequest {
            solver: SolverChoice::DigitalOde { steps: 50 },
            ..base.clone()
        };
        let other_decode = GenRequest { decode: true, ..base.clone() };
        let same = GenRequest { id: 7, n_samples: 3, ..base.clone() };
        assert_ne!(base.batch_key(), other_class.batch_key());
        assert_ne!(base.batch_key(), other_steps.batch_key());
        assert_ne!(base.batch_key(), other_decode.batch_key());
        assert_eq!(base.batch_key(), same.batch_key());
    }

    #[test]
    fn request_class_is_family_times_condition() {
        let mk = |solver, task| GenRequest {
            id: 0,
            task,
            n_samples: 1,
            solver,
            guidance: 0.0,
            decode: false,
            trace: crate::obs::TraceId::NONE,
        };
        let cases = [
            (SolverChoice::AnalogOde, TaskKind::Circle,
             RequestClass { family: SolverFamily::Analog, conditional: false }),
            (SolverChoice::AnalogSde, TaskKind::Letter(2),
             RequestClass { family: SolverFamily::Analog, conditional: true }),
            (SolverChoice::DigitalOde { steps: 10 }, TaskKind::Circle,
             RequestClass { family: SolverFamily::Digital, conditional: false }),
            (SolverChoice::DigitalSde { steps: 10 }, TaskKind::Letter(0),
             RequestClass { family: SolverFamily::Digital, conditional: true }),
        ];
        for (solver, task, want) in cases {
            assert_eq!(mk(solver, task).class(), want);
        }
    }

    #[test]
    fn class_indices_cover_all() {
        let idx: std::collections::HashSet<usize> =
            RequestClass::ALL.iter().map(|c| c.index()).collect();
        assert_eq!(idx, (0..4).collect());
        for c in RequestClass::ALL {
            assert_eq!(RequestClass::ALL[c.index()], c);
        }
        let names: std::collections::HashSet<&str> =
            RequestClass::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), 4);
    }

    #[test]
    fn batch_key_never_crosses_class_condition() {
        // the router batches per class: a key must never be shared by a
        // conditional and an unconditional request (the solver-family axis
        // is separated by routing itself)
        let cond = GenRequest {
            id: 0,
            task: TaskKind::Letter(0),
            n_samples: 1,
            solver: SolverChoice::DigitalOde { steps: 100 },
            guidance: 2.0,
            decode: false,
            trace: crate::obs::TraceId::NONE,
        };
        let uncond = GenRequest { task: TaskKind::Circle, ..cond.clone() };
        assert_ne!(cond.batch_key(), uncond.batch_key());
        assert_ne!(cond.class(), uncond.class());
    }

    #[test]
    fn names_parse_for_cli_and_wire() {
        assert_eq!(TaskKind::from_name("circle"), Some(TaskKind::Circle));
        assert_eq!(TaskKind::from_name("H"), Some(TaskKind::Letter(0)));
        assert_eq!(TaskKind::from_name("u"), Some(TaskKind::Letter(2)));
        assert_eq!(TaskKind::from_name("z"), None);
        assert_eq!(SolverChoice::from_name("analog-sde", 9),
                   Some(SolverChoice::AnalogSde));
        assert_eq!(SolverChoice::from_name("euler", 40),
                   Some(SolverChoice::DigitalOde { steps: 40 }));
        assert_eq!(SolverChoice::from_name("rk4", 40), None);
    }

    #[test]
    fn solver_keys_distinct() {
        let keys = [
            SolverChoice::AnalogOde.batch_key(),
            SolverChoice::AnalogSde.batch_key(),
            SolverChoice::DigitalOde { steps: 100 }.batch_key(),
            SolverChoice::DigitalSde { steps: 100 }.batch_key(),
        ];
        let set: std::collections::HashSet<_> = keys.iter().collect();
        assert_eq!(set.len(), 4);
    }
}
