//! Service metrics: request/sample counters, latency summaries, the
//! engines' macro-bank topology (grid shape + per-bank program/read stats,
//! refreshed after every batch so read counters stay live), the intra-op
//! pool gauges (threads, scopes/tasks run, queue high-water mark,
//! tasks-per-scope histogram) from [`crate::exec`], and — since the
//! deployment router — **per-backend** gauges: each named backend's queue
//! depth, admission-reject count (its bounded lane shedding load),
//! request/sample/batch counters, modeled hardware energy, and any
//! startup degradation (the Hlo→rust fallback chain) surface as a
//! `backend=` column in the report.

use std::sync::Mutex;
use std::time::Duration;

use crate::crossbar::BankReport;
use crate::exec::PoolStats;
use crate::util::stats::Summary;

/// Live per-backend gauge (internal accumulation state).
#[derive(Debug, Clone, Default)]
struct BackendGauge {
    name: String,
    requests: u64,
    samples: u64,
    batches: u64,
    /// Admission rejects against this backend's bounded lane
    /// (`Overloaded` sheds — the 429 count of the front-end).
    rejected: u64,
    queue_depth: usize,
    hw_energy_j: f64,
    wall_latency: Summary,
}

/// Per-state job counts + lifetime totals, pushed by the job runner
/// (None until a `--state-dir` deployment publishes them).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct JobGauges {
    pub queued: usize,
    pub running: usize,
    pub failed: usize,
    pub done: usize,
    pub dead: usize,
    pub cancelled: usize,
    pub enqueued_total: u64,
    pub retries_total: u64,
}

impl JobGauges {
    /// Compact `jobs=[...]` column for the one-line report.
    pub fn summary(&self) -> String {
        format!(
            "[q{} run{} fail{} done{} dead{} canc{} enq{} retry{}]",
            self.queued, self.running, self.failed, self.done, self.dead,
            self.cancelled, self.enqueued_total, self.retries_total,
        )
    }
}

#[derive(Default)]
struct Inner {
    requests: u64,
    samples: u64,
    batches: u64,
    rejected: u64,
    wall_latency: Summary,
    batch_fill: Summary,
    /// Bank reports grouped by backend index, so a worker can refresh its
    /// own engine's group without rebuilding every backend's topology
    /// (single-engine services use one group via [`Metrics::set_banking`]).
    banking: Vec<Vec<BankReport>>,
    pool: Option<PoolStats>,
    backends: Vec<BackendGauge>,
    degraded: Vec<String>,
    jobs: Option<JobGauges>,
    /// Engine panics contained by the worker's `catch_unwind` (each fails
    /// only its own batch's requests).
    worker_panics: u64,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Poison-tolerant lock: a worker panic contained by `catch_unwind`
    /// may poison this mutex mid-update; the counters inside are
    /// monotone scalars, so recovering the guard is always safe and the
    /// alternative (every later metrics call cascading the panic) would
    /// take down exactly the observability needed to diagnose it.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn record_batch(&self, n_requests: usize, n_samples: usize, fill: f64,
                        latency: Duration) {
        let mut m = self.lock();
        m.requests += n_requests as u64;
        m.samples += n_samples as u64;
        m.batches += 1;
        m.wall_latency.record(latency.as_secs_f64());
        m.batch_fill.record(fill);
    }

    pub fn record_rejected(&self) {
        self.lock().rejected += 1;
    }

    /// Publish a single engine's bank topology + per-bank stats as the
    /// whole banking picture (replaces every group).
    pub fn set_banking(&self, banking: Vec<BankReport>) {
        self.lock().banking = vec![banking];
    }

    /// Publish ONE backend's bank topology/read stats, leaving the other
    /// backends' groups alone — each worker refreshes only its own
    /// engine after a batch instead of rebuilding every topology.
    pub fn set_backend_banking(&self, idx: usize, banking: Vec<BankReport>) {
        let mut m = self.lock();
        if m.banking.len() <= idx {
            m.banking.resize_with(idx + 1, Vec::new);
        }
        m.banking[idx] = banking;
    }

    /// Publish the intra-op pool gauges (refreshed after every batch, like
    /// the banking stats, so task counters stay live under traffic).
    pub fn set_pool(&self, pool: PoolStats) {
        self.lock().pool = Some(pool);
    }

    /// Declare the deployment's named backends (index order is the
    /// routing order the service uses).  Resets any prior gauges.
    pub fn set_backends(&self, names: &[String]) {
        self.lock().backends = names
            .iter()
            .map(|n| BackendGauge { name: n.clone(), ..BackendGauge::default() })
            .collect();
    }

    /// Account one completed batch to a backend: request/sample counters,
    /// wall latency, and the batch's total modeled hardware energy.
    pub fn record_backend_batch(&self, idx: usize, n_requests: usize,
                                n_samples: usize, hw_energy_j: f64,
                                latency: Duration) {
        let mut m = self.lock();
        if let Some(b) = m.backends.get_mut(idx) {
            b.requests += n_requests as u64;
            b.samples += n_samples as u64;
            b.batches += 1;
            b.hw_energy_j += hw_energy_j;
            b.wall_latency.record(latency.as_secs_f64());
        }
    }

    /// Count one admission reject (full bounded lane) against a backend
    /// — pairs with [`Metrics::record_rejected`], which tracks the
    /// service-wide total.
    pub fn record_backend_rejected(&self, idx: usize) {
        let mut m = self.lock();
        if let Some(b) = m.backends.get_mut(idx) {
            b.rejected += 1;
        }
    }

    /// Refresh a backend lane's queue-depth gauge (queued samples).
    pub fn set_backend_queue(&self, idx: usize, depth: usize) {
        let mut m = self.lock();
        if let Some(b) = m.backends.get_mut(idx) {
            b.queue_depth = depth;
        }
    }

    /// Record a startup degradation (a class rerouted off its planned
    /// backend, e.g. `digital_cond:hlo->rust`).
    pub fn record_degradation(&self, entry: String) {
        self.lock().degraded.push(entry);
    }

    /// Publish the job-queue gauges (pushed by the job runner).
    pub fn set_jobs(&self, gauges: JobGauges) {
        self.lock().jobs = Some(gauges);
    }

    /// Count one engine panic contained by a worker's `catch_unwind`.
    pub fn record_worker_panic(&self) {
        self.lock().worker_panics += 1;
    }

    /// Estimate how long a shed caller should wait before retrying
    /// against backend `idx`, from the lane's observed drain rate: the
    /// backend has served `samples` over `Σ wall_latency` busy-seconds,
    /// so `queued_samples / rate` is the expected time to drain what is
    /// queued now.  Clamped to [10 ms, 10 s]; 100 ms before any batch
    /// has completed (no rate to derive).
    pub fn retry_after_hint_ms(&self, idx: usize, queued_samples: usize) -> u64 {
        let m = self.lock();
        let Some(b) = m.backends.get(idx) else { return 100 };
        let busy_s = b.wall_latency.sum();
        if b.samples == 0 || busy_s <= 0.0 {
            return 100;
        }
        let rate = b.samples as f64 / busy_s; // samples per busy-second
        ((queued_samples as f64 / rate) * 1e3).clamp(10.0, 10_000.0) as u64
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.lock();
        MetricsSnapshot {
            requests: m.requests,
            samples: m.samples,
            batches: m.batches,
            rejected: m.rejected,
            mean_latency_s: m.wall_latency.mean(),
            p99_latency_s: m.wall_latency.p99(),
            mean_batch_fill: m.batch_fill.mean(),
            wall_latency: m.wall_latency.clone(),
            banking: m.banking.iter().flatten().cloned().collect(),
            pool: m.pool.clone(),
            backends: m
                .backends
                .iter()
                .map(|b| BackendSnapshot {
                    name: b.name.clone(),
                    requests: b.requests,
                    samples: b.samples,
                    batches: b.batches,
                    rejected: b.rejected,
                    queue_depth: b.queue_depth,
                    hw_energy_j: b.hw_energy_j,
                    mean_latency_s: b.wall_latency.mean(),
                    wall_latency: b.wall_latency.clone(),
                })
                .collect(),
            degraded: m.degraded.clone(),
            jobs: m.jobs.clone(),
            worker_panics: m.worker_panics,
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub samples: u64,
    pub batches: u64,
    pub rejected: u64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_batch_fill: f64,
    /// The full (bounded, log-bucketed) wall-latency histogram, for the
    /// Prometheus/JSON exporters.
    pub wall_latency: Summary,
    /// Engine bank topology, one entry per score-net layer (empty when the
    /// engine exposes none, e.g. digital baselines).
    pub banking: Vec<BankReport>,
    /// Intra-op pool gauges (None until a service publishes them).
    pub pool: Option<PoolStats>,
    /// Per-backend gauges, in the deployment's backend-index order (empty
    /// until a routed service declares its backends).
    pub backends: Vec<BackendSnapshot>,
    /// Startup degradations (classes rerouted off a failed backend).
    pub degraded: Vec<String>,
    /// Job-queue gauges (None unless a `--state-dir` deployment runs).
    pub jobs: Option<JobGauges>,
    /// Engine panics contained by worker `catch_unwind`.
    pub worker_panics: u64,
}

/// Point-in-time copy of one backend's gauges.
#[derive(Debug, Clone)]
pub struct BackendSnapshot {
    pub name: String,
    pub requests: u64,
    pub samples: u64,
    pub batches: u64,
    /// Admission rejects against this backend's bounded lane.
    pub rejected: u64,
    /// Samples queued in this backend's lane at the last refresh.
    pub queue_depth: usize,
    /// Accumulated modeled hardware energy (J) served by this backend.
    pub hw_energy_j: f64,
    pub mean_latency_s: f64,
    /// The backend's full wall-latency histogram, for the exporters.
    pub wall_latency: Summary,
}

impl BackendSnapshot {
    /// Compact `name[...]` column for the one-line report.
    pub fn summary(&self) -> String {
        format!(
            "{}[q{} rej{} req{} smp{} bat{} lat{:.1}ms e{:.2e}J]",
            self.name,
            self.queue_depth,
            self.rejected,
            self.requests,
            self.samples,
            self.batches,
            1e3 * self.mean_latency_s,
            self.hw_energy_j,
        )
    }
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} samples={} batches={} rejected={} \
             mean_latency={:.3}ms p99={:.3}ms mean_fill={:.1}%",
            self.requests,
            self.samples,
            self.batches,
            self.rejected,
            1e3 * self.mean_latency_s,
            1e3 * self.p99_latency_s,
            100.0 * self.mean_batch_fill,
        );
        if !self.banking.is_empty() {
            // per-layer grid summaries; '*' marks a monolithic oracle layer
            s.push_str(" banks=");
            let layers: Vec<String> =
                self.banking.iter().map(|r| r.summary()).collect();
            s.push_str(&layers.join(","));
        }
        if !self.backends.is_empty() {
            s.push_str(" backend=");
            let cols: Vec<String> =
                self.backends.iter().map(|b| b.summary()).collect();
            s.push_str(&cols.join(","));
        }
        if !self.degraded.is_empty() {
            s.push_str(" degraded=");
            s.push_str(&self.degraded.join(";"));
        }
        if let Some(j) = &self.jobs {
            s.push_str(" jobs=");
            s.push_str(&j.summary());
        }
        if self.worker_panics > 0 {
            s.push_str(&format!(" panics={}", self.worker_panics));
        }
        if let Some(p) = &self.pool {
            s.push_str(&format!(
                " pool=t{}:scopes={}:tasks={}:qmax={}:hist={}",
                p.threads,
                p.scopes_run,
                p.tasks_run,
                p.max_queue_depth,
                p.scope_size_hist
                    .iter()
                    .map(|h| h.to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_batch(3, 40, 0.625, Duration::from_millis(5));
        m.record_batch(1, 64, 1.0, Duration::from_millis(15));
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.samples, 104);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert!((s.mean_latency_s - 0.010).abs() < 1e-9);
        assert!((s.mean_batch_fill - 0.8125).abs() < 1e-9);
    }

    #[test]
    fn report_formats() {
        let m = Metrics::new();
        m.record_batch(1, 1, 1.0, Duration::from_millis(1));
        let r = m.snapshot().report();
        assert!(r.contains("requests=1"));
        assert!(!r.contains("banks="), "no banking published yet");
        assert!(!r.contains("pool="), "no pool gauges published yet");
        assert!(!r.contains("backend="), "no backends declared yet");
        assert!(!r.contains("degraded="), "no degradations recorded yet");
    }

    #[test]
    fn backend_gauges_accumulate_and_report() {
        let m = Metrics::new();
        m.set_backends(&["analog".to_string(), "rust".to_string()]);
        m.record_backend_batch(0, 2, 32, 3.0e-5, Duration::from_millis(4));
        m.record_backend_batch(0, 1, 16, 1.5e-5, Duration::from_millis(2));
        m.record_backend_batch(1, 3, 24, 2.0e-3, Duration::from_millis(8));
        m.set_backend_queue(1, 40);
        // out-of-range indices are ignored, not panics (late worker after
        // a set_backends reset)
        m.record_backend_rejected(0);
        m.record_backend_rejected(0);
        m.record_backend_batch(9, 1, 1, 1.0, Duration::from_millis(1));
        m.set_backend_queue(9, 1);
        m.record_backend_rejected(9);
        let s = m.snapshot();
        assert_eq!(s.backends.len(), 2);
        let a = &s.backends[0];
        assert_eq!((a.requests, a.samples, a.batches), (3, 48, 2));
        assert_eq!(a.rejected, 2, "per-backend rejects accumulate");
        assert_eq!(s.backends[1].rejected, 0);
        assert!((a.hw_energy_j - 4.5e-5).abs() < 1e-12);
        assert!((a.mean_latency_s - 0.003).abs() < 1e-9);
        assert_eq!(s.backends[1].queue_depth, 40);
        let r = s.report();
        assert!(r.contains("backend=analog[q0 rej2 req3 smp48 bat2"), "{r}");
        assert!(r.contains("rust[q40 rej0 req3 smp24 bat1"), "{r}");
    }

    #[test]
    fn degradations_surface_in_report() {
        let m = Metrics::new();
        m.record_degradation("digital_uncond:hlo->rust".into());
        m.record_degradation("digital_cond:hlo->rust".into());
        let s = m.snapshot();
        assert_eq!(s.degraded.len(), 2);
        let r = s.report();
        assert!(
            r.contains("degraded=digital_uncond:hlo->rust;digital_cond:hlo->rust"),
            "{r}"
        );
    }

    #[test]
    fn pool_gauges_surface_in_report() {
        let m = Metrics::new();
        m.set_pool(PoolStats {
            threads: 4,
            scopes_run: 12,
            tasks_run: 96,
            max_queue_depth: 9,
            scope_size_hist: [0, 3, 9, 0, 0],
        });
        let s = m.snapshot();
        assert_eq!(s.pool.as_ref().unwrap().threads, 4);
        let r = s.report();
        assert!(r.contains("pool=t4:scopes=12:tasks=96:qmax=9:hist=0/3/9/0/0"),
                "{r}");
    }

    #[test]
    fn job_gauges_and_panics_surface_in_report() {
        let m = Metrics::new();
        let base = m.snapshot();
        assert!(base.jobs.is_none());
        assert!(!base.report().contains("jobs="), "absent until published");
        assert!(!base.report().contains("panics="), "absent until one lands");
        m.set_jobs(JobGauges {
            queued: 2,
            running: 1,
            done: 3,
            enqueued_total: 6,
            retries_total: 4,
            ..JobGauges::default()
        });
        m.record_worker_panic();
        let s = m.snapshot();
        assert_eq!(s.jobs.as_ref().unwrap().done, 3);
        assert_eq!(s.worker_panics, 1);
        let r = s.report();
        assert!(r.contains("jobs=[q2 run1 fail0 done3 dead0 canc0 enq6 retry4]"),
                "{r}");
        assert!(r.contains("panics=1"), "{r}");
    }

    #[test]
    fn metrics_survive_a_poisoned_mutex() {
        // a contained worker panic can poison the metrics mutex while a
        // guard is held; every later call must recover, not cascade
        let m = Metrics::new();
        m.record_batch(1, 8, 1.0, Duration::from_millis(2));
        let poison = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _g = m.inner.lock().unwrap();
                panic!("worker panic while holding the metrics lock");
            }));
        assert!(poison.is_err());
        assert!(m.inner.is_poisoned(), "precondition: mutex is poisoned");
        m.record_batch(2, 16, 1.0, Duration::from_millis(4));
        m.record_rejected();
        m.record_worker_panic();
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.samples, 24);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.worker_panics, 1);
        assert!(s.report().contains("requests=3"));
    }

    #[test]
    fn retry_after_hint_tracks_drain_rate() {
        let m = Metrics::new();
        m.set_backends(&["analog".to_string()]);
        // no data yet: conservative default
        assert_eq!(m.retry_after_hint_ms(0, 64), 100);
        assert_eq!(m.retry_after_hint_ms(9, 64), 100, "unknown backend");
        // 32 samples per 100ms busy → 320 samples/s; 64 queued → 200ms
        m.record_backend_batch(0, 4, 32, 0.0, Duration::from_millis(100));
        let hint = m.retry_after_hint_ms(0, 64);
        assert!((190..=210).contains(&hint), "hint={hint}");
        // clamped below and above
        assert_eq!(m.retry_after_hint_ms(0, 0), 10);
        assert_eq!(m.retry_after_hint_ms(0, 1_000_000), 10_000);
    }

    #[test]
    fn banking_topology_surfaces_in_report() {
        use crate::crossbar::{BankReport, BankStat};
        let m = Metrics::new();
        m.set_banking(vec![BankReport {
            layer: 0,
            rows: 48,
            cols: 48,
            tile_rows: 2,
            tile_cols: 2,
            reads: 28,
            banks: vec![
                BankStat { reads: 7, ..BankStat::default() },
                BankStat { reads: 7, ..BankStat::default() },
                BankStat { reads: 7, ..BankStat::default() },
                BankStat { reads: 7, ..BankStat::default() },
            ],
        }]);
        let s = m.snapshot();
        assert_eq!(s.banking.len(), 1);
        assert_eq!(s.banking[0].n_banks(), 4);
        assert_eq!(s.banking[0].total_reads(), 28);
        let r = s.report();
        assert!(r.contains("banks=L0:2x2(reads=28)"), "{r}");
    }
}
