//! Service metrics: request/sample counters and latency summaries.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::Summary;

#[derive(Default)]
struct Inner {
    requests: u64,
    samples: u64,
    batches: u64,
    rejected: u64,
    wall_latency: Summary,
    batch_fill: Summary,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_batch(&self, n_requests: usize, n_samples: usize, fill: f64,
                        latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.requests += n_requests as u64;
        m.samples += n_samples as u64;
        m.batches += 1;
        m.wall_latency.record(latency.as_secs_f64());
        m.batch_fill.record(fill);
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: m.requests,
            samples: m.samples,
            batches: m.batches,
            rejected: m.rejected,
            mean_latency_s: m.wall_latency.mean(),
            p99_latency_s: m.wall_latency.p99(),
            mean_batch_fill: m.batch_fill.mean(),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub samples: u64,
    pub batches: u64,
    pub rejected: u64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_batch_fill: f64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "requests={} samples={} batches={} rejected={} \
             mean_latency={:.3}ms p99={:.3}ms mean_fill={:.1}%",
            self.requests,
            self.samples,
            self.batches,
            self.rejected,
            1e3 * self.mean_latency_s,
            1e3 * self.p99_latency_s,
            100.0 * self.mean_batch_fill,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_batch(3, 40, 0.625, Duration::from_millis(5));
        m.record_batch(1, 64, 1.0, Duration::from_millis(15));
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.samples, 104);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert!((s.mean_latency_s - 0.010).abs() < 1e-9);
        assert!((s.mean_batch_fill - 0.8125).abs() < 1e-9);
    }

    #[test]
    fn report_formats() {
        let m = Metrics::new();
        m.record_batch(1, 1, 1.0, Duration::from_millis(1));
        let r = m.snapshot().report();
        assert!(r.contains("requests=1"));
    }
}
