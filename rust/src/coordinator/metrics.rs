//! Service metrics: request/sample counters, latency summaries, the
//! engine's macro-bank topology (grid shape + per-bank program/read stats,
//! refreshed after every batch so read counters stay live), and the
//! intra-op pool gauges (threads, scopes/tasks run, queue high-water mark,
//! tasks-per-scope histogram) from [`crate::exec`].

use std::sync::Mutex;
use std::time::Duration;

use crate::crossbar::BankReport;
use crate::exec::PoolStats;
use crate::util::stats::Summary;

#[derive(Default)]
struct Inner {
    requests: u64,
    samples: u64,
    batches: u64,
    rejected: u64,
    wall_latency: Summary,
    batch_fill: Summary,
    banking: Vec<BankReport>,
    pool: Option<PoolStats>,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_batch(&self, n_requests: usize, n_samples: usize, fill: f64,
                        latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.requests += n_requests as u64;
        m.samples += n_samples as u64;
        m.batches += 1;
        m.wall_latency.record(latency.as_secs_f64());
        m.batch_fill.record(fill);
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Publish the engine's bank topology + per-bank stats (the service
    /// refreshes this after every batch so the read counters stay live).
    pub fn set_banking(&self, banking: Vec<BankReport>) {
        self.inner.lock().unwrap().banking = banking;
    }

    /// Publish the intra-op pool gauges (refreshed after every batch, like
    /// the banking stats, so task counters stay live under traffic).
    pub fn set_pool(&self, pool: PoolStats) {
        self.inner.lock().unwrap().pool = Some(pool);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: m.requests,
            samples: m.samples,
            batches: m.batches,
            rejected: m.rejected,
            mean_latency_s: m.wall_latency.mean(),
            p99_latency_s: m.wall_latency.p99(),
            mean_batch_fill: m.batch_fill.mean(),
            banking: m.banking.clone(),
            pool: m.pool.clone(),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub samples: u64,
    pub batches: u64,
    pub rejected: u64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_batch_fill: f64,
    /// Engine bank topology, one entry per score-net layer (empty when the
    /// engine exposes none, e.g. digital baselines).
    pub banking: Vec<BankReport>,
    /// Intra-op pool gauges (None until a service publishes them).
    pub pool: Option<PoolStats>,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} samples={} batches={} rejected={} \
             mean_latency={:.3}ms p99={:.3}ms mean_fill={:.1}%",
            self.requests,
            self.samples,
            self.batches,
            self.rejected,
            1e3 * self.mean_latency_s,
            1e3 * self.p99_latency_s,
            100.0 * self.mean_batch_fill,
        );
        if !self.banking.is_empty() {
            // per-layer grid summaries; '*' marks a monolithic oracle layer
            s.push_str(" banks=");
            let layers: Vec<String> =
                self.banking.iter().map(|r| r.summary()).collect();
            s.push_str(&layers.join(","));
        }
        if let Some(p) = &self.pool {
            s.push_str(&format!(
                " pool=t{}:scopes={}:tasks={}:qmax={}:hist={}",
                p.threads,
                p.scopes_run,
                p.tasks_run,
                p.max_queue_depth,
                p.scope_size_hist
                    .iter()
                    .map(|h| h.to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_batch(3, 40, 0.625, Duration::from_millis(5));
        m.record_batch(1, 64, 1.0, Duration::from_millis(15));
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.samples, 104);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert!((s.mean_latency_s - 0.010).abs() < 1e-9);
        assert!((s.mean_batch_fill - 0.8125).abs() < 1e-9);
    }

    #[test]
    fn report_formats() {
        let m = Metrics::new();
        m.record_batch(1, 1, 1.0, Duration::from_millis(1));
        let r = m.snapshot().report();
        assert!(r.contains("requests=1"));
        assert!(!r.contains("banks="), "no banking published yet");
        assert!(!r.contains("pool="), "no pool gauges published yet");
    }

    #[test]
    fn pool_gauges_surface_in_report() {
        let m = Metrics::new();
        m.set_pool(PoolStats {
            threads: 4,
            scopes_run: 12,
            tasks_run: 96,
            max_queue_depth: 9,
            scope_size_hist: [0, 3, 9, 0, 0],
        });
        let s = m.snapshot();
        assert_eq!(s.pool.as_ref().unwrap().threads, 4);
        let r = s.report();
        assert!(r.contains("pool=t4:scopes=12:tasks=96:qmax=9:hist=0/3/9/0/0"),
                "{r}");
    }

    #[test]
    fn banking_topology_surfaces_in_report() {
        use crate::crossbar::{BankReport, BankStat};
        let m = Metrics::new();
        m.set_banking(vec![BankReport {
            layer: 0,
            rows: 48,
            cols: 48,
            tile_rows: 2,
            tile_cols: 2,
            reads: 28,
            banks: vec![
                BankStat { reads: 7, ..BankStat::default() },
                BankStat { reads: 7, ..BankStat::default() },
                BankStat { reads: 7, ..BankStat::default() },
                BankStat { reads: 7, ..BankStat::default() },
            ],
        }]);
        let s = m.snapshot();
        assert_eq!(s.banking.len(), 1);
        assert_eq!(s.banking[0].n_banks(), 4);
        assert_eq!(s.banking[0].total_reads(), 28);
        let r = s.report();
        assert!(r.contains("banks=L0:2x2(reads=28)"), "{r}");
    }
}
