//! L3 coordinator: the routed generation service.
//!
//! The paper's system serves *sampling requests*: a client asks for N
//! samples of a task (unconditional circle, or a conditioned letter), and
//! the hardware answers with latent samples (optionally decoded to
//! pixels).  The paper's own evaluation runs the two solver families on
//! *different substrates* — the analog integrator and the digital
//! baseline side by side — so the serving layer is a **deployment
//! router**, not a single-engine queue.
//!
//! Flow of one request (submit → class → backend → lane → ticket):
//!
//! 1. [`request`] — the request names a solver; its
//!    [`request::RequestClass`] (solver family × conditional) is the
//!    routing unit.
//! 2. [`deploy`] — the [`deploy::EngineRegistry`] maps that class to a
//!    named backend (`analog` simulator / `rust` digital / `hlo` PJRT
//!    artifacts), per the config-driven [`deploy::DeployPlan`] (routes,
//!    per-backend workers / queue bounds / weight paths); a failed
//!    `hlo` construction degrades its classes to `rust` at startup
//!    (recorded in metrics) instead of failing the deployment.
//! 3. [`batcher`] — each backend owns one lane of the
//!    [`batcher::LaneSet`]: a dynamic batching queue coalescing by
//!    (condition, solver, decode) key up to the artifact batch size with a
//!    linger timeout — the same size-or-deadline policy a vLLM-style
//!    router uses, but per class, so a slow analog batch never
//!    head-of-line-blocks digital traffic.  Lanes are **bounded**
//!    (`[service] queue_depth`, per-backend `<backend>_queue`): a full
//!    lane rejects at admission ([`batcher::SubmitOutcome::Overloaded`])
//!    instead of hiding overload in an unbounded queue.
//! 4. [`service`] — the [`service::Service`] facade.  Ingress is
//!    nonblocking: `submit_nb` returns a response
//!    [`Ticket`](crate::serve::Ticket) completed through per-lane maps
//!    (see [`crate::serve`] — poll, deadline-wait, block, or waker); the
//!    blocking `submit`/`generate` wrap the same path.  Per-backend
//!    worker allotments execute each lane's batches against that
//!    backend's engine, plus the compute-vs-programming
//!    [`service::ModeGate`] mirroring the PCB's SPDT mode switches.
//!    Shutdown drains **every** lane under the no-dropped-request
//!    invariant and fails any leftover ticket (no stranded waiter).
//! 5. [`metrics`] — totals plus per-backend queue-depth / reject /
//!    throughput / hardware-energy gauges (`backend=` column) and any
//!    startup degradations (`degraded=` column).
//!
//! The TCP edge over this core — wire protocol, connection handling,
//! graceful drain — lives in [`crate::serve`].

pub mod batcher;
pub mod deploy;
pub mod metrics;
pub mod request;
pub mod service;

/// Shared engine stubs for the coordinator unit tests.
#[cfg(test)]
pub(crate) mod testutil {
    use super::request::SolverChoice;
    use super::service::Engine;
    use crate::util::rng::Rng;

    /// Engine stamping every sample with a constant tag, so routing tests
    /// can prove which backend served a request.
    pub struct TagEngine(pub f32);

    impl Engine for TagEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, _onehot: &[f32], _g: f32,
                    n: usize, _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            Ok(vec![self.0; n * 2])
        }
    }
}

pub use batcher::{Batch, Batcher, BatcherConfig, LaneSet, SubmitOutcome};
pub use deploy::{BackendKind, DeployPlan, EngineRegistry};
pub use metrics::{JobGauges, Metrics};
pub use request::{GenRequest, GenResponse, RequestClass, SolverChoice,
                  SolverFamily, TaskKind};
pub use service::{ModeGate, Service, ServiceConfig};

// the structured admission error `submit_nb` returns (defined next to the
// rest of the serving-edge taxonomy in `crate::serve`)
pub use crate::serve::admission::SubmitError;
