//! L3 coordinator: the generation service.
//!
//! The paper's system serves *sampling requests*: a client asks for N
//! samples of a task (unconditional circle, or a conditioned letter), and
//! the hardware answers with latent samples (optionally decoded to
//! pixels).  This module is the serving layer around the solvers:
//!
//! * [`request`] — request/response types and solver selection.
//! * [`batcher`] — dynamic batching queue: requests coalesce by
//!   (condition, solver) key up to the artifact batch size, with a linger
//!   timeout — the same size-or-deadline policy a vLLM-style router uses.
//! * [`service`] — worker pool executing batches against one of the three
//!   engines (analog simulator / rust digital / PJRT artifacts), plus the
//!   compute-vs-programming [`service::ModeGate`] mirroring the PCB's
//!   SPDT mode switches.
//! * [`metrics`] — latency/throughput counters.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod service;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{GenRequest, GenResponse, SolverChoice, TaskKind};
pub use service::{ModeGate, Service, ServiceConfig};
