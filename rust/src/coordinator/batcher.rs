//! Dynamic batching queue.
//!
//! Requests coalesce by [`GenRequest::batch_key`] (same condition, solver
//! and decode flag) until either the batch reaches `max_batch_samples` or
//! the oldest member has waited `linger` — the size-or-deadline policy of
//! serving routers.  Invariants (property-tested):
//!
//! 1. every submitted request appears in exactly one emitted batch
//!    **or** was rejected at submit (closed / over the queue bound) —
//!    never both, never neither;
//! 2. batches never mix keys;
//! 3. a batch's sample total never exceeds `max_batch_samples` unless a
//!    single oversized request needs its own batch;
//! 4. requests with the same key dequeue FIFO.
//!
//! Queues are **bounded** when `queue_depth > 0`: a submit that would
//! push the queued-sample total past the bound is answered
//! [`SubmitOutcome::Overloaded`] without enqueueing — admission-time
//! backpressure for the serving front-end (the caller sheds or retries;
//! the queue never hides overload by growing without limit).

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::GenRequest;

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max total samples per emitted batch (pairs with the largest AOT
    /// artifact batch — 64 by default).
    pub max_batch_samples: usize,
    /// Max time the oldest queued request waits before a partial batch is
    /// emitted.
    pub linger: Duration,
    /// Queue bound in **samples** (0 = unbounded, the library default;
    /// the CLI config defaults to a finite `[service] queue_depth`).  A
    /// submit that would exceed it is rejected `Overloaded` — except an
    /// oversized single request on an *empty* queue, which is admitted
    /// (mirroring the oversized-request-ships-alone batching rule, so a
    /// request larger than the bound is not unservable by construction).
    pub queue_depth: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(2),
            queue_depth: 0,
        }
    }
}

/// What happened to a submitted request — admission is the only place a
/// request can be refused, so the outcome is structured rather than a
/// bool (the service maps it onto the `SubmitError` taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Enqueued; `queued_samples` is the lane's post-admission fill
    /// (the live queue-depth gauge).
    Accepted { queued_samples: usize },
    /// The bounded queue is full: not enqueued, caller sheds load.
    Overloaded { queued_samples: usize, queue_depth: usize },
    /// The queue is closed (drain in progress): not enqueued.
    Closed,
}

impl SubmitOutcome {
    pub fn is_accepted(&self) -> bool {
        matches!(self, SubmitOutcome::Accepted { .. })
    }
}

/// An emitted batch: requests sharing one key.
#[derive(Debug)]
pub struct Batch {
    pub key: u64,
    pub requests: Vec<GenRequest>,
    /// Per-request queue wait (submit → assembly), parallel to
    /// `requests` — the worker turns these into `queue`/`batch_form`
    /// trace spans without re-deriving submit times.
    pub waits: Vec<Duration>,
}

impl Batch {
    pub fn total_samples(&self) -> usize {
        self.requests.iter().map(|r| r.n_samples).sum()
    }
}

struct Queued {
    req: GenRequest,
    at: Instant,
}

struct State {
    queue: VecDeque<Queued>,
    closed: bool,
    /// Running total of queued samples per batch key, maintained on
    /// submit/assemble so `next_batch` reads the head key's fill level in
    /// O(1) per condvar wakeup instead of rescanning the whole queue.
    key_samples: HashMap<u64, usize>,
    /// Running total across all keys — the O(1) admission check against
    /// `queue_depth` and the queue-depth gauge.
    queued_samples: usize,
}

/// Thread-safe dynamic batcher.
pub struct Batcher {
    cfg: BatcherConfig,
    state: Mutex<State>,
    cv: Condvar,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            cfg,
            state: Mutex::new(State {
                queue: VecDeque::new(),
                closed: false,
                key_samples: HashMap::new(),
                queued_samples: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request (non-blocking, never waits for space).  The
    /// admission decision — and nothing else — happens here: closed
    /// queues and full bounded queues answer without enqueueing.
    #[must_use = "a rejected request must be answered, not dropped"]
    pub fn submit(&self, req: GenRequest) -> SubmitOutcome {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return SubmitOutcome::Closed;
        }
        if self.cfg.queue_depth > 0
            && st.queued_samples > 0
            && st.queued_samples + req.n_samples > self.cfg.queue_depth
        {
            return SubmitOutcome::Overloaded {
                queued_samples: st.queued_samples,
                queue_depth: self.cfg.queue_depth,
            };
        }
        *st.key_samples.entry(req.batch_key()).or_insert(0) += req.n_samples;
        st.queued_samples += req.n_samples;
        let queued_samples = st.queued_samples;
        st.queue.push_back(Queued { req, at: Instant::now() });
        self.cv.notify_one();
        SubmitOutcome::Accepted { queued_samples }
    }

    /// Close the queue; pending requests still drain.  Every caller
    /// blocked in [`Self::next_batch`] — waiting on an empty queue *or*
    /// lingering on a partial batch — is woken promptly (`notify_all`),
    /// so shutdown latency never depends on the linger deadline.  With
    /// intra-op pool threads multiplying worker wakeups, a lost or lazy
    /// wakeup here would strand a worker for a full linger window.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total queued samples (running counter, O(1)) — the queue-depth
    /// gauge the per-backend metrics report and the admission check.
    pub fn queued_samples(&self) -> usize {
        self.state.lock().unwrap().queued_samples
    }

    /// The configured queue bound in samples (0 = unbounded).
    pub fn queue_depth(&self) -> usize {
        self.cfg.queue_depth
    }

    /// Blocking: wait for and assemble the next batch.  Returns None once
    /// closed *and* drained.
    pub fn next_batch(&self) -> Option<Batch> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(head_at) = st.queue.front().map(|q| q.at) {
                // Wait until the head's linger expires or enough same-key
                // work arrives to fill a batch.  The per-key running count
                // makes this an O(1) lookup per wakeup.
                let key = st.queue.front().unwrap().req.batch_key();
                let same_key_samples: usize =
                    st.key_samples.get(&key).copied().unwrap_or(0);
                let deadline = head_at + self.cfg.linger;
                let now = Instant::now();
                if same_key_samples >= self.cfg.max_batch_samples
                    || now >= deadline
                    || st.closed
                {
                    return Some(self.assemble(&mut st, key));
                }
                let (guard, _timeout) =
                    self.cv.wait_timeout(st, deadline - now).unwrap();
                st = guard;
            } else if st.closed {
                return None;
            } else {
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    /// Pull the head-key requests (FIFO among that key) up to the sample
    /// budget; the head request always ships even if oversized.
    fn assemble(&self, st: &mut State, key: u64) -> Batch {
        let mut requests = Vec::new();
        let mut waits = Vec::new();
        let now = Instant::now();
        let mut total = 0usize;
        let mut i = 0;
        while i < st.queue.len() {
            let q = &st.queue[i];
            if q.req.batch_key() != key {
                i += 1;
                continue;
            }
            let n = q.req.n_samples;
            if !requests.is_empty() && total + n > self.cfg.max_batch_samples {
                break;
            }
            let q = st.queue.remove(i).unwrap();
            total += q.req.n_samples;
            waits.push(now.saturating_duration_since(q.at));
            requests.push(q.req);
            if total >= self.cfg.max_batch_samples {
                break;
            }
        }
        // keep the running per-key and total counts exact
        if let Some(cnt) = st.key_samples.get_mut(&key) {
            *cnt = cnt.saturating_sub(total);
            if *cnt == 0 {
                st.key_samples.remove(&key);
            }
        }
        st.queued_samples = st.queued_samples.saturating_sub(total);
        Batch { key, requests, waits }
    }
}

/// Per-backend batching lanes behind one submit surface.
///
/// The deployment router gives every backend its **own** [`Batcher`], so
/// coalescing stays per-class and a slow lane (a 2000-substep analog
/// batch) can never head-of-line-block another backend's traffic.  The
/// shutdown contract extends the single-lane one: [`Self::close_all`]
/// closes *every* lane, each lane still drains fully (close wakes all
/// blocked `next_batch` callers promptly, queued work ships first), and
/// the service asserts no request is dropped with a pending response
/// entry across any lane.
pub struct LaneSet {
    lanes: Vec<Arc<Batcher>>,
}

impl LaneSet {
    /// One lane per backend, all sharing the same batching policy.
    pub fn new(n_lanes: usize, cfg: &BatcherConfig) -> Self {
        Self::with_configs((0..n_lanes).map(|_| cfg.clone()).collect())
    }

    /// One lane per config — the deployment router passes per-backend
    /// queue bounds here (`<backend>_queue` overrides), so a slow
    /// backend can run a shallow shed-early queue while others keep the
    /// service-wide depth.
    pub fn with_configs(cfgs: Vec<BatcherConfig>) -> Self {
        LaneSet {
            lanes: cfgs.into_iter().map(|c| Arc::new(Batcher::new(c))).collect(),
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane(&self, idx: usize) -> &Arc<Batcher> {
        &self.lanes[idx]
    }

    /// Submit to one lane (non-blocking admission — see
    /// [`Batcher::submit`]).
    #[must_use = "a rejected request must be answered, not dropped"]
    pub fn submit(&self, idx: usize, req: GenRequest) -> SubmitOutcome {
        self.lanes[idx].submit(req)
    }

    /// Close every lane; queued work still drains per lane.
    pub fn close_all(&self) {
        for lane in &self.lanes {
            lane.close();
        }
    }

    /// Total queued requests across lanes.
    pub fn queued_requests(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{SolverChoice, TaskKind};
    use crate::util::ptest;
    use crate::util::rng::Rng;

    fn req(id: u64, class: usize, n: usize) -> GenRequest {
        GenRequest {
            id,
            task: TaskKind::Letter(class),
            n_samples: n,
            solver: SolverChoice::DigitalOde { steps: 100 },
            trace: crate::obs::TraceId::NONE,
            guidance: 2.0,
            decode: false,
        }
    }

    fn drain(b: &Batcher) -> Vec<Batch> {
        b.close();
        let mut out = Vec::new();
        while let Some(batch) = b.next_batch() {
            out.push(batch);
        }
        out
    }

    #[test]
    fn single_request_emits_one_batch() {
        let b = Batcher::new(BatcherConfig::default());
        assert!(b.submit(req(1, 0, 10)).is_accepted());
        let batches = drain(&b);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests[0].id, 1);
    }

    #[test]
    fn same_key_coalesces() {
        let b = Batcher::new(BatcherConfig::default());
        for id in 0..4 {
            assert!(b.submit(req(id, 0, 10)).is_accepted());
        }
        let batches = drain(&b);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].total_samples(), 40);
    }

    #[test]
    fn different_keys_do_not_mix() {
        let b = Batcher::new(BatcherConfig::default());
        for r in [req(0, 0, 8), req(1, 1, 8), req(2, 0, 8)] {
            assert!(b.submit(r).is_accepted());
        }
        let batches = drain(&b);
        for batch in &batches {
            let keys: std::collections::HashSet<u64> =
                batch.requests.iter().map(|r| r.batch_key()).collect();
            assert_eq!(keys.len(), 1);
        }
        // class-0 requests coalesce despite the interleaved class-1
        let class0: Vec<&Batch> = batches
            .iter()
            .filter(|b| matches!(b.requests[0].task, TaskKind::Letter(0)))
            .collect();
        assert_eq!(class0.len(), 1);
        assert_eq!(class0[0].requests.len(), 2);
    }

    #[test]
    fn size_cap_splits_batches() {
        let b = Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(1),
            ..BatcherConfig::default()
        });
        for id in 0..5 {
            assert!(b.submit(req(id, 0, 20)).is_accepted());
        }
        let batches = drain(&b);
        for batch in &batches {
            assert!(batch.total_samples() <= 64, "{}", batch.total_samples());
        }
        let total: usize = batches.iter().map(|b| b.total_samples()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn oversized_request_ships_alone() {
        let b = Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(1),
            ..BatcherConfig::default()
        });
        assert!(b.submit(req(0, 0, 500)).is_accepted());
        assert!(b.submit(req(1, 0, 4)).is_accepted());
        let batches = drain(&b);
        assert_eq!(batches[0].requests.len(), 1);
        assert_eq!(batches[0].total_samples(), 500);
    }

    #[test]
    fn closed_queue_rejects_submissions() {
        let b = Batcher::new(BatcherConfig::default());
        b.close();
        assert_eq!(b.submit(req(0, 0, 1)), SubmitOutcome::Closed);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn linger_emits_partial_batch() {
        let b = std::sync::Arc::new(Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(20),
            ..BatcherConfig::default()
        }));
        assert!(b.submit(req(0, 0, 4)).is_accepted());
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch.total_samples(), 4);
        assert!(waited >= Duration::from_millis(10), "{waited:?}");
        b.close();
    }

    #[test]
    fn close_wakes_all_blocked_callers_promptly() {
        // several callers blocked on an empty queue, plus one lingering on
        // a partial batch with a long deadline: close() must release them
        // all far sooner than the linger window
        let b = std::sync::Arc::new(Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_secs(30),
            ..BatcherConfig::default()
        }));
        let _ = b.submit(req(0, 0, 4)); // makes one caller linger instead of idle
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let b = std::sync::Arc::clone(&b);
                std::thread::spawn(move || {
                    let mut seen = 0usize;
                    while b.next_batch().is_some() {
                        seen += 1;
                    }
                    seen
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        b.close();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(t0.elapsed() < Duration::from_secs(5),
                "blocked callers must wake promptly, not after the linger");
        assert_eq!(total, 1, "the queued request still drains exactly once");
    }

    #[test]
    fn key_counts_stay_exact_across_cycles() {
        // interleave submits and pops: the running per-key count must keep
        // matching a full queue rescan at every step
        let b = Batcher::new(BatcherConfig {
            max_batch_samples: 16,
            linger: Duration::from_millis(0),
            ..BatcherConfig::default()
        });
        let mut id = 0u64;
        for round in 0..4 {
            for k in 0..3usize {
                assert!(b.submit(req(id, k, 3 + round)).is_accepted());
                id += 1;
            }
            {
                let st = b.state.lock().unwrap();
                for (&key, &cnt) in &st.key_samples {
                    let rescan: usize = st
                        .queue
                        .iter()
                        .filter(|q| q.req.batch_key() == key)
                        .map(|q| q.req.n_samples)
                        .sum();
                    assert_eq!(cnt, rescan, "key {key} round {round}");
                }
            }
            let batch = b.next_batch().unwrap();
            assert!(!batch.requests.is_empty());
        }
        // drain the rest; the map must end empty
        let _ = drain(&b);
        assert!(b.state.lock().unwrap().key_samples.is_empty());
    }

    #[test]
    fn queued_samples_track_submissions() {
        let b = Batcher::new(BatcherConfig::default());
        assert_eq!(b.queued_samples(), 0);
        let _ = b.submit(req(0, 0, 10));
        let _ = b.submit(req(1, 1, 5));
        assert_eq!(b.queued_samples(), 15);
        let _ = drain(&b);
        assert_eq!(b.queued_samples(), 0);
    }

    #[test]
    fn bounded_queue_rejects_at_depth_and_recovers() {
        let b = Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(0),
            queue_depth: 10,
        });
        assert_eq!(b.queue_depth(), 10);
        assert_eq!(b.submit(req(0, 0, 6)),
                   SubmitOutcome::Accepted { queued_samples: 6 });
        assert_eq!(b.submit(req(1, 0, 4)),
                   SubmitOutcome::Accepted { queued_samples: 10 });
        // full: the next sample over the bound is shed, not queued
        assert_eq!(b.submit(req(2, 0, 1)),
                   SubmitOutcome::Overloaded { queued_samples: 10, queue_depth: 10 });
        assert_eq!(b.queued_samples(), 10, "reject must not enqueue");
        // draining a batch frees capacity again
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.total_samples(), 10);
        assert!(b.submit(req(3, 0, 10)).is_accepted());
        let _ = drain(&b);
    }

    #[test]
    fn bound_applies_across_keys() {
        // the bound is per lane, not per key: two keys share the budget
        let b = Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(0),
            queue_depth: 8,
        });
        assert!(b.submit(req(0, 0, 5)).is_accepted());
        assert!(b.submit(req(1, 1, 3)).is_accepted());
        assert!(matches!(b.submit(req(2, 2, 1)),
                         SubmitOutcome::Overloaded { .. }));
        let _ = drain(&b);
    }

    #[test]
    fn oversized_request_admitted_only_on_empty_queue() {
        let b = Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(0),
            queue_depth: 8,
        });
        // larger than the bound but the queue is empty: admitted (the
        // oversized-ships-alone rule — otherwise it could never run)
        assert!(b.submit(req(0, 0, 500)).is_accepted());
        // now the queue is non-empty: everything further is shed
        assert!(matches!(b.submit(req(1, 0, 1)),
                         SubmitOutcome::Overloaded { .. }));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.total_samples(), 500);
        assert!(b.submit(req(2, 0, 1)).is_accepted());
        let _ = drain(&b);
    }

    #[test]
    fn closed_wins_over_overloaded() {
        let b = Batcher::new(BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(0),
            queue_depth: 4,
        });
        assert!(b.submit(req(0, 0, 4)).is_accepted());
        b.close();
        // a closed full queue reports Closed (drain state), not Overloaded
        assert_eq!(b.submit(req(1, 0, 4)), SubmitOutcome::Closed);
    }

    #[test]
    fn lane_set_per_lane_queue_bounds() {
        let set = LaneSet::with_configs(vec![
            BatcherConfig {
                max_batch_samples: 64,
                linger: Duration::from_millis(0),
                queue_depth: 2,
            },
            BatcherConfig {
                max_batch_samples: 64,
                linger: Duration::from_millis(0),
                queue_depth: 0, // unbounded
            },
        ]);
        assert!(set.submit(0, req(0, 0, 2)).is_accepted());
        assert!(matches!(set.submit(0, req(1, 0, 1)),
                         SubmitOutcome::Overloaded { queue_depth: 2, .. }),
                "lane 0 is full");
        for id in 10..40 {
            assert!(set.submit(1, req(id, 1, 8)).is_accepted(),
                    "lane 1 is unbounded and unaffected by lane 0's bound");
        }
        set.close_all();
    }

    #[test]
    fn lane_set_isolates_lanes() {
        let set = LaneSet::new(2, &BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_millis(0),
            ..BatcherConfig::default()
        });
        assert_eq!(set.n_lanes(), 2);
        assert!(set.submit(0, req(1, 0, 4)).is_accepted());
        assert!(set.submit(1, req(2, 1, 6)).is_accepted());
        assert_eq!(set.queued_requests(), 2);
        // closing lane 0 alone leaves lane 1 accepting work
        set.lane(0).close();
        assert_eq!(set.submit(0, req(3, 0, 1)), SubmitOutcome::Closed);
        assert!(set.submit(1, req(4, 1, 1)).is_accepted());
        // lane 0 still drains its queued request after close
        let batch = set.lane(0).next_batch().unwrap();
        assert_eq!(batch.requests[0].id, 1);
        assert!(set.lane(0).next_batch().is_none());
    }

    #[test]
    fn close_all_drains_every_lane() {
        let set = LaneSet::new(3, &BatcherConfig {
            max_batch_samples: 64,
            linger: Duration::from_secs(30),
            ..BatcherConfig::default()
        });
        for lane in 0..3 {
            for k in 0..2 {
                assert!(set
                    .submit(lane, req((lane * 10 + k) as u64, lane % 3, 3))
                    .is_accepted());
            }
        }
        set.close_all();
        for lane in 0..3 {
            let mut ids = Vec::new();
            while let Some(batch) = set.lane(lane).next_batch() {
                ids.extend(batch.requests.iter().map(|r| r.id));
            }
            assert_eq!(ids.len(), 2, "lane {lane} must drain fully");
        }
        assert_eq!(set.queued_requests(), 0);
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        ptest::check_msg(
            "batcher conservation",
            |rng: &mut Rng| {
                let n_reqs = 1 + rng.below(40);
                (0..n_reqs)
                    .map(|id| {
                        req(id as u64, rng.below(3), 1 + rng.below(30))
                    })
                    .collect::<Vec<_>>()
            },
            |reqs| {
                let b = Batcher::new(BatcherConfig {
                    max_batch_samples: 64,
                    linger: Duration::from_millis(0),
                    ..BatcherConfig::default()
                });
                for r in reqs {
                    assert!(b.submit(r.clone()).is_accepted());
                }
                let batches = drain(&b);
                let mut seen: Vec<u64> = batches
                    .iter()
                    .flat_map(|b| b.requests.iter().map(|r| r.id))
                    .collect();
                seen.sort();
                let mut want: Vec<u64> = reqs.iter().map(|r| r.id).collect();
                want.sort();
                if seen != want {
                    return Err(format!("ids {seen:?} != {want:?}"));
                }
                // FIFO within key
                for batch in &batches {
                    let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
                    let mut sorted = ids.clone();
                    sorted.sort();
                    if ids != sorted {
                        return Err(format!("not FIFO within batch: {ids:?}"));
                    }
                }
                Ok(())
            },
        );
    }
}
