//! The generation service: a routed deployment of engines behind one
//! submit surface.
//!
//! A [`Service`] is the **router facade** over an
//! [`EngineRegistry`](super::deploy::EngineRegistry): every registered
//! backend owns its own [`Batcher`](super::batcher::Batcher) lane (see
//! [`LaneSet`](super::batcher::LaneSet)) and its own worker allotment, and
//! `submit` routes each request by its [`RequestClass`] (solver family ×
//! conditional) to the backend's lane.  Coalescing therefore stays
//! per-class, and a slow analog batch can never head-of-line-block
//! digital traffic.  [`Service::start`] remains the thin one-backend
//! deployment (one engine serving every class) for tests and back-compat;
//! [`Service::start_routed`] hosts a full multi-backend table.
//!
//! Ingress is **nonblocking**: [`Service::submit_nb`] routes by class,
//! enqueues against the lane's *bounded* queue (per-lane backpressure —
//! a full lane answers [`SubmitError::Overloaded`] without blocking the
//! caller or touching other lanes), and returns a
//! [`Ticket`](crate::serve::Ticket) whose result arrives through the
//! per-lane [`TicketBoard`](crate::serve::TicketBoard) — poll it, wait
//! with a deadline, block on it, or register a waker
//! ([`Notify`](crate::serve::Notify)) to multiplex many tickets.  The
//! blocking [`Service::submit`] / [`Service::generate`] are thin
//! wrappers over the same path, so ticket payloads are bitwise-identical
//! to the blocking ones by construction (`rust/tests/frontend_serve.rs`
//! proves it end-to-end).
//!
//! Each emitted batch runs on one of its backend's workers against that
//! backend's [`Engine`]; results are split back to the originating
//! requests in FIFO order and delivered through the ticket board.  The
//! rust engines execute each batch through the batched lane
//! (`sample_batched` / `solve_batched`), so a coalesced 64-sample batch is
//! one sequence of B×dim GEMMs rather than 64 independent single-vector
//! solves — the coalescing actually pays off.
//!
//! The [`ModeGate`] mirrors the PCB's SPDT switches (Methods): the macro
//! is either in *computation* mode (any number of concurrent solves) or
//! *programming* mode (exclusive — weights being rewritten).  Workers take
//! the compute side; reprogramming takes the exclusive side.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{Batch, BatcherConfig, LaneSet, SubmitOutcome};
use super::deploy::EngineRegistry;
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse, SolverChoice, TaskKind};
use crate::analog::solver::{AnalogSolver, SolverConfig, SolverMode};
use crate::crossbar::{BankReport, LayerDrift};
use crate::device::array::ProgramStats;
use crate::exec::{self, Pool};
use crate::diffusion::sampler::{DigitalSampler, SamplerKind, SamplerMode};
use crate::diffusion::schedule::VpSchedule;
use crate::energy::model::{AnalogCost, DigitalCost};
use crate::nn::{AnalogScoreNet, DigitalScoreNet, ScoreNet};
use crate::obs::health::DeviceHealth;
use crate::obs::{self, Stage};
use crate::runtime::ArtifactStore;
use crate::serve::admission::SubmitError;
use crate::serve::ticket::{Ticket, TicketBoard};
use crate::util::rng::Rng;
use crate::vae::PixelDecoder;

/// A sampling backend the service can drive.
pub trait Engine: Send + Sync {
    fn dim(&self) -> usize;
    fn n_classes(&self) -> usize;
    /// Generate `n` samples under `solver` for the given condition.
    fn generate(&self, solver: SolverChoice, onehot: &[f32], guidance: f32,
                n: usize, rng: &mut Rng) -> anyhow::Result<Vec<f32>>;
    /// Macro-bank topology + per-bank program/read stats, for the service
    /// metrics.  Default: none (digital/HLO engines have no crossbars).
    fn bank_report(&self) -> Vec<BankReport> {
        Vec::new()
    }

    /// Device-maintenance surface for the health monitor (retention
    /// aging, drift reports, write-verify reprogramming).  Default: none
    /// — digital/HLO engines have no conductances to drift.
    fn device_health(&self) -> Option<&dyn DeviceHealth> {
        None
    }

    /// Modeled hardware latency for one sampling.
    fn hw_latency_s(&self, solver: SolverChoice, conditional: bool) -> f64 {
        match paper_hw_cost(solver, conditional) {
            HwCost::Analog(c) => c.latency_s(),
            HwCost::Digital(c) => c.latency_s(),
        }
    }

    /// Modeled hardware energy for one sampling (J).  Default: the
    /// paper-shape cost model; engines that know their deployed topology
    /// override this with per-macro accounting.
    fn hw_energy_j(&self, solver: SolverChoice, conditional: bool) -> f64 {
        match paper_hw_cost(solver, conditional) {
            HwCost::Analog(c) => c.energy_j(),
            HwCost::Digital(c) => c.energy_j(),
        }
    }
}

/// Modeled cost of one sampling under either solver family.
pub enum HwCost {
    Analog(AnalogCost),
    Digital(DigitalCost),
}

/// The paper-shape cost model shared by the [`Engine`] trait defaults —
/// one place to change, so engine overrides that only refine the analog
/// side can delegate their digital arms here.
pub fn paper_hw_cost(solver: SolverChoice, conditional: bool) -> HwCost {
    match solver {
        SolverChoice::AnalogOde | SolverChoice::AnalogSde => {
            HwCost::Analog(if conditional {
                AnalogCost::conditional_projected()
            } else {
                AnalogCost::unconditional_projected()
            })
        }
        SolverChoice::DigitalOde { steps } | SolverChoice::DigitalSde { steps } => {
            HwCost::Digital(DigitalCost::new(steps, if conditional { 2 } else { 1 }))
        }
    }
}

/// Engine over the rust analog-hardware simulator.
///
/// The net sits behind a `RwLock` so the health monitor can age and
/// reprogram the conductances in place (write side) while solves share
/// the read side — the per-engine mirror of the [`ModeGate`]'s
/// compute-vs-programming exclusion, for callers that bypass the gate.
pub struct AnalogEngine {
    net: RwLock<AnalogScoreNet>,
    pub sched: VpSchedule,
    pub substeps: usize,
    /// Deterministic stream for retention aging and reprogram noise, so
    /// a monitored run replays bit-for-bit under the same config.
    clock_rng: Mutex<Rng>,
    // cached from the net at construction: hot-path queries must not
    // touch the lock
    dim: usize,
    n_classes: usize,
}

impl AnalogEngine {
    pub fn new(net: AnalogScoreNet, sched: VpSchedule, substeps: usize)
               -> AnalogEngine {
        let dim = net.dim();
        let n_classes = net.n_classes();
        AnalogEngine {
            net: RwLock::new(net),
            sched,
            substeps,
            clock_rng: Mutex::new(Rng::new(0xD21F_C10C)),
            dim,
            n_classes,
        }
    }

    fn net_read(&self) -> std::sync::RwLockReadGuard<'_, AnalogScoreNet> {
        self.net.read().unwrap_or_else(|e| e.into_inner())
    }
}

impl Engine for AnalogEngine {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn bank_report(&self) -> Vec<BankReport> {
        self.net_read().bank_report()
    }

    fn device_health(&self) -> Option<&dyn DeviceHealth> {
        Some(self)
    }

    /// Unlike the trait default (paper-shape counts), this charges the
    /// engine's *actual* deployed topology: per-macro peripherals from the
    /// net's real layer shapes and bank grids.  (Latency keeps the trait
    /// default — the solve window is topology-independent; energy is where
    /// banking shows up.)  Digital arms delegate to the shared
    /// [`paper_hw_cost`] model.
    fn hw_energy_j(&self, solver: SolverChoice, conditional: bool) -> f64 {
        match solver {
            SolverChoice::AnalogOde | SolverChoice::AnalogSde => {
                let shapes = self.net_read().layer_shapes();
                let c = if conditional {
                    AnalogCost::conditional_for_layers(
                        &shapes, self.dim, self.n_classes,
                    )
                } else {
                    AnalogCost::projected_for_layers(&shapes, self.dim)
                };
                c.energy_j()
            }
            _ => match paper_hw_cost(solver, conditional) {
                HwCost::Analog(c) => c.energy_j(),
                HwCost::Digital(c) => c.energy_j(),
            },
        }
    }

    fn generate(&self, solver: SolverChoice, onehot: &[f32], guidance: f32,
                n: usize, rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        let mode = match solver {
            SolverChoice::AnalogOde => SolverMode::Ode,
            SolverChoice::AnalogSde => SolverMode::Sde,
            _ => return Err(anyhow!("AnalogEngine got a digital solver choice")),
        };
        let conditional = onehot.iter().any(|&c| c != 0.0);
        let mut cfg = SolverConfig::new(mode)
            .with_schedule(self.sched)
            .with_substeps(self.substeps);
        if conditional {
            cfg = cfg.with_guidance(guidance);
        }
        let net = self.net_read();
        let solver = AnalogSolver::new(&net, cfg);
        // batched lane: all n lanes advance per sub-step, so the batcher's
        // coalescing amortizes every crossbar inference over the batch
        Ok(solver.solve_batched(n, onehot, rng))
    }
}

impl DeviceHealth for AnalogEngine {
    fn age(&self, dt_s: f64) {
        let mut rng = self.clock_rng.lock().unwrap_or_else(|e| e.into_inner());
        self.net.write().unwrap_or_else(|e| e.into_inner())
            .age(dt_s, &mut rng);
    }

    fn drift_report(&self) -> Vec<LayerDrift> {
        self.net_read().drift_report()
    }

    fn reprogram(&self, tol_ms: f32) -> ProgramStats {
        let mut rng = self.clock_rng.lock().unwrap_or_else(|e| e.into_inner());
        self.net.write().unwrap_or_else(|e| e.into_inner())
            .reprogram(tol_ms, &mut rng)
    }
}

/// Engine over the pure-rust digital baseline (no PJRT needed).
pub struct RustDigitalEngine {
    pub net: DigitalScoreNet,
    pub sched: VpSchedule,
}

impl Engine for RustDigitalEngine {
    fn dim(&self) -> usize {
        self.net.dim()
    }

    fn n_classes(&self) -> usize {
        self.net.n_classes()
    }

    fn generate(&self, solver: SolverChoice, onehot: &[f32], guidance: f32,
                n: usize, rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        let (mode, steps) = match solver {
            SolverChoice::DigitalOde { steps } => (SamplerMode::Ode, steps),
            SolverChoice::DigitalSde { steps } => (SamplerMode::Sde, steps),
            _ => return Err(anyhow!("RustDigitalEngine got an analog solver choice")),
        };
        let conditional = onehot.iter().any(|&c| c != 0.0);
        let mut s = DigitalSampler::new(&self.net, mode)
            .with_schedule(self.sched)
            .with_kind(SamplerKind::Euler);
        if conditional {
            s = s.with_guidance(guidance);
        }
        // batched lane: B×dim GEMMs per step instead of B vector MVMs
        let (pts, _) = s.sample_batched(n, onehot, steps, rng);
        Ok(pts)
    }
}

/// Engine over the AOT PJRT artifacts (the production digital path).
pub struct HloEngine {
    pub store: ArtifactStore,
    pub n_classes: usize,
}

// SAFETY: the PJRT CPU client and loaded executables are thread-safe for
// concurrent Execute calls (PJRT C API contract); the store's lazy-compile
// map is Mutex-protected.  The raw pointers inside the xla wrappers are
// what blocks the auto-impl.
unsafe impl Send for HloEngine {}
unsafe impl Sync for HloEngine {}

impl Engine for HloEngine {
    fn dim(&self) -> usize {
        2
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn generate(&self, solver: SolverChoice, onehot: &[f32], guidance: f32,
                n: usize, rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        let (sde, steps) = match solver {
            SolverChoice::DigitalOde { steps } => (false, steps),
            SolverChoice::DigitalSde { steps } => (true, steps),
            _ => return Err(anyhow!("HloEngine got an analog solver choice")),
        };
        let conditional = onehot.iter().any(|&c| c != 0.0);
        let dim = self.dim();
        let mut out = Vec::with_capacity(n * dim);
        let mut remaining = n;
        while remaining > 0 {
            let b = self.store.pick_batch(remaining);
            let take = b.min(remaining);
            // pad to the artifact batch: extra lanes are generated and
            // discarded (same as a padded GPU batch)
            let oh_b: Vec<f32> = (0..b).flat_map(|_| onehot.iter().copied()).collect();
            let cond = if conditional {
                Some((oh_b.as_slice(), guidance))
            } else {
                None
            };
            let x = self.store.sample_digital(b, steps, sde, cond, rng)?;
            out.extend_from_slice(&x[..take * dim]);
            remaining -= take;
        }
        Ok(out)
    }
}

/// Compute-vs-programming mode gate (the SPDT switches).
#[derive(Default)]
pub struct ModeGate {
    lock: RwLock<()>,
}

impl ModeGate {
    pub fn new() -> Self {
        ModeGate::default()
    }

    /// Enter computation mode (shared).
    pub fn compute(&self) -> std::sync::RwLockReadGuard<'_, ()> {
        self.lock.read().unwrap_or_else(|e| e.into_inner())
    }

    /// Enter programming mode (exclusive: all compute drains first).
    pub fn programming(&self) -> std::sync::RwLockWriteGuard<'_, ()> {
        self.lock.write().unwrap_or_else(|e| e.into_inner())
    }
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub seed: u64,
    /// Intra-op pool threads per process (0 = auto: `RUST_PALLAS_THREADS`
    /// if set, else `cores − workers + 1` — the pool is shared and every
    /// worker participates in its own scopes, so when all workers fork at
    /// once, callers + helpers ≈ cores).  The process-shared pool is
    /// created on the first sizing, which wins for the process lifetime.
    pub intra_threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            batcher: BatcherConfig::default(),
            seed: 0xD1FF_0510,
            intra_threads: 0,
        }
    }
}

/// The running service: the deployment router facade.
pub struct Service {
    /// One batcher lane per registry backend (index-aligned).
    lanes: LaneSet,
    registry: Arc<EngineRegistry>,
    /// Per-lane pending-ticket maps — the completion side of
    /// `submit_nb` (replaces the old global blocking response map).
    tickets: Arc<TicketBoard>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    pub mode_gate: Arc<ModeGate>,
    /// The process-shared intra-op pool, sized coherently against the
    /// total engine worker count at startup.
    pool: Arc<Pool>,
}

impl Service {
    /// Thin one-backend deployment: `engine` serves every request class
    /// through a single lane (the pre-router behaviour, kept for tests
    /// and single-substrate deployments).
    pub fn start(engine: Arc<dyn Engine>, decoder: Option<Arc<PixelDecoder>>,
                 cfg: ServiceConfig) -> Self {
        Self::start_routed(EngineRegistry::single(engine), decoder, cfg)
    }

    /// Start the routed deployment: every backend in `registry` gets its
    /// own batcher lane and its own worker allotment (`Backend::workers`,
    /// 0 = `cfg.workers`), and `submit` routes by request class.
    ///
    /// Also claims (or adopts) the process-shared [`exec::Pool`]: with
    /// `intra_threads = 0` it sizes the pool at `cores − total_workers + 1`
    /// where `total_workers` sums the per-backend allotments (env override
    /// wins; each worker participates in its own fork-join scopes while
    /// the spawned helpers are shared), so when every worker forks at
    /// once, callers + helpers ≈ cores — engine-level and bank-level
    /// parallelism never oversubscribe each other.
    ///
    /// Per-backend worker RNG seeds depend on the *backend-local* worker
    /// index only, so a class stream served by a one-worker backend here
    /// is bitwise identical to the same stream through a one-worker
    /// single-engine service with the same seed (the router-parity
    /// contract; `rust/tests/router_parity.rs`).
    pub fn start_routed(registry: EngineRegistry,
                        decoder: Option<Arc<PixelDecoder>>,
                        cfg: ServiceConfig) -> Self {
        let registry = Arc::new(registry);
        // per-lane batching configs: a backend's explicit `<backend>_queue`
        // bound overrides the service-wide depth for its own lane only
        let lane_cfgs: Vec<BatcherConfig> = registry
            .backends()
            .iter()
            .map(|b| {
                let mut c = cfg.batcher.clone();
                if b.queue_depth > 0 {
                    c.queue_depth = b.queue_depth;
                }
                c
            })
            .collect();
        let lanes = LaneSet::with_configs(lane_cfgs);
        let tickets = Arc::new(TicketBoard::new(registry.n_backends()));
        let metrics = Arc::new(Metrics::new());
        metrics.set_backends(&registry.names());
        for (b, backend) in registry.backends().iter().enumerate() {
            metrics.set_backend_banking(b, backend.engine.bank_report());
        }
        let backend_workers: Vec<usize> = registry
            .backends()
            .iter()
            .map(|b| if b.workers == 0 { cfg.workers.max(1) } else { b.workers })
            .collect();
        let total_workers: usize = backend_workers.iter().sum::<usize>().max(1);
        let pool = exec::shared_sized(if cfg.intra_threads > 0 {
            cfg.intra_threads
        } else {
            exec::intra_threads_for_workers(total_workers)
        });
        metrics.set_pool(pool.stats());
        let mode_gate = Arc::new(ModeGate::new());
        let max_batch = cfg.batcher.max_batch_samples;

        let mut workers = Vec::new();
        for (b, &n_workers) in backend_workers.iter().enumerate() {
            for w in 0..n_workers {
                let lane = Arc::clone(lanes.lane(b));
                let tickets = Arc::clone(&tickets);
                let registry = Arc::clone(&registry);
                let decoder = decoder.clone();
                let metrics = Arc::clone(&metrics);
                let mode_gate = Arc::clone(&mode_gate);
                let pool = Arc::clone(&pool);
                // backend-local worker index → seed, for router parity
                let mut rng =
                    Rng::new(cfg.seed ^ (w as u64).wrapping_mul(0x9E37_79B9));
                workers.push(std::thread::spawn(move || {
                    let engine = Arc::clone(&registry.backend(b).engine);
                    let bname = registry.backend(b).name.clone();
                    while let Some(batch) = lane.next_batch() {
                        let _compute = mode_gate.compute();
                        // queue wait + batch-gather spans per member
                        let oldest = batch.waits.iter().copied().max()
                            .unwrap_or_default();
                        for (req, wait) in
                            batch.requests.iter().zip(batch.waits.iter())
                        {
                            let class = req.class().name();
                            obs::span(req.trace, Stage::Queue, &bname, class,
                                      *wait);
                            obs::span(req.trace, Stage::BatchForm, &bname,
                                      class, oldest);
                        }
                        let t0 = Instant::now();
                        // contain engine panics: a poisoned request fails
                        // its own batch's tickets while the worker (and
                        // every other lane) keeps serving
                        let result = match std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                Self::run_batch(&*engine, decoder.as_deref(),
                                                &batch, &bname, &mut rng)
                            })) {
                            Ok(r) => r,
                            Err(payload) => {
                                metrics.record_worker_panic();
                                obs::flightrec::trigger_global("worker-panic");
                                let msg = payload
                                    .downcast_ref::<&str>()
                                    .map(|s| s.to_string())
                                    .or_else(|| payload.downcast_ref::<String>()
                                                       .cloned())
                                    .unwrap_or_else(|| "opaque panic".into());
                                Err(anyhow!("engine panicked: {msg}"))
                            }
                        };
                        let wall = t0.elapsed();
                        for req in &batch.requests {
                            obs::span(req.trace, Stage::EngineSolve, &bname,
                                      req.class().name(), wall);
                        }
                        metrics.record_batch(
                            batch.requests.len(),
                            batch.total_samples(),
                            batch.total_samples() as f64 / max_batch as f64,
                            wall,
                        );
                        let batch_energy = result
                            .as_ref()
                            .map(|rs| {
                                rs.iter().map(|r| r.hw_energy_j).sum::<f64>()
                            })
                            .unwrap_or(0.0);
                        metrics.record_backend_batch(
                            b,
                            batch.requests.len(),
                            batch.total_samples(),
                            batch_energy,
                            wall,
                        );
                        metrics.set_backend_queue(b, lane.queued_samples());
                        // refresh this backend's per-bank read counters and
                        // the pool gauges alongside the batch counters
                        // (topology is static, reads/tasks are live; other
                        // backends' groups are left untouched)
                        metrics.set_backend_banking(b, engine.bank_report());
                        metrics.set_pool(pool.stats());
                        // deliver through this lane's ticket map only —
                        // completions on one backend never contend with
                        // another backend's submit/complete traffic
                        // end-to-end latency per member (queue wait +
                        // solve wall) feeds the SLO engine's per-class
                        // histogram, exemplar-tagged with the trace
                        let record_latency = |req: &GenRequest,
                                              wait: &Duration| {
                            if obs::enabled() {
                                obs::obs().registry
                                    .hist(obs::slo::REQUEST_LATENCY_HIST,
                                          &[("backend", &bname),
                                            ("class", req.class().name())])
                                    .record_traced(
                                        (*wait + wall).as_secs_f64(),
                                        req.trace.0);
                            }
                        };
                        match result {
                            Ok(responses) => {
                                // run_batch builds responses in request
                                // order, so zipping recovers each trace
                                for (resp, (req, wait)) in responses
                                    .into_iter()
                                    .zip(batch.requests.iter()
                                        .zip(batch.waits.iter()))
                                {
                                    let id = resp.id;
                                    tickets.complete(b, id, Ok(resp));
                                    record_latency(req, wait);
                                    obs::span(req.trace, Stage::Deliver,
                                              &bname, req.class().name(),
                                              Duration::ZERO);
                                }
                            }
                            Err(e) => {
                                for (req, wait) in batch.requests.iter()
                                    .zip(batch.waits.iter())
                                {
                                    tickets.complete(b, req.id,
                                                     Err(anyhow!("{e}")));
                                    record_latency(req, wait);
                                    obs::span(req.trace, Stage::Deliver,
                                              &bname, req.class().name(),
                                              Duration::ZERO);
                                }
                            }
                        }
                    }
                }));
            }
        }

        Service {
            lanes,
            registry,
            tickets,
            workers,
            next_id: AtomicU64::new(1),
            metrics,
            mode_gate,
            pool,
        }
    }

    /// The process-shared intra-op pool this service sized at startup.
    pub fn exec_pool(&self) -> &Arc<Pool> {
        &self.pool
    }

    /// The deployment's routing table (class → named backend).
    pub fn registry(&self) -> &Arc<EngineRegistry> {
        &self.registry
    }

    fn run_batch(engine: &dyn Engine, decoder: Option<&PixelDecoder>,
                 batch: &Batch, backend: &str, rng: &mut Rng)
                 -> anyhow::Result<Vec<GenResponse>> {
        let first = &batch.requests[0];
        let onehot = first.task.onehot(engine.n_classes());
        let n_total = batch.total_samples();
        let t0 = Instant::now();
        let samples =
            engine.generate(first.solver, &onehot, first.guidance, n_total, rng)?;
        let wall = t0.elapsed().as_secs_f64();
        let dim = engine.dim();
        let conditional = first.task.is_conditional();
        let hw = engine.hw_latency_s(first.solver, conditional);
        let hw_e = engine.hw_energy_j(first.solver, conditional);

        let mut responses = Vec::with_capacity(batch.requests.len());
        let mut offset = 0usize;
        for req in &batch.requests {
            let take = req.n_samples * dim;
            let pts = samples[offset..offset + take].to_vec();
            offset += take;
            let images = if req.decode {
                match decoder {
                    Some(d) => {
                        let td = Instant::now();
                        let imgs = d.decode_batch(&pts);
                        obs::span(req.trace, Stage::Decode, backend,
                                  req.class().name(), td.elapsed());
                        Some(imgs)
                    }
                    None => return Err(anyhow!("decode requested but no decoder")),
                }
            } else {
                None
            };
            responses.push(GenResponse {
                id: req.id,
                samples: pts,
                images,
                wall_latency_s: wall,
                hw_latency_s: hw * req.n_samples as f64,
                hw_energy_j: hw_e * req.n_samples as f64,
            });
        }
        Ok(responses)
    }

    /// Nonblocking submit: route by class, admit against the lane's
    /// bounded queue, return a [`Ticket`] for the response.  **Never
    /// blocks** — a full lane answers [`SubmitError::Overloaded`]
    /// immediately (without touching any other lane), a draining lane
    /// [`SubmitError::ShuttingDown`].
    ///
    /// Reject accounting is exactly-once and leak-free: on any error
    /// path the request holds no queue slot and no pending ticket entry,
    /// and the `rejected` counter (plus the backend's own reject gauge
    /// for `Overloaded`) was incremented exactly once.
    pub fn submit_nb(&self, mut req: GenRequest) -> Result<Ticket, SubmitError> {
        let t_admit = Instant::now();
        if req.n_samples == 0 {
            self.metrics.record_rejected();
            return Err(SubmitError::Invalid("n_samples must be > 0".into()));
        }
        let class = req.class();
        let Some(lane_idx) = self.registry.backend_index(class) else {
            self.metrics.record_rejected();
            return Err(SubmitError::Unroutable {
                class,
                routes: self.registry.route_summary(),
            });
        };
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let id = req.id;
        // register BEFORE enqueueing: the instant the lane accepts, a
        // worker may complete the request
        let trace = req.trace;
        let class_name = class.name();
        let ticket = self.tickets.register(lane_idx, id, trace);
        match self.lanes.submit(lane_idx, req) {
            SubmitOutcome::Accepted { queued_samples } => {
                self.metrics.set_backend_queue(lane_idx, queued_samples);
                obs::span(trace, Stage::Admit,
                          &self.registry.backend(lane_idx).name, class_name,
                          t_admit.elapsed());
                Ok(ticket)
            }
            SubmitOutcome::Overloaded { queued_samples, queue_depth } => {
                // never entered the queue: retract the ticket entry or
                // shutdown would see a permanently-pending request
                self.tickets.retract(lane_idx, id);
                self.metrics.record_rejected();
                self.metrics.record_backend_rejected(lane_idx);
                self.metrics.set_backend_queue(lane_idx, queued_samples);
                // a sustained shed burst black-boxes the overload
                obs::flightrec::note_shed();
                Err(SubmitError::Overloaded {
                    backend: self.registry.backend(lane_idx).name.clone(),
                    queued_samples,
                    queue_depth,
                    // adaptive hint from the lane's observed drain rate so
                    // shed callers back off instead of hammering
                    retry_after_ms: self
                        .metrics
                        .retry_after_hint_ms(lane_idx, queued_samples),
                })
            }
            SubmitOutcome::Closed => {
                self.tickets.retract(lane_idx, id);
                self.metrics.record_rejected();
                Err(SubmitError::ShuttingDown)
            }
        }
    }

    /// Submit a request; returns the response [`Ticket`] (block on it
    /// with [`Ticket::recv`]).  Same admission path as [`Self::submit_nb`]
    /// — this wrapper only erases the structured error into `anyhow`
    /// (downcast to [`SubmitError`] to branch on the reject kind).
    pub fn submit(&self, req: GenRequest) -> anyhow::Result<Ticket> {
        self.submit_nb(req).map_err(anyhow::Error::from)
    }

    /// Submit and block for the result.
    pub fn generate(&self, task: TaskKind, n_samples: usize,
                    solver: SolverChoice, guidance: f32, decode: bool)
                    -> anyhow::Result<GenResponse> {
        self.submit(GenRequest {
            id: 0,
            task,
            n_samples,
            solver,
            guidance,
            decode,
            trace: crate::obs::TraceId::mint(),
        })?
        .recv()
    }

    /// Drain and stop.  Closing **every** per-backend lane wakes every
    /// blocked `next_batch` caller promptly (queued work still drains
    /// first, per lane), and once all workers across all backends have
    /// joined, **no ticket may still be pending on the board** — that
    /// would mean a submitted request was dropped without an answer, on
    /// any lane.  Asserted in debug builds; release builds fail any
    /// leftover ticket loudly instead of stranding its waiter forever
    /// (blocked `recv`s and registered wakers all resolve).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.lanes.close_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // typed so job owners can tell a drained ticket (requeue, no
        // retry budget consumed) from a real engine failure
        let leftovers = self
            .tickets
            .fail_all(|| anyhow::Error::new(crate::serve::admission::DrainError));
        if !std::thread::panicking() {
            debug_assert_eq!(
                leftovers, 0,
                "shutdown dropped {leftovers} request(s) with pending tickets"
            );
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;

    use super::*;
    use crate::coordinator::testutil::TagEngine;
    use crate::diffusion::schedule::VpSchedule;

    /// Deterministic linear engine for service-level tests: sample k of a
    /// request = [k, class] so splitting across requests is verifiable.
    struct CountingEngine;

    impl Engine for CountingEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, onehot: &[f32], _g: f32, n: usize,
                    _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            let class = onehot.iter().position(|&c| c != 0.0).map(|c| c as f32 + 1.0)
                .unwrap_or(0.0);
            Ok((0..n).flat_map(|k| [k as f32, class]).collect())
        }
    }

    fn svc(workers: usize) -> Service {
        Service::start(
            Arc::new(CountingEngine),
            None,
            ServiceConfig {
                workers,
                batcher: BatcherConfig {
                    max_batch_samples: 64,
                    linger: std::time::Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
                seed: 1,
                intra_threads: 0,
            },
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let s = svc(1);
        let r = s
            .generate(TaskKind::Circle, 5, SolverChoice::AnalogOde, 0.0, false)
            .unwrap();
        assert_eq!(r.samples.len(), 10);
        assert_eq!(r.samples[8], 4.0); // 5th sample index
        s.shutdown();
    }

    #[test]
    fn concurrent_requests_split_correctly() {
        let s = Arc::new(svc(2));
        let mut rxs = Vec::new();
        for i in 1..=8usize {
            rxs.push((
                i,
                s.submit(GenRequest {
                    id: 0,
                    task: TaskKind::Letter(i % 3),
                    n_samples: i,
                    solver: SolverChoice::DigitalOde { steps: 10 },
                    trace: crate::obs::TraceId::NONE,
                    guidance: 2.0,
                    decode: false,
                })
                .unwrap(),
            ));
        }
        for (i, rx) in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.samples.len(), 2 * i, "request {i}");
            // class payload consistent within the response
            let class = r.samples[1];
            for pair in r.samples.chunks_exact(2) {
                assert_eq!(pair[1], class);
            }
        }
        Arc::try_unwrap(s).ok().unwrap().shutdown();
    }

    #[test]
    fn metrics_track_batches() {
        let s = svc(1);
        for _ in 0..3 {
            s.generate(TaskKind::Circle, 4, SolverChoice::AnalogOde, 0.0, false)
                .unwrap();
        }
        let m = s.metrics.snapshot();
        assert_eq!(m.requests, 3);
        assert_eq!(m.samples, 12);
        assert!(m.batches >= 1);
        s.shutdown();
    }

    #[test]
    fn zero_samples_rejected() {
        let s = svc(1);
        assert!(s
            .submit(GenRequest {
                id: 0,
                task: TaskKind::Circle,
                n_samples: 0,
                solver: SolverChoice::AnalogOde,
                guidance: 0.0,
                decode: false,
                trace: crate::obs::TraceId::NONE,
            })
            .is_err());
        s.shutdown();
    }

    #[test]
    fn decode_without_decoder_errors() {
        let s = svc(1);
        let r = s.generate(TaskKind::Letter(0), 2,
                           SolverChoice::DigitalOde { steps: 5 }, 2.0, true);
        assert!(r.is_err());
        s.shutdown();
    }

    #[test]
    fn rejected_submit_leaves_no_pending_entry() {
        let s = svc(1);
        s.lanes.close_all();
        let r = s.submit(GenRequest {
            id: 0,
            task: TaskKind::Circle,
            n_samples: 2,
            solver: SolverChoice::AnalogOde,
            guidance: 0.0,
            decode: false,
            trace: crate::obs::TraceId::NONE,
        });
        assert!(r.is_err());
        assert_eq!(s.tickets.pending(), 0,
                   "rejected request must not leave a pending ticket entry");
        assert_eq!(s.metrics.snapshot().rejected, 1,
                   "closed-lane reject counted exactly once, not double");
        // shutdown's no-dropped-request assertion must hold
        s.shutdown();
    }

    /// Engine whose `generate` blocks on a shared gate — lets tests hold
    /// a worker busy deterministically while they fill the lane queue.
    struct GateEngine {
        gate: Arc<Mutex<()>>,
        entered: Arc<std::sync::atomic::AtomicUsize>,
    }

    impl Engine for GateEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, _onehot: &[f32], _g: f32,
                    n: usize, _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            self.entered.fetch_add(1, Ordering::SeqCst);
            let _hold = self.gate.lock().unwrap();
            Ok(vec![0.0; n * 2])
        }
    }

    fn circle_req(n: usize) -> GenRequest {
        GenRequest {
            id: 0,
            task: TaskKind::Circle,
            n_samples: n,
            solver: SolverChoice::AnalogOde,
            guidance: 0.0,
            decode: false,
            trace: crate::obs::TraceId::NONE,
        }
    }

    /// The backpressure-accounting regression (double-count/leak paths):
    /// every overload reject must increment `rejected` + the backend
    /// gauge exactly once and leave no pending ticket; accepted work
    /// must still complete afterwards.
    #[test]
    fn overload_rejects_count_once_and_leak_nothing() {
        let gate = Arc::new(Mutex::new(()));
        let entered = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let engine = Arc::new(GateEngine {
            gate: Arc::clone(&gate),
            entered: Arc::clone(&entered),
        });
        let mut reg = EngineRegistry::new();
        // bounded lane: 3 samples deep
        reg.add_backend_cfg("gated", engine, 1, 3).unwrap();
        for class in crate::coordinator::request::RequestClass::ALL {
            reg.route_class(class, "gated").unwrap();
        }
        let s = Service::start_routed(reg, None, ServiceConfig {
            workers: 1,
            batcher: BatcherConfig {
                max_batch_samples: 1, // one request per batch: no coalescing
                linger: std::time::Duration::from_millis(0),
                ..BatcherConfig::default()
            },
            seed: 1,
            intra_threads: 1,
        });

        // hold the worker inside generate(), then fill the queue exactly
        let hold = gate.lock().unwrap();
        let first = s.submit_nb(circle_req(1)).unwrap();
        while entered.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        // worker is busy; lane queue is empty again — fill its 3 slots
        let queued: Vec<Ticket> =
            (0..3).map(|_| s.submit_nb(circle_req(1)).unwrap()).collect();
        // 4th queued sample exceeds the bound: Overloaded, exactly once
        let err = s.submit_nb(circle_req(1)).unwrap_err();
        match &err {
            SubmitError::Overloaded { backend, queued_samples, queue_depth,
                                      retry_after_ms } => {
                assert_eq!(backend, "gated");
                assert_eq!((*queued_samples, *queue_depth), (3, 3));
                assert!(*retry_after_ms > 0, "a backoff hint is always derived");
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.rejected, 1, "service total counted exactly once");
        assert_eq!(snap.backends[0].rejected, 1, "backend gauge counted once");
        assert_eq!(snap.backends[0].queue_depth, 3, "queue gauge shows the fill");
        assert_eq!(s.tickets.pending(), 4,
                   "only the accepted requests hold tickets");
        // a second overload increments again (once)
        assert!(matches!(s.submit_nb(circle_req(1)),
                         Err(SubmitError::Overloaded { .. })));
        assert_eq!(s.metrics.snapshot().rejected, 2);

        // release the worker: every accepted ticket completes
        drop(hold);
        assert!(first.recv().is_ok());
        for t in queued {
            assert!(t.recv_timeout(std::time::Duration::from_secs(30))
                .expect("accepted ticket completes")
                .is_ok());
        }
        assert_eq!(s.tickets.pending(), 0);
        // shutdown's no-dropped-request assertion must hold after rejects
        s.shutdown();
    }

    #[test]
    fn submit_nb_ticket_polls_and_times_out() {
        let s = svc(1);
        let t = s.submit_nb(circle_req(4)).unwrap();
        // recv with deadline resolves (Some) and yields the response
        let r = t.recv_timeout(std::time::Duration::from_secs(30))
            .expect("completes well within the deadline")
            .unwrap();
        assert_eq!(r.samples.len(), 8);
        // spent ticket: try_recv None, recv errors instead of hanging
        assert!(t.try_recv().is_none());
        assert!(t.recv().is_err());
        s.shutdown();
    }

    #[test]
    fn shutting_down_submit_nb_is_structured() {
        let s = svc(1);
        s.lanes.close_all();
        match s.submit_nb(circle_req(1)) {
            Err(SubmitError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
        assert_eq!(s.metrics.snapshot().rejected, 1);
        assert_eq!(s.tickets.pending(), 0);
        s.shutdown();
    }

    #[test]
    fn pool_gauges_surface_in_metrics() {
        let s = svc(1);
        s.generate(TaskKind::Circle, 3, SolverChoice::AnalogOde, 0.0, false)
            .unwrap();
        let m = s.metrics.snapshot();
        let pool = m.pool.as_ref().expect("service must publish pool gauges");
        assert!(pool.threads >= 1);
        assert_eq!(s.exec_pool().threads(), pool.threads);
        assert!(m.report().contains("pool="), "{}", m.report());
        s.shutdown();
    }

    /// Panics on conditional (lettered) requests, serves unconditional
    /// ones — the poisoned-request stand-in.
    struct PoisonEngine;

    impl Engine for PoisonEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, onehot: &[f32], _g: f32,
                    n: usize, _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            if onehot.iter().any(|&c| c != 0.0) {
                panic!("poisoned request");
            }
            Ok(vec![1.0; n * 2])
        }
    }

    #[test]
    fn engine_panic_fails_only_its_own_request() {
        let s = Service::start(
            Arc::new(PoisonEngine),
            None,
            ServiceConfig {
                workers: 1,
                batcher: BatcherConfig {
                    max_batch_samples: 64,
                    linger: std::time::Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
                seed: 1,
                intra_threads: 1,
            },
        );
        // the poisoned request panics the engine; catch_unwind turns it
        // into this request's error instead of killing the worker
        let err = s
            .generate(TaskKind::Letter(0), 2, SolverChoice::AnalogOde, 0.0, false)
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("poisoned request"), "{err}");
        // the same worker keeps serving healthy requests afterwards
        let ok = s
            .generate(TaskKind::Circle, 3, SolverChoice::AnalogOde, 0.0, false)
            .expect("worker survives the panic");
        assert_eq!(ok.samples.len(), 6);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.worker_panics, 1);
        assert!(snap.report().contains("panics=1"), "{}", snap.report());
        s.shutdown();
    }

    /// Two-backend routed service: analog classes tagged 1.0, digital 2.0.
    fn routed_svc(workers: usize) -> Service {
        use crate::coordinator::request::SolverFamily;
        let mut reg = EngineRegistry::new();
        reg.add_backend("analog", Arc::new(TagEngine(1.0)), workers).unwrap();
        reg.add_backend("digital", Arc::new(TagEngine(2.0)), workers).unwrap();
        reg.route_family(SolverFamily::Analog, "analog").unwrap();
        reg.route_family(SolverFamily::Digital, "digital").unwrap();
        Service::start_routed(reg, None, ServiceConfig {
            workers: 1,
            batcher: BatcherConfig {
                max_batch_samples: 64,
                linger: std::time::Duration::from_millis(1),
                ..BatcherConfig::default()
            },
            seed: 5,
            intra_threads: 0,
        })
    }

    #[test]
    fn routed_service_routes_by_class() {
        let s = routed_svc(1);
        let a = s
            .generate(TaskKind::Circle, 3, SolverChoice::AnalogOde, 0.0, false)
            .unwrap();
        assert!(a.samples.iter().all(|&v| v == 1.0), "analog backend tag");
        let d = s
            .generate(TaskKind::Letter(1), 4,
                      SolverChoice::DigitalOde { steps: 5 }, 2.0, false)
            .unwrap();
        assert!(d.samples.iter().all(|&v| v == 2.0), "digital backend tag");
        let m = s.metrics.snapshot();
        assert_eq!(m.backends.len(), 2);
        assert_eq!(m.backends[0].samples, 3, "analog lane counted its batch");
        assert_eq!(m.backends[1].samples, 4, "digital lane counted its batch");
        assert_eq!(m.requests, 2, "totals still aggregate across lanes");
        let r = m.report();
        assert!(r.contains("backend=analog[") && r.contains("digital["), "{r}");
        s.shutdown();
    }

    #[test]
    fn unrouted_class_rejected_at_submit() {
        use crate::coordinator::request::RequestClass;
        let mut reg = EngineRegistry::new();
        reg.add_backend("digital", Arc::new(TagEngine(2.0)), 1).unwrap();
        for class in RequestClass::ALL.into_iter().filter(|c| !c.conditional) {
            reg.route_class(class, "digital").unwrap();
        }
        let s = Service::start_routed(reg, None, ServiceConfig {
            workers: 1,
            batcher: BatcherConfig {
                max_batch_samples: 64,
                linger: std::time::Duration::from_millis(1),
                ..BatcherConfig::default()
            },
            seed: 5,
            intra_threads: 0,
        });
        // unconditional digital routed fine
        assert!(s
            .generate(TaskKind::Circle, 1,
                      SolverChoice::DigitalOde { steps: 2 }, 0.0, false)
            .is_ok());
        // conditional classes are not in the table: rejected pre-queue
        let err = s
            .generate(TaskKind::Letter(0), 1,
                      SolverChoice::DigitalOde { steps: 2 }, 2.0, false)
            .unwrap_err();
        assert!(err.to_string().contains("no backend routed"), "{err}");
        assert_eq!(s.tickets.pending(), 0,
                   "unrouted request must not leave a pending entry");
        assert_eq!(s.metrics.snapshot().rejected, 1);
        s.shutdown();
    }

    #[test]
    fn mixed_class_shutdown_drains_every_lane() {
        // the no-dropped-request invariant must hold across ALL lanes:
        // queue mixed-class work, shut down immediately, and every request
        // must still receive its answer (close() drains, never drops)
        let s = routed_svc(2);
        let mut rxs = Vec::new();
        for i in 0..24usize {
            let (task, solver) = match i % 4 {
                0 => (TaskKind::Circle, SolverChoice::AnalogOde),
                1 => (TaskKind::Letter(i % 3), SolverChoice::AnalogSde),
                2 => (TaskKind::Circle, SolverChoice::DigitalOde { steps: 4 }),
                _ => (TaskKind::Letter(i % 3),
                      SolverChoice::DigitalSde { steps: 4 }),
            };
            rxs.push(s
                .submit(GenRequest {
                    id: 0,
                    task,
                    n_samples: 1 + i % 5,
                    solver,
                    guidance: 2.0,
                    decode: false,
                    trace: crate::obs::TraceId::NONE,
                })
                .unwrap());
        }
        // shutdown closes every lane and joins; the debug assertion inside
        // verifies the pending map drained
        s.shutdown();
        let mut answered = 0;
        for rx in rxs {
            let resp = rx.recv();
            assert!(resp.is_ok(), "worker delivered before joining: {:?}",
                    resp.err());
            answered += 1;
        }
        assert_eq!(answered, 24, "every queued request got an answer");
    }

    #[test]
    fn mode_gate_exclusion() {
        let gate = ModeGate::new();
        {
            let _c1 = gate.compute();
            let _c2 = gate.compute(); // concurrent compute OK
            assert!(gate.lock.try_write().is_err(), "programming must wait");
        }
        {
            let _p = gate.programming();
            assert!(gate.lock.try_read().is_err(), "compute must wait");
        }
    }

    #[test]
    fn engine_latency_model_choices() {
        let e = CountingEngine;
        let a = e.hw_latency_s(SolverChoice::AnalogOde, false);
        let d = e.hw_latency_s(SolverChoice::DigitalOde { steps: 130 }, false);
        assert!(d / a > 10.0, "digital at 130 steps must be much slower");
        let dc = e.hw_latency_s(SolverChoice::DigitalOde { steps: 130 }, true);
        assert!((dc / d - 2.0).abs() < 1e-9, "CFG doubles inferences");
    }

    #[test]
    fn rust_digital_engine_smoke() {
        // exercise the real engine path with the tiny fixture net
        use crate::nn::loader::tests::tiny_json;
        use crate::nn::{DigitalScoreNet, ScoreWeights};
        let net = DigitalScoreNet::new(ScoreWeights::from_json(&tiny_json()).unwrap());
        let engine = RustDigitalEngine { net, sched: VpSchedule::default() };
        let mut rng = Rng::new(0);
        let out = engine
            .generate(SolverChoice::DigitalOde { steps: 8 }, &[0.0, 0.0, 0.0], 0.0,
                      4, &mut rng)
            .unwrap();
        assert_eq!(out.len(), 8);
        for &v in &out {
            assert!(v.is_finite());
        }
    }
}
