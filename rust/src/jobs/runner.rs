//! The job lifecycle driver: submits due jobs through
//! [`Service::submit_nb`], retries transient failures with exponential
//! backoff + jitter, contains budget exhaustion as `dead`, retains
//! results to their TTL, and checkpoints on graceful drain.
//!
//! One background thread owns the whole lifecycle.  It sleeps on a
//! [`Notify`] waker that is registered on every in-flight ticket (the
//! same waker pattern the TCP front-end uses), so completions wake it
//! immediately; deferred jobs and backoff deadlines bound the sleep via
//! [`JobStore::next_run_at`].
//!
//! ## Failure taxonomy
//!
//! * **Transient** — an engine error or an [`SubmitError::Overloaded`]
//!   shed.  One attempt is consumed; the job parks as `failed` until
//!   `now + backoff`, where backoff is `base · 2^(attempt−1)` capped at
//!   `backoff_max`, jittered ×[0.5, 1.5), and never below the lane's
//!   `retry_after_ms` hint when the shed carried one.
//! * **Permanent** — `Unroutable`/`Invalid`, or the retry budget is
//!   exhausted: the job goes `dead` with its last error retained.
//! * **Drain** — a ticket failed by service shutdown
//!   ([`DrainError`](crate::serve::admission::DrainError)) is *not* a
//!   failed attempt: the job is requeued with no budget consumed, so the
//!   restart re-runs it exactly as a crash would have.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::request::GenRequest;
use crate::coordinator::Service;
use crate::serve::admission::{DrainError, SubmitError};
use crate::serve::ticket::{Notify, Ticket};
use crate::util::rng::Rng;

use super::store::{now_ms, Job, JobState, JobStore};

/// Tuning for the [`JobRunner`] (see `[jobs]` in the config file).
#[derive(Debug, Clone)]
pub struct RunnerConfig {
    /// Default retry budget for jobs enqueued without an explicit one
    /// (a job executes at most `max_retries + 1` times).
    pub max_retries: u32,
    /// First-retry backoff; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_max: Duration,
    /// Default retention of a terminal job's result/error.
    pub result_ttl: Duration,
    /// Cadence of the TTL sweep and gauge push.
    pub sweep_interval: Duration,
    /// Compact log → snapshot once this many records have accumulated.
    pub checkpoint_every: usize,
    /// On drain, wait this long for in-flight attempts before requeueing
    /// them (they survive as `queued` either way).
    pub drain_grace: Duration,
}

impl Default for RunnerConfig {
    fn default() -> Self {
        RunnerConfig {
            max_retries: 4,
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_secs(5),
            result_ttl: Duration::from_secs(900),
            sweep_interval: Duration::from_secs(1),
            checkpoint_every: 256,
            drain_grace: Duration::from_secs(30),
        }
    }
}

struct Shared {
    service: Arc<Service>,
    store: Arc<JobStore>,
    cfg: RunnerConfig,
    /// Woken by ticket completions, new enqueues, cancels, and drain.
    wake: Notify,
    stop: AtomicBool,
    /// Long-poll waiters per job id, notified on terminal transitions.
    watchers: Mutex<HashMap<u64, Vec<Notify>>>,
}

impl Shared {
    fn notify_watchers(&self, id: u64) {
        if let Some(list) = self.watchers.lock()
            .unwrap_or_else(|e| e.into_inner()).remove(&id) {
            for n in list {
                n.notify();
            }
        }
    }

    fn push_gauges(&self) {
        self.service.metrics.set_jobs(self.store.gauges());
    }
}

/// Handle to the lifecycle thread.  Dropping it drains gracefully:
/// in-flight attempts get [`RunnerConfig::drain_grace`] to finish, then
/// everything is checkpointed — never discarded.
pub struct JobRunner {
    sh: Arc<Shared>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl JobRunner {
    /// Start the lifecycle thread over an opened store.
    pub fn start(service: Arc<Service>, store: Arc<JobStore>,
                 cfg: RunnerConfig) -> Arc<JobRunner> {
        let sh = Arc::new(Shared {
            service,
            store,
            cfg,
            wake: Notify::new(),
            stop: AtomicBool::new(false),
            watchers: Mutex::new(HashMap::new()),
        });
        sh.push_gauges();
        let loop_sh = Arc::clone(&sh);
        let thread = std::thread::Builder::new()
            .name("job-runner".into())
            .spawn(move || run_loop(&loop_sh))
            .expect("spawning job-runner thread");
        Arc::new(JobRunner { sh, thread: Mutex::new(Some(thread)) })
    }

    /// Durably accept a job (fsync'd before the id is returned).
    /// `defer_ms` delays the first run; `max_retries`/`ttl_ms` default to
    /// the runner config.
    pub fn enqueue(&self, req: &GenRequest, defer_ms: u64,
                   max_retries: Option<u32>, ttl_ms: Option<u64>)
                   -> anyhow::Result<u64> {
        let id = self.sh.store.enqueue(
            req,
            defer_ms,
            max_retries.unwrap_or(self.sh.cfg.max_retries),
            ttl_ms.unwrap_or(self.sh.cfg.result_ttl.as_millis() as u64),
        )?;
        self.sh.push_gauges();
        self.sh.wake.notify();
        Ok(id)
    }

    /// Snapshot a job's current state (None = unknown or swept).
    pub fn get(&self, id: u64) -> Option<Job> {
        self.sh.store.get(id)
    }

    /// Current per-state gauges, also pushed into the service metrics
    /// (the stats op calls this so scrapes are point-in-time fresh).
    pub fn gauges(&self) -> crate::coordinator::JobGauges {
        let g = self.sh.store.gauges();
        self.sh.service.metrics.set_jobs(g.clone());
        g
    }

    /// Cancel a job (see [`JobStore::cancel`] for the state rules).
    pub fn cancel(&self, id: u64) -> anyhow::Result<JobState> {
        let state = self.sh.store.cancel(id)?;
        if state.is_terminal() {
            self.sh.notify_watchers(id);
        }
        self.sh.push_gauges();
        self.sh.wake.notify();
        Ok(state)
    }

    /// Register a waker fired when `id` reaches a terminal state
    /// (immediately if it already has, or is unknown).  This is what the
    /// front-end's long-poll `result` op sleeps on.
    pub fn subscribe(&self, id: u64, notify: &Notify) {
        let mut w = self.sh.watchers.lock().unwrap_or_else(|e| e.into_inner());
        match self.sh.store.get(id) {
            Some(j) if !j.state.is_terminal() => {
                w.entry(id).or_default().push(notify.clone());
            }
            _ => notify.notify(),
        }
    }

    /// Block until `id` is terminal or `timeout` elapses; returns the
    /// latest snapshot (non-terminal on timeout, None if unknown).
    pub fn wait_result(&self, id: u64, timeout: Duration) -> Option<Job> {
        let deadline = Instant::now() + timeout;
        let n = Notify::new();
        loop {
            let job = self.sh.store.get(id)?;
            if job.state.is_terminal() {
                return Some(job);
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(job);
            }
            self.subscribe(id, &n);
            n.wait_timeout(deadline - now);
        }
    }

    /// The underlying store (tests and the serve layer peek at it).
    pub fn store(&self) -> &Arc<JobStore> {
        &self.sh.store
    }

    /// Stop the lifecycle thread: in-flight attempts get `drain_grace`
    /// to finish (results recorded durably), stragglers are requeued,
    /// and the store is checkpointed.  Idempotent.
    pub fn drain(&self) {
        self.sh.stop.store(true, Ordering::SeqCst);
        self.sh.wake.notify();
        if let Some(t) = self.thread.lock()
            .unwrap_or_else(|e| e.into_inner()).take() {
            let _ = t.join();
        }
    }
}

impl Drop for JobRunner {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Backoff before retry `attempt` (1-based): exponential from the base,
/// capped, jittered ×[0.5, 1.5) so synchronized failures don't retry in
/// lockstep.
fn backoff_ms(cfg: &RunnerConfig, attempt: u32, rng: &mut Rng) -> u64 {
    let base = (cfg.backoff_base.as_millis() as u64).max(1);
    let cap = (cfg.backoff_max.as_millis() as u64).max(1);
    let exp = base.saturating_mul(1u64 << attempt.saturating_sub(1).min(20));
    let jitter = 0.5 + rng.uniform();
    (((exp.min(cap) as f64) * jitter) as u64).clamp(1, cap)
}

/// Consume one attempt after a transient failure: park as `failed` with
/// backoff (at least `hint_ms`), or go `dead` when the budget is out.
fn record_attempt_failure(sh: &Shared, job: &Job, err: &str, hint_ms: u64,
                          rng: &mut Rng) {
    let r = if job.attempts >= job.max_retries {
        sh.store.record_dead(job.id, err)
    } else {
        let delay = backoff_ms(&sh.cfg, job.attempts + 1, rng).max(hint_ms);
        sh.store.record_failure(job.id, err, now_ms() + delay)
    };
    if let Err(e) = r {
        eprintln!("job {}: failed to persist outcome: {e}", job.id);
    }
    if sh.store.get(job.id).map(|j| j.state.is_terminal()).unwrap_or(true) {
        sh.notify_watchers(job.id);
    }
}

fn run_loop(sh: &Shared) {
    let mut inflight: HashMap<u64, Ticket> = HashMap::new();
    let mut rng = Rng::new(0x6A6F_6273); // "jobs"
    let mut svc_down = false;
    let mut drain_deadline: Option<Instant> = None;
    let mut last_sweep = Instant::now();

    loop {
        let stopping = sh.stop.load(Ordering::SeqCst);
        if stopping && drain_deadline.is_none() {
            drain_deadline = Some(Instant::now() + sh.cfg.drain_grace);
        }

        // 1. harvest completed tickets
        let done: Vec<u64> = inflight
            .iter()
            .filter(|(_, t)| t.is_done())
            .map(|(&id, _)| id)
            .collect();
        for id in done {
            let ticket = inflight.remove(&id).unwrap();
            match ticket.try_recv() {
                Some(Ok(resp)) => {
                    if let Err(e) = sh.store.record_done(id, resp.into()) {
                        eprintln!("job {id}: failed to persist result: {e}");
                    }
                    sh.notify_watchers(id);
                }
                Some(Err(e)) => {
                    if e.downcast_ref::<DrainError>().is_some() {
                        // the service drained under us: not the job's
                        // fault — requeue with no budget consumed
                        sh.store.requeue(id);
                        svc_down = true;
                    } else if let Some(job) = sh.store.get(id) {
                        record_attempt_failure(sh, &job, &format!("{e:#}"), 0,
                                               &mut rng);
                    }
                }
                None => {
                    // raced with is_done; put it back
                    inflight.insert(id, ticket);
                }
            }
        }

        // 2. submit due jobs (unless the service is going away)
        if !stopping && !svc_down {
            let now = now_ms();
            for id in sh.store.due(now) {
                if inflight.contains_key(&id) {
                    continue;
                }
                let Some(job) = sh.store.get(id) else { continue };
                match sh.service.submit_nb(job.to_request()) {
                    Ok(ticket) => {
                        ticket.set_notify(&sh.wake);
                        sh.store.mark_running(id);
                        inflight.insert(id, ticket);
                    }
                    Err(SubmitError::Overloaded { retry_after_ms, .. }) => {
                        record_attempt_failure(sh, &job, "lane overloaded",
                                               retry_after_ms, &mut rng);
                    }
                    Err(SubmitError::ShuttingDown) => {
                        // leave the job queued: it survives to the restart
                        svc_down = true;
                        break;
                    }
                    Err(e) => {
                        // Unroutable / Invalid: no retry will change it
                        if let Err(pe) = sh.store.record_dead(id, &e.to_string()) {
                            eprintln!("job {id}: failed to persist outcome: {pe}");
                        }
                        sh.notify_watchers(id);
                    }
                }
            }
        }

        // 3. periodic TTL sweep, gauges, compaction
        if last_sweep.elapsed() >= sh.cfg.sweep_interval {
            last_sweep = Instant::now();
            if let Err(e) = sh.store.sweep_expired(now_ms()) {
                eprintln!("job TTL sweep failed: {e}");
            }
        }
        if sh.store.appended_records() >= sh.cfg.checkpoint_every {
            if let Err(e) = sh.store.checkpoint() {
                eprintln!("job checkpoint failed: {e}");
            }
        }
        sh.push_gauges();

        // 4. exit conditions
        if (stopping || svc_down) && inflight.is_empty() {
            break;
        }
        if let Some(dl) = drain_deadline {
            if Instant::now() >= dl {
                // grace expired: the attempts never completed, so they
                // restart as queued — checkpointed, not discarded
                for (id, _ticket) in inflight.drain() {
                    sh.store.requeue(id);
                }
                break;
            }
        }

        // 5. sleep until woken or the next deadline
        let mut timeout = sh.cfg.sweep_interval;
        if let Some(next) = sh.store.next_run_at() {
            let wait = next.saturating_sub(now_ms());
            timeout = timeout.min(Duration::from_millis(wait.max(1)));
        }
        if stopping {
            timeout = timeout.min(Duration::from_millis(50));
        }
        sh.wake.wait_timeout(timeout);
    }

    // graceful exit: everything durable, log compacted
    if let Err(e) = sh.store.checkpoint() {
        eprintln!("final job checkpoint failed: {e}");
    }
    sh.push_gauges();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{GenRequest, SolverChoice, TaskKind};
    use crate::coordinator::service::Engine;
    use crate::coordinator::{BatcherConfig, Service, ServiceConfig};
    use std::path::PathBuf;
    use std::sync::atomic::AtomicUsize;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("memdiff_runner_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn req(n: usize) -> GenRequest {
        GenRequest {
            id: 0,
            task: TaskKind::Circle,
            n_samples: n,
            solver: SolverChoice::AnalogOde,
            guidance: 0.0,
            decode: false,
            trace: crate::obs::TraceId::NONE,
        }
    }

    fn svc(engine: Arc<dyn Engine>) -> Arc<Service> {
        Arc::new(Service::start(
            engine,
            None,
            ServiceConfig {
                workers: 1,
                batcher: BatcherConfig {
                    max_batch_samples: 64,
                    linger: Duration::from_millis(1),
                    ..BatcherConfig::default()
                },
                seed: 7,
                intra_threads: 1,
            },
        ))
    }

    fn fast_cfg() -> RunnerConfig {
        RunnerConfig {
            max_retries: 2,
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(20),
            result_ttl: Duration::from_secs(60),
            sweep_interval: Duration::from_millis(20),
            checkpoint_every: 10_000,
            drain_grace: Duration::from_secs(5),
        }
    }

    /// Succeeds always (tags samples 1.0).
    struct OkEngine;
    impl Engine for OkEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, _c: &[f32], _g: f32, n: usize,
                    _r: &mut Rng) -> anyhow::Result<Vec<f32>> {
            Ok(vec![1.0; n * 2])
        }
    }

    /// Fails the first `fails` calls, then succeeds.
    struct FlakyEngine {
        fails: usize,
        calls: AtomicUsize,
    }
    impl Engine for FlakyEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, _c: &[f32], _g: f32, n: usize,
                    _r: &mut Rng) -> anyhow::Result<Vec<f32>> {
            if self.calls.fetch_add(1, Ordering::SeqCst) < self.fails {
                anyhow::bail!("injected transient failure");
            }
            Ok(vec![2.0; n * 2])
        }
    }

    #[test]
    fn job_runs_to_done_and_result_is_durable() {
        let dir = tmpdir("done");
        let id;
        {
            let store = Arc::new(JobStore::open(&dir).unwrap());
            let runner = JobRunner::start(svc(Arc::new(OkEngine)), store, fast_cfg());
            id = runner.enqueue(&req(4), 0, None, None).unwrap();
            let job = runner.wait_result(id, Duration::from_secs(10)).unwrap();
            assert_eq!(job.state, JobState::Done);
            assert_eq!(job.result.as_ref().unwrap().samples.len(), 8);
            runner.drain();
        }
        // the retained result survives a restart
        let store = JobStore::open(&dir).unwrap();
        let job = store.get(id).unwrap();
        assert_eq!(job.state, JobState::Done);
        assert_eq!(job.result.unwrap().samples, vec![1.0; 8]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn transient_failures_retry_then_succeed() {
        let dir = tmpdir("flaky");
        let store = Arc::new(JobStore::open(&dir).unwrap());
        let engine = Arc::new(FlakyEngine { fails: 2, calls: AtomicUsize::new(0) });
        let runner = JobRunner::start(svc(engine), store, fast_cfg());
        let id = runner.enqueue(&req(2), 0, Some(3), None).unwrap();
        let job = runner.wait_result(id, Duration::from_secs(10)).unwrap();
        assert_eq!(job.state, JobState::Done, "err={:?}", job.error);
        assert_eq!(job.attempts, 2, "two failed attempts before success");
        runner.drain();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_exhaustion_goes_dead_with_error_retained() {
        let dir = tmpdir("dead");
        let store = Arc::new(JobStore::open(&dir).unwrap());
        let engine =
            Arc::new(FlakyEngine { fails: usize::MAX, calls: AtomicUsize::new(0) });
        let runner = JobRunner::start(svc(engine), store, fast_cfg());
        let id = runner.enqueue(&req(1), 0, Some(1), None).unwrap();
        let job = runner.wait_result(id, Duration::from_secs(10)).unwrap();
        assert_eq!(job.state, JobState::Dead);
        assert_eq!(job.attempts, 1);
        assert!(job.error.unwrap().contains("transient failure"));
        runner.drain();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deferred_job_waits_for_run_at() {
        let dir = tmpdir("defer");
        let store = Arc::new(JobStore::open(&dir).unwrap());
        let runner = JobRunner::start(svc(Arc::new(OkEngine)), store, fast_cfg());
        let id = runner.enqueue(&req(1), 150, None, None).unwrap();
        let early = runner.wait_result(id, Duration::from_millis(30)).unwrap();
        assert!(!early.state.is_terminal(), "must still be waiting");
        let job = runner.wait_result(id, Duration::from_secs(10)).unwrap();
        assert_eq!(job.state, JobState::Done);
        runner.drain();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cancel_before_run_prevents_execution() {
        let dir = tmpdir("cancel");
        let store = Arc::new(JobStore::open(&dir).unwrap());
        let runner = JobRunner::start(svc(Arc::new(OkEngine)), store, fast_cfg());
        let id = runner.enqueue(&req(1), 60_000, None, None).unwrap();
        assert_eq!(runner.cancel(id).unwrap(), JobState::Cancelled);
        let job = runner.wait_result(id, Duration::from_secs(2)).unwrap();
        assert_eq!(job.state, JobState::Cancelled);
        assert!(job.result.is_none());
        runner.drain();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gauges_flow_into_service_metrics() {
        let dir = tmpdir("gauges");
        let store = Arc::new(JobStore::open(&dir).unwrap());
        let service = svc(Arc::new(OkEngine));
        let runner = JobRunner::start(Arc::clone(&service), store, fast_cfg());
        let id = runner.enqueue(&req(1), 0, None, None).unwrap();
        runner.wait_result(id, Duration::from_secs(10)).unwrap();
        runner.drain();
        let snap = service.metrics.snapshot();
        let jobs = snap.jobs.expect("job gauges published");
        assert_eq!(jobs.enqueued_total, 1);
        assert_eq!(jobs.done, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
