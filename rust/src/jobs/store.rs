//! Durable job state: append-only record log + snapshot under a state
//! directory.
//!
//! The [`JobStore`] is the persistence half of the job layer.  Every
//! accepted mutation — enqueue, failed attempt, terminal outcome, cancel,
//! TTL expiry — is one checksummed frame ([`super::record`]) appended to
//! `jobs.log` and **fsync'd before the call returns**, so an acknowledged
//! enqueue survives SIGKILL.  [`JobStore::checkpoint`] compacts the pair:
//! the full job table is written to `snapshot.json` atomically (tmp +
//! fsync + rename) and the log is truncated.  [`JobStore::open`] replays
//! snapshot-then-log, tolerating a torn log tail (the partial frame is
//! discarded and the file truncated back to the clean prefix).
//!
//! `Running` is deliberately **not** a durable state: no record marks the
//! start of an attempt, so any job that was in flight at the crash
//! replays as `Queued` and is re-run — at-least-once execution, never
//! silent loss.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context};

use super::record;
use crate::coordinator::metrics::JobGauges;
use crate::coordinator::request::{GenRequest, GenResponse, SolverChoice, TaskKind};
use crate::util::json::Json;

/// Milliseconds since the unix epoch — the store's wall-clock unit
/// (persisted `run_at` / `expire_at` stamps must survive restarts, so
/// they cannot be `Instant`s).
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Lifecycle state of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting for its `run_at` time and a lane slot.
    Queued,
    /// Submitted to the service; a ticket is in flight.
    Running,
    /// Last attempt failed; waiting out its backoff until `run_at`.
    Failed,
    /// Completed; result retained until `expire_at`.
    Done,
    /// Retry budget exhausted; error retained until `expire_at`.
    Dead,
    /// Cancelled by the client.
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Failed => "failed",
            JobState::Done => "done",
            JobState::Dead => "dead",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn from_str(s: &str) -> Option<JobState> {
        match s {
            "queued" => Some(JobState::Queued),
            "running" => Some(JobState::Running),
            "failed" => Some(JobState::Failed),
            "done" => Some(JobState::Done),
            "dead" => Some(JobState::Dead),
            "cancelled" => Some(JobState::Cancelled),
            _ => None,
        }
    }

    /// Terminal states never transition again (and carry an `expire_at`).
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Dead | JobState::Cancelled)
    }
}

/// Retained result of a completed job (the durable subset of
/// [`GenResponse`]).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub samples: Vec<f32>,
    pub images: Option<Vec<f32>>,
    pub wall_latency_s: f64,
    pub hw_latency_s: f64,
    pub hw_energy_j: f64,
}

impl From<GenResponse> for JobResult {
    fn from(r: GenResponse) -> Self {
        JobResult {
            samples: r.samples,
            images: r.images,
            wall_latency_s: r.wall_latency_s,
            hw_latency_s: r.hw_latency_s,
            hw_energy_j: r.hw_energy_j,
        }
    }
}

/// One durable job: the request plus its lifecycle bookkeeping.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub task: TaskKind,
    pub n_samples: usize,
    pub solver: SolverChoice,
    pub guidance: f32,
    pub decode: bool,
    pub state: JobState,
    /// Completed attempts (failed submissions/executions so far).
    pub attempts: u32,
    /// Retry budget: the job goes `Dead` when `attempts` would exceed it.
    pub max_retries: u32,
    /// Earliest time (unix ms) the job may run — enqueue deferral or the
    /// current retry backoff.
    pub run_at_ms: u64,
    /// Retention of the terminal record (result or error) in ms.
    pub ttl_ms: u64,
    /// When a terminal job's record is swept (unix ms; 0 = not terminal).
    pub expire_at_ms: u64,
    /// Last failure message (also the terminal error of a `Dead` job).
    pub error: Option<String>,
    pub result: Option<JobResult>,
    /// Cancel arrived while the job was in flight; the completion will be
    /// discarded and the job finalized as `Cancelled`.
    pub cancel_requested: bool,
    /// Trace identity: taken from the enqueueing request (so wire spans
    /// and job attempts correlate) and restored verbatim on crash replay
    /// — minted ids carry a per-process epoch in their high bits, so a
    /// persisted trace is vanishingly unlikely to collide with the new
    /// incarnation's mints (~1 in 2M per restart) and a job's pre-/
    /// post-restart spans join on one id.
    pub trace: crate::obs::TraceId,
}

impl Job {
    /// The service request this job re-submits on every attempt.
    pub fn to_request(&self) -> GenRequest {
        GenRequest {
            id: 0,
            task: self.task,
            n_samples: self.n_samples,
            solver: self.solver,
            guidance: self.guidance,
            decode: self.decode,
            trace: self.trace,
        }
    }
}

struct Inner {
    log: File,
    /// Records appended since the last checkpoint (compaction trigger).
    appended: usize,
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
    enqueued_total: u64,
    retries_total: u64,
}

/// The durable job table (see the module docs for the crash contract).
pub struct JobStore {
    dir: PathBuf,
    inner: Mutex<Inner>,
}

const LOG_FILE: &str = "jobs.log";
const SNAP_FILE: &str = "snapshot.json";

impl JobStore {
    /// Open (or create) a state directory and replay it: snapshot first,
    /// then every complete log record; a torn log tail is truncated.
    /// Jobs that were `Running` at the crash come back `Queued`.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<JobStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        let mut inner = Inner {
            // placeholder; replaced below after replay/truncate
            log: OpenOptions::new()
                .create(true)
                .append(true)
                .open(dir.join(LOG_FILE))?,
            appended: 0,
            jobs: BTreeMap::new(),
            next_id: 1,
            enqueued_total: 0,
            retries_total: 0,
        };

        let snap_path = dir.join(SNAP_FILE);
        if let Ok(text) = std::fs::read_to_string(&snap_path) {
            let j = Json::parse(&text)
                .map_err(|e| anyhow!("corrupt {}: {e}", snap_path.display()))?;
            inner.next_id =
                j.get("next_id").and_then(|v| v.as_f64()).unwrap_or(1.0) as u64;
            inner.enqueued_total =
                j.get("enqueued_total").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
            inner.retries_total =
                j.get("retries_total").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
            for jj in j.get("jobs").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                let job = job_from_json(jj)
                    .ok_or_else(|| anyhow!("corrupt job in snapshot"))?;
                inner.jobs.insert(job.id, job);
            }
        }

        let log_path = dir.join(LOG_FILE);
        let bytes = std::fs::read(&log_path).unwrap_or_default();
        let (payloads, clean) = record::decode_all(&bytes);
        for p in &payloads {
            let text = std::str::from_utf8(p)
                .map_err(|_| anyhow!("non-utf8 log record"))?;
            let j = Json::parse(text).map_err(|e| anyhow!("corrupt record: {e}"))?;
            apply_record(&mut inner, &j)?;
        }
        if clean < bytes.len() {
            // torn/corrupt tail: cut back to the last complete frame so
            // the next append starts on a frame boundary
            let f = OpenOptions::new().write(true).open(&log_path)?;
            f.set_len(clean as u64)?;
            f.sync_data()?;
        }
        // an attempt in flight at the crash replays as queued (re-run;
        // at-least-once) — unless a durable cancel arrived meanwhile
        for job in inner.jobs.values_mut() {
            if job.state == JobState::Running {
                job.state = JobState::Queued;
            }
        }
        inner.log = OpenOptions::new().create(true).append(true).open(&log_path)?;
        inner.appended = payloads.len();
        Ok(JobStore { dir, inner: Mutex::new(inner) })
    }

    /// The state directory this store persists under.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Persist and admit a new job.  Returns its id only after the `enq`
    /// record is on disk and fsync'd — the durability acknowledgement.
    pub fn enqueue(&self, req: &GenRequest, defer_ms: u64, max_retries: u32,
                   ttl_ms: u64) -> anyhow::Result<u64> {
        let mut m = self.inner.lock().unwrap();
        let id = m.next_id;
        m.next_id += 1;
        let job = Job {
            id,
            task: req.task,
            n_samples: req.n_samples,
            solver: req.solver,
            guidance: req.guidance,
            decode: req.decode,
            state: JobState::Queued,
            attempts: 0,
            max_retries,
            run_at_ms: now_ms() + defer_ms,
            ttl_ms,
            expire_at_ms: 0,
            error: None,
            result: None,
            cancel_requested: false,
            trace: if req.trace.is_none() {
                crate::obs::TraceId::mint()
            } else {
                req.trace
            },
        };
        let rec = enq_record(&job);
        append_synced(&mut m, &rec)?;
        m.enqueued_total += 1;
        m.jobs.insert(id, job);
        Ok(id)
    }

    /// Mark a job in flight.  In-memory only — no record is written, so a
    /// crash now replays the job as `Queued` (the at-least-once contract).
    pub fn mark_running(&self, id: u64) {
        let mut m = self.inner.lock().unwrap();
        if let Some(j) = m.jobs.get_mut(&id) {
            if j.state == JobState::Queued || j.state == JobState::Failed {
                j.state = JobState::Running;
            }
        }
    }

    /// Return a job from flight to `Queued` without burning budget (the
    /// graceful-drain path: the attempt never completed, so the restart
    /// re-runs it — exactly what a crash would have done).
    pub fn requeue(&self, id: u64) {
        let mut m = self.inner.lock().unwrap();
        if let Some(j) = m.jobs.get_mut(&id) {
            if j.state == JobState::Running {
                j.state = JobState::Queued;
                j.run_at_ms = now_ms();
            }
        }
    }

    /// Record one failed attempt: increments the attempt count and parks
    /// the job as `Failed` until `next_run_at_ms` (the backoff deadline).
    pub fn record_failure(&self, id: u64, err: &str, next_run_at_ms: u64)
                          -> anyhow::Result<()> {
        let mut m = self.inner.lock().unwrap();
        let rec = obj(&[
            ("t", Json::Str("fail".into())),
            ("job", num(id)),
            ("err", Json::Str(err.into())),
            ("run_at", num(next_run_at_ms)),
        ]);
        append_synced(&mut m, &rec)?;
        m.retries_total += 1;
        let j = m.jobs.get_mut(&id).ok_or_else(|| anyhow!("unknown job {id}"))?;
        j.attempts += 1;
        j.state = JobState::Failed;
        j.run_at_ms = next_run_at_ms;
        j.error = Some(err.to_string());
        Ok(())
    }

    /// Record the terminal failure: budget exhausted (or unroutable).
    pub fn record_dead(&self, id: u64, err: &str) -> anyhow::Result<()> {
        let mut m = self.inner.lock().unwrap();
        let Some(j) = m.jobs.get(&id) else { return Err(anyhow!("unknown job {id}")) };
        let expire = now_ms() + j.ttl_ms;
        let rec = obj(&[
            ("t", Json::Str("dead".into())),
            ("job", num(id)),
            ("err", Json::Str(err.into())),
            ("exp", num(expire)),
        ]);
        append_synced(&mut m, &rec)?;
        let j = m.jobs.get_mut(&id).unwrap();
        j.state = JobState::Dead;
        j.error = Some(err.to_string());
        j.expire_at_ms = expire;
        Ok(())
    }

    /// Record a completed job; the result is retained until its TTL.  If
    /// a cancel arrived while the job was in flight, the completion is
    /// discarded and the job finalizes as `Cancelled` (already durable
    /// via the cancel record).
    pub fn record_done(&self, id: u64, result: JobResult) -> anyhow::Result<()> {
        let mut m = self.inner.lock().unwrap();
        let Some(j) = m.jobs.get(&id) else { return Err(anyhow!("unknown job {id}")) };
        let expire = now_ms() + j.ttl_ms;
        if j.cancel_requested {
            let j = m.jobs.get_mut(&id).unwrap();
            j.state = JobState::Cancelled;
            j.expire_at_ms = expire;
            return Ok(());
        }
        let mut fields = vec![
            ("t", Json::Str("done".into())),
            ("job", num(id)),
            ("exp", num(expire)),
            ("samples",
             Json::Arr(result.samples.iter().map(|&v| Json::Num(v as f64)).collect())),
            ("wall_latency_s", Json::Num(result.wall_latency_s)),
            ("hw_latency_s", Json::Num(result.hw_latency_s)),
            ("hw_energy_j", Json::Num(result.hw_energy_j)),
        ];
        if let Some(images) = &result.images {
            fields.push(("images",
                         Json::Arr(images.iter().map(|&v| Json::Num(v as f64))
                                         .collect())));
        }
        let rec = obj(&fields);
        append_synced(&mut m, &rec)?;
        let j = m.jobs.get_mut(&id).unwrap();
        j.state = JobState::Done;
        j.expire_at_ms = expire;
        j.result = Some(result);
        Ok(())
    }

    /// Cancel a job.  Waiting jobs (`Queued`/`Failed`) cancel immediately;
    /// a `Running` job is flagged and finalizes as `Cancelled` when its
    /// in-flight attempt resolves; terminal jobs are untouched.  Returns
    /// the state after the call.
    pub fn cancel(&self, id: u64) -> anyhow::Result<JobState> {
        let mut m = self.inner.lock().unwrap();
        let Some(j) = m.jobs.get(&id) else { return Err(anyhow!("unknown job {id}")) };
        if j.state.is_terminal() {
            return Ok(j.state);
        }
        let expire = now_ms() + j.ttl_ms;
        let rec = obj(&[("t", Json::Str("cancel".into())), ("job", num(id))]);
        append_synced(&mut m, &rec)?;
        let j = m.jobs.get_mut(&id).unwrap();
        if j.state == JobState::Running {
            j.cancel_requested = true;
        } else {
            j.state = JobState::Cancelled;
            j.expire_at_ms = expire;
        }
        Ok(j.state)
    }

    /// Snapshot one job (None if unknown or already swept).
    pub fn get(&self, id: u64) -> Option<Job> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
            .jobs.get(&id).cloned()
    }

    /// Ids of jobs ready to submit: `Queued`/`Failed`, due, not flagged
    /// for cancel — in id order (FIFO among equally-due jobs).
    pub fn due(&self, now: u64) -> Vec<u64> {
        let m = self.inner.lock().unwrap();
        m.jobs
            .values()
            .filter(|j| {
                matches!(j.state, JobState::Queued | JobState::Failed)
                    && !j.cancel_requested
                    && j.run_at_ms <= now
            })
            .map(|j| j.id)
            .collect()
    }

    /// Earliest `run_at` among waiting jobs (the scheduler's next wakeup).
    pub fn next_run_at(&self) -> Option<u64> {
        let m = self.inner.lock().unwrap();
        m.jobs
            .values()
            .filter(|j| {
                matches!(j.state, JobState::Queued | JobState::Failed)
                    && !j.cancel_requested
            })
            .map(|j| j.run_at_ms)
            .min()
    }

    /// Sweep expired terminal jobs (TTL retention).  Each removal is
    /// logged so a replay converges to the same table.  Returns how many
    /// were swept.
    pub fn sweep_expired(&self, now: u64) -> anyhow::Result<usize> {
        let mut m = self.inner.lock().unwrap();
        let expired: Vec<u64> = m
            .jobs
            .values()
            .filter(|j| j.state.is_terminal() && j.expire_at_ms > 0
                        && j.expire_at_ms <= now)
            .map(|j| j.id)
            .collect();
        for &id in &expired {
            let rec = obj(&[("t", Json::Str("exp".into())), ("job", num(id))]);
            append_synced(&mut m, &rec)?;
            m.jobs.remove(&id);
        }
        Ok(expired.len())
    }

    /// Records appended since the last checkpoint (compaction trigger).
    pub fn appended_records(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).appended
    }

    /// Compact: write the whole table to `snapshot.json` atomically
    /// (tmp + fsync + rename), then truncate the log.  Crash-safe at any
    /// point — the rename is atomic and the log is only cut *after* the
    /// new snapshot is durable.
    pub fn checkpoint(&self) -> anyhow::Result<()> {
        let mut m = self.inner.lock().unwrap();
        let mut top = BTreeMap::new();
        top.insert("next_id".to_string(), num(m.next_id));
        top.insert("enqueued_total".to_string(), num(m.enqueued_total));
        top.insert("retries_total".to_string(), num(m.retries_total));
        top.insert("jobs".to_string(),
                   Json::Arr(m.jobs.values().map(job_to_json).collect()));
        let text = Json::Obj(top).to_string();

        let tmp = self.dir.join("snapshot.json.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(text.as_bytes())?;
            let _t = crate::obs::phase(crate::obs::Phase::Fsync);
            f.sync_data()?;
        }
        std::fs::rename(&tmp, self.dir.join(SNAP_FILE))?;
        // durability of the rename itself (best-effort where the platform
        // allows opening a directory)
        #[cfg(unix)]
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        // everything in the log is now covered by the snapshot
        let log_path = self.dir.join(LOG_FILE);
        let f = OpenOptions::new().write(true).open(&log_path)?;
        f.set_len(0)?;
        f.sync_data()?;
        m.log = OpenOptions::new().create(true).append(true).open(&log_path)?;
        m.appended = 0;
        Ok(())
    }

    /// Per-state counts + lifetime totals, for the metrics gauges.
    pub fn gauges(&self) -> JobGauges {
        let m = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut g = JobGauges {
            enqueued_total: m.enqueued_total,
            retries_total: m.retries_total,
            ..JobGauges::default()
        };
        for j in m.jobs.values() {
            match j.state {
                JobState::Queued => g.queued += 1,
                JobState::Running => g.running += 1,
                JobState::Failed => g.failed += 1,
                JobState::Done => g.done += 1,
                JobState::Dead => g.dead += 1,
                JobState::Cancelled => g.cancelled += 1,
            }
        }
        g
    }
}

// ---------------------------------------------------------------------
// record / snapshot serialization (hand-rolled JSON, like the wire layer)

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn obj(fields: &[(&str, Json)]) -> Json {
    Json::Obj(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

fn enq_record(job: &Job) -> Json {
    let mut fields = vec![
        ("t", Json::Str("enq".into())),
        ("job", num(job.id)),
        ("task", Json::Str(job.task.name().into())),
        ("n", num(job.n_samples as u64)),
        ("solver", Json::Str(job.solver.name().into())),
        ("guidance", Json::Num(job.guidance as f64)),
        ("decode", Json::Bool(job.decode)),
        ("run_at", num(job.run_at_ms)),
        ("max_retries", num(job.max_retries as u64)),
        ("ttl_ms", num(job.ttl_ms)),
        ("trace", num(job.trace.0)),
    ];
    if let Some(steps) = job.solver.steps() {
        fields.push(("steps", num(steps as u64)));
    }
    obj(&fields)
}

fn parse_solver(j: &Json) -> Option<SolverChoice> {
    let name = j.get("solver")?.as_str()?;
    let steps = j.get("steps").and_then(|v| v.as_usize()).unwrap_or(130);
    SolverChoice::from_name(name, steps)
}

fn apply_record(inner: &mut Inner, j: &Json) -> anyhow::Result<()> {
    let t = j.get("t").and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("record missing type tag"))?;
    let id = j.get("job").and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("record missing job id"))? as u64;
    match t {
        "enq" => {
            let task = j.get("task").and_then(|v| v.as_str())
                .and_then(TaskKind::from_name)
                .ok_or_else(|| anyhow!("enq record: bad task"))?;
            let solver = parse_solver(j)
                .ok_or_else(|| anyhow!("enq record: bad solver"))?;
            let job = Job {
                id,
                task,
                n_samples: j.get("n").and_then(|v| v.as_usize()).unwrap_or(1),
                solver,
                guidance: j.get("guidance").and_then(|v| v.as_f64())
                    .unwrap_or(2.0) as f32,
                decode: matches!(j.get("decode"), Some(Json::Bool(true))),
                state: JobState::Queued,
                attempts: 0,
                max_retries: j.get("max_retries").and_then(|v| v.as_usize())
                    .unwrap_or(0) as u32,
                run_at_ms: j.get("run_at").and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64,
                ttl_ms: j.get("ttl_ms").and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64,
                expire_at_ms: 0,
                error: None,
                result: None,
                cancel_requested: false,
                // restore the persisted trace so replayed attempts keep
                // their identity (see `Job`); absent/zero = mint fresh
                trace: j.get("trace").and_then(|v| v.as_f64())
                    .map(|v| crate::obs::TraceId(v as u64))
                    .filter(|t| !t.is_none())
                    .unwrap_or_else(crate::obs::TraceId::mint),
            };
            inner.jobs.insert(id, job);
            inner.next_id = inner.next_id.max(id + 1);
            inner.enqueued_total += 1;
        }
        "fail" => {
            inner.retries_total += 1;
            if let Some(job) = inner.jobs.get_mut(&id) {
                job.attempts += 1;
                job.state = JobState::Failed;
                job.run_at_ms = j.get("run_at").and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64;
                job.error = j.get("err").and_then(|v| v.as_str()).map(String::from);
            }
        }
        "dead" => {
            if let Some(job) = inner.jobs.get_mut(&id) {
                job.state = JobState::Dead;
                job.error = j.get("err").and_then(|v| v.as_str()).map(String::from);
                job.expire_at_ms = j.get("exp").and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64;
            }
        }
        "done" => {
            if let Some(job) = inner.jobs.get_mut(&id) {
                // a durable cancel before the done record wins
                if job.state == JobState::Cancelled {
                    return Ok(());
                }
                job.state = JobState::Done;
                job.expire_at_ms = j.get("exp").and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64;
                job.result = Some(JobResult {
                    samples: j.get("samples").and_then(|v| v.as_arr())
                        .map(|a| a.iter().filter_map(|v| v.as_f64())
                              .map(|x| x as f32).collect())
                        .unwrap_or_default(),
                    images: j.get("images").and_then(|v| v.as_arr())
                        .map(|a| a.iter().filter_map(|v| v.as_f64())
                              .map(|x| x as f32).collect()),
                    wall_latency_s: j.get("wall_latency_s")
                        .and_then(|v| v.as_f64()).unwrap_or(0.0),
                    hw_latency_s: j.get("hw_latency_s")
                        .and_then(|v| v.as_f64()).unwrap_or(0.0),
                    hw_energy_j: j.get("hw_energy_j")
                        .and_then(|v| v.as_f64()).unwrap_or(0.0),
                });
            }
        }
        "cancel" => {
            if let Some(job) = inner.jobs.get_mut(&id) {
                if !job.state.is_terminal() {
                    job.state = JobState::Cancelled;
                    job.expire_at_ms = now_ms() + job.ttl_ms;
                }
            }
        }
        "exp" => {
            inner.jobs.remove(&id);
        }
        other => return Err(anyhow!("unknown record type {other:?}")),
    }
    Ok(())
}

fn job_to_json(job: &Job) -> Json {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), num(job.id));
    m.insert("task".to_string(), Json::Str(job.task.name().into()));
    m.insert("n".to_string(), num(job.n_samples as u64));
    m.insert("solver".to_string(), Json::Str(job.solver.name().into()));
    if let Some(steps) = job.solver.steps() {
        m.insert("steps".to_string(), num(steps as u64));
    }
    m.insert("guidance".to_string(), Json::Num(job.guidance as f64));
    m.insert("decode".to_string(), Json::Bool(job.decode));
    m.insert("state".to_string(), Json::Str(job.state.as_str().into()));
    m.insert("attempts".to_string(), num(job.attempts as u64));
    m.insert("max_retries".to_string(), num(job.max_retries as u64));
    m.insert("run_at".to_string(), num(job.run_at_ms));
    m.insert("ttl_ms".to_string(), num(job.ttl_ms));
    m.insert("exp".to_string(), num(job.expire_at_ms));
    m.insert("trace".to_string(), num(job.trace.0));
    if let Some(err) = &job.error {
        m.insert("err".to_string(), Json::Str(err.clone()));
    }
    if job.cancel_requested {
        m.insert("cancel_requested".to_string(), Json::Bool(true));
    }
    if let Some(r) = &job.result {
        m.insert("samples".to_string(),
                 Json::Arr(r.samples.iter().map(|&v| Json::Num(v as f64)).collect()));
        if let Some(images) = &r.images {
            m.insert("images".to_string(),
                     Json::Arr(images.iter().map(|&v| Json::Num(v as f64)).collect()));
        }
        m.insert("wall_latency_s".to_string(), Json::Num(r.wall_latency_s));
        m.insert("hw_latency_s".to_string(), Json::Num(r.hw_latency_s));
        m.insert("hw_energy_j".to_string(), Json::Num(r.hw_energy_j));
    }
    Json::Obj(m)
}

fn job_from_json(j: &Json) -> Option<Job> {
    let state = JobState::from_str(j.get("state")?.as_str()?)?;
    let result = j.get("samples").and_then(|v| v.as_arr()).map(|a| JobResult {
        samples: a.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect(),
        images: j.get("images").and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect()),
        wall_latency_s: j.get("wall_latency_s").and_then(|v| v.as_f64())
            .unwrap_or(0.0),
        hw_latency_s: j.get("hw_latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
        hw_energy_j: j.get("hw_energy_j").and_then(|v| v.as_f64()).unwrap_or(0.0),
    });
    Some(Job {
        id: j.get("id")?.as_f64()? as u64,
        task: TaskKind::from_name(j.get("task")?.as_str()?)?,
        n_samples: j.get("n").and_then(|v| v.as_usize()).unwrap_or(1),
        solver: parse_solver(j)?,
        guidance: j.get("guidance").and_then(|v| v.as_f64()).unwrap_or(2.0) as f32,
        decode: matches!(j.get("decode"), Some(Json::Bool(true))),
        state,
        attempts: j.get("attempts").and_then(|v| v.as_usize()).unwrap_or(0) as u32,
        max_retries: j.get("max_retries").and_then(|v| v.as_usize())
            .unwrap_or(0) as u32,
        run_at_ms: j.get("run_at").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        ttl_ms: j.get("ttl_ms").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        expire_at_ms: j.get("exp").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        error: j.get("err").and_then(|v| v.as_str()).map(String::from),
        result,
        cancel_requested: matches!(j.get("cancel_requested"), Some(Json::Bool(true))),
        // restore the persisted trace (see `Job`); absent/zero = mint
        trace: j.get("trace").and_then(|v| v.as_f64())
            .map(|v| crate::obs::TraceId(v as u64))
            .filter(|t| !t.is_none())
            .unwrap_or_else(crate::obs::TraceId::mint),
    })
}

/// Append one framed record and fsync before returning — the durability
/// acknowledgement point of every mutation.
fn append_synced(inner: &mut Inner, rec: &Json) -> anyhow::Result<()> {
    let frame = record::encode(rec.to_string().as_bytes());
    inner.log.write_all(&frame).context("appending job record")?;
    {
        let _t = crate::obs::phase(crate::obs::Phase::Fsync);
        inner.log.sync_data().context("fsyncing job log")?;
    }
    inner.appended += 1;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("memdiff_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn req(n: usize) -> GenRequest {
        GenRequest {
            id: 0,
            task: TaskKind::Letter(1),
            n_samples: n,
            solver: SolverChoice::DigitalOde { steps: 40 },
            trace: crate::obs::TraceId::NONE,
            guidance: 1.5,
            decode: false,
        }
    }

    #[test]
    fn enqueue_replays_across_reopen() {
        let dir = tmpdir("reopen");
        let a;
        {
            let s = JobStore::open(&dir).unwrap();
            a = s.enqueue(&req(3), 0, 4, 60_000).unwrap();
            let b = s.enqueue(&req(5), 10_000, 2, 60_000).unwrap();
            assert_ne!(a, b);
            s.mark_running(a); // running is NOT durable
        }
        let s = JobStore::open(&dir).unwrap();
        let ja = s.get(a).unwrap();
        assert_eq!(ja.state, JobState::Queued, "running replays as queued");
        assert_eq!(ja.n_samples, 3);
        assert_eq!(ja.solver, SolverChoice::DigitalOde { steps: 40 });
        assert_eq!(ja.task, TaskKind::Letter(1));
        assert_eq!(ja.max_retries, 4);
        let g = s.gauges();
        assert_eq!((g.queued, g.enqueued_total), (2, 2));
        // fresh enqueues never collide with replayed ids
        let c = s.enqueue(&req(1), 0, 0, 1000).unwrap();
        assert!(c > a);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_identity_survives_reopen_and_checkpoint() {
        let dir = tmpdir("trace");
        let t = crate::obs::TraceId::mint();
        let a;
        {
            let s = JobStore::open(&dir).unwrap();
            let mut r = req(1);
            r.trace = t;
            a = s.enqueue(&r, 0, 0, 60_000).unwrap();
        }
        {
            let s = JobStore::open(&dir).unwrap();
            assert_eq!(s.get(a).unwrap().trace, t,
                       "log replay keeps the persisted trace");
            s.checkpoint().unwrap();
        }
        let s = JobStore::open(&dir).unwrap();
        assert_eq!(s.get(a).unwrap().trace, t,
                   "snapshot restore keeps the persisted trace");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lifecycle_transitions_are_durable() {
        let dir = tmpdir("lifecycle");
        let (a, b, c, d);
        {
            let s = JobStore::open(&dir).unwrap();
            a = s.enqueue(&req(2), 0, 3, 60_000).unwrap();
            b = s.enqueue(&req(2), 0, 1, 60_000).unwrap();
            c = s.enqueue(&req(2), 0, 0, 60_000).unwrap();
            d = s.enqueue(&req(2), 0, 0, 60_000).unwrap();
            s.record_failure(a, "transient", now_ms() + 50).unwrap();
            s.record_done(b, JobResult {
                samples: vec![1.0, 2.0],
                images: None,
                wall_latency_s: 0.5,
                hw_latency_s: 1e-3,
                hw_energy_j: 2e-6,
            }).unwrap();
            s.record_dead(c, "budget exhausted").unwrap();
            assert_eq!(s.cancel(d).unwrap(), JobState::Cancelled);
        }
        let s = JobStore::open(&dir).unwrap();
        let ja = s.get(a).unwrap();
        assert_eq!((ja.state, ja.attempts), (JobState::Failed, 1));
        assert_eq!(ja.error.as_deref(), Some("transient"));
        let jb = s.get(b).unwrap();
        assert_eq!(jb.state, JobState::Done);
        assert_eq!(jb.result.as_ref().unwrap().samples, vec![1.0, 2.0]);
        assert!(jb.expire_at_ms > 0);
        assert_eq!(s.get(c).unwrap().state, JobState::Dead);
        assert_eq!(s.get(d).unwrap().state, JobState::Cancelled);
        assert_eq!(s.gauges().retries_total, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_compacts_and_preserves_everything() {
        let dir = tmpdir("checkpoint");
        let a;
        {
            let s = JobStore::open(&dir).unwrap();
            a = s.enqueue(&req(2), 0, 3, 60_000).unwrap();
            for _ in 0..3 {
                s.enqueue(&req(1), 5_000, 0, 60_000).unwrap();
            }
            s.record_done(a, JobResult {
                samples: vec![7.0; 4],
                images: None,
                wall_latency_s: 0.1,
                hw_latency_s: 0.0,
                hw_energy_j: 0.0,
            }).unwrap();
            assert!(s.appended_records() >= 5);
            s.checkpoint().unwrap();
            assert_eq!(s.appended_records(), 0);
        }
        assert_eq!(std::fs::metadata(dir.join("jobs.log")).unwrap().len(), 0);
        let s = JobStore::open(&dir).unwrap();
        let g = s.gauges();
        assert_eq!((g.queued, g.done, g.enqueued_total), (3, 1, 4));
        assert_eq!(s.get(a).unwrap().result.unwrap().samples, vec![7.0; 4]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn due_and_next_run_at_respect_deferral() {
        let dir = tmpdir("due");
        let s = JobStore::open(&dir).unwrap();
        let now = now_ms();
        let a = s.enqueue(&req(1), 0, 0, 1000).unwrap();
        let b = s.enqueue(&req(1), 3_600_000, 0, 1000).unwrap();
        let due = s.due(now + 10);
        assert!(due.contains(&a) && !due.contains(&b));
        assert_eq!(s.next_run_at().unwrap(), s.get(a).unwrap().run_at_ms);
        // cancel removes from the schedule
        s.cancel(a).unwrap();
        assert!(s.due(now + 10).is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ttl_sweep_expires_terminal_jobs_durably() {
        let dir = tmpdir("ttl");
        let a;
        {
            let s = JobStore::open(&dir).unwrap();
            a = s.enqueue(&req(1), 0, 0, 10).unwrap(); // 10 ms TTL
            let b = s.enqueue(&req(1), 0, 0, 3_600_000).unwrap();
            s.record_done(a, JobResult {
                samples: vec![0.0; 2], images: None,
                wall_latency_s: 0.0, hw_latency_s: 0.0, hw_energy_j: 0.0,
            }).unwrap();
            s.record_done(b, JobResult {
                samples: vec![0.0; 2], images: None,
                wall_latency_s: 0.0, hw_latency_s: 0.0, hw_energy_j: 0.0,
            }).unwrap();
            let swept = s.sweep_expired(now_ms() + 60_000).unwrap();
            assert_eq!(swept, 1, "only the short-TTL job expires");
            assert!(s.get(a).is_none());
            assert!(s.get(b).is_some());
        }
        let s = JobStore::open(&dir).unwrap();
        assert!(s.get(a).is_none(), "expiry survives replay");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cancel_of_running_job_discards_its_completion() {
        let dir = tmpdir("cancel_running");
        let s = JobStore::open(&dir).unwrap();
        let a = s.enqueue(&req(1), 0, 0, 60_000).unwrap();
        s.mark_running(a);
        assert_eq!(s.cancel(a).unwrap(), JobState::Running, "flagged, not yanked");
        s.record_done(a, JobResult {
            samples: vec![9.0; 2], images: None,
            wall_latency_s: 0.0, hw_latency_s: 0.0, hw_energy_j: 0.0,
        }).unwrap();
        let j = s.get(a).unwrap();
        assert_eq!(j.state, JobState::Cancelled);
        assert!(j.result.is_none(), "cancelled result is discarded");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
