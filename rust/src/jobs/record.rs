//! Checksummed record frames for the append-only job log.
//!
//! Every record the [`JobStore`](super::store::JobStore) appends is one
//! self-delimiting frame:
//!
//! ```text
//! magic "MDJ1" (4) | payload len u32 LE (4) | crc32(payload) u32 LE (4) | payload
//! ```
//!
//! The reader walks frames from the start and **stops at the first
//! invalid one** — bad magic, over-cap length, a length that runs past
//! the buffer, or a CRC mismatch.  That is the torn-tail contract: a
//! crash mid-append leaves at most one partial frame at the end of the
//! log, the replay applies every complete frame before it, and the store
//! truncates the file back to the clean prefix so the next append starts
//! on a frame boundary.  A frame that was fully written and fsync'd can
//! never be lost to a *later* torn append.

/// Frame magic — versioned so a future layout bump is detectable.
pub const MAGIC: [u8; 4] = *b"MDJ1";

/// Fixed frame header size (magic + len + crc).
pub const HEADER_BYTES: usize = 12;

/// Hard cap on one record's payload.  A corrupt length field must not
/// drive an unbounded allocation during replay; real records (one job's
/// request or result) are far below this.
pub const MAX_RECORD_BYTES: usize = 1 << 26; // 64 MiB

/// IEEE CRC-32 lookup table, built at compile time (std has no CRC).
const CRC_TABLE: [u32; 256] = make_crc_table();

const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// IEEE CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Encode one payload as a framed record.
pub fn encode(payload: &[u8]) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_RECORD_BYTES);
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Decode every complete, checksum-valid frame from the start of `buf`.
/// Returns the payloads plus the **clean prefix length** — the byte
/// offset just past the last valid frame.  Anything beyond it (a torn or
/// corrupt tail) should be truncated by the caller before appending.
pub fn decode_all(buf: &[u8]) -> (Vec<Vec<u8>>, usize) {
    let mut payloads = Vec::new();
    let mut off = 0usize;
    while off + HEADER_BYTES <= buf.len() {
        if buf[off..off + 4] != MAGIC {
            break;
        }
        let len =
            u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[off + 8..off + 12].try_into().unwrap());
        if len > MAX_RECORD_BYTES || off + HEADER_BYTES + len > buf.len() {
            break;
        }
        let payload = &buf[off + HEADER_BYTES..off + HEADER_BYTES + len];
        if crc32(payload) != crc {
            break;
        }
        payloads.push(payload.to_vec());
        off += HEADER_BYTES + len;
    }
    (payloads, off)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_multiple_frames() {
        let records: Vec<&[u8]> = vec![b"alpha", b"", b"{\"t\":\"enq\"}"];
        let mut buf = Vec::new();
        for r in &records {
            buf.extend_from_slice(&encode(r));
        }
        let (got, clean) = decode_all(&buf);
        assert_eq!(clean, buf.len());
        assert_eq!(got.len(), 3);
        for (g, r) in got.iter().zip(&records) {
            assert_eq!(g.as_slice(), *r);
        }
    }

    #[test]
    fn truncation_at_every_offset_never_panics_or_loses_prefix() {
        let records: Vec<Vec<u8>> =
            (0..5).map(|i| format!("record-{i}-{}", "x".repeat(i * 7)).into_bytes())
                  .collect();
        let mut buf = Vec::new();
        let mut boundaries = Vec::new(); // clean length after frame i
        for r in &records {
            buf.extend_from_slice(&encode(r));
            boundaries.push(buf.len());
        }
        for cut in 0..=buf.len() {
            let (got, clean) = decode_all(&buf[..cut]);
            // complete frames entirely before the cut always survive
            let expect = boundaries.iter().filter(|&&b| b <= cut).count();
            assert_eq!(got.len(), expect, "cut at {cut}");
            assert_eq!(clean, boundaries.get(expect.wrapping_sub(1)).copied()
                                        .unwrap_or(0));
        }
    }

    #[test]
    fn corruption_stops_at_the_bad_frame() {
        let mut buf = encode(b"good-one");
        let keep = buf.len();
        buf.extend_from_slice(&encode(b"will-be-corrupted"));
        // flip one payload bit of the second frame
        let n = buf.len();
        buf[n - 3] ^= 0x40;
        let (got, clean) = decode_all(&buf);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].as_slice(), b"good-one");
        assert_eq!(clean, keep, "clean prefix ends before the corrupt frame");
        // bad magic likewise stops the walk without consuming bytes
        let mut junk = b"XXXX".to_vec();
        junk.extend_from_slice(&encode(b"unreachable"));
        assert_eq!(decode_all(&junk).0.len(), 0);
    }

    #[test]
    fn absurd_length_field_is_rejected_not_allocated() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        let (got, clean) = decode_all(&buf);
        assert!(got.is_empty());
        assert_eq!(clean, 0);
    }
}
