//! Durable job queue: submit-now / fetch-later semantics over the
//! routed [`Service`](crate::coordinator::Service), with crash recovery,
//! retry with backoff, and TTL result retention.
//!
//! A client no longer has to hold a TCP socket open for the whole
//! generation: `enqueue` durably accepts the request and returns a job
//! id; `status` / `result` (long-poll) fetch the outcome later — across
//! a server restart if need be.
//!
//! ```text
//!             enqueue                 submit_nb          ticket Ok
//!  queued ──────────────▶ (due) ──▶ running ─────────────────▶ done
//!    ▲                                │  │                      │ TTL
//!    │  backoff elapsed               │  │ ticket Err /         ▼
//!  failed ◀───────────────────────────┘  │ Overloaded shed   (swept)
//!    │                                   │
//!    │ budget exhausted / unroutable     │ DrainError (shutdown)
//!    ▼                                   ▼
//!   dead (error retained to TTL)     requeued as queued — no budget
//!                                    consumed, survives the restart
//!  cancel: queued/failed → cancelled immediately; running → flagged,
//!  finalized cancelled when the in-flight attempt resolves.
//! ```
//!
//! ## Crash-consistency contract
//!
//! 1. **Acknowledged means durable.**  [`JobStore::enqueue`] appends a
//!    checksummed record ([`record`]) to the append-only log and
//!    **fsyncs before returning the job id**.  Every later transition
//!    (`fail`/`done`/`dead`/`cancel`/TTL expiry) is likewise an fsync'd
//!    record.  A job id the caller has seen can never be silently lost.
//! 2. **Torn tails are tolerated, never fatal.**  Replay applies every
//!    complete, CRC-valid frame from the log head and stops at the first
//!    invalid one; the file is truncated back to that clean prefix.  A
//!    crash mid-append costs at most the *unacknowledged* record being
//!    written — never an acknowledged one.
//! 3. **`running` is not durable — execution is at-least-once.**  No
//!    record marks attempt start, so a job in flight at the crash (or
//!    requeued by a drain) replays as `queued` and is re-run.  A job
//!    whose `done` record hit the log serves its retained result instead
//!    of re-running.
//! 4. **Checkpoints are atomic.**  [`JobStore::checkpoint`] writes the
//!    full table to `snapshot.json` via tmp-file + fsync + rename, then
//!    truncates the log; replay is snapshot-then-log.  A crash at any
//!    byte of that sequence recovers to a consistent state.
//! 5. **Graceful drain checkpoints rather than discards.**  On
//!    [`JobRunner::drain`], in-flight attempts get a grace period to
//!    finish durably; stragglers return to `queued` with no retry budget
//!    consumed, and a final checkpoint lands before the thread exits.
//!
//! The wire surface (`enqueue`/`status`/`result`/`cancel` ops) lives in
//! [`crate::serve::protocol`]; `memdiff serve --state-dir DIR` turns the
//! whole layer on.

pub mod record;
pub mod runner;
pub mod store;

pub use runner::{JobRunner, RunnerConfig};
pub use store::{now_ms, Job, JobResult, JobState, JobStore};
