//! memdiff CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   generate      one-shot generation (task, solver, sample count)
//!   serve         run the batching service over a scripted client load,
//!                 or (with --listen) the TCP front-end speaking the
//!                 line-JSON protocol of `memdiff::serve::protocol`
//!   client        scripted load generator for a --listen server
//!                 (mixed-class burst including deliberate overload)
//!   characterize  device-level figures (Fig. 2): IV, levels, retention,
//!                 moon-star pattern, error distributions
//!   info          print artifact manifest + platform
//!
//! Argument parsing is hand-rolled (`--key value` pairs) — no clap in the
//! offline vendor set.

use std::collections::HashMap;
use std::sync::Arc;

use memdiff::coordinator::{Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::deploy::{self, BackendKind};
use memdiff::coordinator::service::{AnalogEngine, Engine, HloEngine, RustDigitalEngine};
use memdiff::config::Config;
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::KernelMode;
use memdiff::util::rng::Rng;
use memdiff::util::stats;
use memdiff::vae::{DecoderWeights, PixelDecoder};

fn parse_args(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut kv = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // a `--key` followed by another `--flag` is a boolean flag,
            // not a key swallowing the flag as its value
            let val = match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    i += 2;
                    v.clone()
                }
                _ => {
                    i += 1;
                    "true".into()
                }
            };
            kv.insert(key.to_string(), val);
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, kv)
}

fn opt<T: std::str::FromStr>(kv: &HashMap<String, String>, key: &str, default: T) -> T {
    kv.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn usage() -> ! {
    eprintln!(
        "memdiff — resistive-memory neural differential-equation solver\n\
         usage:\n\
         \x20 memdiff generate [--task circle|h|k|u] [--solver analog-ode|analog-sde|euler|euler-sde]\n\
         \x20                  [--n 500] [--steps 130] [--engine analog|rust|hlo] [--decode]\n\
         \x20 memdiff serve    [--requests 64] [--workers 4] [--threads N]\n\
         \x20                  [--deploy analog=analog,digital=rust|hlo,rust_workers=N,\n\
         \x20                   rust_queue=N,rust_weights=PATH,analog_kernel=f32|quant,...]\n\
         \x20                  [--listen 127.0.0.1:7979] [--queue-depth N] [--max-conns N]\n\
         \x20                  [--state-dir DIR] [--substeps N] [--synthetic]\n\
         \x20                  [--metrics-listen 127.0.0.1:9198]\n\
         \x20 memdiff client   --connect HOST:PORT [--requests N] [--burst N]\n\
         \x20                  [--expect-overload] [--shutdown]\n\
         \x20                  [--stats [--prom]] [--dump]\n\
         \x20                  [--health | --age-device SECONDS | --reprogram]\n\
         \x20                  [--enqueue N [--defer-ms N] [--max-retries N] [--ttl-ms N]]\n\
         \x20                  [--fetch ID[,ID...] [--wait-ms N]] [--cancel ID]\n\
         \x20 memdiff characterize\n\
         \x20 memdiff info\n\
         \x20 (global) [--config memdiff.toml] [--seed N]"
    );
    std::process::exit(2);
}

fn task_of(s: &str) -> TaskKind {
    TaskKind::from_name(s).unwrap_or_else(|| usage())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, kv) = parse_args(&args);
    let cfg = Config::load_or_default(kv.get("config").map(|s| s.as_str()))?;
    memdiff::obs::init(&cfg.obs);
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "generate" => cmd_generate(&kv, &cfg),
        "serve" => cmd_serve(&kv, &cfg),
        "client" => cmd_client(&kv, &cfg),
        "characterize" => cmd_characterize(&kv, &cfg),
        "info" => cmd_info(),
        _ => usage(),
    }
}

/// Score weights for an engine: the `[deploy] <backend>_weights` override
/// when given, the synthetic fixture when requested (runs on a fresh
/// checkout — CI smoke uses it), else the standard per-task artifact.
fn load_weights(task: &TaskKind, path: Option<&str>, synthetic: bool)
                -> anyhow::Result<ScoreWeights> {
    if let Some(p) = path {
        return ScoreWeights::load(p);
    }
    if synthetic {
        return Ok(ScoreWeights::synthetic(2, 48, 3, 2024));
    }
    let dir = Meta::artifacts_dir();
    let file = if task.is_conditional() { "weights_cond.json" } else { "weights_uncond.json" };
    ScoreWeights::load(dir.join(file))
}

fn build_engine(engine: &str, task: &TaskKind, cfg: &Config,
                weights_path: Option<&str>, synthetic: bool,
                kernel: KernelMode)
                -> anyhow::Result<Arc<dyn Engine>> {
    let sched = if synthetic {
        Meta::load_default().map(|m| m.sched).unwrap_or_default()
    } else {
        Meta::load_default()?.sched
    };
    // bank-parallel strategy from config; the pool itself is sized by the
    // Service at startup (workers vs. intra-op threads).  The kernel lane
    // (f32 vs conductance-quantized i8) is per backend, from the deploy
    // plan; the hlo engine runs fixed AOT artifacts and ignores it.
    let exec = memdiff::exec::Ctx::new(cfg.par);
    Ok(match engine {
        "analog" => {
            let w = load_weights(task, weights_path, synthetic)?;
            let mut net = AnalogScoreNet::from_conductances(
                &w, CellParams::default(), NoiseModel::ReadFast)
                .with_exec(exec);
            net.set_kernel(kernel);
            if kernel == KernelMode::Quant {
                // the i8 lane serves Ideal sweeps only; a quant deployment
                // is the deterministic serving mode, not the noisy one
                net.set_noise_model(NoiseModel::Ideal);
            }
            Arc::new(AnalogEngine::new(net, sched, cfg.substeps))
        }
        "rust" => {
            let w = load_weights(task, weights_path, synthetic)?;
            let mut net = DigitalScoreNet::new(w).with_exec(exec);
            net.set_kernel(kernel);
            Arc::new(RustDigitalEngine { net, sched })
        }
        "hlo" => {
            // a weights override names an artifacts directory here
            let store = match weights_path {
                Some(dir) => ArtifactStore::open(dir)?,
                None => ArtifactStore::open_default()?,
            };
            let n_classes = store.meta().n_classes;
            Arc::new(HloEngine { store, n_classes })
        }
        _ => usage(),
    })
}

fn cmd_generate(kv: &HashMap<String, String>, cfg: &Config) -> anyhow::Result<()> {
    let task = task_of(kv.get("task").map(|s| s.as_str()).unwrap_or("circle"));
    let n: usize = opt(kv, "n", 500);
    let steps: usize = opt(kv, "steps", 130);
    let solver = match kv.get("solver").map(|s| s.as_str()).unwrap_or("analog-sde") {
        "analog-ode" => SolverChoice::AnalogOde,
        "analog-sde" => SolverChoice::AnalogSde,
        "euler" => SolverChoice::DigitalOde { steps },
        "euler-sde" => SolverChoice::DigitalSde { steps },
        _ => usage(),
    };
    let engine_name = kv.get("engine").map(|s| s.as_str()).unwrap_or(
        if solver.is_analog() { "analog" } else { "hlo" });
    let decode = kv.contains_key("decode");

    let engine = build_engine(engine_name, &task, cfg, None,
                              kv.contains_key("synthetic"), cfg.kernel)?;
    let decoder = if decode {
        Some(Arc::new(PixelDecoder::new(DecoderWeights::load(
            Meta::artifacts_dir().join("vae_decoder.json"))?)))
    } else {
        None
    };
    let service = Service::start(engine, decoder, ServiceConfig {
        workers: cfg.workers,
        batcher: BatcherConfig {
            max_batch_samples: cfg.max_batch,
            linger: std::time::Duration::from_millis(cfg.linger_ms),
            queue_depth: cfg.queue_depth,
        },
        seed: opt(kv, "seed", cfg.seed),
        intra_threads: opt(kv, "threads", cfg.threads),
    });

    let t0 = std::time::Instant::now();
    let resp = service.generate(task, n, solver, cfg.guidance, decode)?;
    let wall = t0.elapsed();

    println!("task={task:?} solver={solver:?} engine={engine_name} n={n}");
    println!("wall={wall:?}  modeled_hw_latency={:.3e}s", resp.hw_latency_s);
    // quality: KL vs ground truth (circle) or cluster stats (letters)
    match task {
        TaskKind::Circle => {
            let mut rng = Rng::new(999);
            let truth = sample_circle(20 * n.max(1000), &mut rng);
            let kl = stats::kl_points(&resp.samples, &truth, 24, 2.0);
            println!("KL(truth || generated) = {kl:.4}");
        }
        TaskKind::Letter(c) => {
            let meta = Meta::load_default()?;
            let xs: Vec<f32> = resp.samples.iter().step_by(2).copied().collect();
            let ys: Vec<f32> = resp.samples.iter().skip(1).step_by(2).copied().collect();
            let m = meta.latent_class_means[c];
            println!(
                "latent mean = ({:.3}, {:.3})  target class mean = ({:.3}, {:.3})",
                stats::mean(&xs), stats::mean(&ys), m[0], m[1]
            );
        }
    }
    if let Some(images) = &resp.images {
        let side = 12;
        println!("decoded {} images; first sample:", images.len() / (side * side));
        for r in 0..side {
            let row: String = (0..side)
                .map(|c| {
                    let v = images[r * side + c];
                    if v > 0.3 { '#' } else if v > -0.3 { '+' } else { '.' }
                })
                .collect();
            println!("  {row}");
        }
    }
    println!("metrics: {}", service.metrics.snapshot().report());
    service.shutdown();
    Ok(())
}

fn cmd_serve(kv: &HashMap<String, String>, cfg: &Config) -> anyhow::Result<()> {
    // deployment table: [deploy] config section, then --deploy overrides
    let mut plan = cfg.deploy.clone();
    if let Some(spec) = kv.get("deploy") {
        plan.apply_overrides(spec)?;
    }
    let workers: usize = opt(kv, "workers", cfg.workers);
    let synthetic = kv.contains_key("synthetic");
    let mut cfg = cfg.clone();
    cfg.queue_depth = opt(kv, "queue-depth", cfg.queue_depth);
    cfg.substeps = opt(kv, "substeps", cfg.substeps);
    let svc_cfg = ServiceConfig {
        workers,
        batcher: BatcherConfig {
            max_batch_samples: cfg.max_batch,
            linger: std::time::Duration::from_millis(cfg.linger_ms),
            queue_depth: cfg.queue_depth,
        },
        seed: cfg.seed,
        intra_threads: opt(kv, "threads", cfg.threads),
    };
    let decoder = DecoderWeights::load(
        Meta::artifacts_dir().join("vae_decoder.json"))
        .ok()
        .map(|w| Arc::new(PixelDecoder::new(w)));
    if decoder.is_none() && !synthetic {
        anyhow::bail!("vae_decoder.json not found (build artifacts or pass --synthetic)");
    }
    let have_decoder = decoder.is_some();
    // one engine per backend the plan names; the conditional weights serve
    // both classes of a family (zero one-hot = unconditional)
    let mut factory = |kind: BackendKind, weights: Option<&str>| {
        build_engine(kind.name(), &TaskKind::Letter(0), &cfg, weights, synthetic,
                     plan.kernel_for(kind))
    };
    let service =
        deploy::start_deployed(&plan, &mut factory, decoder, svc_cfg)?;

    if let Some(addr) = kv.get("listen") {
        return serve_listen(service, addr, kv, &cfg);
    }

    let service = Arc::new(service);
    let n_requests: usize = opt(kv, "requests", 64);
    println!("serve: {n_requests} mixed requests over {workers} workers/backend");
    println!("deployment: {}", service.registry().route_summary());
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut shed = 0usize;
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        // mixed-class load: analog and digital families side by side,
        // conditional and unconditional
        let solver = match i % 4 {
            0 => SolverChoice::AnalogOde,
            1 => SolverChoice::DigitalOde { steps: 100 },
            _ => SolverChoice::DigitalSde { steps: 100 },
        };
        let task = if i % 3 == 0 {
            TaskKind::Circle
        } else {
            TaskKind::Letter(rng.below(3))
        };
        let n = 1 + rng.below(16);
        match service.submit(memdiff::coordinator::GenRequest {
            id: 0,
            task,
            n_samples: n,
            solver,
            guidance: cfg.guidance,
            decode: have_decoder && task.is_conditional() && rng.uniform() < 0.25,
            trace: memdiff::obs::TraceId::mint(),
        }) {
            Ok(ticket) => rxs.push(ticket),
            // bounded lanes shed under the unpaced burst: that IS the
            // backpressure feature — count it instead of crashing
            Err(e) => match e.downcast_ref::<memdiff::serve::SubmitError>() {
                Some(memdiff::serve::SubmitError::Overloaded { .. }) => shed += 1,
                _ => return Err(e),
            },
        }
    }
    let mut total_samples = 0usize;
    for rx in rxs {
        let resp = rx.recv()?;
        total_samples += resp.samples.len() / 2;
    }
    let wall = t0.elapsed();
    println!(
        "served {total_samples} samples in {wall:?} ({:.0} samples/s), \
         {shed} requests shed by backpressure",
        total_samples as f64 / wall.as_secs_f64()
    );
    println!("metrics: {}", service.metrics.snapshot().report());
    Ok(())
}

/// `memdiff serve --listen ADDR`: run the TCP front-end until a client
/// sends `{"op":"shutdown"}` (or `--for-ms` elapses), then drain
/// gracefully — in-flight tickets complete, new connections get a
/// shutting-down response.  With `--state-dir DIR` the durable job layer
/// is mounted too: the store replays its log (so a SIGKILL'd server picks
/// up exactly where the last fsync left it) and `enqueue`/`status`/
/// `result`/`cancel` wire ops come alive.
fn serve_listen(service: memdiff::coordinator::Service, addr: &str,
                kv: &HashMap<String, String>, cfg: &Config)
                -> anyhow::Result<()> {
    use memdiff::jobs::{JobRunner, JobStore};
    use memdiff::serve::{FrontEnd, FrontEndConfig};
    let route_summary = service.registry().route_summary();
    let service = Arc::new(service);
    let runner = match kv.get("state-dir") {
        Some(dir) => {
            let store = Arc::new(JobStore::open(dir)?);
            println!("state-dir {dir}: replayed jobs {}", store.gauges().summary());
            Some(JobRunner::start(
                Arc::clone(&service), store, cfg.jobs.runner_config()))
        }
        None => None,
    };
    let runner_for_obs = runner.clone();
    // the incident flight recorder rides on the durable state dir: the
    // same Arc serves the wire `dump` op, the health monitor's
    // alert-latch trigger, and (via install) the global trigger sites
    // (worker panics, sustained overload sheds)
    let recorder = match kv.get("state-dir") {
        Some(dir) => {
            let rec = Arc::new(memdiff::obs::FlightRecorder::new(
                dir, Arc::clone(&service.metrics), route_summary.clone())?);
            memdiff::obs::flightrec::install(Arc::clone(&rec));
            println!("flight recorder: dumps in {}", rec.dir().display());
            Some(rec)
        }
        None => None,
    };
    // the analog health monitor: drift tracking, self-test probes and
    // the alert engine, ticking on its own background thread.  The same
    // Arc feeds the wire `health` op, /healthz and the JSONL flush, so
    // all the export paths agree on the alert state.  The SLO engine
    // rides its tick; a newly-latched alert trips the flight recorder.
    let health = if cfg.health.enabled {
        let mon = memdiff::obs::HealthMonitor::new_full(
            cfg.health.clone(),
            cfg.slo.clone(),
            Arc::clone(service.registry()),
            Arc::clone(&service.mode_gate),
            recorder.clone());
        if let Some(rec) = &recorder {
            rec.attach_health(&mon);
        }
        mon.start();
        Some(mon)
    } else {
        None
    };
    let front = FrontEnd::bind_deployment(service, runner, health.clone(),
                                          recorder, addr,
                                          FrontEndConfig {
        max_conns: opt(kv, "max-conns", 64),
        ..FrontEndConfig::default()
    })?;
    let metrics = front.metrics();
    if let Some(maddr) = kv.get("metrics-listen") {
        let bound = spawn_metrics_listener(
            maddr, Arc::clone(&metrics), runner_for_obs.clone(),
            health.clone())?;
        println!("metrics scrape endpoint on http://{bound}/metrics \
                  (health on /healthz)");
    }
    if health.is_some() {
        println!("health monitor: tick {} ms, probes every {} ms",
                 cfg.health.tick_ms, cfg.health.probe_interval_ms);
    }
    let flush_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let flush_thread = match kv.get("state-dir") {
        Some(dir) if cfg.obs.jsonl_flush_ms > 0 => Some(spawn_jsonl_flush(
            dir, cfg.obs.jsonl_flush_ms, Arc::clone(&metrics),
            runner_for_obs, health.clone(), Arc::clone(&flush_stop))),
        _ => None,
    };
    println!("listening on {}", front.local_addr());
    println!("deployment: {route_summary}");
    let for_ms: u64 = opt(kv, "for-ms", 0);
    if for_ms > 0 {
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_millis(for_ms);
        while !front.drain_requested() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    } else {
        front.wait_drain();
    }
    println!("draining...");
    flush_stop.store(true, std::sync::atomic::Ordering::Release);
    if let Some(t) = flush_thread {
        let _ = t.join(); // writes one final line before exiting
    }
    if let Some(mon) = &health {
        mon.stop();
    }
    front.shutdown();
    println!("metrics: {}", metrics.snapshot().report());
    Ok(())
}

/// `--metrics-listen ADDR`: a minimal plaintext HTTP scrape endpoint.
/// `GET /healthz` answers the liveness contract — `200 ok` while no
/// alert fires, `503` listing the firing alert names otherwise — and
/// every other path gets the text rendering of the current metrics
/// snapshot: classic `text/plain; version=0.0.4` (no exemplars) by
/// default, or the OpenMetrics flavor — exemplar suffixes plus the
/// `# EOF` trailer — when the scraper's `Accept` header negotiates
/// `application/openmetrics-text`.  Runs on a detached thread for the
/// life of the process.
fn spawn_metrics_listener(addr: &str,
                          metrics: Arc<memdiff::coordinator::Metrics>,
                          runner: Option<Arc<memdiff::jobs::JobRunner>>,
                          health: Option<Arc<memdiff::obs::HealthMonitor>>)
                          -> anyhow::Result<std::net::SocketAddr> {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("binding --metrics-listen {addr}: {e}"))?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new()
        .name("metrics-listen".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { continue };
                let _ = stream.set_read_timeout(
                    Some(std::time::Duration::from_millis(100)));
                // bounded request-head read: keep reading until the
                // blank line ending the headers arrives (the Accept
                // header decides the exposition flavor), a slow-loris
                // peer exhausts the 500 ms deadline, or the 4 KiB cap
                // trips — a short first segment no longer truncates
                // the request
                let deadline = std::time::Instant::now()
                    + std::time::Duration::from_millis(500);
                let mut head = Vec::with_capacity(256);
                let mut buf = [0u8; 1024];
                loop {
                    match stream.read(&mut buf) {
                        Ok(0) => break,
                        Ok(n) => {
                            head.extend_from_slice(&buf[..n]);
                            let done = head.windows(2).any(|w| w == b"\n\n")
                                || head.windows(4).any(|w| w == b"\r\n\r\n");
                            if done || head.len() >= 4096 {
                                break;
                            }
                        }
                        Err(e) if e.kind()
                            == std::io::ErrorKind::Interrupted => {}
                        Err(_) => break, // timeout or reset
                    }
                    if std::time::Instant::now() >= deadline {
                        break;
                    }
                }
                head.truncate(4096);
                let head = String::from_utf8_lossy(&head);
                let line = head.lines().next().unwrap_or("");
                let path = line.split_whitespace().nth(1).unwrap_or("/");
                // content negotiation: exemplars are syntax errors to the
                // classic text parser, so they are served only when the
                // scraper explicitly asks for OpenMetrics
                let wants_om = head.lines().skip(1).any(|l| {
                    l.split_once(':').is_some_and(|(k, v)| {
                        k.trim().eq_ignore_ascii_case("accept")
                            && v.to_ascii_lowercase()
                                .contains("application/openmetrics-text")
                    })
                });
                if path == "/healthz" || path.starts_with("/healthz?") {
                    let (status, body) = match &health {
                        Some(mon) if !mon.healthy() => (
                            "503 Service Unavailable",
                            format!("unhealthy: {}\n",
                                    mon.firing().join(", ")),
                        ),
                        // no monitor = nothing can fire: stay 200 so a
                        // probe-less deployment is not flagged down
                        _ => ("200 OK", "ok\n".to_string()),
                    };
                    let _ = write!(
                        stream,
                        "HTTP/1.0 {}\r\n\
                         Content-Type: text/plain\r\n\
                         Content-Length: {}\r\n\r\n{}",
                        status, body.len(), body);
                    continue;
                }
                if let Some(r) = &runner {
                    let _ = r.gauges(); // refresh the jobs gauges in-band
                }
                let snap = metrics.snapshot();
                let (body, ctype) = if wants_om {
                    (memdiff::obs::export::render_openmetrics(&snap),
                     "application/openmetrics-text; version=1.0.0; \
                      charset=utf-8")
                } else {
                    (memdiff::obs::export::render_prometheus(&snap),
                     "text/plain; version=0.0.4")
                };
                let _ = write!(
                    stream,
                    "HTTP/1.0 200 OK\r\n\
                     Content-Type: {}\r\n\
                     Content-Length: {}\r\n\r\n{}",
                    ctype, body.len(), body);
            }
        })?;
    Ok(bound)
}

/// Periodic metrics flush: appends one `stats_json` line per period to
/// `<state-dir>/metrics.jsonl`, plus a final line on shutdown, so a
/// crashed or drained server leaves a machine-readable metrics trail
/// next to its job log.
fn spawn_jsonl_flush(dir: &str, period_ms: u64,
                     metrics: Arc<memdiff::coordinator::Metrics>,
                     runner: Option<Arc<memdiff::jobs::JobRunner>>,
                     health: Option<Arc<memdiff::obs::HealthMonitor>>,
                     stop: Arc<std::sync::atomic::AtomicBool>)
                     -> std::thread::JoinHandle<()> {
    use std::io::Write;
    use std::sync::atomic::Ordering;
    let path = std::path::Path::new(dir).join("metrics.jsonl");
    std::thread::spawn(move || {
        let period = std::time::Duration::from_millis(period_ms.max(100));
        let flush = |path: &std::path::Path| {
            if let Some(r) = &runner {
                let _ = r.gauges();
            }
            let mut j = memdiff::obs::export::stats_json(&metrics.snapshot());
            if let (Some(mon), memdiff::util::json::Json::Obj(m)) =
                (&health, &mut j)
            {
                m.insert("health".into(), mon.health_json());
            }
            let line = j.to_string();
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
            {
                let _ = writeln!(f, "{line}");
            }
        };
        let mut last = std::time::Instant::now();
        while !stop.load(Ordering::Acquire) {
            std::thread::sleep(std::time::Duration::from_millis(50));
            if last.elapsed() >= period {
                last = std::time::Instant::now();
                flush(&path);
            }
        }
        flush(&path);
    })
}

/// `memdiff client --connect ADDR`: scripted load for a `--listen`
/// server — a paced sustained phase (every request answered `ok`), then
/// an unpaced mixed-class burst that deliberately overruns the server's
/// bounded lanes (expect `overloaded` sheds), then optionally the
/// shutdown control line.  Exits nonzero on any protocol violation, so
/// CI can smoke-test the front-end with it.
///
/// Job mode (needs a server started with `--state-dir`): `--enqueue N`
/// submits N durable jobs and prints one `job <id>` line per fsync'd
/// acknowledgement; `--fetch ID[,ID...]` long-polls each job's result
/// (`--wait-ms` per poll round); `--cancel ID` requests cancellation.
/// These replace the load phases, so a CI script can enqueue, SIGKILL
/// the server, restart it, and fetch the same ids.
fn cmd_client(kv: &HashMap<String, String>, cfg: &Config) -> anyhow::Result<()> {
    use memdiff::serve::protocol::{self, Status};
    use std::collections::HashMap as Map;
    use std::io::{BufRead, BufReader, Write};

    let addr = kv.get("connect").map(|s| s.as_str()).unwrap_or_else(|| usage());
    let n_sustained: usize = opt(kv, "requests", 32);
    let n_burst: usize = opt(kv, "burst", 32);
    let expect_overload = kv.contains_key("expect-overload");
    let do_shutdown = kv.contains_key("shutdown");

    use memdiff::serve::protocol::read_reply;

    let stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    if kv.contains_key("enqueue") || kv.contains_key("fetch")
        || kv.contains_key("cancel")
    {
        return client_jobs(kv, cfg, &mut writer, &mut reader, do_shutdown);
    }

    // --stats: one stats op, print the reply, done.  --prom switches the
    // output from the JSON stats object to the Prometheus text body.
    if kv.contains_key("stats") {
        writer.write_all(protocol::stats_line(0).as_bytes())?;
        writer.write_all(b"\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let msg = memdiff::util::json::Json::parse(line.trim())?;
        anyhow::ensure!(
            msg.get("status").and_then(|s| s.as_str()) == Some("ok"),
            "stats op failed: {}", line.trim());
        if kv.contains_key("prom") {
            let text = msg
                .get("prometheus")
                .and_then(|p| p.as_str())
                .ok_or_else(|| anyhow::anyhow!("reply without prometheus"))?;
            print!("{text}");
        } else {
            let stats = msg
                .get("stats")
                .ok_or_else(|| anyhow::anyhow!("reply without stats"))?;
            println!("{}", stats.to_string());
        }
        return Ok(());
    }

    // --dump: ask the server for a flight-recorder dump (needs a server
    // started with --state-dir); prints the dump path then the body
    if kv.contains_key("dump") {
        writer.write_all(protocol::dump_line(0).as_bytes())?;
        writer.write_all(b"\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let msg = memdiff::util::json::Json::parse(line.trim())?;
        anyhow::ensure!(
            msg.get("status").and_then(|s| s.as_str()) == Some("ok"),
            "dump op failed: {}", line.trim());
        let path = msg
            .get("path")
            .and_then(|p| p.as_str())
            .ok_or_else(|| anyhow::anyhow!("reply without path"))?;
        println!("dump {path}");
        if let Some(dump) = msg.get("dump") {
            println!("{}", dump.to_string());
        }
        return Ok(());
    }

    // health ops: one wire health line (optionally carrying the age or
    // reprogram maintenance verb), print the monitor state, done
    if kv.contains_key("health") || kv.contains_key("age-device")
        || kv.contains_key("reprogram")
    {
        use memdiff::serve::protocol::HealthAction;
        let action = if let Some(s) = kv.get("age-device") {
            HealthAction::Age {
                dt_s: s.parse().map_err(
                    |_| anyhow::anyhow!("--age-device SECONDS"))?,
            }
        } else if kv.contains_key("reprogram") {
            HealthAction::Reprogram
        } else {
            HealthAction::Status
        };
        writer.write_all(protocol::health_line(0, action).as_bytes())?;
        writer.write_all(b"\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let msg = memdiff::util::json::Json::parse(line.trim())?;
        anyhow::ensure!(
            msg.get("status").and_then(|s| s.as_str()) == Some("ok"),
            "health op failed: {}", line.trim());
        let health = msg
            .get("health")
            .ok_or_else(|| anyhow::anyhow!("reply without health"))?;
        println!("{}", health.to_string());
        return Ok(());
    }

    let mix = |i: usize, rng: &mut Rng| {
        let solver = match i % 4 {
            0 => SolverChoice::AnalogOde,
            1 => SolverChoice::AnalogSde,
            2 => SolverChoice::DigitalOde { steps: 100 },
            _ => SolverChoice::DigitalSde { steps: 100 },
        };
        let task = if i % 3 == 0 {
            TaskKind::Circle
        } else {
            TaskKind::Letter(rng.below(3))
        };
        (task, solver)
    };
    let mut rng = Rng::new(cfg.seed ^ 0xC11E);

    // sustained phase: paced (read each reply before the next request),
    // so the bounded queues never overflow and every answer must be ok
    let mut lat = memdiff::util::stats::Summary::new();
    let mut sustained_samples = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..n_sustained {
        let (task, solver) = mix(i, &mut rng);
        let n = 1 + rng.below(4);
        let line = protocol::request_line(i as u64, task, n, solver,
                                          cfg.guidance, false);
        let t = std::time::Instant::now();
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        let reply = read_reply(&mut reader)?;
        lat.record(t.elapsed().as_secs_f64());
        anyhow::ensure!(reply.id == i as u64,
                        "paced reply id {} != {i}", reply.id);
        anyhow::ensure!(reply.status == Status::Ok,
                        "paced request {i} got {:?} ({:?})",
                        reply.status, reply.error);
        anyhow::ensure!(reply.samples.len() == n * reply.dim,
                        "request {i}: {} samples for n={n} dim={}",
                        reply.samples.len(), reply.dim);
        sustained_samples += n;
    }
    let sustained_wall = t0.elapsed();
    println!(
        "sustained: {n_sustained} requests / {sustained_samples} samples in \
         {sustained_wall:?} (p50 {:.1} ms, p99 {:.1} ms)",
        1e3 * lat.p50(), 1e3 * lat.p99(),
    );

    // burst phase: unpaced — fire everything, then collect; bounded
    // lanes shed the overflow as `overloaded`
    let mut expected: Map<u64, usize> = Map::new();
    for i in 0..n_burst {
        let id = (1000 + i) as u64;
        let (task, solver) = mix(i, &mut rng);
        let n = 2 + rng.below(4);
        let line = protocol::request_line(id, task, n, solver,
                                          cfg.guidance, false);
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        expected.insert(id, n);
    }
    let mut n_ok = 0usize;
    let mut n_overloaded = 0usize;
    for _ in 0..n_burst {
        let reply = read_reply(&mut reader)?;
        let n = expected.remove(&reply.id).ok_or_else(|| {
            anyhow::anyhow!("burst reply for unknown/duplicate id {}", reply.id)
        })?;
        match reply.status {
            Status::Ok => {
                anyhow::ensure!(reply.samples.len() == n * reply.dim);
                n_ok += 1;
            }
            Status::Overloaded => {
                anyhow::ensure!(reply.queue_depth.unwrap_or(0) > 0,
                                "overloaded reply must carry the bound");
                n_overloaded += 1;
            }
            other => anyhow::bail!("burst got {other:?} ({:?})", reply.error),
        }
    }
    anyhow::ensure!(expected.is_empty(), "every burst request answered");
    println!("burst: {n_burst} requests -> {n_ok} ok, {n_overloaded} shed \
              ({:.0}% reject rate)",
             100.0 * n_overloaded as f64 / n_burst.max(1) as f64);
    if expect_overload {
        anyhow::ensure!(n_overloaded > 0,
                        "--expect-overload: the burst should have overrun \
                         the bounded lanes but nothing was shed");
    }

    if do_shutdown {
        writer.write_all(protocol::shutdown_line().as_bytes())?;
        writer.write_all(b"\n")?;
        let ack = read_reply(&mut reader)?;
        anyhow::ensure!(ack.status == Status::Ok, "shutdown ack");
        // server drains and closes the connection
        let mut rest = String::new();
        let _ = reader.read_line(&mut rest);
        println!("server acknowledged shutdown; draining");
    }
    Ok(())
}

/// The job side of `memdiff client` — see [`cmd_client`].
fn client_jobs(kv: &HashMap<String, String>, cfg: &Config,
               writer: &mut std::net::TcpStream,
               reader: &mut std::io::BufReader<std::net::TcpStream>,
               do_shutdown: bool) -> anyhow::Result<()> {
    use memdiff::serve::protocol::{self, read_reply, Status};
    use std::io::{BufRead, Write};

    let mut send = |line: &str| -> anyhow::Result<()> {
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        Ok(())
    };

    if let Some(n) = kv.get("enqueue") {
        let n: usize = n.parse().map_err(|_| anyhow::anyhow!("--enqueue N"))?;
        let defer_ms: u64 = opt(kv, "defer-ms", 0);
        let max_retries: Option<u32> =
            kv.get("max-retries").and_then(|s| s.parse().ok());
        let ttl_ms: Option<u64> = kv.get("ttl-ms").and_then(|s| s.parse().ok());
        let mut rng = Rng::new(cfg.seed ^ 0x10B5);
        for i in 0..n {
            // digital solvers: the job survives a restart, where the
            // synthetic-weights server answers every class
            let solver = if i % 2 == 0 {
                SolverChoice::DigitalOde { steps: 60 }
            } else {
                SolverChoice::DigitalSde { steps: 60 }
            };
            let task = if i % 3 == 0 {
                TaskKind::Circle
            } else {
                TaskKind::Letter(rng.below(3))
            };
            send(&protocol::enqueue_line(
                i as u64, task, 1 + rng.below(4), solver, cfg.guidance,
                false, defer_ms, max_retries, ttl_ms))?;
            let reply = read_reply(reader)?;
            anyhow::ensure!(reply.status == Status::Ok,
                            "enqueue {i} got {:?} ({:?})",
                            reply.status, reply.error);
            let job = reply.job.ok_or_else(|| {
                anyhow::anyhow!("enqueue ack without a job id")
            })?;
            // one machine-greppable line per durable acknowledgement
            println!("job {job}");
        }
    }

    if let Some(ids) = kv.get("fetch") {
        let wait_ms: u64 = opt(kv, "wait-ms", 10_000);
        for (k, id) in ids.split(',').filter(|s| !s.is_empty()).enumerate() {
            let job: u64 = id.trim().parse()
                .map_err(|_| anyhow::anyhow!("--fetch: bad job id {id:?}"))?;
            send(&protocol::result_line(k as u64, job, wait_ms))?;
            let reply = read_reply(reader)?;
            anyhow::ensure!(reply.job == Some(job),
                            "fetch reply for job {:?}, wanted {job}", reply.job);
            let state = reply.state.as_deref().unwrap_or("?");
            anyhow::ensure!(reply.status == Status::Ok && state == "done",
                            "job {job} is {state:?} ({:?})", reply.error);
            println!("job {job} done: {} samples", reply.samples.len()
                     / reply.dim.max(1));
        }
    }

    if let Some(id) = kv.get("cancel") {
        let job: u64 = id.parse()
            .map_err(|_| anyhow::anyhow!("--cancel: bad job id {id:?}"))?;
        send(&protocol::job_op_line("cancel", 0, job))?;
        let reply = read_reply(reader)?;
        println!("job {job} -> {}", reply.state.as_deref().unwrap_or("unknown"));
    }

    if do_shutdown {
        send(&protocol::shutdown_line())?;
        let ack = read_reply(reader)?;
        anyhow::ensure!(ack.status == Status::Ok, "shutdown ack");
        let mut rest = String::new();
        let _ = reader.read_line(&mut rest);
        println!("server acknowledged shutdown; draining");
    }
    Ok(())
}

fn cmd_characterize(kv: &HashMap<String, String>, _cfg: &Config) -> anyhow::Result<()> {
    use memdiff::device::{Cell, Macro};
    let mut rng = Rng::new(opt(kv, "seed", 2024u64));

    println!("== Fig 2c: quasi-static IV (5 of 200 cycles, current at ±1.5 V)");
    let mut cell = Cell::with_default(0.02);
    let up: Vec<f32> = (0..50).map(|i| 1.5 * i as f32 / 49.0).collect();
    let dn: Vec<f32> = (0..50).map(|i| -1.5 * i as f32 / 49.0).collect();
    for cycle in 0..5 {
        let iu = cell.iv_sweep(&up, &mut rng);
        let id = cell.iv_sweep(&dn, &mut rng);
        println!("  cycle {cycle}: I(+1.5V)={:.4} mA  I(-1.5V)={:.4} mA",
                 iu.last().unwrap(), id.last().unwrap());
    }

    println!("== Fig 2d: 64 linear conductance states (showing every 8th)");
    for k in (0..64).step_by(8) {
        println!("  level {k:2}: {:.4} mS", Cell::level_conductance(k));
    }

    println!("== Fig 2e: retention of 4 states over 1e6 s");
    for k in [0, 21, 42, 63] {
        let mut c = Cell::with_default(Cell::level_conductance(k));
        let g0 = c.conductance();
        c.drift(1e6, &mut rng);
        println!("  level {k:2}: {g0:.4} -> {:.4} mS (drift {:+.5})",
                 c.conductance(), c.conductance() - g0);
    }

    println!("== Fig 2f: 32x32 moon-and-star pattern programming");
    let mut array = Macro::new(32, 32);
    let pattern = Macro::moon_star_pattern(32);
    let st = array.program(&pattern, 0.0015, 500, &mut rng);
    println!("  mean pulses/cell = {:.1}, failures = {}, max |err| = {:.4} mS",
             st.mean_pulses(), st.failures, st.max_error_ms());
    let snap = array.conductances();
    for r in (0..32).step_by(2) {
        let row: String = (0..32).step_by(1)
            .map(|c| if snap.get(r, c) > 0.06 { '#' } else { '.' })
            .collect();
        println!("  {row}");
    }

    println!("== Fig 2g: conductance error distribution (read noise over time)");
    let errs: Vec<f32> = {
        let read = array.read_all(&mut rng);
        read.as_slice().iter().zip(snap.as_slice())
            .map(|(r, t)| (r - t) / t * 100.0)
            .collect()
    };
    println!("  relative error: mean={:+.3}%  std={:.3}%",
             stats::mean(&errs), stats::std(&errs));
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    println!("schedule: beta {}..{} over T={} (eps_t {})",
             meta.sched.beta_min, meta.sched.beta_max, meta.sched.t_end,
             meta.sched.eps_t);
    println!("model: {}->{}x2->{} classes={}", meta.dim, meta.hidden, meta.dim,
             meta.n_classes);
    println!("quality gate (python, ODE-200): KL = {:.4}", meta.kl_uncond_gate);
    println!("artifacts:");
    for (name, spec) in &meta.artifacts {
        println!("  {name:<20} {} inputs={:?}", spec.file, spec.inputs);
    }
    let store = ArtifactStore::open_default()?;
    println!("PJRT platform: {}", store.platform());
    Ok(())
}
