//! memdiff CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   generate      one-shot generation (task, solver, sample count)
//!   serve         run the batching service over a scripted client load
//!   characterize  device-level figures (Fig. 2): IV, levels, retention,
//!                 moon-star pattern, error distributions
//!   info          print artifact manifest + platform
//!
//! Argument parsing is hand-rolled (`--key value` pairs) — no clap in the
//! offline vendor set.

use std::collections::HashMap;
use std::sync::Arc;

use memdiff::coordinator::{Service, ServiceConfig, SolverChoice, TaskKind};
use memdiff::coordinator::batcher::BatcherConfig;
use memdiff::coordinator::deploy::{self, BackendKind};
use memdiff::coordinator::service::{AnalogEngine, Engine, HloEngine, RustDigitalEngine};
use memdiff::config::Config;
use memdiff::crossbar::NoiseModel;
use memdiff::data::{sample_circle, Meta};
use memdiff::device::cell::CellParams;
use memdiff::nn::{AnalogScoreNet, DigitalScoreNet, ScoreWeights};
use memdiff::runtime::ArtifactStore;
use memdiff::util::rng::Rng;
use memdiff::util::stats;
use memdiff::vae::{DecoderWeights, PixelDecoder};

fn parse_args(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut kv = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_else(|| "true".into());
            kv.insert(key.to_string(), val);
            i += 2;
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, kv)
}

fn opt<T: std::str::FromStr>(kv: &HashMap<String, String>, key: &str, default: T) -> T {
    kv.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn usage() -> ! {
    eprintln!(
        "memdiff — resistive-memory neural differential-equation solver\n\
         usage:\n\
         \x20 memdiff generate [--task circle|h|k|u] [--solver analog-ode|analog-sde|euler|euler-sde]\n\
         \x20                  [--n 500] [--steps 130] [--engine analog|rust|hlo] [--decode]\n\
         \x20 memdiff serve    [--requests 64] [--workers 4] [--threads N]\n\
         \x20                  [--deploy analog=analog,digital=rust|hlo,rust_workers=N,...]\n\
         \x20 memdiff characterize\n\
         \x20 memdiff info\n\
         \x20 (global) [--config memdiff.toml] [--seed N]"
    );
    std::process::exit(2);
}

fn task_of(s: &str) -> TaskKind {
    match s {
        "circle" => TaskKind::Circle,
        "h" | "H" => TaskKind::Letter(0),
        "k" | "K" => TaskKind::Letter(1),
        "u" | "U" => TaskKind::Letter(2),
        _ => usage(),
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, kv) = parse_args(&args);
    let cfg = Config::load_or_default(kv.get("config").map(|s| s.as_str()))?;
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "generate" => cmd_generate(&kv, &cfg),
        "serve" => cmd_serve(&kv, &cfg),
        "characterize" => cmd_characterize(&kv, &cfg),
        "info" => cmd_info(),
        _ => usage(),
    }
}

fn load_weights(task: &TaskKind) -> anyhow::Result<ScoreWeights> {
    let dir = Meta::artifacts_dir();
    let file = if task.is_conditional() { "weights_cond.json" } else { "weights_uncond.json" };
    ScoreWeights::load(dir.join(file))
}

fn build_engine(engine: &str, task: &TaskKind, cfg: &Config)
                -> anyhow::Result<Arc<dyn Engine>> {
    let meta = Meta::load_default()?;
    // bank-parallel strategy from config; the pool itself is sized by the
    // Service at startup (workers vs. intra-op threads)
    let exec = memdiff::exec::Ctx::new(cfg.par);
    Ok(match engine {
        "analog" => {
            let w = load_weights(task)?;
            let net = AnalogScoreNet::from_conductances(
                &w, CellParams::default(), NoiseModel::ReadFast)
                .with_exec(exec);
            Arc::new(AnalogEngine { net, sched: meta.sched, substeps: cfg.substeps })
        }
        "rust" => {
            let w = load_weights(task)?;
            Arc::new(RustDigitalEngine {
                net: DigitalScoreNet::new(w).with_exec(exec),
                sched: meta.sched,
            })
        }
        "hlo" => {
            let store = ArtifactStore::open_default()?;
            let n_classes = store.meta().n_classes;
            Arc::new(HloEngine { store, n_classes })
        }
        _ => usage(),
    })
}

fn cmd_generate(kv: &HashMap<String, String>, cfg: &Config) -> anyhow::Result<()> {
    let task = task_of(kv.get("task").map(|s| s.as_str()).unwrap_or("circle"));
    let n: usize = opt(kv, "n", 500);
    let steps: usize = opt(kv, "steps", 130);
    let solver = match kv.get("solver").map(|s| s.as_str()).unwrap_or("analog-sde") {
        "analog-ode" => SolverChoice::AnalogOde,
        "analog-sde" => SolverChoice::AnalogSde,
        "euler" => SolverChoice::DigitalOde { steps },
        "euler-sde" => SolverChoice::DigitalSde { steps },
        _ => usage(),
    };
    let engine_name = kv.get("engine").map(|s| s.as_str()).unwrap_or(
        if solver.is_analog() { "analog" } else { "hlo" });
    let decode = kv.contains_key("decode");

    let engine = build_engine(engine_name, &task, cfg)?;
    let decoder = if decode {
        Some(Arc::new(PixelDecoder::new(DecoderWeights::load(
            Meta::artifacts_dir().join("vae_decoder.json"))?)))
    } else {
        None
    };
    let service = Service::start(engine, decoder, ServiceConfig {
        workers: cfg.workers,
        batcher: BatcherConfig {
            max_batch_samples: cfg.max_batch,
            linger: std::time::Duration::from_millis(cfg.linger_ms),
        },
        seed: opt(kv, "seed", cfg.seed),
        intra_threads: opt(kv, "threads", cfg.threads),
    });

    let t0 = std::time::Instant::now();
    let resp = service.generate(task, n, solver, cfg.guidance, decode)?;
    let wall = t0.elapsed();

    println!("task={task:?} solver={solver:?} engine={engine_name} n={n}");
    println!("wall={wall:?}  modeled_hw_latency={:.3e}s", resp.hw_latency_s);
    // quality: KL vs ground truth (circle) or cluster stats (letters)
    match task {
        TaskKind::Circle => {
            let mut rng = Rng::new(999);
            let truth = sample_circle(20 * n.max(1000), &mut rng);
            let kl = stats::kl_points(&resp.samples, &truth, 24, 2.0);
            println!("KL(truth || generated) = {kl:.4}");
        }
        TaskKind::Letter(c) => {
            let meta = Meta::load_default()?;
            let xs: Vec<f32> = resp.samples.iter().step_by(2).copied().collect();
            let ys: Vec<f32> = resp.samples.iter().skip(1).step_by(2).copied().collect();
            let m = meta.latent_class_means[c];
            println!(
                "latent mean = ({:.3}, {:.3})  target class mean = ({:.3}, {:.3})",
                stats::mean(&xs), stats::mean(&ys), m[0], m[1]
            );
        }
    }
    if let Some(images) = &resp.images {
        let side = 12;
        println!("decoded {} images; first sample:", images.len() / (side * side));
        for r in 0..side {
            let row: String = (0..side)
                .map(|c| {
                    let v = images[r * side + c];
                    if v > 0.3 { '#' } else if v > -0.3 { '+' } else { '.' }
                })
                .collect();
            println!("  {row}");
        }
    }
    println!("metrics: {}", service.metrics.snapshot().report());
    service.shutdown();
    Ok(())
}

fn cmd_serve(kv: &HashMap<String, String>, cfg: &Config) -> anyhow::Result<()> {
    let n_requests: usize = opt(kv, "requests", 64);
    let workers: usize = opt(kv, "workers", cfg.workers);

    // deployment table: [deploy] config section, then --deploy overrides
    let mut plan = cfg.deploy.clone();
    if let Some(spec) = kv.get("deploy") {
        plan.apply_overrides(spec)?;
    }
    let decoder = Arc::new(PixelDecoder::new(DecoderWeights::load(
        Meta::artifacts_dir().join("vae_decoder.json"))?));
    // one engine per backend the plan names; the conditional weights serve
    // both classes of a family (zero one-hot = unconditional)
    let service = Arc::new(deploy::start_deployed(
        &plan,
        &mut |kind: BackendKind| build_engine(kind.name(), &TaskKind::Letter(0), cfg),
        Some(decoder),
        ServiceConfig {
            workers,
            batcher: BatcherConfig {
                max_batch_samples: cfg.max_batch,
                linger: std::time::Duration::from_millis(cfg.linger_ms),
            },
            seed: cfg.seed,
            intra_threads: opt(kv, "threads", cfg.threads),
        },
    )?);

    println!("serve: {n_requests} mixed requests over {workers} workers/backend");
    println!("deployment: {}", service.registry().route_summary());
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            // mixed-class load: analog and digital families side by side,
            // conditional and unconditional
            let solver = match i % 4 {
                0 => SolverChoice::AnalogOde,
                1 => SolverChoice::DigitalOde { steps: 100 },
                _ => SolverChoice::DigitalSde { steps: 100 },
            };
            let task = if i % 3 == 0 {
                TaskKind::Circle
            } else {
                TaskKind::Letter(rng.below(3))
            };
            let n = 1 + rng.below(16);
            service
                .submit(memdiff::coordinator::GenRequest {
                    id: 0,
                    task,
                    n_samples: n,
                    solver,
                    guidance: cfg.guidance,
                    decode: task.is_conditional() && rng.uniform() < 0.25,
                })
                .unwrap()
        })
        .collect();
    let mut total_samples = 0usize;
    for rx in rxs {
        let resp = rx.recv()??;
        total_samples += resp.samples.len() / 2;
    }
    let wall = t0.elapsed();
    println!(
        "served {total_samples} samples in {wall:?} ({:.0} samples/s)",
        total_samples as f64 / wall.as_secs_f64()
    );
    println!("metrics: {}", service.metrics.snapshot().report());
    Ok(())
}

fn cmd_characterize(kv: &HashMap<String, String>, _cfg: &Config) -> anyhow::Result<()> {
    use memdiff::device::{Cell, Macro};
    let mut rng = Rng::new(opt(kv, "seed", 2024u64));

    println!("== Fig 2c: quasi-static IV (5 of 200 cycles, current at ±1.5 V)");
    let mut cell = Cell::with_default(0.02);
    let up: Vec<f32> = (0..50).map(|i| 1.5 * i as f32 / 49.0).collect();
    let dn: Vec<f32> = (0..50).map(|i| -1.5 * i as f32 / 49.0).collect();
    for cycle in 0..5 {
        let iu = cell.iv_sweep(&up, &mut rng);
        let id = cell.iv_sweep(&dn, &mut rng);
        println!("  cycle {cycle}: I(+1.5V)={:.4} mA  I(-1.5V)={:.4} mA",
                 iu.last().unwrap(), id.last().unwrap());
    }

    println!("== Fig 2d: 64 linear conductance states (showing every 8th)");
    for k in (0..64).step_by(8) {
        println!("  level {k:2}: {:.4} mS", Cell::level_conductance(k));
    }

    println!("== Fig 2e: retention of 4 states over 1e6 s");
    for k in [0, 21, 42, 63] {
        let mut c = Cell::with_default(Cell::level_conductance(k));
        let g0 = c.conductance();
        c.drift(1e6, &mut rng);
        println!("  level {k:2}: {g0:.4} -> {:.4} mS (drift {:+.5})",
                 c.conductance(), c.conductance() - g0);
    }

    println!("== Fig 2f: 32x32 moon-and-star pattern programming");
    let mut array = Macro::new(32, 32);
    let pattern = Macro::moon_star_pattern(32);
    let st = array.program(&pattern, 0.0015, 500, &mut rng);
    println!("  mean pulses/cell = {:.1}, failures = {}, max |err| = {:.4} mS",
             st.mean_pulses(), st.failures, st.max_error_ms());
    let snap = array.conductances();
    for r in (0..32).step_by(2) {
        let row: String = (0..32).step_by(1)
            .map(|c| if snap.get(r, c) > 0.06 { '#' } else { '.' })
            .collect();
        println!("  {row}");
    }

    println!("== Fig 2g: conductance error distribution (read noise over time)");
    let errs: Vec<f32> = {
        let read = array.read_all(&mut rng);
        read.as_slice().iter().zip(snap.as_slice())
            .map(|(r, t)| (r - t) / t * 100.0)
            .collect()
    };
    println!("  relative error: mean={:+.3}%  std={:.3}%",
             stats::mean(&errs), stats::std(&errs));
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let meta = Meta::load_default()?;
    println!("schedule: beta {}..{} over T={} (eps_t {})",
             meta.sched.beta_min, meta.sched.beta_max, meta.sched.t_end,
             meta.sched.eps_t);
    println!("model: {}->{}x2->{} classes={}", meta.dim, meta.hidden, meta.dim,
             meta.n_classes);
    println!("quality gate (python, ODE-200): KL = {:.4}", meta.kl_uncond_gate);
    println!("artifacts:");
    for (name, spec) in &meta.artifacts {
        println!("  {name:<20} {} inputs={:?}", spec.file, spec.inputs);
    }
    let store = ArtifactStore::open_default()?;
    println!("PJRT platform: {}", store.platform());
    Ok(())
}
