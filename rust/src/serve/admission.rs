//! Admission control: the structured reject taxonomy of the nonblocking
//! submit path, and the connection cap of the TCP edge.
//!
//! Backpressure has two gates.  At the **lane** gate, `Service::submit_nb`
//! checks the routed lane's bounded queue and answers with a
//! [`SubmitError`] instead of blocking — `Overloaded` is the 429-style
//! shed signal (one slow backend rejects while the others keep serving),
//! `ShuttingDown` the drain signal.  At the **edge** gate, the acceptor
//! holds a [`ConnGate`]: at most `max` concurrent connection handlers;
//! connection number `max + 1` is answered and closed instead of admitted,
//! so a connection flood cannot exhaust handler threads.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::request::RequestClass;

/// Why `Service::submit_nb` refused a request **at admission** — the
/// request never entered a lane queue, no ticket remains registered, and
/// the `rejected` counter (plus the per-backend gauge for `Overloaded`)
/// was incremented exactly once.
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    /// The routed lane's bounded queue is full: shed load now rather
    /// than hide the overload in an unbounded queue.
    #[error("backend {backend:?} overloaded: {queued_samples} samples queued \
             (queue_depth {queue_depth}, retry after ~{retry_after_ms}ms)")]
    Overloaded {
        /// Name of the backend whose lane is full.
        backend: String,
        /// Samples queued in that lane at the reject.
        queued_samples: usize,
        /// The lane's configured bound (samples).
        queue_depth: usize,
        /// Adaptive backoff hint from the lane's observed drain rate
        /// (expected ms until the queued samples clear; see
        /// [`Metrics::retry_after_hint_ms`](crate::coordinator::Metrics::retry_after_hint_ms)).
        retry_after_ms: u64,
    },
    /// The service is draining; lanes accept no new work.
    #[error("service is shutting down")]
    ShuttingDown,
    /// No backend is routed for the request's class.
    #[error("no backend routed for request class {class} \
             (deployment routes: {routes})")]
    Unroutable { class: RequestClass, routes: String },
    /// The request is malformed (e.g. zero samples).
    #[error("invalid request: {0}")]
    Invalid(String),
}

/// The typed error `Service::shutdown` fails leftover tickets with, so
/// callers that own durable jobs can tell "the service drained under my
/// in-flight attempt" (requeue, no retry budget consumed) apart from a
/// genuine engine failure.  Match with `err.downcast_ref::<DrainError>()`.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("service shut down before the request completed")]
pub struct DrainError;

/// Concurrent-connection cap for the TCP acceptor.  `try_acquire` hands
/// out at most `max` live [`ConnPermit`]s; a permit releases its slot on
/// drop, so a handler thread cannot leak capacity on any exit path.
pub struct ConnGate {
    max: usize,
    active: Arc<AtomicUsize>,
}

impl ConnGate {
    pub fn new(max: usize) -> Self {
        ConnGate { max: max.max(1), active: Arc::new(AtomicUsize::new(0)) }
    }

    /// Claim a handler slot, or `None` when the edge is at capacity.
    pub fn try_acquire(&self) -> Option<ConnPermit> {
        let mut cur = self.active.load(Ordering::Relaxed);
        loop {
            if cur >= self.max {
                return None;
            }
            match self.active.compare_exchange_weak(
                cur, cur + 1, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => {
                    return Some(ConnPermit { active: Arc::clone(&self.active) })
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Live handler count (gauge).
    pub fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> usize {
        self.max
    }
}

/// RAII handler slot from a [`ConnGate`].
pub struct ConnPermit {
    active: Arc<AtomicUsize>,
}

impl Drop for ConnPermit {
    fn drop(&mut self) {
        self.active.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_caps_and_releases() {
        let gate = ConnGate::new(2);
        let a = gate.try_acquire().unwrap();
        let b = gate.try_acquire().unwrap();
        assert!(gate.try_acquire().is_none(), "at capacity");
        assert_eq!(gate.active(), 2);
        drop(a);
        let c = gate.try_acquire().expect("slot freed on drop");
        assert!(gate.try_acquire().is_none());
        drop(b);
        drop(c);
        assert_eq!(gate.active(), 0);
    }

    #[test]
    fn gate_is_thread_safe_under_contention() {
        let gate = Arc::new(ConnGate::new(4));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let gate = Arc::clone(&gate);
                let peak = Arc::clone(&peak);
                std::thread::spawn(move || {
                    let mut admitted = 0usize;
                    for _ in 0..200 {
                        if let Some(p) = gate.try_acquire() {
                            peak.fetch_max(gate.active(), Ordering::Relaxed);
                            admitted += 1;
                            drop(p);
                        }
                    }
                    admitted
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0);
        assert!(peak.load(Ordering::Relaxed) <= 4, "cap never exceeded");
        assert_eq!(gate.active(), 0, "every permit released");
    }

    #[test]
    fn submit_error_messages() {
        let e = SubmitError::Overloaded {
            backend: "analog".into(),
            queued_samples: 128,
            queue_depth: 128,
            retry_after_ms: 250,
        };
        let s = e.to_string();
        assert!(s.contains("overloaded") && s.contains("128"), "{s}");
        assert!(s.contains("250ms"), "hint surfaces in the message: {s}");
        assert!(SubmitError::ShuttingDown.to_string().contains("shutting down"));
    }

    #[test]
    fn drain_error_downcasts_through_anyhow() {
        let e: anyhow::Error = anyhow::Error::new(DrainError);
        assert!(e.downcast_ref::<DrainError>().is_some());
        assert!(e.to_string().contains("shut down"));
    }
}
