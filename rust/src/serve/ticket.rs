//! Response tickets: the nonblocking completion side of `submit_nb`.
//!
//! The blocking service used to hold one global `id → Sender` response
//! map; every completion and every submit contended on it, and a caller
//! could only *block* on its channel.  The async front-end replaces it
//! with a [`TicketBoard`]: **per-lane** pending maps (a completion on the
//! analog lane never touches the digital lane's lock) whose entries are
//! [`Slot`]s shared with the caller-held [`Ticket`].  A ticket can be
//! polled ([`Ticket::try_recv`]), waited on with a deadline
//! ([`Ticket::recv_deadline`] / [`Ticket::recv_timeout`]), blocked on
//! ([`Ticket::recv`]), or wired into a shared [`Notify`] so one
//! connection handler can sleep on *many* tickets at once (the waker
//! registry of the TCP front-end).
//!
//! Delivery contract: a worker completes a ticket **exactly once**; the
//! result is consumed **at most once** (the first successful receive
//! takes it — later receives report the ticket as spent).  Shutdown
//! fails every still-pending ticket via [`TicketBoard::fail_all`], so no
//! waiter is ever stranded (the no-dropped-request invariant, extended
//! to the nonblocking path).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::request::GenResponse;
use crate::obs::TraceId;

/// One ticket's shared completion cell.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

struct SlotState {
    /// The response, once the worker delivered it (taken by the first
    /// successful receive).
    result: Option<anyhow::Result<GenResponse>>,
    /// The result was delivered *and* already consumed.
    taken: bool,
    /// Optional multi-ticket waker, fired on completion.
    notify: Option<Notify>,
}

impl Slot {
    fn new() -> Self {
        Slot {
            state: Mutex::new(SlotState { result: None, taken: false, notify: None }),
            cv: Condvar::new(),
        }
    }

    fn complete(&self, result: anyhow::Result<GenResponse>) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert!(st.result.is_none() && !st.taken,
                      "ticket completed twice");
        st.result = Some(result);
        let notify = st.notify.take();
        drop(st);
        self.cv.notify_all();
        if let Some(n) = notify {
            n.notify();
        }
    }
}

/// A response ticket: the caller's handle to one in-flight request.
///
/// Obtained from `Service::submit_nb` (or the blocking `submit`, which
/// returns the same handle).  Cheap to move across threads; dropping a
/// ticket without receiving is fine — the worker still completes the
/// slot and the board entry is cleaned up on delivery.
pub struct Ticket {
    id: u64,
    trace: TraceId,
    slot: Arc<Slot>,
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket")
            .field("id", &self.id)
            .field("done", &self.is_done())
            .finish()
    }
}

impl Ticket {
    /// The service-assigned request id this ticket answers.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The request's trace identity (for deliver spans and timelines).
    pub fn trace(&self) -> TraceId {
        self.trace
    }

    /// Nonblocking poll.  `None` while pending — and after the result
    /// has already been taken (a ticket delivers at most once).
    pub fn try_recv(&self) -> Option<anyhow::Result<GenResponse>> {
        let mut st = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.result.is_some() {
            st.taken = true;
        }
        st.result.take()
    }

    /// Whether the worker has delivered (true even after the result was
    /// taken).
    pub fn is_done(&self) -> bool {
        let st = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        st.result.is_some() || st.taken
    }

    /// Block until completion.  Errors if the result was already taken
    /// (never hangs on a spent ticket).
    pub fn recv(&self) -> anyhow::Result<GenResponse> {
        let mut st = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if st.taken && st.result.is_none() {
                anyhow::bail!("ticket {} already received", self.id);
            }
            if st.result.is_some() {
                st.taken = true;
                return st.result.take().unwrap();
            }
            st = self.slot.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Block until completion or `deadline`.  `None` = still pending at
    /// the deadline (or already taken).
    pub fn recv_deadline(&self, deadline: Instant)
                         -> Option<anyhow::Result<GenResponse>> {
        let mut st = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if st.result.is_some() {
                st.taken = true;
                return st.result.take();
            }
            if st.taken {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) =
                self.slot.cv.wait_timeout(st, deadline - now)
                    .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }

    /// [`Self::recv_deadline`] with a relative timeout.
    pub fn recv_timeout(&self, timeout: Duration)
                        -> Option<anyhow::Result<GenResponse>> {
        self.recv_deadline(Instant::now() + timeout)
    }

    /// Register a shared waker: `notify` fires when this ticket
    /// completes (immediately if it already has).  One [`Notify`] can
    /// watch any number of tickets — the front-end's connection handlers
    /// register every in-flight ticket on one waker and sleep on that.
    pub fn set_notify(&self, notify: &Notify) {
        let mut st = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.result.is_some() || st.taken {
            drop(st);
            notify.notify();
        } else {
            st.notify = Some(notify.clone());
        }
    }
}

/// A consumable wakeup flag shared by many tickets (the waker registry
/// unit).  `notify` latches the flag; `wait_timeout` consumes it — a
/// notification between two waits is never lost.
#[derive(Clone, Default)]
pub struct Notify {
    inner: Arc<(Mutex<bool>, Condvar)>,
}

impl Notify {
    pub fn new() -> Self {
        Notify::default()
    }

    /// Latch the flag and wake every waiter.
    pub fn notify(&self) {
        let (flag, cv) = &*self.inner;
        *flag.lock().unwrap_or_else(|e| e.into_inner()) = true;
        cv.notify_all();
    }

    /// Wait until notified or `timeout`; consumes the flag.  Returns
    /// whether a notification was seen.
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        let (flag, cv) = &*self.inner;
        let mut set = flag.lock().unwrap_or_else(|e| e.into_inner());
        let deadline = Instant::now() + timeout;
        while !*set {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = cv.wait_timeout(set, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            set = guard;
        }
        *set = false;
        true
    }
}

/// Per-lane pending-ticket maps: the service-side half of the ticket
/// subsystem (replaces the global blocking response map).
pub struct TicketBoard {
    lanes: Vec<Mutex<HashMap<u64, Arc<Slot>>>>,
}

impl TicketBoard {
    /// One pending map per batcher lane.
    pub fn new(n_lanes: usize) -> Self {
        TicketBoard {
            lanes: (0..n_lanes.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    /// Register a pending request on `lane`, returning the caller's
    /// ticket.  Must happen **before** the request is enqueued (a worker
    /// may complete it immediately after the queue accepts it).
    pub fn register(&self, lane: usize, id: u64, trace: TraceId) -> Ticket {
        let slot = Arc::new(Slot::new());
        self.lanes[lane].lock().unwrap_or_else(|e| e.into_inner()).insert(id, Arc::clone(&slot));
        Ticket { id, trace, slot }
    }

    /// Remove a registration whose enqueue was rejected (the request
    /// never entered the lane, so no worker will ever complete it).
    pub fn retract(&self, lane: usize, id: u64) {
        self.lanes[lane].lock().unwrap_or_else(|e| e.into_inner()).remove(&id);
    }

    /// Deliver one request's result: removes the pending entry and fills
    /// the caller's slot (waking its waiters and any registered notify).
    pub fn complete(&self, lane: usize, id: u64,
                    result: anyhow::Result<GenResponse>) {
        let slot = self.lanes[lane].lock().unwrap_or_else(|e| e.into_inner()).remove(&id);
        if let Some(slot) = slot {
            slot.complete(result);
        } else {
            debug_assert!(false, "completion for unregistered ticket {id}");
        }
    }

    /// Total still-pending tickets across every lane.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.lock().unwrap_or_else(|e| e.into_inner()).len()).sum()
    }

    /// Fail every still-pending ticket (shutdown's no-stranded-waiter
    /// guarantee); returns how many there were.
    pub fn fail_all(&self, mk_err: impl Fn() -> anyhow::Error) -> usize {
        let mut n = 0;
        for lane in &self.lanes {
            let drained: Vec<Arc<Slot>> =
                lane.lock().unwrap_or_else(|e| e.into_inner()).drain().map(|(_, s)| s).collect();
            for slot in drained {
                slot.complete(Err(mk_err()));
                n += 1;
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, v: f32) -> GenResponse {
        GenResponse {
            id,
            samples: vec![v; 2],
            images: None,
            wall_latency_s: 0.0,
            hw_latency_s: 0.0,
            hw_energy_j: 0.0,
        }
    }

    #[test]
    fn try_recv_poll_then_complete() {
        let board = TicketBoard::new(2);
        let t = board.register(1, 7, TraceId::NONE);
        assert!(t.try_recv().is_none());
        assert!(!t.is_done());
        board.complete(1, 7, Ok(resp(7, 3.0)));
        assert!(t.is_done());
        let got = t.try_recv().unwrap().unwrap();
        assert_eq!(got.samples, vec![3.0, 3.0]);
        // a ticket delivers at most once
        assert!(t.try_recv().is_none());
        assert!(t.is_done());
        assert!(t.recv().is_err(), "spent ticket must error, not hang");
        assert_eq!(board.pending(), 0);
    }

    #[test]
    fn recv_blocks_until_completion() {
        let board = Arc::new(TicketBoard::new(1));
        let t = board.register(0, 1, TraceId::NONE);
        let b2 = Arc::clone(&board);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            b2.complete(0, 1, Ok(resp(1, 5.0)));
        });
        let got = t.recv().unwrap();
        assert_eq!(got.samples[0], 5.0);
        h.join().unwrap();
    }

    #[test]
    fn recv_deadline_times_out_then_succeeds() {
        let board = TicketBoard::new(1);
        let t = board.register(0, 2, TraceId::NONE);
        assert!(t.recv_timeout(Duration::from_millis(10)).is_none());
        board.complete(0, 2, Err(anyhow::anyhow!("boom")));
        let got = t.recv_timeout(Duration::from_millis(10)).unwrap();
        assert!(got.is_err());
    }

    #[test]
    fn notify_wakes_on_completion_and_is_consumed() {
        let board = Arc::new(TicketBoard::new(1));
        let t1 = board.register(0, 1, TraceId::NONE);
        let t2 = board.register(0, 2, TraceId::NONE);
        let n = Notify::new();
        t1.set_notify(&n);
        t2.set_notify(&n);
        assert!(!n.wait_timeout(Duration::from_millis(5)), "nothing yet");
        let b2 = Arc::clone(&board);
        let h = std::thread::spawn(move || {
            b2.complete(0, 1, Ok(resp(1, 1.0)));
        });
        assert!(n.wait_timeout(Duration::from_secs(5)), "woken by completion");
        h.join().unwrap();
        assert!(t1.try_recv().is_some());
        // flag consumed; second wait needs the second completion
        board.complete(0, 2, Ok(resp(2, 2.0)));
        assert!(n.wait_timeout(Duration::from_secs(5)));
        assert!(t2.try_recv().is_some());
    }

    #[test]
    fn set_notify_on_already_done_fires_immediately() {
        let board = TicketBoard::new(1);
        let t = board.register(0, 9, TraceId::NONE);
        board.complete(0, 9, Ok(resp(9, 0.0)));
        let n = Notify::new();
        t.set_notify(&n);
        assert!(n.wait_timeout(Duration::from_millis(1)));
    }

    #[test]
    fn retract_removes_pending_entry() {
        let board = TicketBoard::new(3);
        let _t = board.register(2, 4, TraceId::NONE);
        assert_eq!(board.pending(), 1);
        board.retract(2, 4);
        assert_eq!(board.pending(), 0);
    }

    #[test]
    fn fail_all_resolves_every_waiter() {
        let board = TicketBoard::new(2);
        let a = board.register(0, 1, TraceId::NONE);
        let b = board.register(1, 2, TraceId::NONE);
        let n = board.fail_all(|| anyhow::anyhow!("service shut down"));
        assert_eq!(n, 2);
        assert!(a.recv().is_err());
        assert!(b.try_recv().unwrap().is_err());
        assert_eq!(board.pending(), 0);
    }

    #[test]
    fn lanes_are_independent() {
        let board = TicketBoard::new(2);
        let a = board.register(0, 1, TraceId::NONE);
        let b = board.register(1, 1, TraceId::NONE); // same id, different lane: distinct
        board.complete(0, 1, Ok(resp(1, 1.0)));
        assert!(a.is_done());
        assert!(!b.is_done());
        board.complete(1, 1, Ok(resp(1, 2.0)));
        assert_eq!(b.try_recv().unwrap().unwrap().samples[0], 2.0);
    }
}
