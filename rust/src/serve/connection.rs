//! TCP front-end: acceptor + capped connection handlers over the
//! nonblocking core.
//!
//! The [`FrontEnd`] binds a listener and runs one **acceptor** thread
//! plus at most `max_conns` **connection handler** threads (the
//! [`ConnGate`] edge cap — an over-cap connection is answered with an
//! `overloaded` line and closed, never queued).  A handler speaks the
//! line-delimited JSON protocol of [`super::protocol`] and drives *only*
//! the nonblocking core: every parsed request goes through
//! `Service::submit_nb`, the returned [`Ticket`]s are registered on one
//! shared [`Notify`] waker, and the handler multiplexes socket reads
//! (bounded by a poll quantum) with ticket completions — it never blocks
//! on a single response, so one slow request cannot stall the
//! connection's other in-flight work.  Responses are written as tickets
//! complete, correlated by the client-chosen `id`.
//!
//! ## Graceful drain
//!
//! [`FrontEnd::request_drain`] (or a client's `{"op":"shutdown"}`
//! control line) flips the drain flag: the acceptor answers **new**
//! connections with a `shutting_down` line, handlers reject **new**
//! requests the same way while still delivering their in-flight
//! tickets, and once a handler's in-flight set is empty it closes its
//! connection.  [`FrontEnd::shutdown`] performs the full sequence —
//! drain, join every handler, stop the acceptor, then drain the
//! [`Service`] itself (`Service::shutdown` closes every lane under the
//! no-dropped-request invariant) — so every admitted request is
//! answered before the process exits.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::coordinator::Service;
use crate::jobs::JobRunner;
use crate::obs;
use crate::obs::flightrec::FlightRecorder;
use crate::obs::health::HealthMonitor;
use crate::serve::admission::ConnGate;
use crate::serve::protocol::{self, HealthAction, Status, WireMsg};
use crate::serve::ticket::{Notify, Ticket};

/// Front-end tuning.
#[derive(Debug, Clone)]
pub struct FrontEndConfig {
    /// Concurrent connection-handler cap (the edge admission gate).
    pub max_conns: usize,
    /// Poll quantum: socket read timeout between ticket-completion
    /// sweeps.  Bounds the latency of noticing a completed ticket or the
    /// drain flag while blocked on an idle socket.
    pub poll: Duration,
    /// How long a closing connection waits for its in-flight tickets.
    pub drain_grace: Duration,
    /// Socket write timeout.  A client that stops *reading* its socket
    /// would otherwise wedge its handler thread forever inside a
    /// blocking `write_all` once the kernel send buffer fills — and a
    /// wedged handler would hang `FrontEnd::shutdown`'s join.  On
    /// timeout the connection is dropped (its tickets still resolve
    /// server-side).
    pub write_timeout: Duration,
}

impl Default for FrontEndConfig {
    fn default() -> Self {
        FrontEndConfig {
            max_conns: 64,
            poll: Duration::from_millis(5),
            drain_grace: Duration::from_secs(120),
            write_timeout: Duration::from_secs(30),
        }
    }
}

/// Accumulated-request-line cap: a peer that never sends a newline must
/// not grow the buffer unboundedly.
const MAX_LINE_BYTES: usize = 1 << 20;
/// Acceptor wakeup period while the (nonblocking) listener is idle.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

struct Shared {
    service: Arc<Service>,
    /// The durable job layer (None unless started with a state dir —
    /// job ops are answered with an error in that case).
    runner: Option<Arc<JobRunner>>,
    /// The analog health monitor (None when `[health]` is disabled —
    /// health ops are answered with an error in that case).
    health: Option<Arc<HealthMonitor>>,
    /// The incident flight recorder (None without a state dir — dump
    /// ops are answered with an error in that case).
    recorder: Option<Arc<FlightRecorder>>,
    cfg: FrontEndConfig,
    /// Soft stop: reject new work, finish in-flight.
    draining: AtomicBool,
    /// Hard stop: acceptor exits.
    stopped: AtomicBool,
    drain_notify: Notify,
    gate: ConnGate,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }
}

/// The running TCP front-end (owns the [`Service`]).
pub struct FrontEnd {
    shared: Arc<Shared>,
    acceptor: Option<JoinHandle<()>>,
    addr: SocketAddr,
}

impl FrontEnd {
    /// Bind `addr` (e.g. `127.0.0.1:7979`, port 0 for ephemeral) and
    /// start accepting.  Takes ownership of the service; grab an
    /// `Arc<Metrics>` clone first if you need gauges after shutdown.
    pub fn bind(service: Service, addr: &str, cfg: FrontEndConfig)
                -> anyhow::Result<FrontEnd> {
        Self::bind_shared(Arc::new(service), None, addr, cfg)
    }

    /// Like [`Self::bind`], but over a shared service plus an optional
    /// durable [`JobRunner`] — the `--state-dir` deployment shape.  With
    /// a runner, the job ops (`enqueue`/`status`/`result`/`cancel`) come
    /// alive; [`Self::shutdown`] drains the runner (checkpointing, not
    /// discarding) before the service's own lane drain.
    pub fn bind_shared(service: Arc<Service>, runner: Option<Arc<JobRunner>>,
                       addr: &str, cfg: FrontEndConfig)
                       -> anyhow::Result<FrontEnd> {
        Self::bind_full(service, runner, None, addr, cfg)
    }

    /// The fully-wired deployment shape: service + optional durable job
    /// layer + optional [`HealthMonitor`].  With a monitor the `health`
    /// op comes alive (status plus the `age`/`reprogram` maintenance
    /// verbs); the front-end does not start or stop the monitor — its
    /// lifecycle belongs to the caller.
    pub fn bind_full(service: Arc<Service>, runner: Option<Arc<JobRunner>>,
                     health: Option<Arc<HealthMonitor>>, addr: &str,
                     cfg: FrontEndConfig)
                     -> anyhow::Result<FrontEnd> {
        Self::bind_deployment(service, runner, health, None, addr, cfg)
    }

    /// [`Self::bind_full`] plus the incident [`FlightRecorder`] — the
    /// complete `--state-dir` deployment.  With a recorder the `dump`
    /// op comes alive (`memdiff client --dump`); like the monitor, the
    /// recorder's lifecycle belongs to the caller.
    pub fn bind_deployment(service: Arc<Service>,
                           runner: Option<Arc<JobRunner>>,
                           health: Option<Arc<HealthMonitor>>,
                           recorder: Option<Arc<FlightRecorder>>,
                           addr: &str, cfg: FrontEndConfig)
                           -> anyhow::Result<FrontEnd> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding front-end listener on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("setting the listener nonblocking")?;
        let addr = listener.local_addr()?;
        let max_conns = cfg.max_conns;
        let shared = Arc::new(Shared {
            service,
            runner,
            health,
            recorder,
            cfg,
            draining: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            drain_notify: Notify::new(),
            gate: ConnGate::new(max_conns),
            conns: Mutex::new(Vec::new()),
        });
        let sh = Arc::clone(&shared);
        let acceptor = std::thread::spawn(move || accept_loop(listener, sh));
        Ok(FrontEnd { shared, acceptor: Some(acceptor), addr })
    }

    /// The durable job layer, when one was attached at bind time.
    pub fn runner(&self) -> Option<&Arc<JobRunner>> {
        self.shared.runner.as_ref()
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service metrics sink (usable after [`Self::shutdown`] too —
    /// it is an `Arc`).
    pub fn metrics(&self) -> Arc<crate::coordinator::Metrics> {
        Arc::clone(&self.shared.service.metrics)
    }

    /// Live connection-handler count.
    pub fn active_conns(&self) -> usize {
        self.shared.gate.active()
    }

    /// Begin the graceful drain (idempotent, returns immediately): new
    /// connections and new requests get `shutting_down`, in-flight
    /// tickets still complete.
    pub fn request_drain(&self) {
        self.shared.draining.store(true, Ordering::Release);
        self.shared.drain_notify.notify();
    }

    pub fn drain_requested(&self) -> bool {
        self.shared.draining()
    }

    /// Block until a drain is requested (by [`Self::request_drain`] or a
    /// client's `{"op":"shutdown"}` line).
    pub fn wait_drain(&self) {
        while !self.shared.draining() {
            self.shared.drain_notify.wait_timeout(Duration::from_millis(250));
        }
    }

    /// Full graceful shutdown: drain, join every handler, stop the
    /// acceptor, then drain the service's lanes (in-flight tickets
    /// complete; nothing admitted is dropped).  Synchronous: when this
    /// returns, every worker has joined — the final `Arc<Service>` clone
    /// dies here and `Service`'s own drop guard runs the lane drain
    /// under the no-dropped-request assertion.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.request_drain();
        // stop and join the ACCEPTOR first: once it is gone, nothing can
        // spawn or push another handler, so draining `conns` below races
        // with no one (a handler accepted just before the drain flag is
        // in the vec by the time the acceptor exits its loop iteration)
        self.shared.stopped.store(true, Ordering::Release);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let conns: Vec<JoinHandle<()>> = self.shared.conns.lock()
            .unwrap_or_else(|e| e.into_inner()).drain(..).collect();
        for c in conns {
            let _ = c.join();
        }
        // drain the job layer while the service still serves: in-flight
        // job attempts get their grace to complete durably, stragglers
        // requeue, and the store checkpoints — never discards
        if let Some(runner) = &self.shared.runner {
            runner.drain();
        }
        // every handler/acceptor Arc clone is gone; dropping self (the
        // last clone) now drains the Service via its Drop guard
    }
}

impl Drop for FrontEnd {
    fn drop(&mut self) {
        if self.acceptor.is_some() {
            self.shutdown_inner();
        }
    }
}

fn accept_loop(listener: TcpListener, sh: Arc<Shared>) {
    loop {
        if sh.stopped.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                // one-shot rejects below must never wedge the acceptor on
                // a peer that doesn't read
                let _ = stream.set_write_timeout(Some(sh.cfg.write_timeout));
                if sh.draining() {
                    // new connections during drain get one shutting-down
                    // line and are closed
                    let _ = write_line(
                        &mut stream,
                        &protocol::status_line(0, Status::ShuttingDown,
                                               "server draining"),
                    );
                    continue;
                }
                match sh.gate.try_acquire() {
                    Some(permit) => {
                        let sh2 = Arc::clone(&sh);
                        let h = std::thread::spawn(move || {
                            let _permit = permit;
                            handle_conn(stream, sh2);
                        });
                        let mut conns = sh.conns.lock()
                            .unwrap_or_else(|e| e.into_inner());
                        // reap finished handlers so a long-lived server
                        // doesn't accumulate one JoinHandle per past
                        // connection (detaching a finished thread is free)
                        conns.retain(|c| !c.is_finished());
                        conns.push(h);
                    }
                    None => {
                        let _ = write_line(
                            &mut stream,
                            &protocol::status_line(
                                0, Status::Overloaded,
                                "connection limit reached"),
                        );
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
}

/// One in-flight request on a connection: client id, requested sample
/// count, the service ticket.
type InFlight = (u64, usize, Ticket);

/// One long-polling `result` op: client id, job id, poll deadline.
type JobWait = (u64, u64, Instant);

fn handle_conn(mut stream: TcpStream, sh: Arc<Shared>) {
    let _ = stream.set_read_timeout(Some(sh.cfg.poll));
    let _ = stream.set_write_timeout(Some(sh.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let notify = Notify::new();
    let mut inflight: Vec<InFlight> = Vec::new();
    let mut jobwaits: Vec<JobWait> = Vec::new();
    let mut acc: Vec<u8> = Vec::new();
    let mut buf = [0u8; 8192];
    let mut open = true;

    while open {
        if flush_completed(&mut inflight, &mut stream).is_err()
            || flush_jobwaits(&mut jobwaits, &sh, &mut stream).is_err()
        {
            return; // peer gone: tickets resolve server-side regardless
        }
        if sh.draining() && inflight.is_empty() && jobwaits.is_empty() {
            return; // drained: close the connection
        }
        match stream.read(&mut buf) {
            Ok(0) => open = false,
            Ok(n) => {
                acc.extend_from_slice(&buf[..n]);
                if acc.len() > MAX_LINE_BYTES {
                    let _ = write_line(&mut stream, &protocol::status_line(
                        0, Status::Error, "request line too long"));
                    return;
                }
                if process_buffered(&mut acc, &sh, &notify, &mut inflight,
                                    &mut jobwaits, &mut stream).is_err() {
                    return;
                }
            }
            Err(e) if matches!(e.kind(),
                               ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // poll tick: loop back to the completion sweep
            }
            Err(_) => open = false,
        }
    }

    // EOF (or read error): the peer sends nothing more, but its admitted
    // requests still deserve answers — wait out the in-flight set
    let deadline = Instant::now() + sh.cfg.drain_grace;
    while (!inflight.is_empty() || !jobwaits.is_empty())
        && Instant::now() < deadline
    {
        if flush_completed(&mut inflight, &mut stream).is_err()
            || flush_jobwaits(&mut jobwaits, &sh, &mut stream).is_err()
        {
            return;
        }
        if !inflight.is_empty() || !jobwaits.is_empty() {
            notify.wait_timeout(sh.cfg.poll.max(Duration::from_millis(1)));
        }
    }
}

/// Split complete lines off `acc` and process each.  Err = the socket
/// write failed (connection dead).
fn process_buffered(acc: &mut Vec<u8>, sh: &Shared, notify: &Notify,
                    inflight: &mut Vec<InFlight>, jobwaits: &mut Vec<JobWait>,
                    stream: &mut TcpStream)
                    -> std::io::Result<()> {
    while let Some(pos) = acc.iter().position(|&b| b == b'\n') {
        let t_accept = Instant::now();
        let raw: Vec<u8> = acc.drain(..=pos).collect();
        let line = String::from_utf8_lossy(&raw[..raw.len() - 1]);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match protocol::parse_line(line) {
            Err(e) => {
                write_line(stream,
                           &protocol::status_line(e.id, Status::Error, &e.msg))?;
            }
            Ok(WireMsg::Shutdown) => {
                write_line(stream, &protocol::shutdown_ack_line())?;
                sh.draining.store(true, Ordering::Release);
                sh.drain_notify.notify();
            }
            Ok(WireMsg::Request { client_id, req }) => {
                if sh.draining() {
                    write_line(stream, &protocol::status_line(
                        client_id, Status::ShuttingDown, "server draining"))?;
                    continue;
                }
                let n = req.n_samples;
                obs::span(req.trace, obs::Stage::Accept, "",
                          req.class().name(), t_accept.elapsed());
                match sh.service.submit_nb(req) {
                    Ok(ticket) => {
                        ticket.set_notify(notify);
                        inflight.push((client_id, n, ticket));
                    }
                    Err(e) => {
                        write_line(stream, &protocol::reject_line(client_id, &e))?;
                    }
                }
            }
            Ok(WireMsg::Enqueue { client_id, req, defer_ms, max_retries,
                                  ttl_ms }) => {
                let Some(runner) = &sh.runner else {
                    write_line(stream, &protocol::status_line(
                        client_id, Status::Error,
                        "no job queue (start the server with --state-dir)"))?;
                    continue;
                };
                // accepted even while draining: the job is durable, so
                // it runs after the restart — that is the whole point
                match runner.enqueue(&req, defer_ms, max_retries, ttl_ms) {
                    Ok(job) => {
                        write_line(stream,
                                   &protocol::enqueue_ack_line(client_id, job))?;
                    }
                    Err(e) => {
                        write_line(stream, &protocol::status_line(
                            client_id, Status::Error,
                            &format!("enqueue failed: {e:#}")))?;
                    }
                }
            }
            Ok(WireMsg::Stats { client_id }) => {
                if let Some(runner) = &sh.runner {
                    let _ = runner.gauges(); // point-in-time refresh
                }
                let snap = sh.service.metrics.snapshot();
                let stats = obs::export::stats_json(&snap);
                let prom = obs::export::render_prometheus(&snap);
                write_line(stream, &protocol::stats_reply_line(
                    client_id, stats, &prom))?;
            }
            Ok(WireMsg::Health { client_id, action }) => {
                let Some(mon) = &sh.health else {
                    write_line(stream, &protocol::status_line(
                        client_id, Status::Error,
                        "no health monitor (enable the [health] config \
                         section)"))?;
                    continue;
                };
                match action {
                    HealthAction::Status => {}
                    HealthAction::Age { dt_s } => {
                        // apply the drift, then tick so the estimator and
                        // alert rules see it before the reply renders
                        mon.age_all(dt_s);
                        mon.tick();
                    }
                    HealthAction::Reprogram => {
                        mon.reprogram_all();
                        mon.tick();
                    }
                }
                write_line(stream, &protocol::health_reply_line(
                    client_id, mon.health_json()))?;
            }
            Ok(WireMsg::Dump { client_id }) => {
                let Some(rec) = &sh.recorder else {
                    write_line(stream, &protocol::status_line(
                        client_id, Status::Error,
                        "no flight recorder (start the server with \
                         --state-dir)"))?;
                    continue;
                };
                match rec.dump("manual") {
                    Ok(path) => {
                        let dump = std::fs::read_to_string(&path)
                            .ok()
                            .and_then(|s| {
                                crate::util::json::Json::parse(s.trim()).ok()
                            })
                            .unwrap_or(crate::util::json::Json::Null);
                        write_line(stream, &protocol::dump_reply_line(
                            client_id, &path.display().to_string(), dump))?;
                    }
                    Err(e) => {
                        write_line(stream, &protocol::status_line(
                            client_id, Status::Error,
                            &format!("dump failed: {e:#}")))?;
                    }
                }
            }
            Ok(WireMsg::JobStatus { client_id, job }) => {
                let Some(runner) = &sh.runner else {
                    write_line(stream, &protocol::status_line(
                        client_id, Status::Error,
                        "no job queue (start the server with --state-dir)"))?;
                    continue;
                };
                match runner.get(job) {
                    Some(j) => write_line(
                        stream, &protocol::job_status_line(client_id, &j))?,
                    None => write_line(
                        stream, &protocol::job_unknown_line(client_id, job))?,
                }
            }
            Ok(WireMsg::JobCancel { client_id, job }) => {
                let Some(runner) = &sh.runner else {
                    write_line(stream, &protocol::status_line(
                        client_id, Status::Error,
                        "no job queue (start the server with --state-dir)"))?;
                    continue;
                };
                match runner.cancel(job).ok().and_then(|_| runner.get(job)) {
                    Some(j) => write_line(
                        stream, &protocol::job_status_line(client_id, &j))?,
                    None => write_line(
                        stream, &protocol::job_unknown_line(client_id, job))?,
                }
            }
            Ok(WireMsg::JobResult { client_id, job, wait_ms }) => {
                let Some(runner) = &sh.runner else {
                    write_line(stream, &protocol::status_line(
                        client_id, Status::Error,
                        "no job queue (start the server with --state-dir)"))?;
                    continue;
                };
                match runner.get(job) {
                    None => write_line(
                        stream, &protocol::job_unknown_line(client_id, job))?,
                    Some(j) if j.state.is_terminal() || wait_ms == 0 => {
                        write_line(stream,
                                   &protocol::job_result_line(client_id, &j))?;
                    }
                    Some(_) => {
                        // long-poll: ride the connection's Notify waker —
                        // the runner fires it on the terminal transition,
                        // flush_jobwaits writes the answer
                        runner.subscribe(job, notify);
                        jobwaits.push((client_id, job,
                                       Instant::now()
                                       + Duration::from_millis(wait_ms)));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Answer every long-polled `result` op that is ready: terminal job,
/// expired wait, or a draining server (answer with the pollable
/// snapshot rather than holding the connection open).
fn flush_jobwaits(jobwaits: &mut Vec<JobWait>, sh: &Shared,
                  stream: &mut TcpStream) -> std::io::Result<()> {
    let mut i = 0;
    while i < jobwaits.len() {
        let (client_id, job, deadline) = jobwaits[i];
        let answer = match sh.runner.as_ref().and_then(|r| r.get(job)) {
            None => Some(protocol::job_unknown_line(client_id, job)),
            Some(j) if j.state.is_terminal()
                || Instant::now() >= deadline
                || sh.draining() =>
            {
                Some(protocol::job_result_line(client_id, &j))
            }
            Some(_) => None,
        };
        match answer {
            Some(line) => {
                jobwaits.remove(i);
                write_line(stream, &line)?;
            }
            None => i += 1,
        }
    }
    Ok(())
}

/// Write response lines for every completed in-flight ticket (order of
/// completion, not submission — responses are id-correlated).
fn flush_completed(inflight: &mut Vec<InFlight>, stream: &mut TcpStream)
                   -> std::io::Result<()> {
    let mut i = 0;
    while i < inflight.len() {
        match inflight[i].2.try_recv() {
            Some(result) => {
                let (client_id, n, _) = inflight.remove(i);
                let line = match result {
                    Ok(resp) => protocol::ok_line(client_id, n, &resp),
                    Err(e) => protocol::status_line(
                        client_id, Status::Error, &format!("{e:#}")),
                };
                write_line(stream, &line)?;
            }
            None => i += 1,
        }
    }
    Ok(())
}

fn write_line(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")
}
