//! Wire protocol of the TCP front-end: line-delimited JSON.
//!
//! One request per line, one response line per request.  Responses may
//! arrive **out of submission order** (lanes run concurrently); the
//! client-chosen `id` correlates them.
//!
//! ## Request schema
//!
//! ```text
//! {"id": 7, "task": "circle"|"h"|"k"|"u", "n": 4,
//!  "solver": "analog-ode"|"analog-sde"|"euler"|"euler-sde",
//!  "steps": 100, "guidance": 2.0, "decode": false}
//! ```
//!
//! `id` defaults to 0, `task` to `"circle"`, `n` to 1, `solver` to
//! `"analog-ode"`, `steps` (digital solvers only) to 130, `guidance` to
//! 2.0, `decode` to false.  `n` is capped at [`MAX_WIRE_SAMPLES`] and
//! `steps` at [`MAX_WIRE_STEPS`] — over-cap requests are rejected at
//! parse time, before admission, so a remote client cannot force an
//! unbounded allocation or step loop.  A control line
//! `{"op": "shutdown"}` asks the server to begin its graceful drain
//! (demo/CI affordance — see `memdiff serve --listen`).
//!
//! ## Response schema
//!
//! ```text
//! {"id": 7, "status": "ok", "dim": 2, "samples": [x0,y0,x1,y1,...],
//!  "wall_latency_s": ..., "hw_latency_s": ..., "hw_energy_j": ...}
//! {"id": 8, "status": "overloaded", "error": "...",
//!  "queued_samples": 128, "queue_depth": 128}
//! {"id": 9, "status": "shutting_down", "error": "..."}
//! {"id": 0, "status": "error", "error": "bad request: ..."}
//! ```
//!
//! `status` is the machine-readable outcome: `ok`, `overloaded` (the
//! lane's bounded queue was full — retry later or back off;
//! `retry_after_ms` carries the lane's drain-rate-derived backoff
//! hint), `shutting_down` (server draining — reconnect elsewhere), or
//! `error` (malformed request, unrouted class, or engine failure).
//! Decoded images ride an `images` array when `decode` was requested.
//!
//! ## Job ops (durable queue — servers started with `--state-dir`)
//!
//! ```text
//! {"op": "enqueue", "id": 3, ...request fields...,
//!  "defer_ms": 0, "max_retries": 4, "ttl_ms": 900000}
//!                    -> {"id": 3, "status": "ok", "job": 17, "state": "queued"}
//! {"op": "status", "id": 3, "job": 17}
//!                    -> {"id": 3, "status": "ok", "job": 17,
//!                        "state": "running", "attempts": 0}
//! {"op": "result", "id": 3, "job": 17, "wait_ms": 5000}   # long-poll
//!                    -> done:  ok + state "done" + samples/dim/latencies
//!                    -> dead/cancelled: status "error" + state + error
//!                    -> still pending at the deadline: ok + non-terminal
//!                       state + attempts (poll again)
//! {"op": "cancel", "id": 3, "job": 17}
//!                    -> {"id": 3, "status": "ok", "job": 17, "state": ...}
//! ```
//!
//! `enqueue` acks only after the job is fsync-durable — the returned
//! `job` id survives a server crash (see [`crate::jobs`] for the
//! contract).  An unknown/expired job id answers `status: "error"`.
//! Servers without a state dir answer every job op with an error.
//!
//! ## Stats op (observability — always available)
//!
//! ```text
//! {"op": "stats", "id": 3}
//!   -> {"id": 3, "status": "ok", "op": "stats",
//!       "stats": {"requests": ..., "samples": ..., "rejected": ...,
//!                 "backends": [{"name": ..., "queue_depth": ...,
//!                               "p50_latency_s": ..., ...}],
//!                 "banks": [{"layer": 0, "reads": ..., "banks": [...]}],
//!                 "jobs": {"queued": ..., ...},       # state-dir servers
//!                 "stages": [{"stage": "engine_solve", "backend": ...,
//!                             "class": ..., "count": ..., "p50_s": ...}],
//!                 "phases": [{"phase": "gemm", "total_s": ..., ...}],
//!                 "traces": [{"trace": N, "spans": [...]}]},
//!       "prometheus": "# HELP memdiff_requests_total ...\n..."}
//! ```
//!
//! `stats` embeds the same JSON the periodic JSONL flush writes plus the
//! full Prometheus text exposition (also served plainly on
//! `--metrics-listen`); see [`crate::obs`] for the metric families.
//!
//! ## Health op (servers running the analog health monitor)
//!
//! ```text
//! {"op": "health", "id": 3}
//!   -> {"id": 3, "status": "ok", "op": "health",
//!       "health": {"healthy": true,
//!                  "alerts": [{"name": "drift:analog", "firing": false,
//!                              "breaches": 0, "value": 1.2e-5}],
//!                  "drift": [{"backend": "analog", "cells": ...,
//!                             "mean_abs_ms": ..., "max_abs_ms": ...,
//!                             "stuck": ..., "stuck_pct": ...,
//!                             "layers": [{"layer": 0, ...,
//!                                         "banks": [{"bank": "r0c0", ...}]}]}],
//!                  "probes": [{"backend": ..., "class": ..., "kl": ...,
//!                              "ok": true, "error": null}],
//!                  "reprogram": [...], "ticks": ..., "reprograms": ...}}
//! {"op": "health", "id": 3, "action": "age", "dt_s": 1e9}
//!   -> same reply shape, after applying the retention drift
//! {"op": "health", "id": 3, "action": "reprogram"}
//!   -> same reply shape, after the write-verify reprogram
//! ```
//!
//! `age` and `reprogram` are maintenance verbs (CI uses them to force an
//! alert and then clear it); a server without the monitor answers every
//! health op with `status: "error"`.  The same `health` object rides the
//! JSONL flush, and `/healthz` on `--metrics-listen` answers 200/503
//! from the `healthy` bit.
//!
//! ## Dump op (flight recorder — servers started with `--state-dir`)
//!
//! ```text
//! {"op": "dump", "id": 3}
//!   -> {"id": 3, "status": "ok", "op": "dump",
//!       "path": "<state-dir>/flightrec/<ts>-manual.json",
//!       "dump": {"reason": "manual", "fingerprint": ...,
//!                "health": {...}, "firing": [...], "stats": {...}}}
//! ```
//!
//! `dump` writes an incident flight record on demand (reason `manual`)
//! and echoes both the file path and the record itself.  The same
//! records are written automatically on alert latch, worker panic, and
//! sustained overload shed — see [`crate::obs::flightrec`].  A server
//! without a state dir answers `status: "error"`.

use crate::coordinator::request::{GenRequest, GenResponse, SolverChoice, TaskKind};
use crate::jobs::store::Job;
use crate::serve::admission::SubmitError;
use crate::util::json::Json;

use std::collections::BTreeMap;

/// Machine-readable response outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok,
    Overloaded,
    ShuttingDown,
    Error,
}

impl Status {
    pub fn as_str(&self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Overloaded => "overloaded",
            Status::ShuttingDown => "shutting_down",
            Status::Error => "error",
        }
    }

    pub fn from_str(s: &str) -> Option<Status> {
        match s {
            "ok" => Some(Status::Ok),
            "overloaded" => Some(Status::Overloaded),
            "shutting_down" => Some(Status::ShuttingDown),
            "error" => Some(Status::Error),
            _ => None,
        }
    }
}

/// One parsed request line.
#[derive(Debug)]
pub enum WireMsg {
    /// A generation request: the client's correlation id plus the
    /// service request (its `id` field is 0 — the service assigns its
    /// own internal ids).
    Request { client_id: u64, req: GenRequest },
    /// `{"op": "shutdown"}` — begin the graceful drain.
    Shutdown,
    /// `{"op": "enqueue", ...}` — durably accept a job and answer with
    /// its id immediately (submit-now/fetch-later).
    Enqueue {
        client_id: u64,
        req: GenRequest,
        /// Delay before the first run (the `run_at` deferral).
        defer_ms: u64,
        /// Retry budget override (None = server default).
        max_retries: Option<u32>,
        /// Result-retention override (None = server default).
        ttl_ms: Option<u64>,
    },
    /// `{"op": "status", "job": N}` — job lifecycle snapshot.
    JobStatus { client_id: u64, job: u64 },
    /// `{"op": "result", "job": N, "wait_ms": T}` — fetch the result,
    /// long-polling up to `wait_ms` for a terminal state.
    JobResult { client_id: u64, job: u64, wait_ms: u64 },
    /// `{"op": "cancel", "job": N}`.
    JobCancel { client_id: u64, job: u64 },
    /// `{"op": "stats"}` — the full observability snapshot (JSON stats +
    /// Prometheus text) in one reply line.
    Stats { client_id: u64 },
    /// `{"op": "health"}` — the health monitor's state, optionally after
    /// a maintenance action.
    Health { client_id: u64, action: HealthAction },
    /// `{"op": "dump"}` — write a flight record now and echo it.
    Dump { client_id: u64 },
}

/// The maintenance verb of a health op.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HealthAction {
    /// Report only.
    Status,
    /// Apply `dt_s` simulated seconds of retention drift first.
    Age { dt_s: f64 },
    /// Re-run write-verify programming on every device backend first.
    Reprogram,
}

/// A request-line parse failure: the message goes into an
/// `error`-status response, echoed under the best-effort client `id`
/// (0 when the line wasn't valid JSON at all).
#[derive(Debug)]
pub struct WireError {
    pub id: u64,
    pub msg: String,
}

/// Hard cap on a single wire request's sample count.  In-process
/// callers are trusted with any `n` (and the batcher deliberately
/// admits an oversized request on an empty queue), but over TCP an
/// unbounded `n` would let any remote client force an `n × dim`
/// allocation in the worker — so the edge rejects it at parse time,
/// before it can reach admission.
pub const MAX_WIRE_SAMPLES: usize = 4096;

/// Companion cap on a digital request's step count (an unbounded
/// `steps` is a CPU-time attack the same way an unbounded `n` is a
/// memory one).
pub const MAX_WIRE_STEPS: usize = 65_536;

/// Parse the generation-request fields shared by plain requests and
/// `enqueue` (task/n/solver/steps/guidance/decode, with the wire caps).
fn parse_gen(j: &Json, client_id: u64) -> Result<GenRequest, WireError> {
    let err = |msg: String| WireError { id: client_id, msg };
    let task_name = j.get("task").and_then(|v| v.as_str()).unwrap_or("circle");
    let task = TaskKind::from_name(task_name)
        .ok_or_else(|| err(format!("bad request: unknown task {task_name:?}")))?;
    let n = j.get("n").and_then(|v| v.as_usize()).unwrap_or(1);
    if n > MAX_WIRE_SAMPLES {
        return Err(err(format!(
            "bad request: n = {n} exceeds the per-request cap of \
             {MAX_WIRE_SAMPLES} samples"
        )));
    }
    let steps = j.get("steps").and_then(|v| v.as_usize()).unwrap_or(130);
    if steps > MAX_WIRE_STEPS {
        return Err(err(format!(
            "bad request: steps = {steps} exceeds the cap of {MAX_WIRE_STEPS}"
        )));
    }
    let solver_name =
        j.get("solver").and_then(|v| v.as_str()).unwrap_or("analog-ode");
    let solver = SolverChoice::from_name(solver_name, steps).ok_or_else(|| {
        err(format!("bad request: unknown solver {solver_name:?}"))
    })?;
    let guidance = j.get("guidance").and_then(|v| v.as_f64()).unwrap_or(2.0) as f32;
    let decode = matches!(j.get("decode"), Some(Json::Bool(true)));
    Ok(GenRequest { id: 0, task, n_samples: n, solver, guidance, decode,
                    trace: crate::obs::TraceId::mint() })
}

/// Parse one request line.
pub fn parse_line(line: &str) -> Result<WireMsg, WireError> {
    let j = Json::parse(line)
        .map_err(|e| WireError { id: 0, msg: format!("bad request: {e}") })?;
    if j.as_obj().is_none() {
        return Err(WireError {
            id: 0,
            msg: "bad request: expected a JSON object".into(),
        });
    }
    let client_id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let err = |msg: String| WireError { id: client_id, msg };
    if let Some(op) = j.get("op").and_then(|v| v.as_str()) {
        return match op {
            "shutdown" => Ok(WireMsg::Shutdown),
            "stats" => Ok(WireMsg::Stats { client_id }),
            "dump" => Ok(WireMsg::Dump { client_id }),
            "health" => {
                let action = match j.get("action").and_then(|v| v.as_str()) {
                    None | Some("status") => HealthAction::Status,
                    Some("age") => HealthAction::Age {
                        dt_s: j.get("dt_s").and_then(|v| v.as_f64())
                            .ok_or_else(|| err(
                                "bad request: health action \"age\" requires \
                                 dt_s".into()))?,
                    },
                    Some("reprogram") => HealthAction::Reprogram,
                    Some(other) => {
                        return Err(err(format!(
                            "bad request: unknown health action {other:?}")));
                    }
                };
                Ok(WireMsg::Health { client_id, action })
            }
            "enqueue" => Ok(WireMsg::Enqueue {
                client_id,
                req: parse_gen(&j, client_id)?,
                defer_ms: j.get("defer_ms").and_then(|v| v.as_f64())
                    .unwrap_or(0.0) as u64,
                max_retries: j.get("max_retries").and_then(|v| v.as_usize())
                    .map(|v| v as u32),
                ttl_ms: j.get("ttl_ms").and_then(|v| v.as_f64()).map(|v| v as u64),
            }),
            "status" | "result" | "cancel" => {
                let job = j
                    .get("job")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| {
                        err(format!("bad request: op {op:?} requires a job id"))
                    })? as u64;
                Ok(match op {
                    "status" => WireMsg::JobStatus { client_id, job },
                    "cancel" => WireMsg::JobCancel { client_id, job },
                    _ => WireMsg::JobResult {
                        client_id,
                        job,
                        wait_ms: j.get("wait_ms").and_then(|v| v.as_f64())
                            .unwrap_or(0.0) as u64,
                    },
                })
            }
            other => Err(err(format!("bad request: unknown op {other:?}"))),
        };
    }
    Ok(WireMsg::Request { client_id, req: parse_gen(&j, client_id)? })
}

fn base_obj(client_id: u64, status: Status) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("id".into(), Json::Num(client_id as f64));
    m.insert("status".into(), Json::Str(status.as_str().into()));
    m
}

/// Success response line for a completed ticket.  `n_samples` is the
/// request's sample count (the handler knows it from the parsed
/// request) — it recovers the per-sample dimensionality for the client.
pub fn ok_line(client_id: u64, n_samples: usize, resp: &GenResponse) -> String {
    let mut m = base_obj(client_id, Status::Ok);
    let dim = if n_samples > 0 { resp.samples.len() / n_samples } else { 0 };
    m.insert("dim".into(), Json::Num(dim as f64));
    m.insert("samples".into(),
             Json::Arr(resp.samples.iter().map(|&v| Json::Num(v as f64)).collect()));
    if let Some(images) = &resp.images {
        m.insert("images".into(),
                 Json::Arr(images.iter().map(|&v| Json::Num(v as f64)).collect()));
    }
    m.insert("wall_latency_s".into(), Json::Num(resp.wall_latency_s));
    m.insert("hw_latency_s".into(), Json::Num(resp.hw_latency_s));
    m.insert("hw_energy_j".into(), Json::Num(resp.hw_energy_j));
    Json::Obj(m).to_string()
}

/// Plain non-ok response line.
pub fn status_line(client_id: u64, status: Status, error: &str) -> String {
    let mut m = base_obj(client_id, status);
    m.insert("error".into(), Json::Str(error.into()));
    Json::Obj(m).to_string()
}

/// Response line for an admission reject, mapping the structured
/// [`SubmitError`] onto a wire status (`Overloaded` carries the queue
/// numbers so clients can implement informed backoff).
pub fn reject_line(client_id: u64, err: &SubmitError) -> String {
    match err {
        SubmitError::Overloaded { queued_samples, queue_depth, retry_after_ms,
                                  .. } => {
            let mut m = base_obj(client_id, Status::Overloaded);
            m.insert("error".into(), Json::Str(err.to_string()));
            m.insert("queued_samples".into(), Json::Num(*queued_samples as f64));
            m.insert("queue_depth".into(), Json::Num(*queue_depth as f64));
            m.insert("retry_after_ms".into(), Json::Num(*retry_after_ms as f64));
            Json::Obj(m).to_string()
        }
        SubmitError::ShuttingDown => {
            status_line(client_id, Status::ShuttingDown, &err.to_string())
        }
        SubmitError::Unroutable { .. } | SubmitError::Invalid(_) => {
            status_line(client_id, Status::Error, &err.to_string())
        }
    }
}

/// Ack line for a `{"op":"shutdown"}` control request.
pub fn shutdown_ack_line() -> String {
    let mut m = base_obj(0, Status::Ok);
    m.insert("op".into(), Json::Str("shutdown".into()));
    Json::Obj(m).to_string()
}

fn job_obj(client_id: u64, status: Status, job: u64, state: &str)
           -> BTreeMap<String, Json> {
    let mut m = base_obj(client_id, status);
    m.insert("job".into(), Json::Num(job as f64));
    m.insert("state".into(), Json::Str(state.into()));
    m
}

/// Ack line for a durably-accepted `enqueue` (sent only after the fsync).
pub fn enqueue_ack_line(client_id: u64, job: u64) -> String {
    Json::Obj(job_obj(client_id, Status::Ok, job, "queued")).to_string()
}

/// Response line for a `status` op (also the post-`cancel` snapshot).
pub fn job_status_line(client_id: u64, job: &Job) -> String {
    let mut m = job_obj(client_id, Status::Ok, job.id, job.state.as_str());
    m.insert("attempts".into(), Json::Num(job.attempts as f64));
    if let Some(err) = &job.error {
        m.insert("error".into(), Json::Str(err.clone()));
    }
    Json::Obj(m).to_string()
}

/// Response line for a `result` op: a done job's retained result, a
/// dead/cancelled job's error, or (still pending at the long-poll
/// deadline) the non-terminal state for the client to poll again.
pub fn job_result_line(client_id: u64, job: &Job) -> String {
    use crate::jobs::store::JobState;
    match (&job.state, &job.result) {
        (JobState::Done, Some(r)) => {
            let mut m = job_obj(client_id, Status::Ok, job.id, "done");
            let dim = if job.n_samples > 0 {
                r.samples.len() / job.n_samples
            } else {
                0
            };
            m.insert("dim".into(), Json::Num(dim as f64));
            m.insert("samples".into(),
                     Json::Arr(r.samples.iter().map(|&v| Json::Num(v as f64))
                                .collect()));
            if let Some(images) = &r.images {
                m.insert("images".into(),
                         Json::Arr(images.iter().map(|&v| Json::Num(v as f64))
                                    .collect()));
            }
            m.insert("wall_latency_s".into(), Json::Num(r.wall_latency_s));
            m.insert("hw_latency_s".into(), Json::Num(r.hw_latency_s));
            m.insert("hw_energy_j".into(), Json::Num(r.hw_energy_j));
            Json::Obj(m).to_string()
        }
        (s, _) if s.is_terminal() => {
            // dead or cancelled (a done job always retains its result)
            let mut m = job_obj(client_id, Status::Error, job.id, s.as_str());
            m.insert("error".into(), Json::Str(
                job.error.clone()
                   .unwrap_or_else(|| format!("job is {}", s.as_str()))));
            Json::Obj(m).to_string()
        }
        _ => job_status_line(client_id, job),
    }
}

/// Error line for a job op against an unknown (or TTL-swept) job id.
pub fn job_unknown_line(client_id: u64, job: u64) -> String {
    status_line(client_id, Status::Error, &format!("unknown job {job}"))
}

/// One parsed response line (the client side of the protocol — used by
/// `memdiff client`, the front-end bench scenario and the tests).
#[derive(Debug, Clone)]
pub struct WireReply {
    pub id: u64,
    pub status: Status,
    /// Flat `n × dim` samples (empty unless `status == Ok`).
    pub samples: Vec<f32>,
    pub dim: usize,
    pub error: Option<String>,
    /// Queue numbers of an `overloaded` reject.
    pub queued_samples: Option<usize>,
    pub queue_depth: Option<usize>,
    /// Adaptive backoff hint of an `overloaded` reject (drain-rate
    /// derived; wait this long before retrying).
    pub retry_after_ms: Option<u64>,
    pub wall_latency_s: f64,
    /// Job id of a job-op reply.
    pub job: Option<u64>,
    /// Job lifecycle state of a job-op reply.
    pub state: Option<String>,
    /// Failed attempts so far, on `status`/pending-`result` replies.
    pub attempts: Option<u32>,
}

/// Parse one response line.
pub fn parse_reply(line: &str) -> Result<WireReply, String> {
    let j = Json::parse(line).map_err(|e| format!("bad response: {e}"))?;
    let status_str = j
        .get("status")
        .and_then(|v| v.as_str())
        .ok_or("bad response: missing status")?;
    let status = Status::from_str(status_str)
        .ok_or_else(|| format!("bad response: unknown status {status_str:?}"))?;
    let samples = j
        .get("samples")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect())
        .unwrap_or_default();
    Ok(WireReply {
        id: j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        status,
        samples,
        dim: j.get("dim").and_then(|v| v.as_usize()).unwrap_or(2),
        error: j.get("error").and_then(|v| v.as_str()).map(String::from),
        queued_samples: j.get("queued_samples").and_then(|v| v.as_usize()),
        queue_depth: j.get("queue_depth").and_then(|v| v.as_usize()),
        retry_after_ms: j.get("retry_after_ms").and_then(|v| v.as_f64())
            .map(|v| v as u64),
        wall_latency_s: j.get("wall_latency_s").and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN),
        job: j.get("job").and_then(|v| v.as_f64()).map(|v| v as u64),
        state: j.get("state").and_then(|v| v.as_str()).map(String::from),
        attempts: j.get("attempts").and_then(|v| v.as_usize()).map(|v| v as u32),
    })
}

/// The generation fields shared by `request_line` and `enqueue_line`.
fn gen_fields(client_id: u64, task: TaskKind, n: usize, solver: SolverChoice,
              guidance: f32, decode: bool) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("id".into(), Json::Num(client_id as f64));
    m.insert("task".into(), Json::Str(task.name().into()));
    m.insert("n".into(), Json::Num(n as f64));
    m.insert("solver".into(), Json::Str(solver.name().into()));
    if let Some(steps) = solver.steps() {
        m.insert("steps".into(), Json::Num(steps as f64));
    }
    m.insert("guidance".into(), Json::Num(guidance as f64));
    if decode {
        m.insert("decode".into(), Json::Bool(true));
    }
    m
}

/// Build a request line (client side).
pub fn request_line(client_id: u64, task: TaskKind, n: usize,
                    solver: SolverChoice, guidance: f32, decode: bool)
                    -> String {
    Json::Obj(gen_fields(client_id, task, n, solver, guidance, decode))
        .to_string()
}

/// Build an `enqueue` line (client side).  `None` overrides defer to the
/// server's configured defaults.
#[allow(clippy::too_many_arguments)]
pub fn enqueue_line(client_id: u64, task: TaskKind, n: usize,
                    solver: SolverChoice, guidance: f32, decode: bool,
                    defer_ms: u64, max_retries: Option<u32>,
                    ttl_ms: Option<u64>) -> String {
    let mut m = gen_fields(client_id, task, n, solver, guidance, decode);
    m.insert("op".into(), Json::Str("enqueue".into()));
    if defer_ms > 0 {
        m.insert("defer_ms".into(), Json::Num(defer_ms as f64));
    }
    if let Some(r) = max_retries {
        m.insert("max_retries".into(), Json::Num(r as f64));
    }
    if let Some(t) = ttl_ms {
        m.insert("ttl_ms".into(), Json::Num(t as f64));
    }
    Json::Obj(m).to_string()
}

/// Build a `status` or `cancel` line (client side).
pub fn job_op_line(op: &str, client_id: u64, job: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str(op.into()));
    m.insert("id".into(), Json::Num(client_id as f64));
    m.insert("job".into(), Json::Num(job as f64));
    Json::Obj(m).to_string()
}

/// Build a long-polling `result` line (client side).
pub fn result_line(client_id: u64, job: u64, wait_ms: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("result".into()));
    m.insert("id".into(), Json::Num(client_id as f64));
    m.insert("job".into(), Json::Num(job as f64));
    if wait_ms > 0 {
        m.insert("wait_ms".into(), Json::Num(wait_ms as f64));
    }
    Json::Obj(m).to_string()
}

/// Build the shutdown control line (client side).
pub fn shutdown_line() -> String {
    r#"{"op":"shutdown"}"#.to_string()
}

/// Build a `stats` line (client side — `memdiff client --stats`).
pub fn stats_line(client_id: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("stats".into()));
    m.insert("id".into(), Json::Num(client_id as f64));
    Json::Obj(m).to_string()
}

/// Reply line for a `stats` op: the JSON stats object plus the full
/// Prometheus text exposition as one string field.
pub fn stats_reply_line(client_id: u64, stats: Json, prometheus: &str)
                        -> String {
    let mut m = base_obj(client_id, Status::Ok);
    m.insert("op".into(), Json::Str("stats".into()));
    m.insert("stats".into(), stats);
    m.insert("prometheus".into(), Json::Str(prometheus.into()));
    Json::Obj(m).to_string()
}

/// Build a `dump` line (client side — `memdiff client --dump`).
pub fn dump_line(client_id: u64) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("dump".into()));
    m.insert("id".into(), Json::Num(client_id as f64));
    Json::Obj(m).to_string()
}

/// Reply line for a `dump` op: the written record's path plus the
/// record itself.
pub fn dump_reply_line(client_id: u64, path: &str, dump: Json) -> String {
    let mut m = base_obj(client_id, Status::Ok);
    m.insert("op".into(), Json::Str("dump".into()));
    m.insert("path".into(), Json::Str(path.into()));
    m.insert("dump".into(), dump);
    Json::Obj(m).to_string()
}

/// Build a `health` line (client side — `memdiff client --health`
/// and the maintenance verbs `--age-device` / `--reprogram`).
pub fn health_line(client_id: u64, action: HealthAction) -> String {
    let mut m = BTreeMap::new();
    m.insert("op".into(), Json::Str("health".into()));
    m.insert("id".into(), Json::Num(client_id as f64));
    match action {
        HealthAction::Status => {}
        HealthAction::Age { dt_s } => {
            m.insert("action".into(), Json::Str("age".into()));
            m.insert("dt_s".into(), Json::Num(dt_s));
        }
        HealthAction::Reprogram => {
            m.insert("action".into(), Json::Str("reprogram".into()));
        }
    }
    Json::Obj(m).to_string()
}

/// Reply line for a `health` op: the monitor's full state object
/// (same shape as the JSONL flush's `health` key).
pub fn health_reply_line(client_id: u64, health: Json) -> String {
    let mut m = base_obj(client_id, Status::Ok);
    m.insert("op".into(), Json::Str("health".into()));
    m.insert("health".into(), health);
    Json::Obj(m).to_string()
}

/// Read and parse one reply line from a buffered stream (the client
/// side's read loop — shared by `memdiff client`, the front-end bench
/// scenario and the tests).  EOF is an error: callers use this only
/// while expecting an answer.
pub fn read_reply(reader: &mut impl std::io::BufRead)
                  -> anyhow::Result<WireReply> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        anyhow::bail!("server closed the connection early");
    }
    parse_reply(line.trim()).map_err(|e| anyhow::anyhow!("{e} in {line:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SolverFamily;

    #[test]
    fn request_roundtrip_all_fields() {
        let line = request_line(42, TaskKind::Letter(1), 6,
                                SolverChoice::DigitalSde { steps: 77 }, 1.5, true);
        let WireMsg::Request { client_id, req } = parse_line(&line).unwrap()
        else { panic!("expected request") };
        assert_eq!(client_id, 42);
        assert_eq!(req.task, TaskKind::Letter(1));
        assert_eq!(req.n_samples, 6);
        assert_eq!(req.solver, SolverChoice::DigitalSde { steps: 77 });
        assert_eq!(req.guidance, 1.5);
        assert!(req.decode);
        assert_eq!(req.id, 0, "service assigns its own ids");
        assert_eq!(req.class().family, SolverFamily::Digital);
    }

    #[test]
    fn request_defaults() {
        let WireMsg::Request { client_id, req } = parse_line("{}").unwrap()
        else { panic!() };
        assert_eq!(client_id, 0);
        assert_eq!(req.task, TaskKind::Circle);
        assert_eq!(req.n_samples, 1);
        assert_eq!(req.solver, SolverChoice::AnalogOde);
        assert_eq!(req.guidance, 2.0);
        assert!(!req.decode);
    }

    #[test]
    fn shutdown_op_parses() {
        assert!(matches!(parse_line(&shutdown_line()).unwrap(),
                         WireMsg::Shutdown));
        assert!(parse_line(r#"{"op":"reboot"}"#).is_err());
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(parse_line("not json").is_err());
        assert!(parse_line("[1,2]").is_err());
        assert!(parse_line(r#"{"task":"z"}"#).is_err());
        assert!(parse_line(r#"{"solver":"warp"}"#).is_err());
        // a parseable object with bad fields echoes the client id back
        let e = parse_line(r#"{"id": 4, "task": "zebra"}"#).unwrap_err();
        assert_eq!(e.id, 4);
        assert!(e.msg.contains("unknown task"), "{}", e.msg);
        assert_eq!(parse_line("not json").unwrap_err().id, 0);
    }

    #[test]
    fn wire_caps_reject_abusive_requests() {
        // an in-cap request parses; one past either cap is refused at
        // parse time with the client id echoed
        assert!(parse_line(&format!(r#"{{"n": {MAX_WIRE_SAMPLES}}}"#)).is_ok());
        let e = parse_line(&format!(
            r#"{{"id": 3, "n": {}}}"#, MAX_WIRE_SAMPLES + 1)).unwrap_err();
        assert_eq!(e.id, 3);
        assert!(e.msg.contains("cap"), "{}", e.msg);
        let e = parse_line(&format!(
            r#"{{"solver": "euler", "steps": {}}}"#, MAX_WIRE_STEPS + 1))
            .unwrap_err();
        assert!(e.msg.contains("steps"), "{}", e.msg);
    }

    #[test]
    fn read_reply_reads_one_line_and_flags_eof() {
        let data = format!("{}\nleftover", status_line(4, Status::Error, "x"));
        let mut r = std::io::BufReader::new(data.as_bytes());
        let reply = read_reply(&mut r).unwrap();
        assert_eq!((reply.id, reply.status), (4, Status::Error));
        // EOF mid-stream is an error, not a hang or a default reply
        let mut empty = std::io::BufReader::new(&b""[..]);
        assert!(read_reply(&mut empty).is_err());
    }

    #[test]
    fn ok_line_roundtrips_samples_bitwise() {
        let resp = GenResponse {
            id: 9,
            samples: vec![1.5, -2.25, 0.0, 3.125],
            images: None,
            wall_latency_s: 0.25,
            hw_latency_s: 1e-3,
            hw_energy_j: 2e-6,
        };
        let line = ok_line(7, 2, &resp);
        let r = parse_reply(&line).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.status, Status::Ok);
        assert_eq!(r.dim, 2);
        for (a, b) in r.samples.iter().zip(&resp.samples) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(r.wall_latency_s, 0.25);
    }

    #[test]
    fn reject_lines_carry_status_and_queue_numbers() {
        let e = SubmitError::Overloaded {
            backend: "analog".into(),
            queued_samples: 96,
            queue_depth: 128,
            retry_after_ms: 350,
        };
        let r = parse_reply(&reject_line(5, &e)).unwrap();
        assert_eq!(r.status, Status::Overloaded);
        assert_eq!(r.queued_samples, Some(96));
        assert_eq!(r.queue_depth, Some(128));
        assert_eq!(r.retry_after_ms, Some(350), "backoff hint rides the wire");
        assert!(r.error.unwrap().contains("overloaded"));

        let r = parse_reply(&reject_line(5, &SubmitError::ShuttingDown)).unwrap();
        assert_eq!(r.status, Status::ShuttingDown);

        let r = parse_reply(&reject_line(
            5, &SubmitError::Invalid("n_samples must be > 0".into()))).unwrap();
        assert_eq!(r.status, Status::Error);
    }

    #[test]
    fn shutdown_ack_parses_as_ok() {
        let r = parse_reply(&shutdown_ack_line()).unwrap();
        assert_eq!(r.status, Status::Ok);
        assert!(r.samples.is_empty());
    }

    #[test]
    fn enqueue_line_roundtrips_job_fields() {
        let line = enqueue_line(8, TaskKind::Letter(0), 5,
                                SolverChoice::DigitalOde { steps: 40 }, 1.0,
                                false, 2500, Some(3), Some(60_000));
        let WireMsg::Enqueue { client_id, req, defer_ms, max_retries, ttl_ms } =
            parse_line(&line).unwrap()
        else { panic!("expected enqueue") };
        assert_eq!(client_id, 8);
        assert_eq!(req.task, TaskKind::Letter(0));
        assert_eq!(req.n_samples, 5);
        assert_eq!(req.solver, SolverChoice::DigitalOde { steps: 40 });
        assert_eq!(defer_ms, 2500);
        assert_eq!(max_retries, Some(3));
        assert_eq!(ttl_ms, Some(60_000));
        // omitted knobs come back None (server defaults)
        let line = enqueue_line(8, TaskKind::Circle, 1, SolverChoice::AnalogOde,
                                0.0, false, 0, None, None);
        let WireMsg::Enqueue { defer_ms, max_retries, ttl_ms, .. } =
            parse_line(&line).unwrap()
        else { panic!() };
        assert_eq!((defer_ms, max_retries, ttl_ms), (0, None, None));
        // the wire caps guard enqueue exactly like plain requests
        assert!(parse_line(&format!(
            r#"{{"op":"enqueue","n":{}}}"#, MAX_WIRE_SAMPLES + 1)).is_err());
    }

    #[test]
    fn stats_op_roundtrips() {
        let WireMsg::Stats { client_id } =
            parse_line(&stats_line(6)).unwrap()
        else { panic!("expected stats") };
        assert_eq!(client_id, 6);
        // the reply line is a parseable object carrying both renderings
        let stats = Json::parse(
            r#"{"requests": 3, "jobs": {"queued": 1}}"#).unwrap();
        let line = stats_reply_line(6, stats, "memdiff_requests_total 3\n");
        let r = parse_reply(&line).unwrap();
        assert_eq!((r.id, r.status), (6, Status::Ok));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("stats").and_then(|s| s.get("requests"))
                    .and_then(|v| v.as_usize()), Some(3));
        assert_eq!(j.get("stats").and_then(|s| s.get("jobs"))
                    .and_then(|g| g.get("queued"))
                    .and_then(|v| v.as_usize()), Some(1));
        assert!(j.get("prometheus").and_then(|v| v.as_str()).unwrap()
                 .contains("memdiff_requests_total"));
    }

    #[test]
    fn dump_op_roundtrips() {
        let WireMsg::Dump { client_id } =
            parse_line(&dump_line(11)).unwrap()
        else { panic!("expected dump") };
        assert_eq!(client_id, 11);
        let dump = Json::parse(
            r#"{"reason": "manual", "fingerprint": "d", "stats": {}}"#)
            .unwrap();
        let line = dump_reply_line(11, "/var/lib/memdiff/flightrec/1-manual.json",
                                   dump);
        let r = parse_reply(&line).unwrap();
        assert_eq!((r.id, r.status), (11, Status::Ok));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("op").and_then(|v| v.as_str()), Some("dump"));
        assert!(j.get("path").and_then(|v| v.as_str()).unwrap()
                 .ends_with("manual.json"));
        assert_eq!(j.get("dump").and_then(|d| d.get("reason"))
                    .and_then(|v| v.as_str()), Some("manual"));
    }

    #[test]
    fn health_op_roundtrips_all_actions() {
        let WireMsg::Health { client_id, action } =
            parse_line(&health_line(9, HealthAction::Status)).unwrap()
        else { panic!("expected health") };
        assert_eq!(client_id, 9);
        assert_eq!(action, HealthAction::Status);
        // a bare {"op":"health"} is a status query too
        assert!(matches!(parse_line(r#"{"op":"health"}"#).unwrap(),
                         WireMsg::Health { action: HealthAction::Status, .. }));
        let WireMsg::Health { action, .. } =
            parse_line(&health_line(9, HealthAction::Age { dt_s: 1e9 })).unwrap()
        else { panic!() };
        assert_eq!(action, HealthAction::Age { dt_s: 1e9 });
        let WireMsg::Health { action, .. } =
            parse_line(&health_line(9, HealthAction::Reprogram)).unwrap()
        else { panic!() };
        assert_eq!(action, HealthAction::Reprogram);
        // age without dt_s and unknown verbs echo the client id back
        let e = parse_line(r#"{"op":"health","id":5,"action":"age"}"#)
            .unwrap_err();
        assert_eq!(e.id, 5);
        assert!(e.msg.contains("dt_s"), "{}", e.msg);
        let e = parse_line(r#"{"op":"health","id":5,"action":"explode"}"#)
            .unwrap_err();
        assert!(e.msg.contains("unknown health action"), "{}", e.msg);
    }

    #[test]
    fn health_reply_line_carries_the_monitor_state() {
        let health = Json::parse(
            r#"{"healthy": false,
                "alerts": [{"name": "drift:analog", "firing": true}]}"#)
            .unwrap();
        let line = health_reply_line(9, health);
        let r = parse_reply(&line).unwrap();
        assert_eq!((r.id, r.status), (9, Status::Ok));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("op").and_then(|v| v.as_str()), Some("health"));
        let h = j.get("health").unwrap();
        assert_eq!(h.get("healthy"), Some(&Json::Bool(false)));
        assert_eq!(h.get("alerts").and_then(|a| a.as_arr()).and_then(|a| a.first())
                    .and_then(|a| a.get("name")).and_then(|v| v.as_str()),
                   Some("drift:analog"));
    }

    #[test]
    fn job_ops_parse_and_require_ids() {
        let WireMsg::JobStatus { client_id, job } =
            parse_line(&job_op_line("status", 2, 17)).unwrap()
        else { panic!() };
        assert_eq!((client_id, job), (2, 17));
        let WireMsg::JobCancel { job, .. } =
            parse_line(&job_op_line("cancel", 2, 17)).unwrap()
        else { panic!() };
        assert_eq!(job, 17);
        let WireMsg::JobResult { job, wait_ms, .. } =
            parse_line(&result_line(2, 17, 5000)).unwrap()
        else { panic!() };
        assert_eq!((job, wait_ms), (17, 5000));
        let e = parse_line(r#"{"op":"status","id":4}"#).unwrap_err();
        assert_eq!(e.id, 4);
        assert!(e.msg.contains("requires a job id"), "{}", e.msg);
    }

    #[test]
    fn job_reply_lines_roundtrip() {
        use crate::jobs::store::{Job, JobResult, JobState};
        let r = parse_reply(&enqueue_ack_line(3, 17)).unwrap();
        assert_eq!((r.id, r.status), (3, Status::Ok));
        assert_eq!(r.job, Some(17));
        assert_eq!(r.state.as_deref(), Some("queued"));

        let mut job = Job {
            id: 17,
            task: TaskKind::Circle,
            n_samples: 2,
            solver: SolverChoice::AnalogOde,
            guidance: 0.0,
            decode: false,
            state: JobState::Failed,
            attempts: 2,
            max_retries: 4,
            run_at_ms: 0,
            ttl_ms: 1000,
            expire_at_ms: 0,
            error: Some("transient".into()),
            result: None,
            cancel_requested: false,
            trace: crate::obs::TraceId::NONE,
        };
        let r = parse_reply(&job_status_line(3, &job)).unwrap();
        assert_eq!(r.state.as_deref(), Some("failed"));
        assert_eq!(r.attempts, Some(2));
        assert!(r.error.unwrap().contains("transient"));
        // result op on a non-terminal job answers the pollable snapshot
        let r = parse_reply(&job_result_line(3, &job)).unwrap();
        assert_eq!(r.status, Status::Ok);
        assert_eq!(r.state.as_deref(), Some("failed"));

        job.state = JobState::Done;
        job.result = Some(JobResult {
            samples: vec![1.0, 2.0, 3.0, 4.0],
            images: None,
            wall_latency_s: 0.5,
            hw_latency_s: 1e-3,
            hw_energy_j: 2e-6,
        });
        let r = parse_reply(&job_result_line(3, &job)).unwrap();
        assert_eq!(r.status, Status::Ok);
        assert_eq!(r.state.as_deref(), Some("done"));
        assert_eq!(r.dim, 2);
        assert_eq!(r.samples, vec![1.0, 2.0, 3.0, 4.0]);

        job.state = JobState::Dead;
        let r = parse_reply(&job_result_line(3, &job)).unwrap();
        assert_eq!(r.status, Status::Error);
        assert_eq!(r.state.as_deref(), Some("dead"));

        let r = parse_reply(&job_unknown_line(3, 99)).unwrap();
        assert_eq!(r.status, Status::Error);
        assert!(r.error.unwrap().contains("unknown job 99"));
    }
}
