//! Async serving front-end: nonblocking ingress, response tickets, and
//! per-lane backpressure over the routed [`Service`](crate::coordinator::Service).
//!
//! The coordinator turns the paper's solvers into a routed service; this
//! module turns that service into a **deployable server**.  It is
//! std-only (no tokio/epoll): blocking threads at the edge, a
//! nonblocking core in the middle.
//!
//! ## The flow of one request
//!
//! ```text
//!            TCP line (JSON)             class route        bounded lane
//! client ──▶ connection handler ──▶ Service::submit_nb ──▶ Batcher queue
//!               │       ▲                  │ reject: Overloaded /
//!               │       │ Notify waker     │         ShuttingDown /
//!               │       │                  ▼         Unroutable
//!               │   Ticket ◀── TicketBoard.complete ◀── backend worker
//!               ▼
//!            response line (id-correlated, completion order)
//! ```
//!
//! * [`ticket`] — [`Ticket`](ticket::Ticket) /
//!   [`TicketBoard`](ticket::TicketBoard): per-lane completion maps
//!   replacing the old global blocking response map.  Poll
//!   (`try_recv`), wait with a deadline (`recv_deadline` /
//!   `recv_timeout`), block (`recv`), or register a shared
//!   [`Notify`](ticket::Notify) waker to sleep on many tickets at once.
//! * [`admission`] — the structured
//!   [`SubmitError`](admission::SubmitError) taxonomy (`Overloaded` is
//!   the 429-style shed signal from a full bounded lane) and the
//!   [`ConnGate`](admission::ConnGate) connection cap at the TCP edge.
//! * [`protocol`] — the line-delimited JSON wire format (request /
//!   response schema including the `overloaded` and `shutting_down`
//!   statuses; see the module docs for the exact schema).
//! * [`connection`] — the [`FrontEnd`](connection::FrontEnd): acceptor +
//!   capped connection handlers, every one of them driving only the
//!   nonblocking core, with graceful drain wired through to
//!   `Service::shutdown` (in-flight tickets complete; new connections
//!   and requests get `shutting_down`).
//!
//! ## Backpressure contract
//!
//! Every batcher lane is **bounded** (`[service] queue_depth`, samples;
//! per-backend `<backend>_queue` overrides in `[deploy]`).  A full lane
//! rejects at admission — `submit_nb` returns
//! `SubmitError::Overloaded` *without blocking* and without touching
//! any other lane, the service `rejected` counter and the backend's
//! `rej`/queue gauges record it, and the caller holds no dangling
//! ticket.  A slow analog lane therefore sheds its own overload while
//! the digital lanes keep serving — overload is surfaced, never hidden
//! in an unbounded queue.
//!
//! ## Durable jobs
//!
//! With `--state-dir DIR`, the front-end also hosts the
//! [`crate::jobs`] layer: `enqueue`/`status`/`result`/`cancel` wire ops
//! give submit-now/fetch-later semantics backed by an fsync'd log —
//! an acknowledged job survives SIGKILL and is re-run (or its retained
//! result served) after restart.  The long-poll `result` op rides the
//! same per-connection [`Notify`](ticket::Notify) waker the tickets
//! use, and `overloaded` rejects carry a `retry_after_ms` hint derived
//! from the lane's drain rate so both remote clients and the job
//! runner's backoff adapt to actual throughput.
//!
//! Run the server with `memdiff serve --listen 127.0.0.1:7979` (add
//! `--state-dir state/` for durable jobs) and drive it with
//! `memdiff client --connect 127.0.0.1:7979` (a scripted mixed-class
//! load generator speaking this protocol; `--enqueue`/`--fetch` for
//! the job ops).

pub mod admission;
pub mod connection;
pub mod protocol;
pub mod ticket;

pub use admission::{ConnGate, SubmitError};
pub use connection::{FrontEnd, FrontEndConfig};
pub use protocol::{parse_reply, Status, WireReply};
pub use ticket::{Notify, Ticket, TicketBoard};
