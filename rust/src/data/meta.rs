//! Typed access to `artifacts/meta.json` (the build manifest emitted by
//! `python/compile/aot.py`): artifact IO specs, schedule constants, class
//! statistics, and the training-time quality gates.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context};

use crate::diffusion::schedule::VpSchedule;
use crate::util::json::Json;

/// One AOT artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
}

/// Parsed meta.json.
#[derive(Debug, Clone)]
pub struct Meta {
    pub sched: VpSchedule,
    pub hidden: usize,
    pub dim: usize,
    pub n_classes: usize,
    pub class_centers: Vec<[f32; 2]>,
    pub latent_class_means: Vec<[f32; 2]>,
    pub latent_class_stds: Vec<[f32; 2]>,
    pub batches: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub kl_uncond_gate: f64,
}

fn pairs(j: &Json, key: &str) -> anyhow::Result<Vec<[f32; 2]>> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("missing '{key}'"))?
        .iter()
        .map(|row| {
            let r = row.as_arr().ok_or_else(|| anyhow!("'{key}' row not array"))?;
            Ok([
                r[0].as_f64().unwrap_or(f64::NAN) as f32,
                r[1].as_f64().unwrap_or(f64::NAN) as f32,
            ])
        })
        .collect()
}

impl Meta {
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).context("parsing meta.json")?;
        let sj = j.get("schedule").ok_or_else(|| anyhow!("missing schedule"))?;
        let num = |o: &Json, k: &str| -> anyhow::Result<f64> {
            o.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("missing number '{k}'"))
        };
        let sched = VpSchedule {
            beta_min: num(sj, "beta_min")?,
            beta_max: num(sj, "beta_max")?,
            t_end: num(sj, "t_end")?,
            eps_t: num(sj, "eps_t")?,
        };
        let mj = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact '{name}' missing file"))?
                .to_string();
            let inputs = spec
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("artifact '{name}' missing inputs"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect()
                })
                .collect();
            artifacts.insert(name.clone(), ArtifactSpec { file, inputs });
        }
        Ok(Meta {
            sched,
            hidden: num(mj, "hidden")? as usize,
            dim: num(mj, "dim")? as usize,
            n_classes: num(mj, "n_classes")? as usize,
            class_centers: pairs(&j, "class_centers")?,
            latent_class_means: pairs(&j, "latent_class_means")?,
            latent_class_stds: pairs(&j, "latent_class_stds")?,
            artifacts,
            batches: j
                .get("batches")
                .and_then(|b| b.as_arr())
                .ok_or_else(|| anyhow!("missing batches"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            kl_uncond_gate: j
                .get("quality")
                .and_then(|q| q.get("kl_uncond_ode200"))
                .and_then(|v| v.as_f64())
                .unwrap_or(f64::NAN),
        })
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_json(&text)
    }

    /// Default artifacts directory (crate root / artifacts).
    pub fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load from the default location.
    pub fn load_default() -> anyhow::Result<Self> {
        Self::load(Self::artifacts_dir().join("meta.json"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_meta_if_present() {
        let p = Meta::artifacts_dir().join("meta.json");
        if !p.exists() {
            return;
        }
        let m = Meta::load(p).unwrap();
        assert_eq!(m.dim, 2);
        assert_eq!(m.hidden, 14);
        assert_eq!(m.n_classes, 3);
        assert_eq!(m.class_centers.len(), 3);
        assert_eq!(m.latent_class_means.len(), 3);
        assert!(m.batches.contains(&1) && m.batches.contains(&64));
        assert!(m.artifacts.contains_key("step_uncond_b64"));
        assert!(m.kl_uncond_gate < 0.8);
    }

    #[test]
    fn rejects_incomplete_meta() {
        assert!(Meta::from_json("{}").is_err());
        assert!(Meta::from_json(r#"{"schedule": {"beta_min": 0.001}}"#).is_err());
    }
}
