//! The unconditional target distribution of Fig. 3: a circle of radius 1
//! (software units = 0.1 V) with small radial jitter — rust mirror of
//! `python/compile/datasets.sample_circle`.

use crate::util::rng::Rng;

pub const RADIUS: f64 = 1.0;
pub const RADIAL_STD: f64 = 0.05;

/// `n` interleaved 2-D ground-truth points.
pub fn sample_circle(n: usize, rng: &mut Rng) -> Vec<f32> {
    sample_circle_with(n, RADIUS, RADIAL_STD, rng)
}

pub fn sample_circle_with(n: usize, radius: f64, radial_std: f64,
                          rng: &mut Rng) -> Vec<f32> {
    let mut out = Vec::with_capacity(2 * n);
    for _ in 0..n {
        let theta = rng.uniform_range(0.0, 2.0 * std::f64::consts::PI);
        let r = radius + radial_std * rng.gaussian();
        out.push((r * theta.cos()) as f32);
        out.push((r * theta.sin()) as f32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn radius_statistics() {
        let mut rng = Rng::new(0);
        let pts = sample_circle(50_000, &mut rng);
        let radii: Vec<f32> = pts
            .chunks_exact(2)
            .map(|p| (p[0] * p[0] + p[1] * p[1]).sqrt())
            .collect();
        assert!((stats::mean(&radii) - 1.0).abs() < 0.01);
        assert!((stats::std(&radii) - 0.05).abs() < 0.01);
    }

    #[test]
    fn angles_uniform() {
        let mut rng = Rng::new(1);
        let pts = sample_circle(50_000, &mut rng);
        let mut quad = [0usize; 4];
        for p in pts.chunks_exact(2) {
            let q = match (p[0] >= 0.0, p[1] >= 0.0) {
                (true, true) => 0,
                (false, true) => 1,
                (false, false) => 2,
                (true, false) => 3,
            };
            quad[q] += 1;
        }
        for &c in &quad {
            assert!((c as f64 / 50_000.0 - 0.25).abs() < 0.01);
        }
    }

    #[test]
    fn kl_of_truth_vs_truth_is_small() {
        let mut rng = Rng::new(2);
        let a = sample_circle(30_000, &mut rng);
        let b = sample_circle(30_000, &mut rng);
        let kl = stats::kl_points(&a, &b, 24, 2.0);
        assert!(kl < 0.02, "kl={kl}");
    }
}
