//! Ground-truth data for evaluation: the 2-D circular distribution
//! (Fig. 3) and the latent-space class clusters of the letters task
//! (Fig. 4), plus meta.json access.

pub mod circle;
pub mod meta;

pub use circle::sample_circle;
pub use meta::Meta;
