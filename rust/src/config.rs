//! Configuration: a small INI-style `key = value` parser with sections,
//! plus the typed [`Config`] the CLI and examples consume.
//!
//! Example file (see `memdiff.toml.example` in the repo root):
//!
//! ```text
//! [service]
//! workers = 4
//! max_batch = 64
//! linger_ms = 2
//! queue_depth = 512  # per-lane queue bound in samples (0 = unbounded)
//! threads = 0        # intra-op pool threads (0 = auto / RUST_PALLAS_THREADS)
//! par = auto         # serial | banks | lanes | auto
//! kernel = f32       # f32 | quant — MVM kernel lane for every backend
//!                    # (per-backend <backend>_kernel keys in [deploy] override)
//!
//! [solver]
//! substeps = 2000
//! guidance = 2.0
//!
//! [deploy]
//! analog = analog      # backend for the analog solver family
//! digital = rust       # rust | hlo (per-class keys like digital_cond work too)
//! analog_workers = 2   # per-backend worker counts (0 = [service] workers)
//! rust_workers = 2
//! analog_queue = 128   # per-backend lane bound in samples (0 = queue_depth)
//! rust_weights = w.json  # per-backend weight path (default: standard artifacts)
//! analog_kernel = quant  # per-backend MVM kernel lane ([service] kernel default)
//!
//! [jobs]
//! max_retries = 4        # retry budget per job (runs at most budget+1 times)
//! backoff_base_ms = 50   # first-retry backoff; doubles per attempt
//! backoff_max_ms = 5000  # backoff ceiling
//! result_ttl_ms = 900000 # retention of a terminal job's result/error
//! checkpoint_every = 256 # log records between snapshot compactions
//!
//! [obs]
//! enabled = true         # master switch; off = one atomic load per probe
//! ring_capacity = 4096   # span-ring slots (overwrite-oldest, ~32 B each)
//! jsonl_flush_ms = 10000 # metrics.jsonl flush period under --state-dir (0 = off)
//!
//! [health]
//! enabled = true           # master switch for the analog health monitor
//! tick_ms = 200            # monitor cadence (drift refresh + rule eval)
//! retention_dt_s = 0       # simulated drift seconds applied per tick (0 = off)
//! drift_alert_ms = 0.0004  # mean |dG| (mS) that latches drift:<backend>
//! clear_frac = 0.5         # hysteresis: clear below threshold * clear_frac
//! stuck_cell_pct = 1.0     # stuck-cell % that latches stuck:<backend>
//! probe_interval_ms = 30000  # self-test cadence (0 = on demand only)
//! probe_samples = 800      # samples per probe / oracle cloud
//! probe_steps = 100        # Euler steps for digital probes + oracle
//! probe_streak = 2         # consecutive breaches before a probe alert
//! kl_budget_analog_uncond = 1.2   # per-class KL gates (probe vs oracle)
//! kl_budget_analog_cond = 1.2
//! kl_budget_digital_uncond = 1.0
//! kl_budget_digital_cond = 1.0
//! reprogram_on_drift = false  # auto-heal: write-verify on a drift alert
//! reprogram_tol_ms = 0.0015   # write-verify tolerance (mS)
//!
//! [slo]
//! enabled = true          # master switch for the latency SLO engine
//! p99_ms_digital = 50     # family shorthand: seeds both digital classes
//! p99_ms_analog = 200     # family shorthand: seeds both analog classes
//! p99_ms_digital_cond = 80  # per-class keys win over the family shorthand
//! target_frac = 0.99      # fraction that must finish inside the objective
//! fast_window_ms = 60000  # fast burn window (responsiveness)
//! slow_window_ms = 1800000  # slow burn window (sustained-breach confirm)
//! burn_threshold = 2.0    # burn rate that latches slo:<backend>:<class>
//! clear_frac = 0.5        # hysteresis: clear below threshold * clear_frac
//! streak = 1              # consecutive breaching ticks before the latch
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context};

/// Parsed raw config: section → key → value.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                cfg.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(k.trim().to_string(), v.trim().to_string());
            } else {
                return Err(anyhow!("line {}: expected 'key = value'", lineno + 1));
            }
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Every `key = value` entry of a section, in file-stable (sorted)
    /// order — used by table-shaped sections like `[deploy]` whose key set
    /// is open-ended.
    pub fn section_entries(&self, section: &str) -> Vec<(&str, &str)> {
        self.sections
            .get(section)
            .map(|kvs| {
                kvs.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect()
            })
            .unwrap_or_default()
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, section: &str, key: &str)
                                            -> anyhow::Result<Option<T>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("[{section}] {key} = {s:?}: parse error")),
        }
    }
}

/// Typed configuration with defaults.
#[derive(Debug, Clone)]
pub struct Config {
    pub workers: usize,
    pub max_batch: usize,
    pub linger_ms: u64,
    /// Per-lane queue bound in samples (0 = unbounded).  The serving
    /// front-end's backpressure knob: a lane whose queued samples would
    /// exceed this sheds the request with an `Overloaded` reject instead
    /// of queueing it.  Per-backend `<backend>_queue` keys in `[deploy]`
    /// override it lane by lane.
    pub queue_depth: usize,
    /// Intra-op pool threads (0 = auto: `RUST_PALLAS_THREADS` if set, else
    /// sized against `workers` — see [`crate::exec`]).
    pub threads: usize,
    /// Bank-parallel strategy for the crossbar/net forward paths.
    pub par: crate::exec::ParStrategy,
    /// MVM kernel lane every backend defaults to (`f32` | `quant`);
    /// per-backend `<backend>_kernel` keys in `[deploy]` override it.
    pub kernel: crate::util::KernelMode,
    pub substeps: usize,
    pub guidance: f32,
    pub seed: u64,
    pub artifacts_dir: Option<String>,
    /// Deployment table from the `[deploy]` section: request class →
    /// backend plus per-backend worker counts (see
    /// [`crate::coordinator::deploy::DeployPlan`]).  Default routes
    /// analog classes to the analog simulator and digital classes to the
    /// rust baseline.
    pub deploy: crate::coordinator::DeployPlan,
    /// Durable-job-queue knobs from the `[jobs]` section (used only when
    /// the server runs with `--state-dir`).
    pub jobs: JobsConfig,
    /// Observability knobs from the `[obs]` section (tracing ring size,
    /// master enable switch, JSONL flush cadence — see [`crate::obs`]).
    pub obs: crate::obs::ObsConfig,
    /// Analog health-monitor knobs from the `[health]` section (drift
    /// thresholds, probe cadence, per-class KL budgets — see
    /// [`crate::obs::health`]).
    pub health: crate::obs::HealthConfig,
    /// Latency-SLO knobs from the `[slo]` section (per-class p99
    /// objectives, burn windows, latch thresholds — see
    /// [`crate::obs::slo`]).
    pub slo: crate::obs::SloConfig,
}

/// Typed `[jobs]` section — the config-file surface of
/// [`crate::jobs::RunnerConfig`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobsConfig {
    pub max_retries: u32,
    pub backoff_base_ms: u64,
    pub backoff_max_ms: u64,
    pub result_ttl_ms: u64,
    pub checkpoint_every: usize,
}

impl Default for JobsConfig {
    fn default() -> Self {
        JobsConfig {
            max_retries: 4,
            backoff_base_ms: 50,
            backoff_max_ms: 5000,
            result_ttl_ms: 900_000,
            checkpoint_every: 256,
        }
    }
}

impl JobsConfig {
    /// Lower into the runner's tuning (sweep/drain cadences keep the
    /// runner defaults — they are operational, not workload, knobs).
    pub fn runner_config(&self) -> crate::jobs::RunnerConfig {
        use std::time::Duration;
        crate::jobs::RunnerConfig {
            max_retries: self.max_retries,
            backoff_base: Duration::from_millis(self.backoff_base_ms),
            backoff_max: Duration::from_millis(self.backoff_max_ms),
            result_ttl: Duration::from_millis(self.result_ttl_ms),
            checkpoint_every: self.checkpoint_every,
            ..crate::jobs::RunnerConfig::default()
        }
    }
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: 2,
            max_batch: 64,
            linger_ms: 2,
            queue_depth: 512,
            threads: 0,
            par: crate::exec::ParStrategy::Auto,
            kernel: crate::util::KernelMode::F32,
            substeps: 2000,
            guidance: 2.0,
            seed: 7,
            artifacts_dir: None,
            deploy: crate::coordinator::DeployPlan::default(),
            jobs: JobsConfig::default(),
            obs: crate::obs::ObsConfig::default(),
            health: crate::obs::HealthConfig::default(),
            slo: crate::obs::SloConfig::default(),
        }
    }
}

impl Config {
    pub fn from_raw(raw: &RawConfig) -> anyhow::Result<Self> {
        let d = Config::default();
        Ok(Config {
            workers: raw.get_parsed("service", "workers")?.unwrap_or(d.workers),
            max_batch: raw.get_parsed("service", "max_batch")?.unwrap_or(d.max_batch),
            linger_ms: raw.get_parsed("service", "linger_ms")?.unwrap_or(d.linger_ms),
            queue_depth: raw
                .get_parsed("service", "queue_depth")?
                .unwrap_or(d.queue_depth),
            threads: raw.get_parsed("service", "threads")?.unwrap_or(d.threads),
            par: match raw.get("service", "par") {
                None => d.par,
                Some(s) => s
                    .parse()
                    .map_err(|e| anyhow!("[service] par = {s:?}: {e}"))?,
            },
            kernel: match raw.get("service", "kernel") {
                None => d.kernel,
                Some(s) => s
                    .parse()
                    .map_err(|e| anyhow!("[service] kernel = {s:?}: {e}"))?,
            },
            substeps: raw.get_parsed("solver", "substeps")?.unwrap_or(d.substeps),
            guidance: raw.get_parsed("solver", "guidance")?.unwrap_or(d.guidance),
            seed: raw.get_parsed("solver", "seed")?.unwrap_or(d.seed),
            artifacts_dir: raw.get("paths", "artifacts").map(String::from),
            deploy: {
                let mut plan = d.deploy;
                // [service] kernel seeds every backend's lane; per-backend
                // <backend>_kernel keys below override it
                if let Some(s) = raw.get("service", "kernel") {
                    let k = s
                        .parse()
                        .map_err(|e| anyhow!("[service] kernel = {s:?}: {e}"))?;
                    plan.set_base_kernel(k);
                }
                for (k, v) in raw.section_entries("deploy") {
                    plan.set(k, v)?;
                }
                plan
            },
            jobs: JobsConfig {
                max_retries: raw
                    .get_parsed("jobs", "max_retries")?
                    .unwrap_or(d.jobs.max_retries),
                backoff_base_ms: raw
                    .get_parsed("jobs", "backoff_base_ms")?
                    .unwrap_or(d.jobs.backoff_base_ms),
                backoff_max_ms: raw
                    .get_parsed("jobs", "backoff_max_ms")?
                    .unwrap_or(d.jobs.backoff_max_ms),
                result_ttl_ms: raw
                    .get_parsed("jobs", "result_ttl_ms")?
                    .unwrap_or(d.jobs.result_ttl_ms),
                checkpoint_every: raw
                    .get_parsed("jobs", "checkpoint_every")?
                    .unwrap_or(d.jobs.checkpoint_every),
            },
            obs: crate::obs::ObsConfig {
                enabled: raw
                    .get_parsed("obs", "enabled")?
                    .unwrap_or(d.obs.enabled),
                ring_capacity: raw
                    .get_parsed("obs", "ring_capacity")?
                    .unwrap_or(d.obs.ring_capacity),
                jsonl_flush_ms: raw
                    .get_parsed("obs", "jsonl_flush_ms")?
                    .unwrap_or(d.obs.jsonl_flush_ms),
            },
            health: {
                let h = d.health;
                let mut kl_budget = h.kl_budget;
                for (i, class) in
                    crate::coordinator::request::RequestClass::ALL.iter()
                        .enumerate()
                {
                    let key = format!("kl_budget_{}", class.name());
                    if let Some(v) = raw.get_parsed("health", &key)? {
                        kl_budget[i] = v;
                    }
                }
                crate::obs::HealthConfig {
                    enabled: raw
                        .get_parsed("health", "enabled")?
                        .unwrap_or(h.enabled),
                    tick_ms: raw
                        .get_parsed("health", "tick_ms")?
                        .unwrap_or(h.tick_ms),
                    retention_dt_s: raw
                        .get_parsed("health", "retention_dt_s")?
                        .unwrap_or(h.retention_dt_s),
                    drift_alert_ms: raw
                        .get_parsed("health", "drift_alert_ms")?
                        .unwrap_or(h.drift_alert_ms),
                    clear_frac: raw
                        .get_parsed("health", "clear_frac")?
                        .unwrap_or(h.clear_frac),
                    stuck_cell_pct: raw
                        .get_parsed("health", "stuck_cell_pct")?
                        .unwrap_or(h.stuck_cell_pct),
                    probe_interval_ms: raw
                        .get_parsed("health", "probe_interval_ms")?
                        .unwrap_or(h.probe_interval_ms),
                    probe_samples: raw
                        .get_parsed("health", "probe_samples")?
                        .unwrap_or(h.probe_samples),
                    probe_steps: raw
                        .get_parsed("health", "probe_steps")?
                        .unwrap_or(h.probe_steps),
                    probe_seed: raw
                        .get_parsed("health", "probe_seed")?
                        .unwrap_or(h.probe_seed),
                    probe_streak: raw
                        .get_parsed("health", "probe_streak")?
                        .unwrap_or(h.probe_streak),
                    kl_budget,
                    reprogram_on_drift: raw
                        .get_parsed("health", "reprogram_on_drift")?
                        .unwrap_or(h.reprogram_on_drift),
                    reprogram_tol_ms: raw
                        .get_parsed("health", "reprogram_tol_ms")?
                        .unwrap_or(h.reprogram_tol_ms),
                }
            },
            slo: {
                let s = d.slo;
                let mut p99_ms = s.p99_ms;
                for (i, class) in
                    crate::coordinator::request::RequestClass::ALL.iter()
                        .enumerate()
                {
                    // family shorthand seeds both classes of the family;
                    // a per-class key wins over it
                    let family = class.name()
                        .split('_')
                        .next()
                        .unwrap_or_default();
                    let fam_key = format!("p99_ms_{family}");
                    if let Some(v) = raw.get_parsed("slo", &fam_key)? {
                        p99_ms[i] = v;
                    }
                    let key = format!("p99_ms_{}", class.name());
                    if let Some(v) = raw.get_parsed("slo", &key)? {
                        p99_ms[i] = v;
                    }
                }
                crate::obs::SloConfig {
                    enabled: raw
                        .get_parsed("slo", "enabled")?
                        .unwrap_or(s.enabled),
                    p99_ms,
                    target_frac: raw
                        .get_parsed("slo", "target_frac")?
                        .unwrap_or(s.target_frac),
                    fast_window_ms: raw
                        .get_parsed("slo", "fast_window_ms")?
                        .unwrap_or(s.fast_window_ms),
                    slow_window_ms: raw
                        .get_parsed("slo", "slow_window_ms")?
                        .unwrap_or(s.slow_window_ms),
                    burn_threshold: raw
                        .get_parsed("slo", "burn_threshold")?
                        .unwrap_or(s.burn_threshold),
                    clear_frac: raw
                        .get_parsed("slo", "clear_frac")?
                        .unwrap_or(s.clear_frac),
                    streak: raw
                        .get_parsed("slo", "streak")?
                        .unwrap_or(s.streak),
                }
            },
        })
    }

    pub fn load_or_default(path: Option<&str>) -> anyhow::Result<Self> {
        match path {
            None => Ok(Config::default()),
            Some(p) => Config::from_raw(&RawConfig::load(p)?),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let raw = RawConfig::parse(
            "# comment\n[service]\nworkers = 4 # inline\nmax_batch=32\n\n[solver]\nguidance = 1.5\n",
        )
        .unwrap();
        assert_eq!(raw.get("service", "workers"), Some("4"));
        assert_eq!(raw.get("service", "max_batch"), Some("32"));
        assert_eq!(raw.get("solver", "guidance"), Some("1.5"));
        assert_eq!(raw.get("solver", "nope"), None);
    }

    #[test]
    fn typed_config_with_defaults() {
        let raw = RawConfig::parse("[service]\nworkers = 8\n").unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.max_batch, 64); // default
        assert_eq!(cfg.queue_depth, 512); // default: bounded lanes
        assert_eq!(cfg.substeps, 2000);
        assert_eq!(cfg.threads, 0); // auto
        assert_eq!(cfg.par, crate::exec::ParStrategy::Auto);
    }

    #[test]
    fn parallel_knobs_parse() {
        let raw =
            RawConfig::parse("[service]\nthreads = 4\npar = banks\n").unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.par, crate::exec::ParStrategy::Banks);
        let bad = RawConfig::parse("[service]\npar = rayon\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
    }

    #[test]
    fn deploy_section_parses_into_plan() {
        use crate::coordinator::deploy::BackendKind;
        use crate::coordinator::request::{RequestClass, SolverFamily};
        let raw = RawConfig::parse(
            "[deploy]\ndigital = hlo\ndigital_cond = rust\nanalog_workers = 3\n",
        )
        .unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        let uncond = RequestClass { family: SolverFamily::Digital, conditional: false };
        let cond = RequestClass { family: SolverFamily::Digital, conditional: true };
        assert_eq!(cfg.deploy.backend_for(uncond), BackendKind::Hlo);
        assert_eq!(cfg.deploy.backend_for(cond), BackendKind::Rust);
        assert_eq!(cfg.deploy.workers_for(BackendKind::Analog), 3);
        // default plan when the section is absent
        let plain = Config::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(plain.deploy, crate::coordinator::DeployPlan::default());
        // family mismatches and junk keys are config errors
        let bad = RawConfig::parse("[deploy]\nanalog = hlo\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
        let junk = RawConfig::parse("[deploy]\nteleport = analog\n").unwrap();
        assert!(Config::from_raw(&junk).is_err());
    }

    #[test]
    fn kernel_knob_parses_and_seeds_deploy_plan() {
        use crate::coordinator::deploy::BackendKind;
        use crate::util::KernelMode;
        // absent = f32 everywhere
        let plain = Config::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(plain.kernel, KernelMode::F32);
        for kind in BackendKind::ALL {
            assert_eq!(plain.deploy.kernel_for(kind), KernelMode::F32);
        }
        // [service] kernel seeds every backend; [deploy] overrides per backend
        let raw = RawConfig::parse(
            "[service]\nkernel = quant\n[deploy]\nrust_kernel = f32\n",
        )
        .unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert_eq!(cfg.kernel, KernelMode::Quant);
        assert_eq!(cfg.deploy.kernel_for(BackendKind::Analog), KernelMode::Quant);
        assert_eq!(cfg.deploy.kernel_for(BackendKind::Rust), KernelMode::F32);
        assert_eq!(cfg.deploy.kernel_for(BackendKind::Hlo), KernelMode::Quant);
        // i8 is an accepted spelling of the quant lane
        let i8_raw = RawConfig::parse("[service]\nkernel = i8\n").unwrap();
        assert_eq!(Config::from_raw(&i8_raw).unwrap().kernel, KernelMode::Quant);
        let bad = RawConfig::parse("[service]\nkernel = f16\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
        let bad_dep = RawConfig::parse("[deploy]\nanalog_kernel = f64\n").unwrap();
        assert!(Config::from_raw(&bad_dep).is_err());
    }

    #[test]
    fn queue_depth_parses() {
        let raw =
            RawConfig::parse("[service]\nqueue_depth = 96\n").unwrap();
        assert_eq!(Config::from_raw(&raw).unwrap().queue_depth, 96);
        let off = RawConfig::parse("[service]\nqueue_depth = 0\n").unwrap();
        assert_eq!(Config::from_raw(&off).unwrap().queue_depth, 0, "0 = unbounded");
        let bad = RawConfig::parse("[service]\nqueue_depth = deep\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
    }

    #[test]
    fn jobs_section_parses_with_defaults() {
        let raw = RawConfig::parse(
            "[jobs]\nmax_retries = 7\nbackoff_base_ms = 25\nresult_ttl_ms = 60000\n",
        )
        .unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert_eq!(cfg.jobs.max_retries, 7);
        assert_eq!(cfg.jobs.backoff_base_ms, 25);
        assert_eq!(cfg.jobs.backoff_max_ms, 5000, "untouched keys keep defaults");
        assert_eq!(cfg.jobs.result_ttl_ms, 60_000);
        assert_eq!(cfg.jobs.checkpoint_every, 256);
        let rc = cfg.jobs.runner_config();
        assert_eq!(rc.max_retries, 7);
        assert_eq!(rc.backoff_base, std::time::Duration::from_millis(25));
        // absent section = all defaults
        let plain = Config::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(plain.jobs, JobsConfig::default());
        let bad = RawConfig::parse("[jobs]\nmax_retries = many\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
    }

    #[test]
    fn obs_section_parses_with_defaults() {
        let raw = RawConfig::parse(
            "[obs]\nenabled = false\nring_capacity = 1024\n",
        )
        .unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert!(!cfg.obs.enabled);
        assert_eq!(cfg.obs.ring_capacity, 1024);
        assert_eq!(cfg.obs.jsonl_flush_ms, 10_000, "untouched keys keep defaults");
        // absent section = all defaults (enabled by default)
        let plain = Config::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert!(plain.obs.enabled);
        assert_eq!(plain.obs.ring_capacity, 4096);
        let bad = RawConfig::parse("[obs]\nenabled = maybe\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
    }

    #[test]
    fn health_section_parses_with_defaults() {
        let raw = RawConfig::parse(
            "[health]\nretention_dt_s = 1e8\ndrift_alert_ms = 0.001\n\
             kl_budget_digital_cond = 0.8\nreprogram_on_drift = true\n",
        )
        .unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert_eq!(cfg.health.retention_dt_s, 1e8);
        assert_eq!(cfg.health.drift_alert_ms, 0.001);
        assert_eq!(cfg.health.kl_budget[3], 0.8, "digital_cond is index 3");
        assert!(cfg.health.reprogram_on_drift);
        let d = crate::obs::HealthConfig::default();
        assert_eq!(cfg.health.tick_ms, d.tick_ms, "untouched keys keep defaults");
        assert_eq!(cfg.health.kl_budget[0], d.kl_budget[0]);
        assert_eq!(cfg.health.probe_samples, d.probe_samples);
        // absent section = all defaults (monitor enabled, retention off)
        let plain = Config::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert!(plain.health.enabled);
        assert_eq!(plain.health.retention_dt_s, 0.0);
        let bad = RawConfig::parse("[health]\ntick_ms = fast\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
    }

    #[test]
    fn slo_section_parses_with_defaults() {
        let raw = RawConfig::parse(
            "[slo]\np99_ms_digital = 50\np99_ms_digital_cond = 80\n\
             target_frac = 0.95\nburn_threshold = 4.0\n",
        )
        .unwrap();
        let cfg = Config::from_raw(&raw).unwrap();
        assert_eq!(cfg.slo.p99_ms[2], 50.0, "family shorthand seeds digital_uncond");
        assert_eq!(cfg.slo.p99_ms[3], 80.0, "per-class key wins over shorthand");
        assert_eq!(cfg.slo.target_frac, 0.95);
        assert_eq!(cfg.slo.burn_threshold, 4.0);
        let s = crate::obs::SloConfig::default();
        assert_eq!(cfg.slo.p99_ms[0], s.p99_ms[0], "untouched analog keeps default");
        assert_eq!(cfg.slo.fast_window_ms, s.fast_window_ms);
        assert_eq!(cfg.slo.streak, s.streak);
        assert!(cfg.slo.enabled, "enabled by default");
        // absent section = all defaults
        let plain = Config::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(plain.slo, s);
        let bad = RawConfig::parse("[slo]\ntarget_frac = most\n").unwrap();
        assert!(Config::from_raw(&bad).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(RawConfig::parse("[unterminated\n").is_err());
        assert!(RawConfig::parse("no equals here\n").is_err());
        let raw = RawConfig::parse("[service]\nworkers = lots\n").unwrap();
        assert!(Config::from_raw(&raw).is_err());
    }

    #[test]
    fn empty_config_is_defaults() {
        let cfg = Config::load_or_default(None).unwrap();
        assert_eq!(cfg.workers, Config::default().workers);
    }
}
