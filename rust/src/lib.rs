//! # memdiff — resistive-memory neural differential-equation solver
//!
//! Production-grade reproduction of *"Resistive Memory-based Neural
//! Differential Equation Solver for Score-based Diffusion Model"*
//! (Yang et al., 2024) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator and analog-hardware substrate**:
//!
//! * [`device`] / [`crossbar`] — behavioural 180 nm RRAM simulator: 1T1R
//!   cells, 32×32 macros, write-verify programming, read/write noise,
//!   differential-pair analog matrix-vector multiplication, and macro-bank
//!   sharding ([`crossbar::bank`]) for layers wider than one array.
//! * [`analog`] — op-amp circuit blocks (TIA, diode-clamp ReLU, AD633
//!   multipliers, RC integrator) and the closed-loop continuous-time
//!   neural-ODE/SDE solver — the paper's core contribution.
//! * [`nn`] — the 3-layer analog score network assembled from crossbars.
//! * [`diffusion`] — VP-SDE schedule, digital baseline samplers
//!   (Euler–Maruyama / probability-flow Euler / Heun), classifier-free
//!   guidance.
//! * [`vae`] — the latent-diffusion pixel decoder (linear + 2 deconv).
//! * [`runtime`] — PJRT CPU client; loads the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text) and executes them.
//! * [`exec`] — deterministic bank-parallel execution: a std-only scoped
//!   worker pool with a fixed task→slot fork-join contract, so N-thread
//!   evaluation stays bitwise equal to the serial oracle.
//! * [`coordinator`] — generation service: request queue, dynamic batcher,
//!   worker scheduler, metrics.
//! * [`serve`] — async serving front-end over the coordinator:
//!   nonblocking `submit_nb` ingress with response tickets, per-lane
//!   bounded-queue backpressure, and a line-JSON TCP front-end
//!   (`memdiff serve --listen`) with graceful drain.
//! * [`jobs`] — durable job queue over the front-end: fsync'd append-only
//!   log + snapshot under `--state-dir`, crash recovery with torn-tail
//!   tolerance, retry with exponential backoff + jitter, TTL result
//!   retention, and submit-now/fetch-later wire ops.
//! * [`obs`] — end-to-end observability: request tracing with per-stage
//!   spans and tail-bucket trace exemplars, a bounded metrics registry
//!   exported as Prometheus text and JSON (`{"op":"stats"}`,
//!   `--metrics-listen`), hot-path phase timers that cost one atomic
//!   load when disabled, the analog health monitor + alert engine, a
//!   burn-rate latency SLO engine over the `[slo]` per-class p99
//!   objectives, and an incident flight recorder (`{"op":"dump"}`,
//!   auto-triggered black-box dumps under `--state-dir`).
//! * [`energy`] — analog-vs-digital latency & energy models behind the
//!   paper's Fig. 3f/3g/4g/4h comparisons.
//! * [`util`] — self-contained substrates (PRNG, JSON, tensors, stats,
//!   property-testing) — the offline build has no external crates beyond
//!   `xla`/`anyhow`/`thiserror`/`num-traits`.
//!
//! Python (JAX + Pallas) exists only on the build path; after
//! `make artifacts` the binary is self-contained.

pub mod analog;
pub mod config;
pub mod coordinator;
pub mod crossbar;
pub mod data;
pub mod device;
pub mod diffusion;
pub mod energy;
pub mod exec;
pub mod jobs;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod vae;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Software voltage unit: 0.1 V == 1.0 (paper Fig. 3).
pub const VOLT_UNIT: f64 = 0.1;
/// Protective clamp window in software units ([-0.2 V, 0.4 V]).
pub const V_CLAMP_LO: f32 = -2.0;
pub const V_CLAMP_HI: f32 = 4.0;

/// Clamp a voltage into the macro's protective window.
#[inline(always)]
pub fn clamp_voltage(v: f32) -> f32 {
    v.clamp(V_CLAMP_LO, V_CLAMP_HI)
}
