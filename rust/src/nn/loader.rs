//! Load the trained score-network weights exported by `aot.py`
//! (`artifacts/weights_{uncond,cond}.json`).

use std::path::Path;

use anyhow::{anyhow, Context};

use crate::crossbar::mapper::map_layer;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tensor::Mat;

/// Weight-space + conductance-space parameters of one trained score net.
#[derive(Debug, Clone)]
pub struct ScoreWeights {
    // weight space (software baseline)
    pub w1: Mat,
    pub b1: Vec<f32>,
    pub w2: Mat,
    pub b2: Vec<f32>,
    pub w3: Mat,
    pub b3: Vec<f32>,
    pub emb_w: Vec<f32>,
    pub cond_proj: Mat,
    // conductance space (deployment)
    pub g1: Mat,
    pub g2: Mat,
    pub g3: Mat,
    pub gains: [f32; 3],
}

fn tensor(obj: &Json, key: &str) -> anyhow::Result<(Vec<usize>, Vec<f32>)> {
    obj.get(key)
        .and_then(|v| v.as_tensor())
        .ok_or_else(|| anyhow!("missing/invalid tensor '{key}'"))
}

fn mat2(obj: &Json, key: &str) -> anyhow::Result<Mat> {
    let (shape, data) = tensor(obj, key)?;
    if shape.len() != 2 {
        return Err(anyhow!("'{key}' must be rank-2, got {shape:?}"));
    }
    Ok(Mat::from_vec(shape[0], shape[1], data))
}

fn vec1(obj: &Json, key: &str) -> anyhow::Result<Vec<f32>> {
    let (shape, data) = tensor(obj, key)?;
    if shape.len() != 1 {
        return Err(anyhow!("'{key}' must be rank-1, got {shape:?}"));
    }
    Ok(data)
}

impl ScoreWeights {
    /// Parse from JSON text.
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).context("parsing weights json")?;
        let scalars = j.get("scalars").ok_or_else(|| anyhow!("missing scalars"))?;
        let gain = |k: &str| -> anyhow::Result<f32> {
            scalars
                .get(k)
                .and_then(|v| v.as_f64())
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("missing scalar '{k}'"))
        };
        let w = ScoreWeights {
            w1: mat2(&j, "w1")?,
            b1: vec1(&j, "b1")?,
            w2: mat2(&j, "w2")?,
            b2: vec1(&j, "b2")?,
            w3: mat2(&j, "w3")?,
            b3: vec1(&j, "b3")?,
            emb_w: vec1(&j, "emb_w")?,
            cond_proj: mat2(&j, "cond_proj")?,
            g1: mat2(&j, "g1")?,
            g2: mat2(&j, "g2")?,
            g3: mat2(&j, "g3")?,
            gains: [gain("gain1")?, gain("gain2")?, gain("gain3")?],
        };
        w.validate()?;
        Ok(w)
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_json(&text)
    }

    /// Structural consistency checks.
    pub fn validate(&self) -> anyhow::Result<()> {
        let (din, h) = self.w1.shape();
        if self.w2.shape() != (h, h) {
            return Err(anyhow!("w2 shape {:?} != ({h},{h})", self.w2.shape()));
        }
        if self.w3.shape().0 != h {
            return Err(anyhow!("w3 rows != hidden"));
        }
        if self.w3.shape().1 != din {
            return Err(anyhow!("w3 cols != dim"));
        }
        if self.b1.len() != h || self.b2.len() != h || self.b3.len() != din {
            return Err(anyhow!("bias length mismatch"));
        }
        if self.emb_w.len() * 2 != h {
            return Err(anyhow!("emb_w len {} != hidden/2", self.emb_w.len()));
        }
        if self.cond_proj.cols() != h {
            return Err(anyhow!("cond_proj cols != hidden"));
        }
        for (g, w) in [(&self.g1, &self.w1), (&self.g2, &self.w2), (&self.g3, &self.w3)] {
            if g.shape() != w.shape() {
                return Err(anyhow!("conductance/weight shape mismatch"));
            }
        }
        Ok(())
    }

    /// Synthesize a random-but-valid `dim→hidden→hidden→dim` network with
    /// conductances produced by the real mapper, so both realizations
    /// deploy consistently.  This is the shared fixture for benches and
    /// the bank-sharding parity suite — `hidden` may exceed one macro
    /// width (it must be even for the sin/cos embedding split).
    pub fn synthetic(dim: usize, hidden: usize, n_classes: usize,
                     seed: u64) -> Self {
        assert!(hidden % 2 == 0, "hidden must be even (sin/cos embedding)");
        let mut rng = Rng::new(seed);
        let w1 = Mat::from_fn(dim, hidden, |_, _| 0.5 * rng.gaussian_f32());
        let w2 = Mat::from_fn(hidden, hidden, |_, _| 0.25 * rng.gaussian_f32());
        let w3 = Mat::from_fn(hidden, dim, |_, _| 0.5 * rng.gaussian_f32());
        let m1 = map_layer(&w1);
        let m2 = map_layer(&w2);
        let m3 = map_layer(&w3);
        let w = ScoreWeights {
            b1: (0..hidden).map(|_| 0.05 * rng.gaussian_f32()).collect(),
            b2: (0..hidden).map(|_| 0.05 * rng.gaussian_f32()).collect(),
            b3: (0..dim).map(|_| 0.05 * rng.gaussian_f32()).collect(),
            emb_w: (0..hidden / 2).map(|i| 0.5 * (i + 1) as f32).collect(),
            cond_proj: Mat::from_fn(n_classes, hidden,
                                    |_, _| 0.2 * rng.gaussian_f32()),
            g1: m1.g_target,
            g2: m2.g_target,
            g3: m3.g_target,
            gains: [m1.gain, m2.gain, m3.gain],
            w1,
            w2,
            w3,
        };
        w.validate().expect("synthetic weights must validate");
        w
    }

    pub fn dim(&self) -> usize {
        self.w1.shape().0
    }

    pub fn hidden(&self) -> usize {
        self.w1.shape().1
    }

    pub fn n_classes(&self) -> usize {
        self.cond_proj.rows()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Construct a tiny valid weights JSON for parser tests.
    pub(crate) fn tiny_json() -> String {
        fn t(shape: &[usize], v: f32) -> String {
            let n: usize = shape.iter().product();
            format!(
                "{{\"shape\": [{}], \"data\": [{}]}}",
                shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(","),
                vec![v.to_string(); n].join(",")
            )
        }
        format!(
            "{{\"w1\": {}, \"b1\": {}, \"w2\": {}, \"b2\": {}, \"w3\": {}, \"b3\": {},
              \"emb_w\": {}, \"cond_proj\": {},
              \"g1\": {}, \"g2\": {}, \"g3\": {},
              \"scalars\": {{\"gain1\": 2.0, \"gain2\": 3.0, \"gain3\": 4.0}}}}",
            t(&[2, 4], 0.1),
            t(&[4], 0.0),
            t(&[4, 4], 0.1),
            t(&[4], 0.0),
            t(&[4, 2], 0.1),
            t(&[2], 0.0),
            t(&[2], 1.0),
            t(&[3, 4], 0.5),
            t(&[2, 4], 0.06),
            t(&[4, 4], 0.06),
            t(&[4, 2], 0.06),
        )
    }

    #[test]
    fn parses_valid_json() {
        let w = ScoreWeights::from_json(&tiny_json()).unwrap();
        assert_eq!(w.dim(), 2);
        assert_eq!(w.hidden(), 4);
        assert_eq!(w.n_classes(), 3);
        assert_eq!(w.gains, [2.0, 3.0, 4.0]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let bad = tiny_json().replace(
            "\"b3\": {\"shape\": [2]",
            "\"b3\": {\"shape\": [5]",
        );
        // data length no longer matches shape -> as_tensor fails or validate fails
        assert!(ScoreWeights::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_missing_field() {
        let bad = tiny_json().replace("\"emb_w\"", "\"emb_q\"");
        assert!(ScoreWeights::from_json(&bad).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/weights_uncond.json");
        if std::path::Path::new(path).exists() {
            let w = ScoreWeights::load(path).unwrap();
            assert_eq!(w.dim(), 2);
            assert_eq!(w.hidden(), 14);
            // conductances in window
            for g in [&w.g1, &w.g2, &w.g3] {
                for &x in g.as_slice() {
                    assert!((0.02 - 1e-6..=0.10 + 1e-6).contains(&x));
                }
            }
        }
    }
}
