//! Time + condition embedding (paper Eq. 9 and Fig. 4b).
//!
//! `v_t = [sin(2πWt), cos(2πWt)]` with a fixed frequency vector `W`; the
//! condition is a one-hot label passed through a fixed projection, summed
//! with the time embedding.  On the PCB these are pre-programmed DAC
//! waveforms injected as currents at the TIA summing nodes — here they are
//! evaluated on demand (optionally through the DAC quantizer below).

use crate::util::tensor::Mat;

/// Precomputed embedding generators for one deployed network.
#[derive(Debug, Clone)]
pub struct Embedding {
    /// Frequency vector W, length hidden/2.
    pub freqs: Vec<f32>,
    /// Condition projection (n_classes × hidden).
    pub cond_proj: Mat,
    /// If Some(bits), quantize outputs like the PCB's 12-bit DACs.
    pub dac_bits: Option<u32>,
    /// DAC full-scale range in software units (±fs).
    pub dac_fullscale: f32,
}

impl Embedding {
    pub fn new(freqs: Vec<f32>, cond_proj: Mat) -> Self {
        Embedding { freqs, cond_proj, dac_bits: None, dac_fullscale: 4.0 }
    }

    /// Enable DAC quantization (12-bit MAX5742 on the PCB).
    pub fn with_dac(mut self, bits: u32) -> Self {
        self.dac_bits = Some(bits);
        self
    }

    /// Embedding dimension (== hidden layer width).
    pub fn dim(&self) -> usize {
        2 * self.freqs.len()
    }

    pub fn n_classes(&self) -> usize {
        self.cond_proj.rows()
    }

    #[inline]
    fn dac(&self, v: f32) -> f32 {
        match self.dac_bits {
            None => v,
            Some(bits) => {
                let levels = (1u32 << bits) as f32;
                let step = 2.0 * self.dac_fullscale / levels;
                (v / step).round() * step
            }
        }
    }

    /// Write the summed time+condition embedding into `out` (len = dim()).
    /// `onehot` may be all zeros (unconditional / CFG null token).
    pub fn eval(&self, t: f32, onehot: &[f32], out: &mut [f32]) {
        let h = self.freqs.len();
        debug_assert_eq!(out.len(), 2 * h);
        let two_pi_t = 2.0 * std::f32::consts::PI * t;
        for (k, &w) in self.freqs.iter().enumerate() {
            let ang = two_pi_t * w;
            out[k] = ang.sin();
            out[h + k] = ang.cos();
        }
        if !onehot.iter().all(|&c| c == 0.0) {
            debug_assert_eq!(onehot.len(), self.cond_proj.rows());
            for (ci, &c) in onehot.iter().enumerate() {
                if c == 0.0 {
                    continue;
                }
                let row = self.cond_proj.row(ci);
                for (o, &p) in out.iter_mut().zip(row) {
                    *o += c * p;
                }
            }
        }
        if self.dac_bits.is_some() {
            for o in out.iter_mut() {
                *o = self.dac(*o);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emb() -> Embedding {
        Embedding::new(
            vec![0.5, 1.0, 2.0],
            Mat::from_fn(2, 6, |r, c| (r * 6 + c) as f32 * 0.1),
        )
    }

    #[test]
    fn sin_cos_layout() {
        let e = emb();
        let mut out = vec![0.0; 6];
        e.eval(0.25, &[0.0, 0.0], &mut out);
        let tp = 2.0 * std::f32::consts::PI * 0.25;
        assert!((out[0] - (tp * 0.5).sin()).abs() < 1e-6);
        assert!((out[3] - (tp * 0.5).cos()).abs() < 1e-6);
        assert!((out[2] - (tp * 2.0).sin()).abs() < 1e-6);
    }

    #[test]
    fn condition_adds_projection() {
        let e = emb();
        let mut t_only = vec![0.0; 6];
        let mut both = vec![0.0; 6];
        e.eval(0.4, &[0.0, 0.0], &mut t_only);
        e.eval(0.4, &[0.0, 1.0], &mut both);
        for k in 0..6 {
            let want = t_only[k] + e.cond_proj.get(1, k);
            assert!((both[k] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn dac_quantization_steps() {
        let e = emb().with_dac(4); // coarse for visibility
        let mut out = vec![0.0; 6];
        e.eval(0.123, &[0.0, 0.0], &mut out);
        let step = 2.0 * 4.0 / 16.0;
        for &v in &out {
            let k = v / step;
            assert!((k - k.round()).abs() < 1e-4, "{v} not on DAC grid");
        }
    }

    #[test]
    fn twelve_bit_dac_error_small() {
        let e12 = emb().with_dac(12);
        let e = emb();
        let mut a = vec![0.0; 6];
        let mut b = vec![0.0; 6];
        e12.eval(0.777, &[1.0, 0.0], &mut a);
        e.eval(0.777, &[1.0, 0.0], &mut b);
        for k in 0..6 {
            assert!((a[k] - b[k]).abs() <= 4.0 / 4096.0 + 1e-6);
        }
    }

    #[test]
    fn periodic_in_integer_frequencies() {
        let e = Embedding::new(vec![1.0, 3.0], Mat::zeros(1, 4));
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        e.eval(0.2, &[0.0], &mut a);
        e.eval(1.2, &[0.0], &mut b);
        for k in 0..4 {
            assert!((a[k] - b[k]).abs() < 1e-5);
        }
    }
}
