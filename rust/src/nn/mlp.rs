//! The 3-layer score MLP in both realizations.
//!
//! Forward semantics (identical across python ref / Pallas kernel / here,
//! asserted by the integration tests):
//!
//! ```text
//! h1 = clamp(relu( clamp(x)·W1 + b1 + emb ))
//! h2 = clamp(relu( h1·W2 + b2 + emb ))
//! out = h2·W3 + b3
//! ```
//!
//! where `clamp` is the protective voltage window [-2, 4] and `emb` is the
//! summed time(+condition) embedding injected at both hidden layers.

use super::embedding::Embedding;
use super::loader::ScoreWeights;
use super::{BatchScratch, ScoreNet};
use crate::analog::activation::relu_diode;
use crate::clamp_voltage;
use crate::crossbar::{mapper, BankReport, Banking, LayerDrift, NoiseModel, ScoreLayer};
use crate::device::array::ProgramStats;
use crate::device::cell::CellParams;
use crate::exec::{self, lane_chunk_lens, lane_plan, Shards};
use crate::util::qkernel::QuantBank;
use crate::util::rng::Rng;
use crate::util::simd::{self, KernelMode};
use crate::util::tensor::{matmul_bias_into, scratch_slice, vecmat_bias_into, Mat};

/// One weight matrix of the digital net in conductance-quantized form:
/// the mapper's 64-level conductance image plus its TIA gain — the same
/// discretization the analog substrate realizes physically.
struct QuantLinear {
    qb: QuantBank,
    gain: f32,
}

impl QuantLinear {
    fn from_weights(w: &Mat) -> Self {
        let m = mapper::map_layer(w);
        QuantLinear { qb: QuantBank::from_conductances(&m.g_target), gain: m.gain }
    }
}

/// Exact f32 weight-space network — the paper's software baseline and the
/// semantics the AOT artifacts implement.
pub struct DigitalScoreNet {
    w: ScoreWeights,
    emb: Embedding,
    /// Parallel-execution context: the batched lane chunks lanes over the
    /// pool (the scaling axis for nets too small to bank).
    exec: exec::Ctx,
    /// Conductance-quantized views of the three weight matrices, present
    /// only under [`KernelMode::Quant`].
    q: Option<Box<[QuantLinear; 3]>>,
}

impl DigitalScoreNet {
    pub fn new(w: ScoreWeights) -> Self {
        let emb = Embedding::new(w.emb_w.clone(), w.cond_proj.clone());
        DigitalScoreNet { w, emb, exec: exec::Ctx::default(), q: None }
    }

    pub fn weights(&self) -> &ScoreWeights {
        &self.w
    }

    /// Set the execution context; outputs are context-invariant bit for
    /// bit (lane chunks never change a lane's accumulation order).
    pub fn set_exec(&mut self, exec: exec::Ctx) {
        self.exec = exec;
    }

    pub fn with_exec(mut self, exec: exec::Ctx) -> Self {
        self.set_exec(exec);
        self
    }

    /// Select the MVM kernel lane.  [`KernelMode::Quant`] routes both eval
    /// lanes through i8 kernels against the mapper's 64-level conductance
    /// image of each weight matrix — the digital twin of the analog quant
    /// lane, which is what makes digital-vs-analog quant comparisons
    /// apples to apples.
    pub fn set_kernel(&mut self, kernel: KernelMode) {
        self.q = match kernel {
            KernelMode::Quant => Some(Box::new([
                QuantLinear::from_weights(&self.w.w1),
                QuantLinear::from_weights(&self.w.w2),
                QuantLinear::from_weights(&self.w.w3),
            ])),
            KernelMode::F32 => None,
        };
    }

    /// Active MVM kernel lane.
    pub fn kernel(&self) -> KernelMode {
        if self.q.is_some() { KernelMode::Quant } else { KernelMode::F32 }
    }

    /// Shared quantized forward over `lanes` contiguous lanes (both eval
    /// lanes route here under [`KernelMode::Quant`], so they agree bit for
    /// bit): i8 MVM per layer, bias + embedding + ReLU + clamp epilogues
    /// identical to the f32 path.  Serial — the i8 lane is already far
    /// below the f32 GEMM cost at the paper's net widths.
    fn quant_eval(&self, ql: &[QuantLinear; 3], xc: &[f32], emb: &[f32],
                  h1: &mut [f32], h2: &mut [f32], out: &mut [f32],
                  lanes: usize) {
        let h = self.w.hidden();
        let d = self.w.dim();
        let backend = simd::active();
        ql[0].qb.forward_batch(xc, h1, lanes, ql[0].gain, backend);
        for row in h1.chunks_exact_mut(h) {
            for (v, (&b, &e)) in row.iter_mut().zip(self.w.b1.iter().zip(emb)) {
                *v = clamp_voltage((*v + b + e).max(0.0));
            }
        }
        ql[1].qb.forward_batch(h1, h2, lanes, ql[1].gain, backend);
        for row in h2.chunks_exact_mut(h) {
            for (v, (&b, &e)) in row.iter_mut().zip(self.w.b2.iter().zip(emb)) {
                *v = clamp_voltage((*v + b + e).max(0.0));
            }
        }
        ql[2].qb.forward_batch(h2, out, lanes, ql[2].gain, backend);
        for row in out.chunks_exact_mut(d) {
            for (o, &b) in row.iter_mut().zip(self.w.b3.iter()) {
                *o += b;
            }
        }
    }
}

impl ScoreNet for DigitalScoreNet {
    fn dim(&self) -> usize {
        self.w.dim()
    }

    fn n_classes(&self) -> usize {
        self.w.n_classes()
    }

    fn eval(&self, x: &[f32], t: f32, onehot: &[f32], out: &mut [f32], _rng: &mut Rng) {
        let h = self.w.hidden();
        let d = self.w.dim();
        debug_assert_eq!(x.len(), d);
        if let Some(ql) = &self.q {
            let mut emb = vec![0.0f32; h];
            self.emb.eval(t, onehot, &mut emb);
            let xc: Vec<f32> = x.iter().map(|&v| clamp_voltage(v)).collect();
            let mut h1 = vec![0.0f32; h];
            let mut h2 = vec![0.0f32; h];
            self.quant_eval(ql, &xc, &emb, &mut h1, &mut h2, out, 1);
            return;
        }
        // hot path: stack scratch (no per-eval heap traffic) whenever the
        // network fits the macro width — true for every paper net
        if h <= MAX_HIDDEN && d <= MAX_HIDDEN {
            let mut emb = [0.0f32; MAX_HIDDEN];
            self.emb.eval(t, onehot, &mut emb[..h]);
            let mut xc = [0.0f32; MAX_HIDDEN];
            for (o, &v) in xc.iter_mut().zip(x) {
                *o = clamp_voltage(v);
            }
            let mut h1 = [0.0f32; MAX_HIDDEN];
            vecmat_bias_into(&xc[..d], self.w.w1.as_slice(), &self.w.b1,
                             &mut h1[..h]);
            for (v, &e) in h1[..h].iter_mut().zip(&emb[..h]) {
                *v = clamp_voltage((*v + e).max(0.0));
            }
            let mut h2 = [0.0f32; MAX_HIDDEN];
            vecmat_bias_into(&h1[..h], self.w.w2.as_slice(), &self.w.b2,
                             &mut h2[..h]);
            for (v, &e) in h2[..h].iter_mut().zip(&emb[..h]) {
                *v = clamp_voltage((*v + e).max(0.0));
            }
            vecmat_bias_into(&h2[..h], self.w.w3.as_slice(), &self.w.b3, out);
            return;
        }
        // oversized fallback (no such net in the paper, but keep it correct)
        let mut emb = vec![0.0f32; h];
        self.emb.eval(t, onehot, &mut emb);
        let xc: Vec<f32> = x.iter().map(|&v| clamp_voltage(v)).collect();
        let mut h1 = vec![0.0f32; h];
        vecmat_bias_into(&xc, self.w.w1.as_slice(), &self.w.b1, &mut h1);
        for (v, &e) in h1.iter_mut().zip(&emb) {
            *v = clamp_voltage((*v + e).max(0.0));
        }
        let mut h2 = vec![0.0f32; h];
        vecmat_bias_into(&h1, self.w.w2.as_slice(), &self.w.b2, &mut h2);
        for (v, &e) in h2.iter_mut().zip(&emb) {
            *v = clamp_voltage((*v + e).max(0.0));
        }
        vecmat_bias_into(&h2, self.w.w3.as_slice(), &self.w.b3, out);
    }

    /// Native batched lane: B×d · d×h GEMMs with the embedding computed
    /// once for all lanes.  Zero heap allocation at steady state (scratch
    /// reused across timesteps); bitwise equal to per-lane [`Self::eval`].
    /// Under a parallel [`exec::Ctx`] the lanes split into contiguous
    /// chunks, one pool task each, with disjoint scratch/output shards —
    /// still bitwise equal (each lane's float-op sequence is untouched).
    fn eval_batch(&self, xs: &[f32], t: f32, onehot: &[f32], out: &mut [f32],
                  scratch: &mut BatchScratch, _rng: &mut Rng) {
        let h = self.w.hidden();
        let d = self.w.dim();
        debug_assert_eq!(xs.len() % d, 0);
        debug_assert_eq!(xs.len(), out.len());
        let batch = xs.len() / d;

        let emb = scratch_slice(&mut scratch.emb, h);
        self.emb.eval(t, onehot, emb);

        if let Some(ql) = &self.q {
            let xc = scratch_slice(&mut scratch.x, batch * d);
            for (o, &v) in xc.iter_mut().zip(xs) {
                *o = clamp_voltage(v);
            }
            let h1 = scratch_slice(&mut scratch.h1, batch * h);
            let h2 = scratch_slice(&mut scratch.h2, batch * h);
            self.quant_eval(ql, xc, emb, h1, h2, out, batch);
            return;
        }

        let nt = self
            .exec
            .lane_tasks(batch, batch * (d * h + h * h + h * d));
        if nt > 1 {
            let (chunk, nt) = lane_plan(batch, nt);
            let lens_d = lane_chunk_lens(batch, d, chunk, nt);
            let lens_h = lane_chunk_lens(batch, h, chunk, nt);
            let emb_ro: &[f32] = emb;
            let sx = Shards::new(scratch_slice(&mut scratch.x, batch * d),
                                 lens_d.iter().copied());
            let s1 = Shards::new(scratch_slice(&mut scratch.h1, batch * h),
                                 lens_h.iter().copied());
            let s2 = Shards::new(scratch_slice(&mut scratch.h2, batch * h),
                                 lens_h.iter().copied());
            let so = Shards::new(out, lens_d.iter().copied());
            self.exec.run(nt, &|i| {
                let xc = sx.take(i);
                let h1 = s1.take(i);
                let h2 = s2.take(i);
                let ob = so.take(i);
                let lanes = ob.len() / d;
                let lane0 = i * chunk;
                let xs_c = &xs[lane0 * d..(lane0 + lanes) * d];
                for (o, &v) in xc.iter_mut().zip(xs_c) {
                    *o = clamp_voltage(v);
                }
                matmul_bias_into(xc, self.w.w1.as_slice(), &self.w.b1, h1,
                                 lanes, d, h);
                for row in h1.chunks_exact_mut(h) {
                    for (v, &e) in row.iter_mut().zip(emb_ro) {
                        *v = clamp_voltage((*v + e).max(0.0));
                    }
                }
                matmul_bias_into(h1, self.w.w2.as_slice(), &self.w.b2, h2,
                                 lanes, h, h);
                for row in h2.chunks_exact_mut(h) {
                    for (v, &e) in row.iter_mut().zip(emb_ro) {
                        *v = clamp_voltage((*v + e).max(0.0));
                    }
                }
                matmul_bias_into(h2, self.w.w3.as_slice(), &self.w.b3, ob,
                                 lanes, h, d);
            });
            return;
        }

        let xc = scratch_slice(&mut scratch.x, batch * d);
        for (o, &v) in xc.iter_mut().zip(xs) {
            *o = clamp_voltage(v);
        }
        let h1 = scratch_slice(&mut scratch.h1, batch * h);
        matmul_bias_into(xc, self.w.w1.as_slice(), &self.w.b1, h1, batch, d, h);
        for row in h1.chunks_exact_mut(h) {
            for (v, &e) in row.iter_mut().zip(emb.iter()) {
                *v = clamp_voltage((*v + e).max(0.0));
            }
        }
        let h2 = scratch_slice(&mut scratch.h2, batch * h);
        matmul_bias_into(h1, self.w.w2.as_slice(), &self.w.b2, h2, batch, h, h);
        for row in h2.chunks_exact_mut(h) {
            for (v, &e) in row.iter_mut().zip(emb.iter()) {
                *v = clamp_voltage((*v + e).max(0.0));
            }
        }
        matmul_bias_into(h2, self.w.w3.as_slice(), &self.w.b3, out, batch, h, d);
    }
}

/// Analog network: three crossbar layers + TIA + diode-ReLU, with device
/// noise models.  This is the hardware of Fig. 2h–i.
///
/// Each layer deploys on a [`ScoreLayer`]: monolithic when it fits one
/// 32×32 macro, sharded across a bank grid
/// ([`crate::crossbar::BankedCrossbarLayer`]) when it doesn't — so nets
/// with hidden layers wider than one macro run end-to-end.  The banking
/// policy is overridable for the parity suite (the monolithic layer is the
/// oracle the banked substrate is checked against).
pub struct AnalogScoreNet {
    l1: ScoreLayer,
    l2: ScoreLayer,
    l3: ScoreLayer,
    b1: Vec<f32>,
    b2: Vec<f32>,
    b3: Vec<f32>,
    emb: Embedding,
    noise: NoiseModel,
    dim: usize,
    hidden: usize,
    n_classes: usize,
    /// Scratch buffers (interior mutability avoided: eval allocates on the
    /// stack via fixed-size arrays when hidden ≤ 32; see `eval`).
    _priv: (),
}

/// Max hidden width supported by the stack-allocated hot path.
const MAX_HIDDEN: usize = 32;

/// Base seed for the banked layers' per-bank noise streams (xored with the
/// layer index so the three layers decorrelate deterministically).
const BANK_STREAM_SEED: u64 = 0x5EED_BA4C_0000_0000;

impl AnalogScoreNet {
    fn assemble(w: &ScoreWeights, l1: ScoreLayer, l2: ScoreLayer,
                l3: ScoreLayer, noise: NoiseModel) -> Self {
        AnalogScoreNet {
            l1,
            l2,
            l3,
            b1: w.b1.clone(),
            b2: w.b2.clone(),
            b3: w.b3.clone(),
            emb: Embedding::new(w.emb_w.clone(), w.cond_proj.clone()).with_dac(12),
            noise,
            dim: w.dim(),
            hidden: w.hidden(),
            n_classes: w.n_classes(),
            _priv: (),
        }
    }

    /// Deploy from exported conductances (exact, plus optional write noise
    /// applied by reprogramming — see [`Self::program_from_weights`]).
    /// Layers wider than one macro deploy banked automatically.
    pub fn from_conductances(w: &ScoreWeights, params: CellParams,
                             noise: NoiseModel) -> Self {
        Self::from_conductances_with(w, params, noise, Banking::Auto)
    }

    /// [`Self::from_conductances`] with an explicit banking policy.
    pub fn from_conductances_with(w: &ScoreWeights, params: CellParams,
                                  noise: NoiseModel, banking: Banking) -> Self {
        let l = |g, gain, i: u64| {
            ScoreLayer::from_conductances(g, gain, params.clone(),
                                          BANK_STREAM_SEED ^ i, banking)
        };
        let l1 = l(&w.g1, w.gains[0], 1);
        let l2 = l(&w.g2, w.gains[1], 2);
        let l3 = l(&w.g3, w.gains[2], 3);
        Self::assemble(w, l1, l2, l3, noise)
    }

    /// Deploy by *programming* the weight matrices with write-verify —
    /// includes realistic write noise (Fig. 5b/e).  `tol_ms` is the verify
    /// band; smaller = more pulses, less residual error.  Layers wider
    /// than one macro program banked (per-bank streams, per-tile-column
    /// gains) automatically.
    pub fn program_from_weights(w: &ScoreWeights, params: CellParams,
                                tol_ms: f32, noise: NoiseModel,
                                rng: &mut Rng) -> (Self, usize) {
        Self::program_from_weights_with(w, params, tol_ms, noise, rng,
                                        Banking::Auto)
    }

    /// [`Self::program_from_weights`] with an explicit banking policy.
    pub fn program_from_weights_with(w: &ScoreWeights, params: CellParams,
                                     tol_ms: f32, noise: NoiseModel,
                                     rng: &mut Rng, banking: Banking)
                                     -> (Self, usize) {
        let (l1, s1) =
            ScoreLayer::program(&w.w1, params.clone(), tol_ms, rng, banking);
        let (l2, s2) =
            ScoreLayer::program(&w.w2, params.clone(), tol_ms, rng, banking);
        let (l3, s3) = ScoreLayer::program(&w.w3, params, tol_ms, rng, banking);
        let total_pulses = s1.pulses.iter().sum::<usize>()
            + s2.pulses.iter().sum::<usize>()
            + s3.pulses.iter().sum::<usize>();
        (Self::assemble(w, l1, l2, l3, noise), total_pulses)
    }

    pub fn noise_model(&self) -> NoiseModel {
        self.noise
    }

    pub fn set_noise_model(&mut self, noise: NoiseModel) {
        self.noise = noise;
    }

    /// Set the execution context on all three crossbar layers.  The banked
    /// substrate forks per tile-column (and per lane chunk when noise-free);
    /// outputs stay bitwise identical under any context.  Lane order of the
    /// per-bank noise draws is preserved by construction, so this is safe
    /// for noisy modes too.
    pub fn set_exec(&mut self, exec: exec::Ctx) {
        self.l1.set_exec(exec.clone());
        self.l2.set_exec(exec.clone());
        self.l3.set_exec(exec);
    }

    pub fn with_exec(mut self, exec: exec::Ctx) -> Self {
        self.set_exec(exec);
        self
    }

    /// Select the MVM kernel lane on all three crossbar layers.  The i8
    /// lane serves `Ideal` sweeps only — noisy modes need per-cell float
    /// conductances and fall back to f32 transparently — and each layer's
    /// i8 view tracks aging / reprogramming through its conductance cache.
    pub fn set_kernel(&mut self, kernel: KernelMode) {
        self.l1.set_kernel(kernel);
        self.l2.set_kernel(kernel);
        self.l3.set_kernel(kernel);
    }

    /// Active MVM kernel lane.
    pub fn kernel(&self) -> KernelMode {
        self.l1.kernel()
    }

    /// Total programmed cells across the three layers (energy model input).
    pub fn n_cells(&self) -> usize {
        self.l1.n_cells() + self.l2.n_cells() + self.l3.n_cells()
    }

    /// Logical (rows, cols) of the three layers — the energy model scales
    /// per-macro peripheral counts from these.
    pub fn layer_shapes(&self) -> [(usize, usize); 3] {
        [self.l1.shape(), self.l2.shape(), self.l3.shape()]
    }

    /// Bank topology + per-bank program/read stats of every layer, for the
    /// serving metrics.  Monolithic layers report their implicit grid with
    /// no per-bank stats.
    pub fn bank_report(&self) -> Vec<BankReport> {
        vec![self.l1.report(0), self.l2.report(1), self.l3.report(2)]
    }

    /// True if any layer runs on the banked substrate.
    pub fn is_banked(&self) -> bool {
        self.l1.is_banked() || self.l2.is_banked() || self.l3.is_banked()
    }

    /// Effective realized weights (for deployment-error diagnostics).
    pub fn effective_weights(&self) -> (Mat, Mat, Mat) {
        (
            self.l1.effective_weights(),
            self.l2.effective_weights(),
            self.l3.effective_weights(),
        )
    }

    /// Age all layers (retention experiments / the health monitor's
    /// retention clock).  Banked layers draw from their own per-bank
    /// streams; monolithic layers from `rng`.  No-op at `dt_s <= 0`.
    pub fn age(&mut self, dt_s: f64, rng: &mut Rng) {
        self.l1.age(dt_s, rng);
        self.l2.age(dt_s, rng);
        self.l3.age(dt_s, rng);
    }

    /// Per-layer drift since the last (re)program, with per-bank
    /// breakdowns on the banked substrate (health monitor input).
    pub fn drift_report(&self) -> Vec<LayerDrift> {
        vec![
            self.l1.drift_report(0),
            self.l2.drift_report(1),
            self.l3.drift_report(2),
        ]
    }

    /// Write-verify recovery of every layer toward its programmed
    /// baseline; drift estimators re-zero at the achieved state.  Returns
    /// the aggregated programming stats (residual-error histogram input).
    pub fn reprogram(&mut self, tol_ms: f32, rng: &mut Rng) -> ProgramStats {
        let mut agg = self.l1.reprogram(tol_ms, rng);
        agg.merge(self.l2.reprogram(tol_ms, rng));
        agg.merge(self.l3.reprogram(tol_ms, rng));
        agg
    }
}

impl ScoreNet for AnalogScoreNet {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn eval(&self, x: &[f32], t: f32, onehot: &[f32], out: &mut [f32], rng: &mut Rng) {
        debug_assert_eq!(x.len(), self.dim);
        let h = self.hidden;
        if h <= MAX_HIDDEN && self.dim <= MAX_HIDDEN {
            // hot path: stack scratch whenever the net fits one macro width
            let mut emb = [0.0f32; MAX_HIDDEN];
            self.emb.eval(t, onehot, &mut emb[..h]);

            let mut xin = [0.0f32; MAX_HIDDEN];
            for (o, &v) in xin.iter_mut().zip(x) {
                *o = clamp_voltage(v);
            }
            let mut h1 = [0.0f32; MAX_HIDDEN];
            self.l1.forward(&xin[..self.dim], &mut h1[..h], self.noise, rng);
            for k in 0..h {
                h1[k] = clamp_voltage(relu_diode(h1[k] + self.b1[k] + emb[k]));
            }
            let mut h2 = [0.0f32; MAX_HIDDEN];
            self.l2.forward(&h1[..h], &mut h2[..h], self.noise, rng);
            for k in 0..h {
                h2[k] = clamp_voltage(relu_diode(h2[k] + self.b2[k] + emb[k]));
            }
            self.l3.forward(&h2[..h], out, self.noise, rng);
            for (o, &b) in out.iter_mut().zip(&self.b3) {
                *o += b;
            }
            return;
        }
        // banked-width fallback: heap scratch for nets wider than one
        // macro (reference lane; the batched lane reuses grow-only scratch
        // and stays zero-alloc at steady state)
        let mut emb = vec![0.0f32; h];
        self.emb.eval(t, onehot, &mut emb);
        let xin: Vec<f32> = x.iter().map(|&v| clamp_voltage(v)).collect();
        let mut h1 = vec![0.0f32; h];
        self.l1.forward(&xin, &mut h1, self.noise, rng);
        for k in 0..h {
            h1[k] = clamp_voltage(relu_diode(h1[k] + self.b1[k] + emb[k]));
        }
        let mut h2 = vec![0.0f32; h];
        self.l2.forward(&h1, &mut h2, self.noise, rng);
        for k in 0..h {
            h2[k] = clamp_voltage(relu_diode(h2[k] + self.b2[k] + emb[k]));
        }
        self.l3.forward(&h2, out, self.noise, rng);
        for (o, &b) in out.iter_mut().zip(&self.b3) {
            *o += b;
        }
    }

    /// Native batched lane: all three crossbar layers evaluate B lanes per
    /// GEMM ([`ScoreLayer::forward_batch`]), with the DAC-quantized
    /// embedding computed once for all lanes.  Ideal mode is bitwise equal
    /// to per-lane [`Self::eval`]; noisy modes draw per lane in lane order.
    fn eval_batch(&self, xs: &[f32], t: f32, onehot: &[f32], out: &mut [f32],
                  scratch: &mut BatchScratch, rng: &mut Rng) {
        let d = self.dim;
        let h = self.hidden;
        debug_assert_eq!(xs.len() % d, 0);
        debug_assert_eq!(xs.len(), out.len());
        let batch = xs.len() / d;

        let emb = scratch_slice(&mut scratch.emb, h);
        self.emb.eval(t, onehot, emb);

        let xin = scratch_slice(&mut scratch.x, batch * d);
        for (o, &v) in xin.iter_mut().zip(xs) {
            *o = clamp_voltage(v);
        }
        let h1 = scratch_slice(&mut scratch.h1, batch * h);
        self.l1.forward_batch(xin, h1, batch, self.noise, rng);
        for row in h1.chunks_exact_mut(h) {
            for (v, (&b, &e)) in
                row.iter_mut().zip(self.b1.iter().zip(emb.iter()))
            {
                *v = clamp_voltage(relu_diode(*v + b + e));
            }
        }
        let h2 = scratch_slice(&mut scratch.h2, batch * h);
        self.l2.forward_batch(h1, h2, batch, self.noise, rng);
        for row in h2.chunks_exact_mut(h) {
            for (v, (&b, &e)) in
                row.iter_mut().zip(self.b2.iter().zip(emb.iter()))
            {
                *v = clamp_voltage(relu_diode(*v + b + e));
            }
        }
        self.l3.forward_batch(h2, out, batch, self.noise, rng);
        for row in out.chunks_exact_mut(d) {
            for (o, &b) in row.iter_mut().zip(self.b3.iter()) {
                *o += b;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loader::tests::tiny_json;

    fn quiet() -> CellParams {
        CellParams { read_noise_frac: 0.0, ..CellParams::default() }
    }

    fn weights() -> ScoreWeights {
        ScoreWeights::from_json(&tiny_json()).unwrap()
    }

    #[test]
    fn digital_eval_shapes_and_determinism() {
        let net = DigitalScoreNet::new(weights());
        let mut rng = Rng::new(0);
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        net.eval(&[0.3, -0.2], 0.5, &[0.0, 0.0, 0.0], &mut a, &mut rng);
        net.eval(&[0.3, -0.2], 0.5, &[0.0, 0.0, 0.0], &mut b, &mut rng);
        assert_eq!(a, b, "digital net must be deterministic");
    }

    #[test]
    fn condition_changes_output() {
        let net = DigitalScoreNet::new(weights());
        let mut rng = Rng::new(0);
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        net.eval(&[0.3, -0.2], 0.5, &[0.0, 0.0, 0.0], &mut a, &mut rng);
        net.eval(&[0.3, -0.2], 0.5, &[1.0, 0.0, 0.0], &mut b, &mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn cfg_lambda_zero_equals_conditional() {
        let net = DigitalScoreNet::new(weights());
        let mut rng = Rng::new(0);
        let oh = [0.0, 1.0, 0.0];
        let mut cfg = [0.0f32; 2];
        let mut cond = [0.0f32; 2];
        net.eval_cfg(&[0.1, 0.2], 0.3, &oh, 0.0, &mut cfg, &mut rng);
        net.eval(&[0.1, 0.2], 0.3, &oh, &mut cond, &mut rng);
        for k in 0..2 {
            assert!((cfg[k] - cond[k]).abs() < 1e-6);
        }
    }

    #[test]
    fn cfg_extrapolation_formula() {
        let net = DigitalScoreNet::new(weights());
        let mut rng = Rng::new(0);
        let oh = [0.0, 0.0, 1.0];
        let zeros = [0.0, 0.0, 0.0];
        let (mut c, mut u, mut g) = ([0.0f32; 2], [0.0f32; 2], [0.0f32; 2]);
        net.eval(&[0.1, -0.4], 0.6, &oh, &mut c, &mut rng);
        net.eval(&[0.1, -0.4], 0.6, &zeros, &mut u, &mut rng);
        net.eval_cfg(&[0.1, -0.4], 0.6, &oh, 2.0, &mut g, &mut rng);
        for k in 0..2 {
            let want = 3.0 * c[k] - 2.0 * u[k];
            assert!((g[k] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn analog_matches_digital_when_ideal() {
        // With exact conductances, zero read noise and no DAC quantization
        // surprises, analog ≈ digital up to conductance quantization of the
        // *stored* weights (tiny_json stores g = 0.06 exactly on a level).
        let w = weights();
        let analog = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
        let digital = DigitalScoreNet::new(ScoreWeights {
            // make digital use the weights implied by the conductances
            w1: crate::crossbar::conductance_to_weight(&w.g1, w.gains[0]),
            w2: crate::crossbar::conductance_to_weight(&w.g2, w.gains[1]),
            w3: crate::crossbar::conductance_to_weight(&w.g3, w.gains[2]),
            ..w.clone()
        });
        let mut rng = Rng::new(1);
        let mut a = [0.0f32; 2];
        let mut d = [0.0f32; 2];
        for i in 0..20 {
            let x = [0.1 * i as f32 - 1.0, 0.05 * i as f32];
            let t = i as f32 / 20.0;
            analog.eval(&x, t, &[0.0, 0.0, 0.0], &mut a, &mut rng);
            digital.eval(&x, t, &[0.0, 0.0, 0.0], &mut d, &mut rng);
            for k in 0..2 {
                // 12-bit DAC on the embedding is the only remaining delta
                assert!((a[k] - d[k]).abs() < 5e-3, "i={i} k={k}: {} vs {}", a[k], d[k]);
            }
        }
    }

    #[test]
    fn read_noise_perturbs_analog_eval() {
        let w = weights();
        let net = AnalogScoreNet::from_conductances(
            &w,
            CellParams::default(),
            NoiseModel::ReadFast,
        );
        let mut rng = Rng::new(2);
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        net.eval(&[0.5, 0.5], 0.5, &[0.0, 0.0, 0.0], &mut a, &mut rng);
        net.eval(&[0.5, 0.5], 0.5, &[0.0, 0.0, 0.0], &mut b, &mut rng);
        assert_ne!(a, b, "read noise must decorrelate consecutive evals");
    }

    #[test]
    fn digital_eval_batch_matches_scalar_bitwise() {
        let net = DigitalScoreNet::new(weights());
        let mut rng = Rng::new(4);
        let batch = 7; // exercises the 4-row block + remainder
        let xs: Vec<f32> = (0..batch * 2).map(|i| 0.1 * i as f32 - 0.6).collect();
        let oh = [0.0, 1.0, 0.0];
        let mut scratch = BatchScratch::new();
        let mut batched = vec![0.0f32; batch * 2];
        net.eval_batch(&xs, 0.4, &oh, &mut batched, &mut scratch, &mut rng);
        let mut scalar = [0.0f32; 2];
        for b in 0..batch {
            net.eval(&xs[b * 2..(b + 1) * 2], 0.4, &oh, &mut scalar, &mut rng);
            assert_eq!(&batched[b * 2..(b + 1) * 2], scalar.as_slice(),
                       "lane {b}");
        }
    }

    #[test]
    fn digital_eval_cfg_batch_matches_scalar() {
        let net = DigitalScoreNet::new(weights());
        let mut rng = Rng::new(5);
        let batch = 5;
        let xs: Vec<f32> = (0..batch * 2).map(|i| 0.07 * i as f32 - 0.3).collect();
        let oh = [0.0, 0.0, 1.0];
        let mut scratch = BatchScratch::new();
        let mut batched = vec![0.0f32; batch * 2];
        net.eval_cfg_batch(&xs, 0.6, &oh, 2.0, &mut batched, &mut scratch,
                           &mut rng);
        let mut scalar = [0.0f32; 2];
        for b in 0..batch {
            net.eval_cfg(&xs[b * 2..(b + 1) * 2], 0.6, &oh, 2.0, &mut scalar,
                         &mut rng);
            for k in 0..2 {
                assert!((batched[b * 2 + k] - scalar[k]).abs() < 1e-6,
                        "lane {b} k={k}");
            }
        }
    }

    #[test]
    fn analog_eval_batch_matches_scalar_bitwise_when_ideal() {
        let w = weights();
        let net = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
        let mut rng = Rng::new(6);
        let batch = 6;
        let xs: Vec<f32> = (0..batch * 2).map(|i| 0.09 * i as f32 - 0.5).collect();
        let mut scratch = BatchScratch::new();
        let mut batched = vec![0.0f32; batch * 2];
        net.eval_batch(&xs, 0.3, &[0.0, 0.0, 0.0], &mut batched, &mut scratch,
                       &mut rng);
        let mut scalar = [0.0f32; 2];
        for b in 0..batch {
            net.eval(&xs[b * 2..(b + 1) * 2], 0.3, &[0.0, 0.0, 0.0],
                     &mut scalar, &mut rng);
            assert_eq!(&batched[b * 2..(b + 1) * 2], scalar.as_slice(),
                       "lane {b}");
        }
    }

    #[test]
    fn analog_eval_batch_read_fast_decorrelates_lanes() {
        let w = weights();
        let net = AnalogScoreNet::from_conductances(
            &w,
            CellParams::default(),
            NoiseModel::ReadFast,
        );
        let mut rng = Rng::new(7);
        let batch = 4;
        // identical inputs in every lane: read noise must still decorrelate
        let xs: Vec<f32> = (0..batch).flat_map(|_| [0.5f32, 0.5]).collect();
        let mut scratch = BatchScratch::new();
        let mut out = vec![0.0f32; batch * 2];
        net.eval_batch(&xs, 0.5, &[0.0, 0.0, 0.0], &mut out, &mut scratch,
                       &mut rng);
        for b in 1..batch {
            assert_ne!(&out[..2], &out[b * 2..(b + 1) * 2], "lane {b}");
        }
    }

    #[test]
    fn wide_net_auto_banks_and_matches_monolithic_oracle() {
        // hidden = 48 > MACRO_DIM: layers must shard onto bank grids and
        // stay bitwise equal to the forced-monolithic oracle under Ideal
        let w = ScoreWeights::synthetic(2, 48, 3, 31);
        let banked = AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
        assert!(banked.is_banked());
        let mono = AnalogScoreNet::from_conductances_with(
            &w, quiet(), NoiseModel::Ideal, Banking::ForceMonolithic);
        assert!(!mono.is_banked());
        let grids: Vec<(usize, usize)> = banked
            .bank_report()
            .iter()
            .map(|r| (r.tile_rows, r.tile_cols))
            .collect();
        assert_eq!(grids, vec![(1, 2), (2, 2), (2, 1)]);

        let mut rng = Rng::new(32);
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        for i in 0..10 {
            let x = [0.2 * i as f32 - 1.0, 0.1 * i as f32];
            let t = i as f32 / 10.0;
            banked.eval(&x, t, &[0.0, 0.0, 0.0], &mut a, &mut rng);
            mono.eval(&x, t, &[0.0, 0.0, 0.0], &mut b, &mut rng);
            assert_eq!(a, b, "i={i}");
        }
        // batched lane bitwise equal to the scalar lane on the banked net
        let batch = 5;
        let xs: Vec<f32> = (0..batch * 2).map(|i| 0.11 * i as f32 - 0.4).collect();
        let mut scratch = BatchScratch::new();
        let mut outb = vec![0.0f32; batch * 2];
        banked.eval_batch(&xs, 0.4, &[0.0, 0.0, 0.0], &mut outb, &mut scratch,
                          &mut rng);
        let mut s = [0.0f32; 2];
        for lane in 0..batch {
            banked.eval(&xs[lane * 2..(lane + 1) * 2], 0.4, &[0.0, 0.0, 0.0],
                        &mut s, &mut rng);
            assert_eq!(&outb[lane * 2..(lane + 1) * 2], s.as_slice(),
                       "lane {lane}");
        }
    }

    #[test]
    fn digital_lane_chunked_eval_batch_is_bitwise_serial() {
        use crate::exec::{Ctx, ParStrategy, Pool};
        use std::sync::Arc;
        let serial = DigitalScoreNet::new(weights()).with_exec(Ctx::serial());
        let par = DigitalScoreNet::new(weights())
            .with_exec(Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(3))));
        let mut rng = Rng::new(8);
        for batch in [2usize, 5, 8] {
            let xs: Vec<f32> =
                (0..batch * 2).map(|i| 0.07 * i as f32 - 0.4).collect();
            let oh = [0.0, 1.0, 0.0];
            let mut sa = BatchScratch::new();
            let mut sb = BatchScratch::new();
            let mut a = vec![0.0f32; batch * 2];
            let mut b = vec![0.0f32; batch * 2];
            serial.eval_batch(&xs, 0.4, &oh, &mut a, &mut sa, &mut rng);
            par.eval_batch(&xs, 0.4, &oh, &mut b, &mut sb, &mut rng);
            assert_eq!(a, b, "batch {batch}");
        }
    }

    #[test]
    fn net_drift_report_and_reprogram_lifecycle() {
        // banked fixture (hidden = 48): all three layers report drift,
        // aging raises it, reprogram returns residuals and re-zeroes it
        let w = ScoreWeights::synthetic(2, 48, 3, 33);
        let mut rng = Rng::new(34);
        let mut net =
            AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
        let d0 = net.drift_report();
        assert_eq!(d0.len(), 3);
        assert!(d0.iter().all(|l| l.drift.sum_abs_ms == 0.0));
        net.age(1e12, &mut rng);
        let d1 = net.drift_report();
        assert!(d1.iter().all(|l| l.drift.mean_abs_ms() > 1e-4),
                "every layer must drift");
        let cells: usize = d1.iter().map(|l| l.drift.cells).sum();
        assert_eq!(cells, net.n_cells());
        let ps = net.reprogram(0.0015, &mut rng);
        assert_eq!(ps.pulses.len() + ps.failures, net.n_cells());
        assert!(ps.max_error_ms() > 0.0, "write noise leaves residuals");
        assert!(net.drift_report().iter().all(|l| l.drift.sum_abs_ms == 0.0));
    }

    #[test]
    fn digital_quant_scalar_matches_batched_bitwise() {
        let mut net = DigitalScoreNet::new(weights());
        net.set_kernel(KernelMode::Quant);
        assert_eq!(net.kernel(), KernelMode::Quant);
        let mut rng = Rng::new(41);
        let batch = 6;
        let xs: Vec<f32> = (0..batch * 2).map(|i| 0.13 * i as f32 - 0.7).collect();
        let oh = [0.0, 1.0, 0.0];
        let mut scratch = BatchScratch::new();
        let mut batched = vec![0.0f32; batch * 2];
        net.eval_batch(&xs, 0.4, &oh, &mut batched, &mut scratch, &mut rng);
        let mut scalar = [0.0f32; 2];
        for b in 0..batch {
            net.eval(&xs[b * 2..(b + 1) * 2], 0.4, &oh, &mut scalar, &mut rng);
            assert_eq!(&batched[b * 2..(b + 1) * 2], scalar.as_slice(),
                       "lane {b}");
        }
        // switching back restores the exact f32 lane
        net.set_kernel(KernelMode::F32);
        assert_eq!(net.kernel(), KernelMode::F32);
        let f32_net = DigitalScoreNet::new(weights());
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        net.eval(&xs[..2], 0.4, &oh, &mut a, &mut rng);
        f32_net.eval(&xs[..2], 0.4, &oh, &mut b, &mut rng);
        assert_eq!(a, b);
    }

    #[test]
    fn digital_quant_tracks_f32_reference() {
        // the i8 lane sees mapper-quantized weights and DAC-quantized
        // inputs — a coarse but faithful image of the f32 reference
        let f32_net = DigitalScoreNet::new(weights());
        let mut q_net = DigitalScoreNet::new(weights());
        q_net.set_kernel(KernelMode::Quant);
        let mut rng = Rng::new(42);
        let mut fo = [0.0f32; 2];
        let mut qo = [0.0f32; 2];
        for i in 0..20 {
            let x = [0.1 * i as f32 - 1.0, 0.06 * i as f32 - 0.4];
            let t = i as f32 / 20.0;
            f32_net.eval(&x, t, &[0.0, 0.0, 0.0], &mut fo, &mut rng);
            q_net.eval(&x, t, &[0.0, 0.0, 0.0], &mut qo, &mut rng);
            for k in 0..2 {
                assert!((fo[k] - qo[k]).abs() < 0.15,
                        "i={i} k={k}: {} vs {}", fo[k], qo[k]);
            }
        }
    }

    #[test]
    fn analog_quant_banked_matches_mono_bitwise() {
        // net-level twin of the layer parity: integer partial sums make
        // the banked i8 lane bitwise equal to the monolithic i8 oracle
        let w = ScoreWeights::synthetic(2, 48, 3, 35);
        let mut banked =
            AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
        banked.set_kernel(KernelMode::Quant);
        assert_eq!(banked.kernel(), KernelMode::Quant);
        let mut mono = AnalogScoreNet::from_conductances_with(
            &w, quiet(), NoiseModel::Ideal, Banking::ForceMonolithic);
        mono.set_kernel(KernelMode::Quant);
        let mut rng = Rng::new(36);
        let batch = 5;
        let xs: Vec<f32> =
            (0..batch * 2).map(|i| 0.17 * i as f32 - 0.5).collect();
        let mut sa = BatchScratch::new();
        let mut sb = BatchScratch::new();
        let mut a = vec![0.0f32; batch * 2];
        let mut b = vec![0.0f32; batch * 2];
        banked.eval_batch(&xs, 0.3, &[0.0, 0.0, 0.0], &mut a, &mut sa, &mut rng);
        mono.eval_batch(&xs, 0.3, &[0.0, 0.0, 0.0], &mut b, &mut sb, &mut rng);
        assert_eq!(a, b);
    }

    #[test]
    fn analog_quant_stays_close_to_f32_ideal() {
        // tiny_json conductances sit exactly on the 64-level grid, so the
        // only quant-lane delta is input DAC rounding
        let w = weights();
        let f32_net =
            AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
        let mut q_net =
            AnalogScoreNet::from_conductances(&w, quiet(), NoiseModel::Ideal);
        q_net.set_kernel(KernelMode::Quant);
        let mut rng = Rng::new(37);
        let mut fo = [0.0f32; 2];
        let mut qo = [0.0f32; 2];
        for i in 0..20 {
            let x = [0.1 * i as f32 - 1.0, 0.05 * i as f32];
            let t = i as f32 / 20.0;
            f32_net.eval(&x, t, &[0.0, 0.0, 0.0], &mut fo, &mut rng);
            q_net.eval(&x, t, &[0.0, 0.0, 0.0], &mut qo, &mut rng);
            for k in 0..2 {
                assert!((fo[k] - qo[k]).abs() < 0.1,
                        "i={i} k={k}: {} vs {}", fo[k], qo[k]);
            }
        }
    }

    #[test]
    fn programming_deploys_close_to_target() {
        let w = weights();
        let mut rng = Rng::new(3);
        let (net, pulses) = AnalogScoreNet::program_from_weights(
            &w,
            quiet(),
            0.0005,
            NoiseModel::Ideal,
            &mut rng,
        );
        assert!(pulses > 0);
        let (e1, _, _) = net.effective_weights();
        assert!(e1.max_abs_diff(&w.w1) < 0.1 * w.gains[0].max(1.0));
    }
}
