//! The paper's score network: a 3-layer fully connected net (2→14→14→2)
//! with sinusoidal time embedding and optional condition embedding injected
//! as bias currents into both hidden layers (Fig. 2i / 4b).
//!
//! Two interchangeable realizations implement [`ScoreNet`]:
//! * [`mlp::AnalogScoreNet`] — crossbar tiles + TIA + diode-ReLU, with
//!   device read/write noise (the paper's hardware).
//! * [`mlp::DigitalScoreNet`] — exact f32 weight-space math (the software
//!   baseline the paper compares against, and the semantics of the AOT
//!   artifacts).

pub mod embedding;
pub mod loader;
pub mod mlp;

pub use embedding::Embedding;
pub use loader::ScoreWeights;
pub use mlp::{AnalogScoreNet, DigitalScoreNet};

use crate::util::rng::Rng;

/// Reusable scratch buffers for the batched evaluation lane.
///
/// One instance lives per sampler/solver invocation and is threaded through
/// every [`ScoreNet::eval_batch`] call, so the per-timestep hot path runs
/// with zero heap allocation once the buffers have grown to their
/// steady-state batch size.  Buffers are grow-only and never cleared —
/// implementations fully overwrite what they use (via
/// [`crate::util::tensor::scratch_slice`]).
#[derive(Debug, Default)]
pub struct BatchScratch {
    /// Shared time+condition embedding (length hidden) — computed once per
    /// batched eval instead of once per lane.
    pub emb: Vec<f32>,
    /// Clamped input lanes (batch × dim).
    pub x: Vec<f32>,
    /// First hidden activations (batch × hidden).
    pub h1: Vec<f32>,
    /// Second hidden activations (batch × hidden).
    pub h2: Vec<f32>,
    /// CFG conditional branch output (batch × dim).
    pub cond: Vec<f32>,
    /// CFG unconditional branch output (batch × dim).
    pub unc: Vec<f32>,
    /// CFG null-token one-hot (n_classes).
    pub zeros: Vec<f32>,
}

impl BatchScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The epsilon-parameterized score network interface.
///
/// `eval` writes the network output ``net(x, t)`` (≈ the noise prediction;
/// score = −net/σ(t)) into `out`.  `onehot` is the condition (all-zero =
/// unconditional / CFG null token).  `rng` feeds device noise in analog
/// implementations; digital ones ignore it.
///
/// `eval_batch`/`eval_cfg_batch` evaluate B lane-contiguous states sharing
/// one `(t, onehot)` — the shape the coordinator's dynamic batcher emits.
/// The defaults fall back to per-lane `eval`; [`mlp::DigitalScoreNet`] and
/// [`mlp::AnalogScoreNet`] override them with matrix-matrix paths that are
/// bitwise equal to the scalar lane under ideal (noise-free) evaluation.
pub trait ScoreNet: Send + Sync {
    /// State dimension (2 for both paper tasks).
    fn dim(&self) -> usize;
    /// Number of condition classes (0 = unconditional-only net).
    fn n_classes(&self) -> usize;
    /// Evaluate the network for a single state vector.
    fn eval(&self, x: &[f32], t: f32, onehot: &[f32], out: &mut [f32], rng: &mut Rng);

    /// Classifier-free guidance (paper Eq. 7), in network space:
    /// `(1+λ)·net(x,c,t) − λ·net(x,t)`.
    fn eval_cfg(&self, x: &[f32], t: f32, onehot: &[f32], lambda: f32,
                out: &mut [f32], rng: &mut Rng) {
        let d = self.dim();
        let mut cond = vec![0.0f32; d];
        let mut unc = vec![0.0f32; d];
        self.eval(x, t, onehot, &mut cond, rng);
        let zeros = vec![0.0f32; onehot.len()];
        self.eval(x, t, &zeros, &mut unc, rng);
        for i in 0..d {
            out[i] = (1.0 + lambda) * cond[i] - lambda * unc[i];
        }
    }

    /// Evaluate B lane-contiguous states (`xs` = batch × dim, row-major)
    /// sharing one `(t, onehot)`.  Default: per-lane [`Self::eval`]
    /// fallback.  Noisy implementations draw per lane in lane order from
    /// `rng`.
    fn eval_batch(&self, xs: &[f32], t: f32, onehot: &[f32], out: &mut [f32],
                  scratch: &mut BatchScratch, rng: &mut Rng) {
        let _ = scratch;
        let d = self.dim();
        debug_assert_eq!(xs.len() % d, 0);
        debug_assert_eq!(xs.len(), out.len());
        for (xrow, orow) in xs.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.eval(xrow, t, onehot, orow, rng);
        }
    }

    /// Batched classifier-free guidance: both CFG branches run through
    /// [`Self::eval_batch`], so native batched implementations are reused.
    #[allow(clippy::too_many_arguments)]
    fn eval_cfg_batch(&self, xs: &[f32], t: f32, onehot: &[f32], lambda: f32,
                      out: &mut [f32], scratch: &mut BatchScratch,
                      rng: &mut Rng) {
        let len = xs.len();
        debug_assert_eq!(out.len(), len);
        // take the CFG buffers out so `scratch` stays free for eval_batch
        let mut cond = std::mem::take(&mut scratch.cond);
        let mut unc = std::mem::take(&mut scratch.unc);
        let mut zeros = std::mem::take(&mut scratch.zeros);
        if cond.len() < len {
            cond.resize(len, 0.0);
        }
        if unc.len() < len {
            unc.resize(len, 0.0);
        }
        zeros.clear();
        zeros.resize(onehot.len(), 0.0);
        self.eval_batch(xs, t, onehot, &mut cond[..len], scratch, rng);
        self.eval_batch(xs, t, &zeros, &mut unc[..len], scratch, rng);
        for (o, (&c, &u)) in out.iter_mut().zip(cond.iter().zip(unc.iter())) {
            *o = (1.0 + lambda) * c - lambda * u;
        }
        scratch.cond = cond;
        scratch.unc = unc;
        scratch.zeros = zeros;
    }
}
