//! The paper's score network: a 3-layer fully connected net (2→14→14→2)
//! with sinusoidal time embedding and optional condition embedding injected
//! as bias currents into both hidden layers (Fig. 2i / 4b).
//!
//! Two interchangeable realizations implement [`ScoreNet`]:
//! * [`mlp::AnalogScoreNet`] — crossbar tiles + TIA + diode-ReLU, with
//!   device read/write noise (the paper's hardware).
//! * [`mlp::DigitalScoreNet`] — exact f32 weight-space math (the software
//!   baseline the paper compares against, and the semantics of the AOT
//!   artifacts).

pub mod embedding;
pub mod loader;
pub mod mlp;

pub use embedding::Embedding;
pub use loader::ScoreWeights;
pub use mlp::{AnalogScoreNet, DigitalScoreNet};

use crate::util::rng::Rng;

/// The epsilon-parameterized score network interface.
///
/// `eval` writes the network output ``net(x, t)`` (≈ the noise prediction;
/// score = −net/σ(t)) into `out`.  `onehot` is the condition (all-zero =
/// unconditional / CFG null token).  `rng` feeds device noise in analog
/// implementations; digital ones ignore it.
pub trait ScoreNet: Send + Sync {
    /// State dimension (2 for both paper tasks).
    fn dim(&self) -> usize;
    /// Number of condition classes (0 = unconditional-only net).
    fn n_classes(&self) -> usize;
    /// Evaluate the network for a single state vector.
    fn eval(&self, x: &[f32], t: f32, onehot: &[f32], out: &mut [f32], rng: &mut Rng);

    /// Classifier-free guidance (paper Eq. 7), in network space:
    /// `(1+λ)·net(x,c,t) − λ·net(x,t)`.
    fn eval_cfg(&self, x: &[f32], t: f32, onehot: &[f32], lambda: f32,
                out: &mut [f32], rng: &mut Rng) {
        let d = self.dim();
        let mut cond = vec![0.0f32; d];
        let mut unc = vec![0.0f32; d];
        self.eval(x, t, onehot, &mut cond, rng);
        let zeros = vec![0.0f32; onehot.len()];
        self.eval(x, t, &zeros, &mut unc, rng);
        for i in 0..d {
            out[i] = (1.0 + lambda) * cond[i] - lambda * unc[i];
        }
    }
}
