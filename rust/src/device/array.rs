//! 32×32 1T1R crossbar macro (the paper's in-memory computing unit).
//!
//! Rows share a Word Line (transistor gates) and Source Line; columns share
//! a Bit Line connected to the cells' top electrodes.  The macro supports
//! two modes, as on the PCB (Methods): **programming** (write-verify via
//! the B1500A-analogue) and **computation** (voltages on BLs, currents
//! summed on SLs — Ohm's law × Kirchhoff's current law).

use super::cell::{Cell, CellParams, G_HI_MS, G_LO_MS};
use crate::util::rng::Rng;
use crate::util::tensor::Mat;

/// Physical array dimension of one macro (paper: 32×32).
pub const MACRO_DIM: usize = 32;

/// Result of programming a full target pattern.
#[derive(Debug, Clone, Default)]
pub struct ProgramStats {
    /// Pulses used per cell (write-verify iterations, Fig. 5b).
    pub pulses: Vec<usize>,
    /// Cells that failed to verify within the pulse budget.
    pub failures: usize,
    /// Final absolute conductance errors |G - target| in mS (Fig. 2g).
    pub abs_errors_ms: Vec<f32>,
}

impl ProgramStats {
    pub fn mean_pulses(&self) -> f64 {
        if self.pulses.is_empty() {
            return 0.0;
        }
        self.pulses.iter().sum::<usize>() as f64 / self.pulses.len() as f64
    }

    pub fn max_error_ms(&self) -> f32 {
        self.abs_errors_ms.iter().copied().fold(0.0, f32::max)
    }
}

/// One 32×32 (or smaller) 1T1R macro.
#[derive(Debug, Clone)]
pub struct Macro {
    rows: usize,
    cols: usize,
    cells: Vec<Cell>,
}

impl Macro {
    /// Fresh macro with all cells at the window floor.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows <= MACRO_DIM && cols <= MACRO_DIM, "exceeds 32x32 macro");
        let cells = (0..rows * cols)
            .map(|_| Cell::with_default(G_LO_MS))
            .collect();
        Macro { rows, cols, cells }
    }

    /// Macro with custom device parameters (noise ablations).
    pub fn with_params(rows: usize, cols: usize, params: CellParams) -> Self {
        assert!(rows <= MACRO_DIM && cols <= MACRO_DIM);
        let cells = (0..rows * cols)
            .map(|_| Cell::new(G_LO_MS, params.clone()))
            .collect();
        Macro { rows, cols, cells }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn cell(&self, r: usize, c: usize) -> &Cell {
        &self.cells[r * self.cols + c]
    }

    #[inline]
    pub fn cell_mut(&mut self, r: usize, c: usize) -> &mut Cell {
        &mut self.cells[r * self.cols + c]
    }

    /// Inject stuck-at faults into a fraction of cells (yield model).
    pub fn inject_faults(&mut self, fraction: f64, rng: &mut Rng) {
        for cell in &mut self.cells {
            if rng.uniform() < fraction {
                cell.set_stuck(true);
            }
        }
    }

    /// Program a conductance pattern with write-verify (Fig. 2f / 5b).
    ///
    /// `targets` must be rows×cols in mS; values are clamped to the window.
    pub fn program(&mut self, targets: &Mat, tol_ms: f32, max_pulses: usize,
                   rng: &mut Rng) -> ProgramStats {
        assert_eq!(targets.shape(), (self.rows, self.cols));
        let mut stats = ProgramStats::default();
        for r in 0..self.rows {
            for c in 0..self.cols {
                let target = targets.get(r, c).clamp(G_LO_MS, G_HI_MS);
                match self.cell_mut(r, c).program_verify(target, tol_ms, max_pulses, rng) {
                    Some(p) => stats.pulses.push(p),
                    None => stats.failures += 1,
                }
                stats
                    .abs_errors_ms
                    .push((self.cell(r, c).conductance() - target).abs());
            }
        }
        stats
    }

    /// Noise-free conductance snapshot (the "true" programmed weights).
    pub fn conductances(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| self.cell(r, c).conductance())
    }

    /// One noisy read of the full array (Fig. 2g error-distribution data).
    pub fn read_all(&self, rng: &mut Rng) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| self.cell(r, c).read(rng))
    }

    /// Analog MVM in computation mode: BL voltages (len = rows) drive the
    /// array; SL currents (len = cols) are the Kirchhoff sums of Ohm's-law
    /// products against *instantaneous noisy* conductances.
    ///
    /// Units: volts (software units) × mS → current in software-unit·mS;
    /// the TIA stage in [`crate::crossbar`] converts back to voltage.
    pub fn mvm(&self, v_bl: &[f32], out_sl: &mut [f32], rng: &mut Rng) {
        assert_eq!(v_bl.len(), self.rows);
        assert_eq!(out_sl.len(), self.cols);
        out_sl.fill(0.0);
        for r in 0..self.rows {
            let v = v_bl[r];
            if v == 0.0 {
                continue;
            }
            for c in 0..self.cols {
                out_sl[c] += v * self.cell(r, c).read(rng);
            }
        }
    }

    /// Deterministic MVM against the true conductances (no read noise) —
    /// the idealized reference the noise ablations compare against.
    pub fn mvm_ideal(&self, v_bl: &[f32], out_sl: &mut [f32]) {
        assert_eq!(v_bl.len(), self.rows);
        assert_eq!(out_sl.len(), self.cols);
        out_sl.fill(0.0);
        for r in 0..self.rows {
            let v = v_bl[r];
            if v == 0.0 {
                continue;
            }
            for c in 0..self.cols {
                out_sl[c] += v * self.cell(r, c).conductance();
            }
        }
    }

    /// Age the whole array by `dt_s` seconds (retention experiments).
    pub fn age(&mut self, dt_s: f64, rng: &mut Rng) {
        for cell in &mut self.cells {
            cell.drift(dt_s, rng);
        }
    }

    /// The moon-and-star demo pattern of Fig. 2f, scaled into the window.
    /// A crescent moon (disk minus offset disk) plus a 4-point star.
    pub fn moon_star_pattern(dim: usize) -> Mat {
        let f = dim as f32;
        Mat::from_fn(dim, dim, |r, c| {
            let (y, x) = (r as f32 / f - 0.5, c as f32 / f - 0.5);
            // moon: disk at (-0.12, -0.1) r=0.3 minus disk at (-0.04, -0.02) r=0.26
            let d1 = ((x + 0.12).powi(2) + (y + 0.10).powi(2)).sqrt();
            let d2 = ((x + 0.02).powi(2) + (y + 0.02).powi(2)).sqrt();
            let moon = d1 < 0.30 && d2 > 0.26;
            // star: diamond |x-cx| + |y-cy| < 0.12 around (0.25, 0.22)
            let star = (x - 0.25).abs() + (y - 0.22).abs() < 0.12;
            if moon || star {
                G_HI_MS
            } else {
                G_LO_MS + 0.1 * (G_HI_MS - G_LO_MS)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn program_pattern_accurate() {
        let mut rng = Rng::new(7);
        let mut m = Macro::new(16, 16);
        let targets = Mat::from_fn(16, 16, |r, c| {
            G_LO_MS + (G_HI_MS - G_LO_MS) * ((r * 16 + c) as f32 / 255.0)
        });
        let st = m.program(&targets, 0.0015, 500, &mut rng);
        assert_eq!(st.failures, 0);
        assert!(st.max_error_ms() < 0.004, "max err {}", st.max_error_ms());
        assert!(st.mean_pulses() > 1.0, "write-verify should need pulses");
    }

    #[test]
    fn program_errors_gaussian_like() {
        // Fig. 2g: relative conductance errors roughly symmetric, small.
        let mut rng = Rng::new(9);
        let mut m = Macro::new(32, 32);
        let targets = Mat::full(32, 32, 0.06);
        let _ = m.program(&targets, 0.0015, 500, &mut rng);
        let snap = m.conductances();
        let errs: Vec<f32> = snap.as_slice().iter().map(|&g| g - 0.06).collect();
        let mu = stats::mean(&errs);
        let sd = stats::std(&errs);
        assert!(mu.abs() < 0.001, "bias {mu}");
        assert!(sd > 0.0 && sd < 0.002, "std {sd}");
    }

    #[test]
    fn mvm_matches_manual_sum() {
        let mut rng = Rng::new(3);
        let mut m = Macro::new(4, 3);
        let targets = Mat::from_fn(4, 3, |r, c| 0.02 + 0.01 * (r + c) as f32);
        let _ = m.program(&targets, 0.0005, 2000, &mut rng);
        let v = [1.0f32, -0.5, 0.25, 2.0];
        let mut out = [0.0f32; 3];
        m.mvm_ideal(&v, &mut out);
        let g = m.conductances();
        for c in 0..3 {
            let want: f32 = (0..4).map(|r| v[r] * g.get(r, c)).sum();
            assert!((out[c] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn mvm_noisy_fluctuates_around_ideal() {
        let mut rng = Rng::new(5);
        let mut m = Macro::new(8, 8);
        let _ = m.program(&Mat::full(8, 8, 0.06), 0.001, 1000, &mut rng);
        let v = [1.0f32; 8];
        let mut ideal = [0.0f32; 8];
        m.mvm_ideal(&v, &mut ideal);
        let mut acc = vec![0.0f64; 8];
        let n = 2000;
        let mut any_diff = false;
        for _ in 0..n {
            let mut noisy = [0.0f32; 8];
            m.mvm(&v, &mut noisy, &mut rng);
            for c in 0..8 {
                acc[c] += noisy[c] as f64;
                if (noisy[c] - ideal[c]).abs() > 1e-7 {
                    any_diff = true;
                }
            }
        }
        assert!(any_diff, "read noise must perturb MVM");
        for c in 0..8 {
            let mean = acc[c] / n as f64;
            assert!(
                (mean - ideal[c] as f64).abs() < 0.01 * ideal[c].abs() as f64 + 1e-4,
                "col {c}: mean {mean} vs ideal {}",
                ideal[c]
            );
        }
    }

    #[test]
    fn faults_limit_programming() {
        let mut rng = Rng::new(11);
        let mut m = Macro::new(16, 16);
        m.inject_faults(0.2, &mut rng);
        let st = m.program(&Mat::full(16, 16, 0.09), 0.001, 200, &mut rng);
        assert!(st.failures > 0, "stuck cells must fail verify");
        assert!(st.failures < 16 * 16 / 2);
    }

    #[test]
    fn moon_star_pattern_structure() {
        let p = Macro::moon_star_pattern(32);
        let hi = p.as_slice().iter().filter(|&&g| g > 0.09).count();
        // both shapes present but sparse
        assert!(hi > 30 && hi < 512, "hi cells = {hi}");
    }

    #[test]
    fn aging_preserves_window() {
        let mut rng = Rng::new(13);
        let mut m = Macro::new(8, 8);
        let _ = m.program(&Mat::full(8, 8, 0.07), 0.001, 500, &mut rng);
        m.age(1e6, &mut rng);
        for g in m.conductances().as_slice() {
            assert!(*g >= G_LO_MS && *g <= G_HI_MS);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds 32x32")]
    fn oversize_macro_rejected() {
        let _ = Macro::new(33, 8);
    }
}
