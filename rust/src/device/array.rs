//! 32×32 1T1R crossbar macro (the paper's in-memory computing unit).
//!
//! Rows share a Word Line (transistor gates) and Source Line; columns share
//! a Bit Line connected to the cells' top electrodes.  The macro supports
//! two modes, as on the PCB (Methods): **programming** (write-verify via
//! the B1500A-analogue) and **computation** (voltages on BLs, currents
//! summed on SLs — Ohm's law × Kirchhoff's current law).

use super::cell::{Cell, CellParams, G_HI_MS, G_LO_MS};
use crate::util::rng::Rng;
use crate::util::tensor::Mat;

/// Physical array dimension of one macro (paper: 32×32).
pub const MACRO_DIM: usize = 32;

/// Result of programming a full target pattern.
#[derive(Debug, Clone, Default)]
pub struct ProgramStats {
    /// Pulses used per cell (write-verify iterations, Fig. 5b).
    pub pulses: Vec<usize>,
    /// Cells that failed to verify within the pulse budget.
    pub failures: usize,
    /// Final absolute conductance errors |G - target| in mS (Fig. 2g).
    pub abs_errors_ms: Vec<f32>,
}

impl ProgramStats {
    pub fn mean_pulses(&self) -> f64 {
        if self.pulses.is_empty() {
            return 0.0;
        }
        self.pulses.iter().sum::<usize>() as f64 / self.pulses.len() as f64
    }

    pub fn max_error_ms(&self) -> f32 {
        self.abs_errors_ms.iter().copied().fold(0.0, f32::max)
    }

    /// Fold another macro's programming result into this aggregate.
    pub fn merge(&mut self, other: ProgramStats) {
        self.pulses.extend(other.pulses);
        self.failures += other.failures;
        self.abs_errors_ms.extend(other.abs_errors_ms);
    }
}

/// Retention-drift measurement against a programmed-target snapshot:
/// the live `|G − target|` residuals plus the stuck-cell census, the raw
/// material for the health monitor's per-bank drift gauges.
#[derive(Debug, Clone, Default)]
pub struct DriftStats {
    /// Cells compared.
    pub cells: usize,
    /// Σ |G − target| in mS (use [`Self::mean_abs_ms`]).
    pub sum_abs_ms: f64,
    /// max |G − target| in mS.
    pub max_abs_ms: f32,
    /// Cells with the stuck-at fault flag set.
    pub stuck: usize,
}

impl DriftStats {
    pub fn mean_abs_ms(&self) -> f64 {
        if self.cells == 0 {
            return 0.0;
        }
        self.sum_abs_ms / self.cells as f64
    }

    /// Stuck cells as a percentage of the compared population.
    pub fn stuck_pct(&self) -> f64 {
        if self.cells == 0 {
            return 0.0;
        }
        100.0 * self.stuck as f64 / self.cells as f64
    }

    pub fn merge(&mut self, other: &DriftStats) {
        self.cells += other.cells;
        self.sum_abs_ms += other.sum_abs_ms;
        self.max_abs_ms = self.max_abs_ms.max(other.max_abs_ms);
        self.stuck += other.stuck;
    }
}

/// One 32×32 (or smaller) 1T1R macro.
#[derive(Debug, Clone)]
pub struct Macro {
    rows: usize,
    cols: usize,
    cells: Vec<Cell>,
}

impl Macro {
    /// Fresh macro with all cells at the window floor.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows <= MACRO_DIM && cols <= MACRO_DIM, "exceeds 32x32 macro");
        let cells = (0..rows * cols)
            .map(|_| Cell::with_default(G_LO_MS))
            .collect();
        Macro { rows, cols, cells }
    }

    /// Macro with custom device parameters (noise ablations).
    pub fn with_params(rows: usize, cols: usize, params: CellParams) -> Self {
        assert!(rows <= MACRO_DIM && cols <= MACRO_DIM);
        let cells = (0..rows * cols)
            .map(|_| Cell::new(G_LO_MS, params.clone()))
            .collect();
        Macro { rows, cols, cells }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn cell(&self, r: usize, c: usize) -> &Cell {
        &self.cells[r * self.cols + c]
    }

    #[inline]
    pub fn cell_mut(&mut self, r: usize, c: usize) -> &mut Cell {
        &mut self.cells[r * self.cols + c]
    }

    /// Inject stuck-at faults into a fraction of cells (yield model).
    pub fn inject_faults(&mut self, fraction: f64, rng: &mut Rng) {
        for cell in &mut self.cells {
            if rng.uniform() < fraction {
                cell.set_stuck(true);
            }
        }
    }

    /// Program a conductance pattern with write-verify (Fig. 2f / 5b).
    ///
    /// `targets` must be rows×cols in mS; values are clamped to the window.
    pub fn program(&mut self, targets: &Mat, tol_ms: f32, max_pulses: usize,
                   rng: &mut Rng) -> ProgramStats {
        assert_eq!(targets.shape(), (self.rows, self.cols));
        let mut stats = ProgramStats::default();
        for r in 0..self.rows {
            for c in 0..self.cols {
                let target = targets.get(r, c).clamp(G_LO_MS, G_HI_MS);
                match self.cell_mut(r, c).program_verify(target, tol_ms, max_pulses, rng) {
                    Some(p) => stats.pulses.push(p),
                    None => stats.failures += 1,
                }
                stats
                    .abs_errors_ms
                    .push((self.cell(r, c).conductance() - target).abs());
            }
        }
        stats
    }

    /// Noise-free conductance snapshot (the "true" programmed weights).
    pub fn conductances(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| self.cell(r, c).conductance())
    }

    /// One noisy read of the full array (Fig. 2g error-distribution data).
    pub fn read_all(&self, rng: &mut Rng) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| self.cell(r, c).read(rng))
    }

    /// Analog MVM in computation mode: BL voltages (len = rows) drive the
    /// array; SL currents (len = cols) are the Kirchhoff sums of Ohm's-law
    /// products against *instantaneous noisy* conductances.
    ///
    /// Units: volts (software units) × mS → current in software-unit·mS;
    /// the TIA stage in [`crate::crossbar`] converts back to voltage.
    pub fn mvm(&self, v_bl: &[f32], out_sl: &mut [f32], rng: &mut Rng) {
        assert_eq!(v_bl.len(), self.rows);
        assert_eq!(out_sl.len(), self.cols);
        out_sl.fill(0.0);
        for r in 0..self.rows {
            let v = v_bl[r];
            if v == 0.0 {
                continue;
            }
            for c in 0..self.cols {
                out_sl[c] += v * self.cell(r, c).read(rng);
            }
        }
    }

    /// Deterministic MVM against the true conductances (no read noise) —
    /// the idealized reference the noise ablations compare against.
    pub fn mvm_ideal(&self, v_bl: &[f32], out_sl: &mut [f32]) {
        assert_eq!(v_bl.len(), self.rows);
        assert_eq!(out_sl.len(), self.cols);
        out_sl.fill(0.0);
        for r in 0..self.rows {
            let v = v_bl[r];
            if v == 0.0 {
                continue;
            }
            for c in 0..self.cols {
                out_sl[c] += v * self.cell(r, c).conductance();
            }
        }
    }

    /// Age the whole array by `dt_s` seconds (retention experiments).
    /// No-op at `dt_s <= 0` (each cell's drift model short-circuits).
    pub fn age(&mut self, dt_s: f64, rng: &mut Rng) {
        for cell in &mut self.cells {
            cell.drift(dt_s, rng);
        }
    }

    /// Retention-clock alias for [`Self::age`]: the health monitor's
    /// background clock advances device time through this name.
    pub fn drift(&mut self, dt_s: f64, rng: &mut Rng) {
        self.age(dt_s, rng);
    }

    /// Measure live conductances against a target snapshot (same shape).
    pub fn drift_from(&self, targets: &Mat) -> DriftStats {
        assert_eq!(targets.shape(), (self.rows, self.cols));
        let mut st = DriftStats { cells: self.rows * self.cols, ..Default::default() };
        for r in 0..self.rows {
            for c in 0..self.cols {
                let cell = self.cell(r, c);
                let d = (cell.conductance() - targets.get(r, c)).abs();
                st.sum_abs_ms += d as f64;
                st.max_abs_ms = st.max_abs_ms.max(d);
                if cell.is_stuck() {
                    st.stuck += 1;
                }
            }
        }
        st
    }

    /// Stuck-at fault census.
    pub fn count_stuck(&self) -> usize {
        self.cells.iter().filter(|c| c.is_stuck()).count()
    }

    /// The moon-and-star demo pattern of Fig. 2f, scaled into the window.
    /// A crescent moon (disk minus offset disk) plus a 4-point star.
    pub fn moon_star_pattern(dim: usize) -> Mat {
        let f = dim as f32;
        Mat::from_fn(dim, dim, |r, c| {
            let (y, x) = (r as f32 / f - 0.5, c as f32 / f - 0.5);
            // moon: disk at (-0.12, -0.1) r=0.3 minus disk at (-0.04, -0.02) r=0.26
            let d1 = ((x + 0.12).powi(2) + (y + 0.10).powi(2)).sqrt();
            let d2 = ((x + 0.02).powi(2) + (y + 0.02).powi(2)).sqrt();
            let moon = d1 < 0.30 && d2 > 0.26;
            // star: diamond |x-cx| + |y-cy| < 0.12 around (0.25, 0.22)
            let star = (x - 0.25).abs() + (y - 0.22).abs() < 0.12;
            if moon || star {
                G_HI_MS
            } else {
                G_LO_MS + 0.1 * (G_HI_MS - G_LO_MS)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn program_pattern_accurate() {
        let mut rng = Rng::new(7);
        let mut m = Macro::new(16, 16);
        let targets = Mat::from_fn(16, 16, |r, c| {
            G_LO_MS + (G_HI_MS - G_LO_MS) * ((r * 16 + c) as f32 / 255.0)
        });
        let st = m.program(&targets, 0.0015, 500, &mut rng);
        assert_eq!(st.failures, 0);
        assert!(st.max_error_ms() < 0.004, "max err {}", st.max_error_ms());
        assert!(st.mean_pulses() > 1.0, "write-verify should need pulses");
    }

    #[test]
    fn program_errors_gaussian_like() {
        // Fig. 2g: relative conductance errors roughly symmetric, small.
        let mut rng = Rng::new(9);
        let mut m = Macro::new(32, 32);
        let targets = Mat::full(32, 32, 0.06);
        let _ = m.program(&targets, 0.0015, 500, &mut rng);
        let snap = m.conductances();
        let errs: Vec<f32> = snap.as_slice().iter().map(|&g| g - 0.06).collect();
        let mu = stats::mean(&errs);
        let sd = stats::std(&errs);
        assert!(mu.abs() < 0.001, "bias {mu}");
        assert!(sd > 0.0 && sd < 0.002, "std {sd}");
    }

    #[test]
    fn mvm_matches_manual_sum() {
        let mut rng = Rng::new(3);
        let mut m = Macro::new(4, 3);
        let targets = Mat::from_fn(4, 3, |r, c| 0.02 + 0.01 * (r + c) as f32);
        let _ = m.program(&targets, 0.0005, 2000, &mut rng);
        let v = [1.0f32, -0.5, 0.25, 2.0];
        let mut out = [0.0f32; 3];
        m.mvm_ideal(&v, &mut out);
        let g = m.conductances();
        for c in 0..3 {
            let want: f32 = (0..4).map(|r| v[r] * g.get(r, c)).sum();
            assert!((out[c] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn mvm_noisy_fluctuates_around_ideal() {
        let mut rng = Rng::new(5);
        let mut m = Macro::new(8, 8);
        let _ = m.program(&Mat::full(8, 8, 0.06), 0.001, 1000, &mut rng);
        let v = [1.0f32; 8];
        let mut ideal = [0.0f32; 8];
        m.mvm_ideal(&v, &mut ideal);
        let mut acc = vec![0.0f64; 8];
        let n = 2000;
        let mut any_diff = false;
        for _ in 0..n {
            let mut noisy = [0.0f32; 8];
            m.mvm(&v, &mut noisy, &mut rng);
            for c in 0..8 {
                acc[c] += noisy[c] as f64;
                if (noisy[c] - ideal[c]).abs() > 1e-7 {
                    any_diff = true;
                }
            }
        }
        assert!(any_diff, "read noise must perturb MVM");
        for c in 0..8 {
            let mean = acc[c] / n as f64;
            assert!(
                (mean - ideal[c] as f64).abs() < 0.01 * ideal[c].abs() as f64 + 1e-4,
                "col {c}: mean {mean} vs ideal {}",
                ideal[c]
            );
        }
    }

    #[test]
    fn faults_limit_programming() {
        let mut rng = Rng::new(11);
        let mut m = Macro::new(16, 16);
        m.inject_faults(0.2, &mut rng);
        let st = m.program(&Mat::full(16, 16, 0.09), 0.001, 200, &mut rng);
        assert!(st.failures > 0, "stuck cells must fail verify");
        assert!(st.failures < 16 * 16 / 2);
    }

    #[test]
    fn moon_star_pattern_structure() {
        let p = Macro::moon_star_pattern(32);
        let hi = p.as_slice().iter().filter(|&&g| g > 0.09).count();
        // both shapes present but sparse
        assert!(hi > 30 && hi < 512, "hi cells = {hi}");
    }

    #[test]
    fn aging_preserves_window() {
        let mut rng = Rng::new(13);
        let mut m = Macro::new(8, 8);
        let _ = m.program(&Mat::full(8, 8, 0.07), 0.001, 500, &mut rng);
        m.age(1e6, &mut rng);
        for g in m.conductances().as_slice() {
            assert!(*g >= G_LO_MS && *g <= G_HI_MS);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds 32x32")]
    fn oversize_macro_rejected() {
        let _ = Macro::new(33, 8);
    }

    #[test]
    fn drift_from_zero_at_snapshot_then_grows_with_age() {
        let mut rng = Rng::new(17);
        let mut m = Macro::new(12, 12);
        let _ = m.program(&Mat::full(12, 12, 0.055), 0.0015, 500, &mut rng);
        // baseline = current state: residual is exactly zero
        let snap = m.conductances();
        let st0 = m.drift_from(&snap);
        assert_eq!(st0.cells, 144);
        assert_eq!(st0.sum_abs_ms, 0.0);
        assert_eq!(st0.max_abs_ms, 0.0);
        // dt = 0 is a no-op: the retention clock may tick with zero step
        m.drift(0.0, &mut rng);
        assert_eq!(m.drift_from(&snap).sum_abs_ms, 0.0);
        // a real retention interval must move cells off the snapshot
        m.drift(1e9, &mut rng);
        let st1 = m.drift_from(&snap);
        assert!(st1.mean_abs_ms() > 0.0, "aging must register as drift");
        assert!(st1.max_abs_ms >= st1.mean_abs_ms() as f32);
        assert!(st1.max_abs_ms < 0.01, "1e9 s drift stays small (Fig. 2e)");
    }

    #[test]
    fn drift_stats_count_stuck_and_merge() {
        let mut rng = Rng::new(19);
        let mut m = Macro::new(10, 10);
        m.inject_faults(0.15, &mut rng);
        let n_stuck = m.count_stuck();
        assert!(n_stuck > 0, "15% fault injection on 100 cells");
        let snap = m.conductances();
        let st = m.drift_from(&snap);
        assert_eq!(st.stuck, n_stuck);
        assert!((st.stuck_pct() - 100.0 * n_stuck as f64 / 100.0).abs() < 1e-12);
        let mut agg = DriftStats::default();
        agg.merge(&st);
        agg.merge(&st);
        assert_eq!(agg.cells, 200);
        assert_eq!(agg.stuck, 2 * n_stuck);
    }
}
