//! Single 1T1R resistive-memory cell model.
//!
//! Figures of merit are taken from the paper's characterization:
//! * conductance window 0.02–0.10 mS with ≥64 discernible linear states
//!   (Fig. 2d),
//! * repeatable bipolar resistive switching under quasi-static sweeps
//!   (Fig. 2c),
//! * read noise: Gaussian conductance fluctuation whose magnitude scales
//!   with the mean conductance (Fig. 2e, Fig. 5c),
//! * write noise: stochastic SET/RESET increments — programming therefore
//!   uses a write-verify loop with a random landing point inside the
//!   tolerance band (Fig. 5b),
//! * retention: states stable over >1e6 s with small log-time drift
//!   (Fig. 2e).

use crate::util::rng::Rng;

/// Conductance units are mS throughout (matches python `kernels.ref`).
pub const G_LO_MS: f32 = 0.02;
pub const G_HI_MS: f32 = 0.10;
pub const N_LEVELS: usize = 64;

/// Device parameters with paper-derived defaults.
#[derive(Debug, Clone)]
pub struct CellParams {
    /// Mean conductance increment of one SET pulse, fraction of window.
    pub set_step_frac: f32,
    /// Mean decrement of one RESET pulse, fraction of window.
    pub reset_step_frac: f32,
    /// Cycle-to-cycle variability of a pulse increment (relative std).
    pub pulse_cv: f32,
    /// Read-noise std as a fraction of current conductance (Fig. 5c).
    pub read_noise_frac: f32,
    /// SET threshold voltage (V) for quasi-static sweeps.
    pub v_set: f32,
    /// RESET threshold voltage (V, negative).
    pub v_reset: f32,
    /// Retention drift coefficient per log10-decade of seconds.
    pub drift_per_decade: f32,
}

impl Default for CellParams {
    fn default() -> Self {
        CellParams {
            set_step_frac: 0.04,
            reset_step_frac: 0.05,
            pulse_cv: 0.35,
            read_noise_frac: 0.01,
            v_set: 1.0,
            v_reset: -1.1,
            drift_per_decade: 0.002,
        }
    }
}

/// One 1T1R cell: internal "true" conductance plus stochastic dynamics.
#[derive(Debug, Clone)]
pub struct Cell {
    g_ms: f32,
    params: CellParams,
    /// Stuck-at fault: programming no longer changes the conductance
    /// (yield model for Fig. 2f's array-level imperfections).
    stuck: bool,
}

impl Cell {
    pub fn new(g_init_ms: f32, params: CellParams) -> Self {
        Cell { g_ms: g_init_ms.clamp(G_LO_MS, G_HI_MS), params, stuck: false }
    }

    pub fn with_default(g_init_ms: f32) -> Self {
        Cell::new(g_init_ms, CellParams::default())
    }

    /// True (noise-free) conductance in mS.
    pub fn conductance(&self) -> f32 {
        self.g_ms
    }

    pub fn is_stuck(&self) -> bool {
        self.stuck
    }

    pub fn set_stuck(&mut self, stuck: bool) {
        self.stuck = stuck;
    }

    /// Instantaneous read: conductance + proportional Gaussian fluctuation
    /// (random telegraph noise + thermal, lumped — Fig. 2e / 5c).
    pub fn read(&self, rng: &mut Rng) -> f32 {
        let noisy =
            self.g_ms * (1.0 + self.params.read_noise_frac * rng.gaussian_f32());
        noisy.clamp(0.0, 2.0 * G_HI_MS)
    }

    /// One SET pulse: increment with cycle-to-cycle variability, saturating
    /// toward the window ceiling (filament growth slows as it completes).
    pub fn set_pulse(&mut self, rng: &mut Rng) {
        if self.stuck {
            return;
        }
        let window = G_HI_MS - G_LO_MS;
        let headroom = (G_HI_MS - self.g_ms) / window; // 1 at floor, 0 at ceiling
        let step = self.params.set_step_frac
            * window
            * headroom.max(0.05)
            * (1.0 + self.params.pulse_cv * rng.gaussian_f32());
        self.g_ms = (self.g_ms + step.max(0.0)).clamp(G_LO_MS, G_HI_MS);
    }

    /// One RESET pulse: stochastic decrement, saturating toward the floor.
    pub fn reset_pulse(&mut self, rng: &mut Rng) {
        if self.stuck {
            return;
        }
        let window = G_HI_MS - G_LO_MS;
        let headroom = (self.g_ms - G_LO_MS) / window;
        let step = self.params.reset_step_frac
            * window
            * headroom.max(0.05)
            * (1.0 + self.params.pulse_cv * rng.gaussian_f32());
        self.g_ms = (self.g_ms - step.max(0.0)).clamp(G_LO_MS, G_HI_MS);
    }

    /// Write-verify programming (Fig. 5b): pulse until a read lands within
    /// ±tol_ms of target.  Returns the number of pulses used, or None if
    /// max_pulses was exhausted (stuck / unlucky cell).
    pub fn program_verify(
        &mut self,
        target_ms: f32,
        tol_ms: f32,
        max_pulses: usize,
        rng: &mut Rng,
    ) -> Option<usize> {
        let target = target_ms.clamp(G_LO_MS, G_HI_MS);
        for pulse in 0..max_pulses {
            let g = self.read(rng);
            let err = g - target;
            if err.abs() <= tol_ms {
                return Some(pulse);
            }
            if err < 0.0 {
                self.set_pulse(rng);
            } else {
                self.reset_pulse(rng);
            }
        }
        None
    }

    /// Retention drift after `dt_s` seconds at rest: small deterministic
    /// log-time relaxation toward the window midpoint plus a random walk.
    pub fn drift(&mut self, dt_s: f64, rng: &mut Rng) {
        if dt_s <= 0.0 {
            return;
        }
        let decades = (1.0 + dt_s).log10() as f32;
        let mid = 0.5 * (G_LO_MS + G_HI_MS);
        let pull = self.params.drift_per_decade * decades * (mid - self.g_ms);
        let walk = self.params.drift_per_decade
            * 0.5
            * decades.sqrt()
            * (G_HI_MS - G_LO_MS)
            * rng.gaussian_f32();
        self.g_ms = (self.g_ms + pull + walk).clamp(G_LO_MS, G_HI_MS);
    }

    /// The k-th of the 64 linear programmable levels (Fig. 2d).
    pub fn level_conductance(k: usize) -> f32 {
        assert!(k < N_LEVELS);
        G_LO_MS + (G_HI_MS - G_LO_MS) * k as f32 / (N_LEVELS - 1) as f32
    }

    /// Quasi-static I-V sweep (Fig. 2c): drive the voltage sequence and
    /// return per-point currents (mA) while the cell switches bipolar-ly.
    /// Threshold positions carry cycle-to-cycle variability.
    pub fn iv_sweep(&mut self, voltages: &[f32], rng: &mut Rng) -> Vec<f32> {
        let v_set = self.params.v_set * (1.0 + 0.05 * rng.gaussian_f32());
        let v_reset = self.params.v_reset * (1.0 + 0.05 * rng.gaussian_f32());
        let mut out = Vec::with_capacity(voltages.len());
        for &v in voltages {
            if v >= v_set {
                // gradual SET: filament grows while overdrive persists
                let over = ((v - v_set) / 0.3).min(1.0);
                self.g_ms =
                    (self.g_ms + over * 0.3 * (G_HI_MS - self.g_ms)).clamp(G_LO_MS, G_HI_MS);
            } else if v <= v_reset {
                let over = ((v_reset - v) / 0.3).min(1.0);
                self.g_ms =
                    (self.g_ms - over * 0.3 * (self.g_ms - G_LO_MS)).clamp(G_LO_MS, G_HI_MS);
            }
            // mild conduction nonlinearity on top of Ohm's law
            let i = self.g_ms * v * (1.0 + 0.08 * v * v);
            out.push(i);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn levels_are_linear_and_within_window() {
        let g0 = Cell::level_conductance(0);
        let g63 = Cell::level_conductance(63);
        assert!((g0 - G_LO_MS).abs() < 1e-7);
        assert!((g63 - G_HI_MS).abs() < 1e-7);
        let step = Cell::level_conductance(1) - g0;
        for k in 1..N_LEVELS {
            let d = Cell::level_conductance(k) - Cell::level_conductance(k - 1);
            assert!((d - step).abs() < 1e-6);
        }
    }

    #[test]
    fn read_noise_scales_with_conductance() {
        let mut r = rng();
        let lo = Cell::with_default(0.02);
        let hi = Cell::with_default(0.10);
        let n = 50_000;
        let std_of = |c: &Cell, r: &mut Rng| {
            let xs: Vec<f32> = (0..n).map(|_| c.read(r) - c.conductance()).collect();
            crate::util::stats::std(&xs)
        };
        let s_lo = std_of(&lo, &mut r);
        let s_hi = std_of(&hi, &mut r);
        assert!(s_hi > 3.0 * s_lo, "read noise must scale with G: {s_lo} vs {s_hi}");
        assert!((s_hi - 0.10 * 0.01).abs() / (0.10 * 0.01) < 0.15);
    }

    #[test]
    fn set_pulses_increase_reset_decrease() {
        let mut r = rng();
        let mut c = Cell::with_default(0.05);
        let g0 = c.conductance();
        for _ in 0..5 {
            c.set_pulse(&mut r);
        }
        assert!(c.conductance() > g0);
        let g1 = c.conductance();
        for _ in 0..5 {
            c.reset_pulse(&mut r);
        }
        assert!(c.conductance() < g1);
    }

    #[test]
    fn conductance_stays_in_window_under_pulsing() {
        let mut r = rng();
        let mut c = Cell::with_default(0.06);
        for _ in 0..1000 {
            if r.uniform() < 0.5 {
                c.set_pulse(&mut r);
            } else {
                c.reset_pulse(&mut r);
            }
            assert!(c.conductance() >= G_LO_MS && c.conductance() <= G_HI_MS);
        }
    }

    #[test]
    fn program_verify_converges() {
        let mut r = rng();
        for k in [5, 20, 40, 60] {
            let mut c = Cell::with_default(0.05);
            let target = Cell::level_conductance(k);
            let tol = 0.0015; // ~1.2 levels
            let pulses = c.program_verify(target, tol, 500, &mut r);
            assert!(pulses.is_some(), "did not converge to level {k}");
            assert!((c.conductance() - target).abs() <= tol + 0.002);
        }
    }

    #[test]
    fn program_verify_pulse_count_is_stochastic() {
        let mut r = rng();
        let counts: Vec<usize> = (0..50)
            .map(|_| {
                let mut c = Cell::with_default(0.03);
                c.program_verify(0.08, 0.0015, 500, &mut r).unwrap()
            })
            .collect();
        let distinct: std::collections::HashSet<_> = counts.iter().collect();
        assert!(distinct.len() > 3, "write noise must randomize pulse counts");
    }

    #[test]
    fn stuck_cell_ignores_programming() {
        let mut r = rng();
        let mut c = Cell::with_default(0.04);
        c.set_stuck(true);
        let g0 = c.conductance();
        for _ in 0..50 {
            c.set_pulse(&mut r);
        }
        assert_eq!(c.conductance(), g0);
        assert!(c.program_verify(0.09, 0.001, 100, &mut r).is_none());
    }

    #[test]
    fn retention_drift_small_but_nonzero() {
        let mut r = rng();
        let mut c = Cell::with_default(0.08);
        let g0 = c.conductance();
        c.drift(1e6, &mut r);
        let delta = (c.conductance() - g0).abs();
        assert!(delta > 0.0, "drift must perturb");
        assert!(delta < 0.01, "1e6 s drift must stay small (Fig. 2e): {delta}");
    }

    #[test]
    fn iv_sweep_shows_bipolar_hysteresis() {
        let mut r = rng();
        let mut c = Cell::with_default(G_LO_MS);
        // up sweep: 0 -> +1.5 -> 0 (SET), then 0 -> -1.5 -> 0 (RESET)
        let up: Vec<f32> = (0..60).map(|i| 1.5 * i as f32 / 59.0).collect();
        let down: Vec<f32> = up.iter().rev().copied().collect();
        let neg: Vec<f32> = (0..60).map(|i| -1.5 * i as f32 / 59.0).collect();
        let negb: Vec<f32> = neg.iter().rev().copied().collect();

        let i_up = c.iv_sweep(&up, &mut r);
        let g_after_set = c.conductance();
        let _ = c.iv_sweep(&down, &mut r);
        let _ = c.iv_sweep(&neg, &mut r);
        let g_after_reset = c.conductance();
        let _ = c.iv_sweep(&negb, &mut r);

        assert!(g_after_set > 0.8 * G_HI_MS, "SET must drive toward LRS");
        assert!(g_after_reset < 1.5 * G_LO_MS, "RESET must drive toward HRS");
        // hysteresis: current at +1.0 V higher after SET than before
        let idx_1v = up.iter().position(|&v| v >= 1.0).unwrap();
        let i_before = i_up[idx_1v.saturating_sub(5)];
        let i_after = *i_up.last().unwrap() * (1.0 / 1.5) / (1.0 + 0.08 * 1.0);
        assert!(i_after.abs() > i_before.abs());
    }

    #[test]
    fn iv_sweep_cycles_repeatable() {
        // 200-cycle repeatability (Fig. 2c): final conductances cluster.
        let mut r = rng();
        let up: Vec<f32> = (0..40).map(|i| 1.5 * i as f32 / 39.0).collect();
        let neg: Vec<f32> = (0..40).map(|i| -1.5 * i as f32 / 39.0).collect();
        let mut finals = Vec::new();
        let mut c = Cell::with_default(G_LO_MS);
        for _ in 0..200 {
            let _ = c.iv_sweep(&up, &mut r);
            finals.push(c.conductance());
            let _ = c.iv_sweep(&neg, &mut r);
        }
        let m = crate::util::stats::mean(&finals);
        let s = crate::util::stats::std(&finals);
        assert!(s / m < 0.1, "cycle variability too large: {s}/{m}");
    }
}
