//! Behavioural RRAM device simulator — the substrate standing in for the
//! paper's 180 nm TiN/TaOx/Ta2O5/TiN chips (DESIGN.md §3, substitution 1).
//!
//! * [`cell`] — a single 1T1R cell: 64-level conductance window, bipolar
//!   quasi-static IV switching (Fig. 2c), SET/RESET pulse dynamics with
//!   stochastic write noise (Fig. 5b), conductance-proportional read noise
//!   (Fig. 2e / 5c), and long-time retention drift (Fig. 2e).
//! * [`array`] — a 32×32 crossbar macro: WL/BL/SL addressing, write-verify
//!   programming, array-level conductance-error statistics (Fig. 2f/g),
//!   and the raw Ohm+Kirchhoff MVM.
//!
//! All stochastic behaviour flows through an explicit [`crate::util::Rng`],
//! so every device-level figure is reproducible from its seed.

pub mod array;
pub mod cell;

pub use array::{Macro, ProgramStats, MACRO_DIM};
pub use cell::{Cell, CellParams};
