//! Digital baseline samplers: discretized reverse-time integration of the
//! paper's Eq. (1)/(2) — N score-network inferences per sample, exactly
//! what the compared GPU runs.  Sweeping N against generation quality
//! produces the Fig. 3f / 4g speed-vs-quality trade-off.
//!
//! Update rules (positive step `dt`, integrating t: T → ε):
//!
//! ```text
//! score s = −net(x,t)/σ(t)                       (ε-parameterization)
//! SDE : x' = x − dt·(f(x,t) − β·s) + √(β·dt)·z,  z ~ N(0,I)
//! ODE : x' = x − dt·(f(x,t) − β/2·s)
//! ```
//!
//! followed by the protective state clamp — identical semantics to the
//! python `ref.euler_step` + clamp, and to the AOT `step_*` artifacts.
//!
//! ## Scalar vs batched path
//!
//! [`DigitalSampler::sample_into`] / [`DigitalSampler::sample_batch`] are
//! the per-sample reference lane: one trajectory at a time, N tiny
//! single-vector MVMs per step.  [`DigitalSampler::sample_batched`] is the
//! production lane: it advances all B states per timestep through
//! [`ScoreNet::eval_batch`] (B×dim GEMMs, embedding shared across lanes,
//! zero per-step allocation) with per-lane RNG streams split from the base
//! seed, so each lane's noise depends only on the seed and its lane index —
//! deterministic and independent of how requests were coalesced.  The
//! engines behind the serving coordinator route through the batched lane;
//! use the scalar lane for single-trajectory studies and as the parity
//! oracle.  In ODE mode (no Wiener draws) the two lanes are bitwise
//! identical; in SDE mode they agree in distribution (parity-tested).

use super::schedule::VpSchedule;
use crate::clamp_voltage;
use crate::exec::{self, lane_chunk_lens, lane_plan, Shards};
use crate::nn::{BatchScratch, ScoreNet};
use crate::util::rng::Rng;
use crate::util::tensor::scratch_slice;

/// Time-stepping scheme.  Heun and RK4 upgrade the probability-flow ODE
/// only; for the SDE they degrade to Euler–Maruyama (strong order 1/2 is
/// the noise-limited ceiling for this driver anyway).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerKind {
    Euler,
    Heun,
    Rk4,
}

/// Reverse SDE (Eq. 1) or probability-flow ODE (Eq. 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerMode {
    Sde,
    Ode,
}

/// A digital sampler bound to a score network.
pub struct DigitalSampler<'a> {
    pub net: &'a dyn ScoreNet,
    pub sched: VpSchedule,
    pub kind: SamplerKind,
    pub mode: SamplerMode,
    /// CFG guidance strength λ; None = unconditional evaluation.
    pub guidance: Option<f32>,
    /// Parallel-execution context for the batched lane's per-step state
    /// update (the score-net GEMMs parallelize inside the net itself).
    /// Per-lane RNG streams keep any chunking bitwise deterministic.
    pub exec: exec::Ctx,
}

impl<'a> DigitalSampler<'a> {
    pub fn new(net: &'a dyn ScoreNet, mode: SamplerMode) -> Self {
        DigitalSampler {
            net,
            sched: VpSchedule::default(),
            kind: SamplerKind::Euler,
            mode,
            guidance: None,
            exec: exec::Ctx::default(),
        }
    }

    pub fn with_kind(mut self, kind: SamplerKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn with_exec(mut self, exec: exec::Ctx) -> Self {
        self.exec = exec;
        self
    }

    pub fn with_guidance(mut self, lambda: f32) -> Self {
        self.guidance = Some(lambda);
        self
    }

    pub fn with_schedule(mut self, sched: VpSchedule) -> Self {
        self.sched = sched;
        self
    }

    #[inline]
    fn net_eval(&self, x: &[f32], t: f64, onehot: &[f32], out: &mut [f32],
                rng: &mut Rng) {
        match self.guidance {
            Some(lam) => self.net.eval_cfg(x, t as f32, onehot, lam, out, rng),
            None => self.net.eval(x, t as f32, onehot, out, rng),
        }
    }

    /// Reverse-time drift F(x,t) with the ε-parameterized score.
    #[inline]
    fn rhs(&self, x: &[f32], net_out: &[f32], t: f64, out: &mut [f32]) {
        let beta = self.sched.beta(t);
        let sigma = self.sched.sigma(t);
        // score = −net/σ; SDE uses β·score, ODE β/2·score
        let score_coeff = match self.mode {
            SamplerMode::Sde => beta / sigma,
            SamplerMode::Ode => 0.5 * beta / sigma,
        };
        for i in 0..x.len() {
            let drift = -0.5 * beta * x[i] as f64;
            out[i] = (drift + score_coeff * net_out[i] as f64) as f32;
        }
    }

    /// Batched reverse-time drift over `n` lane-contiguous states — the
    /// same per-element float ops as [`Self::rhs`], applied to B lanes.
    #[inline]
    fn rhs_batch(&self, x: &[f32], net_out: &[f32], t: f64, out: &mut [f32]) {
        let beta = self.sched.beta(t);
        let sigma = self.sched.sigma(t);
        let score_coeff = match self.mode {
            SamplerMode::Sde => beta / sigma,
            SamplerMode::Ode => 0.5 * beta / sigma,
        };
        for ((o, &xv), &nv) in out.iter_mut().zip(x).zip(net_out) {
            let drift = -0.5 * beta * xv as f64;
            *o = (drift + score_coeff * nv as f64) as f32;
        }
    }

    #[inline]
    fn net_eval_batch(&self, xs: &[f32], t: f64, onehot: &[f32],
                      out: &mut [f32], scratch: &mut BatchScratch,
                      rng: &mut Rng) {
        match self.guidance {
            Some(lam) => self.net.eval_cfg_batch(xs, t as f32, onehot, lam,
                                                out, scratch, rng),
            None => self.net.eval_batch(xs, t as f32, onehot, out, scratch, rng),
        }
    }

    /// Score-net inferences per integration step (CFG doubles them).
    fn evals_per_step(&self) -> usize {
        (match (self.kind, self.mode) {
            (SamplerKind::Heun, SamplerMode::Ode) => 2,
            (SamplerKind::Rk4, SamplerMode::Ode) => 4,
            _ => 1,
        }) * if self.guidance.is_some() { 2 } else { 1 }
    }

    /// Generate one sample of dimension `dim` with `n_steps` integration
    /// steps.  `onehot` selects the condition (empty or all-zero =
    /// unconditional).  Returns the final state; `x` doubles as the
    /// initial condition buffer (pass N(0,I) noise).
    pub fn sample_into(&self, x: &mut [f32], onehot: &[f32], n_steps: usize,
                       rng: &mut Rng) {
        let mut s = StepScratch::default();
        self.sample_into_scratch(x, onehot, n_steps, rng, &mut s);
    }

    /// Scalar stepper with caller-owned scratch (the per-sample loop of
    /// [`Self::sample_batch`] reuses one scratch across all samples).
    fn sample_into_scratch(&self, x: &mut [f32], onehot: &[f32],
                           n_steps: usize, rng: &mut Rng, s: &mut StepScratch) {
        let dim = x.len();
        let (dt, ts) = self.sched.reverse_grid(n_steps);
        let net_out = scratch_slice(&mut s.net_out, dim);
        let rhs = scratch_slice(&mut s.rhs, dim);
        let rhs2 = scratch_slice(&mut s.rhs2, dim);
        let x_pred = scratch_slice(&mut s.x_pred, dim);
        let k2 = scratch_slice(&mut s.k2, dim);
        let k3 = scratch_slice(&mut s.k3, dim);
        let k4 = scratch_slice(&mut s.k4, dim);

        for &t in &ts {
            self.net_eval(x, t, onehot, net_out, rng);
            self.rhs(x, net_out, t, rhs);
            match (self.kind, self.mode) {
                (SamplerKind::Euler, _)
                | (SamplerKind::Heun, SamplerMode::Sde)
                | (SamplerKind::Rk4, SamplerMode::Sde) => {
                    // Euler(-Maruyama); Heun degenerates to Euler for SDE
                    let diff = match self.mode {
                        SamplerMode::Sde => (self.sched.beta(t) * dt).sqrt(),
                        SamplerMode::Ode => 0.0,
                    };
                    for i in 0..dim {
                        let z = if diff > 0.0 { rng.gaussian_f32() } else { 0.0 };
                        x[i] = clamp_voltage(
                            x[i] - (dt as f32) * rhs[i] + (diff as f32) * z,
                        );
                    }
                }
                (SamplerKind::Heun, SamplerMode::Ode) => {
                    let t1 = (t - dt).max(self.sched.eps_t);
                    for i in 0..dim {
                        x_pred[i] = clamp_voltage(x[i] - (dt as f32) * rhs[i]);
                    }
                    self.net_eval(x_pred, t1, onehot, net_out, rng);
                    self.rhs(x_pred, net_out, t1, rhs2);
                    for i in 0..dim {
                        x[i] = clamp_voltage(
                            x[i] - (dt as f32) * 0.5 * (rhs[i] + rhs2[i]),
                        );
                    }
                }
                (SamplerKind::Rk4, SamplerMode::Ode) => {
                    // classical RK4 on the reverse-time ODE (negative step)
                    let h = -(dt as f32);
                    let tm = (t - 0.5 * dt).max(self.sched.eps_t);
                    let t1 = (t - dt).max(self.sched.eps_t);
                    // k2 at midpoint using k1 = rhs
                    for i in 0..dim {
                        x_pred[i] = clamp_voltage(x[i] + 0.5 * h * rhs[i]);
                    }
                    self.net_eval(x_pred, tm, onehot, net_out, rng);
                    self.rhs(x_pred, net_out, tm, k2);
                    // k3 at midpoint using k2
                    for i in 0..dim {
                        x_pred[i] = clamp_voltage(x[i] + 0.5 * h * k2[i]);
                    }
                    self.net_eval(x_pred, tm, onehot, net_out, rng);
                    self.rhs(x_pred, net_out, tm, k3);
                    // k4 at endpoint using k3
                    for i in 0..dim {
                        x_pred[i] = clamp_voltage(x[i] + h * k3[i]);
                    }
                    self.net_eval(x_pred, t1, onehot, net_out, rng);
                    self.rhs(x_pred, net_out, t1, k4);
                    for i in 0..dim {
                        x[i] = clamp_voltage(
                            x[i] + h / 6.0
                                * (rhs[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]),
                        );
                    }
                }
            }
        }
    }

    /// Generate `n` samples from N(0,I) priors; returns interleaved points
    /// (n × dim flattened) and the number of network inferences used.
    /// Scalar reference lane: one trajectory at a time.
    pub fn sample_batch(&self, n: usize, onehot: &[f32], n_steps: usize,
                        rng: &mut Rng) -> (Vec<f32>, usize) {
        let dim = self.net.dim();
        let mut out = vec![0.0f32; n * dim];
        let mut scratch = StepScratch::default();
        for s in 0..n {
            let x = &mut out[s * dim..(s + 1) * dim];
            {
                let _t = crate::obs::phase(crate::obs::Phase::NoisePass);
                for v in x.iter_mut() {
                    *v = rng.gaussian_f32();
                }
            }
            self.sample_into_scratch(x, onehot, n_steps, rng, &mut scratch);
        }
        (out, n * n_steps * self.evals_per_step())
    }

    /// Batched production lane: advance all `n` states per timestep through
    /// [`ScoreNet::eval_batch`] — one B×dim GEMM sweep per inference
    /// instead of B single-vector MVMs, embedding shared across lanes, zero
    /// per-step allocation.  Priors draw from `rng` lane-by-lane in the
    /// same order as [`Self::sample_batch`] (so ODE lanes are
    /// batch-prefix-stable); Wiener increments come from per-lane streams
    /// split off the base rng, keeping lanes decorrelated and the result
    /// deterministic per (seed, n).  In ODE mode this lane is bitwise
    /// identical to the scalar lane for digital nets; in SDE mode it
    /// agrees in distribution (parity-tested).
    pub fn sample_batched(&self, n: usize, onehot: &[f32], n_steps: usize,
                          rng: &mut Rng) -> (Vec<f32>, usize) {
        let dim = self.net.dim();
        let len = n * dim;
        let mut x = vec![0.0f32; len];
        {
            let _t = crate::obs::phase(crate::obs::Phase::NoisePass);
            for v in x.iter_mut() {
                *v = rng.gaussian_f32();
            }
        }
        let mut lane_rngs: Vec<Rng> = (0..n).map(|_| rng.split()).collect();
        let (dt, ts) = self.sched.reverse_grid(n_steps);
        // lane-chunk plan for the Euler update (fixed for the whole solve so
        // chunk boundaries — and the per-lane stream draws within them —
        // never move between steps); per-lane RNGs make any chunking
        // bitwise-deterministic, serial included
        let (upd_chunk, upd_tasks) =
            lane_plan(n, self.exec.lane_tasks(n, len));
        let lens_x = lane_chunk_lens(n, dim, upd_chunk, upd_tasks);
        let lens_r = lane_chunk_lens(n, 1, upd_chunk, upd_tasks);
        let mut s = StepScratch::default();
        let mut scratch = BatchScratch::new();
        let net_out = scratch_slice(&mut s.net_out, len);
        let rhs = scratch_slice(&mut s.rhs, len);
        let rhs2 = scratch_slice(&mut s.rhs2, len);
        let x_pred = scratch_slice(&mut s.x_pred, len);
        let k2 = scratch_slice(&mut s.k2, len);
        let k3 = scratch_slice(&mut s.k3, len);
        let k4 = scratch_slice(&mut s.k4, len);

        for &t in &ts {
            self.net_eval_batch(&x, t, onehot, net_out, &mut scratch, rng);
            self.rhs_batch(&x, net_out, t, rhs);
            match (self.kind, self.mode) {
                (SamplerKind::Euler, _)
                | (SamplerKind::Heun, SamplerMode::Sde)
                | (SamplerKind::Rk4, SamplerMode::Sde) => {
                    let diff = match self.mode {
                        SamplerMode::Sde => (self.sched.beta(t) * dt).sqrt(),
                        SamplerMode::Ode => 0.0,
                    };
                    // one update body for both execution shapes: a lane
                    // chunk is (states, its lanes' Wiener streams, the
                    // chunk's base offset into rhs)
                    let rhs_ro: &[f32] = rhs;
                    let update = |xc: &mut [f32], rngs: &mut [Rng],
                                  base: usize| {
                        for (bl, lane) in rngs.iter_mut().enumerate() {
                            for j in bl * dim..(bl + 1) * dim {
                                let z = if diff > 0.0 {
                                    lane.gaussian_f32()
                                } else {
                                    0.0
                                };
                                xc[j] = clamp_voltage(
                                    xc[j] - (dt as f32) * rhs_ro[base + j]
                                        + (diff as f32) * z,
                                );
                            }
                        }
                    };
                    if upd_tasks > 1 {
                        // one task per lane chunk; each lane's state and
                        // Wiener stream live whole inside one task, so the
                        // chunked update is bitwise equal to serial
                        let sx =
                            Shards::new(&mut x[..], lens_x.iter().copied());
                        let sr = Shards::new(&mut lane_rngs[..],
                                             lens_r.iter().copied());
                        self.exec.run(upd_tasks, &|ti| {
                            update(sx.take(ti), sr.take(ti),
                                   ti * upd_chunk * dim);
                        });
                    } else {
                        update(&mut x[..], &mut lane_rngs[..], 0);
                    }
                }
                (SamplerKind::Heun, SamplerMode::Ode) => {
                    let t1 = (t - dt).max(self.sched.eps_t);
                    for i in 0..len {
                        x_pred[i] = clamp_voltage(x[i] - (dt as f32) * rhs[i]);
                    }
                    self.net_eval_batch(x_pred, t1, onehot, net_out,
                                        &mut scratch, rng);
                    self.rhs_batch(x_pred, net_out, t1, rhs2);
                    for i in 0..len {
                        x[i] = clamp_voltage(
                            x[i] - (dt as f32) * 0.5 * (rhs[i] + rhs2[i]),
                        );
                    }
                }
                (SamplerKind::Rk4, SamplerMode::Ode) => {
                    let h = -(dt as f32);
                    let tm = (t - 0.5 * dt).max(self.sched.eps_t);
                    let t1 = (t - dt).max(self.sched.eps_t);
                    for i in 0..len {
                        x_pred[i] = clamp_voltage(x[i] + 0.5 * h * rhs[i]);
                    }
                    self.net_eval_batch(x_pred, tm, onehot, net_out,
                                        &mut scratch, rng);
                    self.rhs_batch(x_pred, net_out, tm, k2);
                    for i in 0..len {
                        x_pred[i] = clamp_voltage(x[i] + 0.5 * h * k2[i]);
                    }
                    self.net_eval_batch(x_pred, tm, onehot, net_out,
                                        &mut scratch, rng);
                    self.rhs_batch(x_pred, net_out, tm, k3);
                    for i in 0..len {
                        x_pred[i] = clamp_voltage(x[i] + h * k3[i]);
                    }
                    self.net_eval_batch(x_pred, t1, onehot, net_out,
                                        &mut scratch, rng);
                    self.rhs_batch(x_pred, net_out, t1, k4);
                    for i in 0..len {
                        x[i] = clamp_voltage(
                            x[i] + h / 6.0
                                * (rhs[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]),
                        );
                    }
                }
            }
        }
        (x, n * n_steps * self.evals_per_step())
    }
}

/// Reusable integration scratch — hoisted out of the per-sample loop so the
/// scalar lane allocates once per `sample_batch` call (not seven Vecs per
/// sample) and the batched lane once per batch.
#[derive(Debug, Default)]
struct StepScratch {
    net_out: Vec<f32>,
    rhs: Vec<f32>,
    rhs2: Vec<f32>,
    x_pred: Vec<f32>,
    k2: Vec<f32>,
    k3: Vec<f32>,
    k4: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    /// Analytic Gaussian score net: data x0 ~ N(0, s0² I) ⇒
    /// net(x,t) = σ(t)·x / (α²s0² + σ²)  (ε-parameterization of the
    /// closed-form score).  Lets sampler tests run without training.
    struct GaussianNet {
        s0: f64,
        sched: VpSchedule,
    }

    impl ScoreNet for GaussianNet {
        fn dim(&self) -> usize {
            2
        }

        fn n_classes(&self) -> usize {
            0
        }

        fn eval(&self, x: &[f32], t: f32, _onehot: &[f32], out: &mut [f32],
                _rng: &mut Rng) {
            let a = self.sched.alpha(t as f64);
            let sg = self.sched.sigma(t as f64);
            let v = a * a * self.s0 * self.s0 + sg * sg;
            for i in 0..x.len() {
                out[i] = (sg * x[i] as f64 / v) as f32;
            }
        }
    }

    fn run(mode: SamplerMode, kind: SamplerKind, steps: usize, n: usize) -> Vec<f32> {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let sampler = DigitalSampler::new(&net, mode).with_kind(kind);
        let mut rng = Rng::new(42);
        let (pts, _) = sampler.sample_batch(n, &[], steps, &mut rng);
        pts
    }

    fn std2(pts: &[f32]) -> (f64, f64) {
        let xs: Vec<f32> = pts.iter().step_by(2).copied().collect();
        let ys: Vec<f32> = pts.iter().skip(1).step_by(2).copied().collect();
        (stats::std(&xs), stats::std(&ys))
    }

    #[test]
    fn ode_euler_transports_gaussian() {
        let pts = run(SamplerMode::Ode, SamplerKind::Euler, 200, 2000);
        let (sx, sy) = std2(&pts);
        assert!((sx - 0.5).abs() < 0.05, "sx={sx}");
        assert!((sy - 0.5).abs() < 0.05, "sy={sy}");
    }

    #[test]
    fn sde_euler_transports_gaussian() {
        let pts = run(SamplerMode::Sde, SamplerKind::Euler, 400, 2000);
        let (sx, sy) = std2(&pts);
        assert!((sx - 0.5).abs() < 0.07, "sx={sx}");
        assert!((sy - 0.5).abs() < 0.07, "sy={sy}");
    }

    #[test]
    fn heun_ode_more_accurate_than_euler_at_few_steps() {
        let target = 0.5;
        let e = run(SamplerMode::Ode, SamplerKind::Euler, 8, 3000);
        let h = run(SamplerMode::Ode, SamplerKind::Heun, 8, 3000);
        let (se, _) = std2(&e);
        let (sh, _) = std2(&h);
        assert!(
            (sh - target).abs() <= (se - target).abs() + 0.005,
            "heun {sh} vs euler {se}"
        );
    }

    #[test]
    fn quality_improves_with_steps() {
        // SDE discretization error is O(sqrt(dt)) — visible at 2 steps,
        // gone at 256 (the ODE variant converges too fast to resolve
        // against the finite-sample noise floor of ~0.01).
        let errs: Vec<f64> = [2usize, 8, 64, 256]
            .iter()
            .map(|&s| {
                let pts = run(SamplerMode::Sde, SamplerKind::Euler, s, 4000);
                let (sx, _) = std2(&pts);
                (sx - 0.5).abs()
            })
            .collect();
        assert!(errs[0] > 0.03, "2-step SDE must be visibly wrong: {errs:?}");
        assert!(
            errs[3] < errs[0],
            "error must shrink with steps: {errs:?}"
        );
    }

    #[test]
    fn rk4_ode_transports_gaussian() {
        let pts = run(SamplerMode::Ode, SamplerKind::Rk4, 16, 3000);
        let (sx, sy) = std2(&pts);
        assert!((sx - 0.5).abs() < 0.04, "sx={sx}");
        assert!((sy - 0.5).abs() < 0.04, "sy={sy}");
    }

    #[test]
    fn rk4_accurate_at_very_few_steps() {
        // On this smooth analytic ODE even Euler sits near the sampling
        // noise floor at 4 steps, so "beats Euler" is not testable here;
        // assert 4-step RK4 is already within the floor instead.
        let r = run(SamplerMode::Ode, SamplerKind::Rk4, 4, 3000);
        let (sr, _) = std2(&r);
        assert!((sr - 0.5).abs() < 0.05, "rk4 4-step std {sr}");
    }

    #[test]
    fn rk4_sde_degrades_to_euler() {
        // identical RNG stream => identical samples
        let a = run(SamplerMode::Sde, SamplerKind::Rk4, 20, 10);
        let b = run(SamplerMode::Sde, SamplerKind::Euler, 20, 10);
        assert_eq!(a, b);
    }

    #[test]
    fn inference_count_accounting() {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let mut rng = Rng::new(0);
        let s = DigitalSampler::new(&net, SamplerMode::Ode);
        let (_, evals) = s.sample_batch(3, &[], 10, &mut rng);
        assert_eq!(evals, 30);
        let s = DigitalSampler::new(&net, SamplerMode::Ode).with_kind(SamplerKind::Heun);
        let (_, evals) = s.sample_batch(3, &[], 10, &mut rng);
        assert_eq!(evals, 60);
        let s = DigitalSampler::new(&net, SamplerMode::Ode).with_kind(SamplerKind::Rk4);
        let (_, evals) = s.sample_batch(3, &[], 10, &mut rng);
        assert_eq!(evals, 120);
        let s = DigitalSampler::new(&net, SamplerMode::Sde).with_guidance(2.0);
        let (_, evals) = s.sample_batch(3, &[], 10, &mut rng);
        assert_eq!(evals, 60);
    }

    #[test]
    fn state_stays_clamped() {
        let pts = run(SamplerMode::Sde, SamplerKind::Euler, 50, 500);
        for &v in &pts {
            assert!((-2.0..=4.0).contains(&v));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(SamplerMode::Sde, SamplerKind::Euler, 20, 10);
        let b = run(SamplerMode::Sde, SamplerKind::Euler, 20, 10);
        assert_eq!(a, b);
    }

    fn run_batched(mode: SamplerMode, kind: SamplerKind, steps: usize,
                   n: usize) -> (Vec<f32>, usize) {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        let sampler = DigitalSampler::new(&net, mode).with_kind(kind);
        let mut rng = Rng::new(42);
        sampler.sample_batched(n, &[], steps, &mut rng)
    }

    #[test]
    fn batched_ode_bitwise_matches_scalar() {
        // no Wiener draws in ODE mode ⇒ the batched lane must reproduce the
        // scalar lane exactly, for every stepper
        for kind in [SamplerKind::Euler, SamplerKind::Heun, SamplerKind::Rk4] {
            let scalar = run(SamplerMode::Ode, kind, 12, 9);
            let (batched, _) = run_batched(SamplerMode::Ode, kind, 12, 9);
            assert_eq!(scalar, batched, "{kind:?}");
        }
    }

    #[test]
    fn batched_sde_transports_gaussian() {
        let (pts, _) = run_batched(SamplerMode::Sde, SamplerKind::Euler, 400, 2000);
        let (sx, sy) = std2(&pts);
        assert!((sx - 0.5).abs() < 0.07, "sx={sx}");
        assert!((sy - 0.5).abs() < 0.07, "sy={sy}");
    }

    #[test]
    fn batched_inference_count_matches_scalar() {
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        for (kind, lam, want) in [
            (SamplerKind::Euler, None, 30usize),
            (SamplerKind::Heun, None, 60),
            (SamplerKind::Rk4, None, 120),
            (SamplerKind::Euler, Some(2.0), 60),
        ] {
            let mut s = DigitalSampler::new(&net, SamplerMode::Ode).with_kind(kind);
            if let Some(l) = lam {
                s = s.with_guidance(l);
            }
            let mut rng = Rng::new(0);
            let (_, evals) = s.sample_batched(3, &[], 10, &mut rng);
            assert_eq!(evals, want, "{kind:?} lam={lam:?}");
        }
    }

    #[test]
    fn batched_deterministic_and_clamped() {
        let (a, _) = run_batched(SamplerMode::Sde, SamplerKind::Euler, 50, 40);
        let (b, _) = run_batched(SamplerMode::Sde, SamplerKind::Euler, 50, 40);
        assert_eq!(a, b);
        for &v in &a {
            assert!((-2.0..=4.0).contains(&v));
        }
    }

    #[test]
    fn batched_update_bitwise_across_exec_contexts() {
        // per-lane RNG streams make the lane-chunked Euler update bitwise
        // equal to serial at any thread count, in ODE *and* SDE mode
        use crate::exec::{Ctx, ParStrategy, Pool};
        use std::sync::Arc;
        let net = GaussianNet { s0: 0.5, sched: VpSchedule::default() };
        for mode in [SamplerMode::Ode, SamplerMode::Sde] {
            let ctxs = [
                Ctx::serial(),
                Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(1))),
                Ctx::with_pool(ParStrategy::Lanes, Arc::new(Pool::new(4))),
            ];
            let outs: Vec<Vec<f32>> = ctxs
                .into_iter()
                .map(|ctx| {
                    let s = DigitalSampler::new(&net, mode).with_exec(ctx);
                    let mut rng = Rng::new(77);
                    s.sample_batched(10, &[], 25, &mut rng).0
                })
                .collect();
            assert_eq!(outs[0], outs[1], "{mode:?} 1-thread pool");
            assert_eq!(outs[0], outs[2], "{mode:?} 4-thread pool");
        }
    }
}
