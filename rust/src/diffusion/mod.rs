//! Score-based diffusion core: the VP-SDE schedule (paper Eq. 4–5), the
//! reverse-time samplers, and classifier-free guidance (Eq. 6–7).
//!
//! Two sampler families reproduce the paper's comparison:
//! * [`sampler`] — **digital baseline**: discretized Euler(-Maruyama) and
//!   Heun integration of Eq. (1)/(2), N network inferences per sample —
//!   what the paper's GPU runs.
//! * [`crate::analog::solver`] — **the contribution**: time-continuous
//!   closed-loop analog integration.

pub mod sampler;
pub mod schedule;

pub use sampler::{DigitalSampler, SamplerKind, SamplerMode};
pub use schedule::VpSchedule;
