//! VP-SDE schedule — rust mirror of `python/compile/schedule.py`.
//!
//! `beta(t) = beta_min + (beta_max − beta_min)·t/T`;
//! `f(x,t) = −β/2·x` (Eq. 4), `g(t) = √β` (Eq. 5).
//! See the python module docstring for the documented deviation from the
//! paper's quoted `beta_max = 0.5` and for the epsilon-parameterization
//! (`g²(t)/σ(t)` folded into the predetermined multiplier waveform).

/// Linear VP schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VpSchedule {
    pub beta_min: f64,
    pub beta_max: f64,
    pub t_end: f64,
    /// Smallest t used in sampling (σ(ε)>0).
    pub eps_t: f64,
}

impl Default for VpSchedule {
    fn default() -> Self {
        VpSchedule { beta_min: 0.001, beta_max: 12.0, t_end: 1.0, eps_t: 0.01 }
    }
}

impl VpSchedule {
    /// The paper's quoted range (ablation; see DESIGN.md §Deviations).
    pub fn paper_quoted() -> Self {
        VpSchedule { beta_max: 0.5, ..Self::default() }
    }

    /// Instantaneous noise rate β(t).
    #[inline]
    pub fn beta(&self, t: f64) -> f64 {
        self.beta_min + (self.beta_max - self.beta_min) * (t / self.t_end)
    }

    /// ∫₀ᵗ β(s) ds (closed form for the linear schedule).
    #[inline]
    pub fn int_beta(&self, t: f64) -> f64 {
        self.beta_min * t + 0.5 * (self.beta_max - self.beta_min) * t * t / self.t_end
    }

    /// Signal retention α(t) = exp(−½∫β).
    #[inline]
    pub fn alpha(&self, t: f64) -> f64 {
        (-0.5 * self.int_beta(t)).exp()
    }

    /// Perturbation std σ(t) = √(1−α²).
    #[inline]
    pub fn sigma(&self, t: f64) -> f64 {
        (1.0 - self.alpha(t).powi(2)).max(1e-12).sqrt()
    }

    /// The predetermined multiplier waveform g²(t)/σ(t) (ε-parameterized
    /// score: g²·score = −(g²/σ)·net).
    #[inline]
    pub fn g2_over_sigma(&self, t: f64) -> f64 {
        self.beta(t) / self.sigma(t)
    }

    /// Uniform reverse-time grid T → eps_t with n steps; returns the step
    /// size dt and the sequence of (t_k) left endpoints.
    pub fn reverse_grid(&self, n_steps: usize) -> (f64, Vec<f64>) {
        assert!(n_steps > 0);
        let dt = (self.t_end - self.eps_t) / n_steps as f64;
        let ts = (0..n_steps).map(|k| self.t_end - k as f64 * dt).collect();
        (dt, ts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let s = VpSchedule::default();
        assert!((s.beta(0.0) - s.beta_min).abs() < 1e-12);
        assert!((s.beta(s.t_end) - s.beta_max).abs() < 1e-12);
    }

    #[test]
    fn variance_preserving_identity() {
        let s = VpSchedule::default();
        for k in 0..50 {
            let t = 0.01 + 0.99 * k as f64 / 49.0;
            let (a, sg) = (s.alpha(t), s.sigma(t));
            assert!((a * a + sg * sg - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn int_beta_matches_numeric() {
        let s = VpSchedule::default();
        let n = 100_000;
        let dt = s.t_end / n as f64;
        let num: f64 = (0..n).map(|k| s.beta((k as f64 + 0.5) * dt) * dt).sum();
        assert!((s.int_beta(s.t_end) - num).abs() < 1e-6);
    }

    #[test]
    fn terminal_marginal_near_gaussian() {
        let s = VpSchedule::default();
        assert!(s.alpha(s.t_end) < 0.1);
        assert!(s.sigma(s.t_end) > 0.99);
    }

    #[test]
    fn paper_quoted_barely_diffuses() {
        let s = VpSchedule::paper_quoted();
        assert!(s.alpha(1.0) > 0.8);
    }

    #[test]
    fn reverse_grid_covers_interval() {
        let s = VpSchedule::default();
        let (dt, ts) = s.reverse_grid(100);
        assert_eq!(ts.len(), 100);
        assert!((ts[0] - s.t_end).abs() < 1e-12);
        assert!((ts[99] - dt - s.eps_t).abs() < 1e-9);
        assert!(dt > 0.0);
    }

    #[test]
    fn matches_python_constants() {
        // spot-check values the python side logs into meta.json
        let s = VpSchedule::default();
        assert_eq!(s.beta_min, 0.001);
        assert_eq!(s.beta_max, 12.0);
        assert_eq!(s.eps_t, 0.01);
    }
}
