//! VAE pixel decoder for the latent-diffusion task (paper Fig. 4a/c).
//!
//! Only the decoder deploys (the encoder exists at training time in
//! python); topology is the paper's: one linear layer + two deconvolution
//! layers, mirrored exactly against `python/compile/kernels/ref.vae_decoder`
//! and the `decoder_b*.hlo.txt` artifacts.

pub mod decoder;

pub use decoder::{DecoderWeights, PixelDecoder};
