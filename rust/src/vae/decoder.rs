//! Latent → pixel decoder: linear(2→3·3·C) → ReLU → deconv(4,2,1) → ReLU
//! → deconv(4,2,1) → tanh, NHWC/HWIO layouts, loop-for-loop identical to
//! `ref.deconv2d` so the three implementations cross-validate.

use std::path::Path;

use anyhow::{anyhow, Context};

use crate::util::json::Json;

/// One deconv layer's weights: (kh, kw, ci, co) flattened HWIO + bias.
#[derive(Debug, Clone)]
pub struct Deconv {
    pub kh: usize,
    pub kw: usize,
    pub ci: usize,
    pub co: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub stride: usize,
    pub pad: usize,
}

impl Deconv {
    #[inline]
    fn tap(&self, ky: usize, kx: usize, ci: usize, co: usize) -> f32 {
        self.w[((ky * self.kw + kx) * self.ci + ci) * self.co + co]
    }

    /// Transposed conv on one NHWC feature map (n=1):
    /// out[oy,ox,co] = b[co] + Σ x[iy,ix,ci]·w[ky,kx,ci,co],
    /// oy = iy·stride + ky − pad.
    pub fn forward(&self, x: &[f32], side: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), side * side * self.ci);
        let os = side * self.stride;
        let mut out = vec![0.0f32; os * os * self.co];
        // init bias
        for oy in 0..os {
            for ox in 0..os {
                let base = (oy * os + ox) * self.co;
                out[base..base + self.co].copy_from_slice(&self.b);
            }
        }
        for iy in 0..side {
            for ix in 0..side {
                let xin = &x[(iy * side + ix) * self.ci..(iy * side + ix + 1) * self.ci];
                for ky in 0..self.kh {
                    let oy = (iy * self.stride + ky) as isize - self.pad as isize;
                    if oy < 0 || oy >= os as isize {
                        continue;
                    }
                    for kx in 0..self.kw {
                        let ox = (ix * self.stride + kx) as isize - self.pad as isize;
                        if ox < 0 || ox >= os as isize {
                            continue;
                        }
                        let obase = ((oy as usize) * os + ox as usize) * self.co;
                        for (ci, &xv) in xin.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            for co in 0..self.co {
                                out[obase + co] += xv * self.tap(ky, kx, ci, co);
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// All decoder weights.
#[derive(Debug, Clone)]
pub struct DecoderWeights {
    pub lin_w: Vec<f32>, // (latent=2) × (3·3·C) row-major
    pub lin_b: Vec<f32>,
    pub dc1: Deconv,
    pub dc2: Deconv,
}

fn tensor(j: &Json, key: &str) -> anyhow::Result<(Vec<usize>, Vec<f32>)> {
    j.get(key)
        .and_then(|v| v.as_tensor())
        .ok_or_else(|| anyhow!("missing tensor '{key}'"))
}

impl DecoderWeights {
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).context("parsing vae_decoder.json")?;
        let (ls, lin_w) = tensor(&j, "lin_w")?;
        let (_, lin_b) = tensor(&j, "lin_b")?;
        let (s1, w1) = tensor(&j, "dc1_w")?;
        let (_, b1) = tensor(&j, "dc1_b")?;
        let (s2, w2) = tensor(&j, "dc2_w")?;
        let (_, b2) = tensor(&j, "dc2_b")?;
        if ls.len() != 2 || s1.len() != 4 || s2.len() != 4 {
            return Err(anyhow!("unexpected decoder tensor ranks"));
        }
        Ok(DecoderWeights {
            lin_w,
            lin_b,
            dc1: Deconv {
                kh: s1[0], kw: s1[1], ci: s1[2], co: s1[3],
                w: w1, b: b1, stride: 2, pad: 1,
            },
            dc2: Deconv {
                kh: s2[0], kw: s2[1], ci: s2[2], co: s2[3],
                w: w2, b: b2, stride: 2, pad: 1,
            },
        })
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_json(&text)
    }
}

/// The runnable decoder.
pub struct PixelDecoder {
    w: DecoderWeights,
    latent: usize,
    c1: usize,
}

impl PixelDecoder {
    pub fn new(w: DecoderWeights) -> Self {
        let c1 = w.dc1.ci;
        let latent = w.lin_w.len() / w.lin_b.len();
        PixelDecoder { w, latent, c1 }
    }

    /// Output image side (3 → 6 → 12 for the paper's geometry).
    pub fn img_side(&self) -> usize {
        12
    }

    /// Decode one latent (len 2) to a 12×12 image in [-1, 1] (row-major).
    pub fn decode(&self, z: &[f32]) -> Vec<f32> {
        debug_assert_eq!(z.len(), self.latent);
        let hidden = self.w.lin_b.len();
        // linear + relu
        let mut h = self.w.lin_b.clone();
        for (r, &zv) in z.iter().enumerate() {
            if zv == 0.0 {
                continue;
            }
            let row = &self.w.lin_w[r * hidden..(r + 1) * hidden];
            for (hv, &wv) in h.iter_mut().zip(row) {
                *hv += zv * wv;
            }
        }
        for v in h.iter_mut() {
            *v = v.max(0.0);
        }
        debug_assert_eq!(hidden, 3 * 3 * self.c1);
        // deconv1 + relu (3→6)
        let mut f = self.w.dc1.forward(&h, 3);
        for v in f.iter_mut() {
            *v = v.max(0.0);
        }
        // deconv2 + tanh (6→12), single output channel
        let out = self.w.dc2.forward(&f, 6);
        out.iter().map(|&v| v.tanh()).collect()
    }

    /// Decode a batch of interleaved latents; returns images concatenated.
    pub fn decode_batch(&self, zs: &[f32]) -> Vec<f32> {
        let n = zs.len() / self.latent;
        let side = self.img_side();
        let mut out = Vec::with_capacity(n * side * side);
        for s in 0..n {
            out.extend(self.decode(&zs[s * self.latent..(s + 1) * self.latent]));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_deconv() -> Deconv {
        // 4×4 kernel, 1→1 channel, all-ones taps, zero bias
        Deconv {
            kh: 4, kw: 4, ci: 1, co: 1,
            w: vec![1.0; 16], b: vec![0.0],
            stride: 2, pad: 1,
        }
    }

    #[test]
    fn deconv_doubles_side() {
        let d = tiny_deconv();
        let x = vec![1.0f32; 9];
        let out = d.forward(&x, 3);
        assert_eq!(out.len(), 36);
    }

    #[test]
    fn deconv_single_input_spreads_kernel() {
        // one nonzero input pixel at (0,0): output = shifted kernel window
        let d = tiny_deconv();
        let mut x = vec![0.0f32; 9];
        x[0] = 1.0;
        let out = d.forward(&x, 3);
        // oy = 0*2 + ky - 1 ∈ {-1,0,1,2} → rows 0..=2 get taps ky=1..=3
        let nonzero = out.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nonzero, 9); // 3×3 of the 4×4 kernel lands in-bounds
        assert_eq!(out[0], 1.0);
    }

    #[test]
    fn deconv_matches_python_ref_numbers() {
        // cross-language fixture: computed with kernels/ref.deconv2d
        // x = [[1,2],[3,4]] (1 ch), w[ky,kx,0,0] = ky*4+kx, b=0.5
        let d = Deconv {
            kh: 4, kw: 4, ci: 1, co: 1,
            w: (0..16).map(|i| i as f32).collect(),
            b: vec![0.5],
            stride: 2, pad: 1,
        };
        let out = d.forward(&[1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(out.len(), 16);
        // expected full map computed with kernels/ref.deconv2d (python):
        let want = [
            5.5, 14.5, 17.5, 12.5,
            12.5, 32.5, 42.5, 28.5,
            28.5, 72.5, 82.5, 52.5,
            27.5, 62.5, 69.5, 40.5,
        ];
        for (k, (&got, &w)) in out.iter().zip(&want).enumerate() {
            assert_eq!(got, w, "pixel {k}");
        }
    }

    #[test]
    fn decoder_loads_real_artifact_and_outputs_range() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/vae_decoder.json");
        if !std::path::Path::new(path).exists() {
            return;
        }
        let dec = PixelDecoder::new(DecoderWeights::load(path).unwrap());
        let img = dec.decode(&[0.5, -0.5]);
        assert_eq!(img.len(), 144);
        for &p in &img {
            assert!((-1.0..=1.0).contains(&p));
        }
        // different latents decode to different images
        let img2 = dec.decode(&[-1.0, 1.0]);
        let diff: f32 = img.iter().zip(&img2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 0.1);
    }
}
