//! Alert engine: threshold rules with latch/hysteresis semantics over
//! scalar health signals.
//!
//! A rule fires when its signal holds **at or above** `threshold` for
//! `streak` consecutive observations (the streak suppresses one-off
//! spikes), then **latches**: it stays firing until the signal drops
//! below `clear_below` (< `threshold`), so a value oscillating around
//! the threshold can never flap the alert.  Every observation mirrors
//! the rule's state into the registry as `memdiff_alert{name=...}`
//! (1 = firing), which the Prometheus exposition and the JSONL flush
//! pick up with no exporter changes; transitions additionally bump
//! `memdiff_alert_transitions_total{name,to}`.
//!
//! The engine is just the state machine — *what* to observe (drift
//! magnitudes, probe KL, stuck-cell fractions) and *when* lives in
//! [`super::health::HealthMonitor`].

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::obs;
use crate::util::json::Json;

/// One threshold rule (see the module doc for the semantics).
#[derive(Debug, Clone)]
pub struct AlertRule {
    /// Stable alert name (`drift:analog`, `probe:analog:analog_cond`, ...)
    /// — the `name` label of the exported series.
    pub name: String,
    /// Fire when the signal is ≥ this for `streak` observations.
    pub threshold: f64,
    /// Once firing, clear only when the signal drops below this
    /// (hysteresis; must be ≤ `threshold`).
    pub clear_below: f64,
    /// Consecutive breaching observations required to latch (≥ 1).
    pub streak: u32,
}

impl AlertRule {
    pub fn new(name: impl Into<String>, threshold: f64, clear_below: f64,
               streak: u32) -> AlertRule {
        AlertRule { name: name.into(), threshold, clear_below,
                    streak: streak.max(1) }
    }
}

/// Per-rule latch state.
#[derive(Debug, Clone, Default)]
struct AlertState {
    firing: bool,
    /// Consecutive breaching observations while not firing.
    breaches: u32,
    last_value: f64,
}

/// Point-in-time view of one alert, for `{"op":"health"}` JSON.
#[derive(Debug, Clone)]
pub struct AlertSnapshot {
    pub name: String,
    pub firing: bool,
    pub breaches: u32,
    pub last_value: f64,
}

impl AlertSnapshot {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("firing".into(), Json::Bool(self.firing));
        o.insert("breaches".into(), Json::Num(self.breaches as f64));
        o.insert("value".into(), Json::Num(self.last_value));
        Json::Obj(o)
    }
}

/// The alert state machine: named latches driven by `observe` calls.
#[derive(Default)]
pub struct AlertEngine {
    states: Mutex<BTreeMap<String, AlertState>>,
}

impl AlertEngine {
    pub fn new() -> AlertEngine {
        AlertEngine::default()
    }

    /// Feed one observation of `rule`'s signal; returns whether the
    /// alert is firing *after* this observation.  Also mirrors the state
    /// into the `memdiff_alert{name=}` gauge.
    pub fn observe(&self, rule: &AlertRule, value: f64) -> bool {
        let mut states = self.states.lock().unwrap_or_else(|e| e.into_inner());
        let st = states.entry(rule.name.clone()).or_default();
        st.last_value = value;
        if st.firing {
            // latched: only a drop below the clear line releases it —
            // values in [clear_below, threshold) keep it firing (no flap)
            if value < rule.clear_below {
                st.firing = false;
                st.breaches = 0;
                Self::record_transition(&rule.name, false);
            }
        } else if value >= rule.threshold {
            st.breaches += 1;
            if st.breaches >= rule.streak {
                st.firing = true;
                st.breaches = 0;
                Self::record_transition(&rule.name, true);
            }
        } else {
            // sub-threshold observation breaks a building streak
            st.breaches = 0;
        }
        let firing = st.firing;
        drop(states);
        obs().registry
            .gauge("memdiff_alert", &[("name", &rule.name)])
            .set(if firing { 1.0 } else { 0.0 });
        firing
    }

    fn record_transition(name: &str, firing: bool) {
        obs().registry
            .counter("memdiff_alert_transitions_total",
                     &[("name", name), ("to", if firing { "firing" } else { "clear" })])
            .inc();
    }

    /// Whether the named alert is currently firing.
    pub fn is_firing(&self, name: &str) -> bool {
        self.states.lock().unwrap_or_else(|e| e.into_inner())
            .get(name).map(|s| s.firing).unwrap_or(false)
    }

    /// Names of all currently-firing alerts, sorted.
    pub fn firing(&self) -> Vec<String> {
        self.states.lock().unwrap_or_else(|e| e.into_inner())
            .iter()
            .filter(|(_, s)| s.firing)
            .map(|(n, _)| n.clone())
            .collect()
    }

    pub fn any_firing(&self) -> bool {
        self.states.lock().unwrap_or_else(|e| e.into_inner())
            .values().any(|s| s.firing)
    }

    /// Every rule the engine has seen, with its current latch state.
    pub fn snapshot(&self) -> Vec<AlertSnapshot> {
        self.states.lock().unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(n, s)| AlertSnapshot {
                name: n.clone(),
                firing: s.firing,
                breaches: s.breaches,
                last_value: s.last_value,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latches_after_streak_and_holds_through_hysteresis_band() {
        let e = AlertEngine::new();
        let r = AlertRule::new("t_alert_latch", 1.0, 0.5, 2);
        assert!(!e.observe(&r, 1.2), "first breach only starts the streak");
        assert!(e.observe(&r, 1.1), "second consecutive breach latches");
        // inside the hysteresis band: stays firing (no flapping)
        assert!(e.observe(&r, 0.7));
        assert!(e.observe(&r, 0.99));
        assert!(e.is_firing("t_alert_latch"));
        // below the clear line: releases
        assert!(!e.observe(&r, 0.4));
        assert!(!e.is_firing("t_alert_latch"));
        assert_eq!(e.firing(), Vec::<String>::new());
    }

    #[test]
    fn sub_threshold_observation_resets_a_building_streak() {
        let e = AlertEngine::new();
        let r = AlertRule::new("t_alert_streak", 1.0, 0.5, 3);
        assert!(!e.observe(&r, 2.0));
        assert!(!e.observe(&r, 2.0));
        assert!(!e.observe(&r, 0.1), "dip resets the streak");
        assert!(!e.observe(&r, 2.0));
        assert!(!e.observe(&r, 2.0));
        assert!(e.observe(&r, 2.0), "needs 3 consecutive again");
    }

    #[test]
    fn oscillation_around_threshold_never_flaps_a_latched_alert() {
        let e = AlertEngine::new();
        let r = AlertRule::new("t_alert_flap", 1.0, 0.5, 1);
        assert!(e.observe(&r, 1.5));
        let mut transitions = 0;
        let mut was = true;
        // oscillate across the threshold but above the clear line
        for i in 0..20 {
            let v = if i % 2 == 0 { 1.3 } else { 0.8 };
            let now = e.observe(&r, v);
            if now != was {
                transitions += 1;
            }
            was = now;
        }
        assert_eq!(transitions, 0, "hysteresis must absorb the oscillation");
        assert!(e.is_firing("t_alert_flap"));
    }

    #[test]
    fn gauge_mirrors_state_and_snapshot_reports_values() {
        let e = AlertEngine::new();
        let r = AlertRule::new("t_alert_gauge", 1.0, 0.5, 1);
        e.observe(&r, 3.0);
        assert_eq!(
            obs().registry.gauge("memdiff_alert", &[("name", "t_alert_gauge")])
                .get(),
            1.0);
        e.observe(&r, 0.0);
        assert_eq!(
            obs().registry.gauge("memdiff_alert", &[("name", "t_alert_gauge")])
                .get(),
            0.0);
        let snap = e.snapshot();
        let s = snap.iter().find(|s| s.name == "t_alert_gauge").unwrap();
        assert!(!s.firing);
        assert_eq!(s.last_value, 0.0);
        let j = s.to_json().to_string();
        assert!(j.contains("\"firing\":false"), "{j}");
    }
}
