//! Request tracing: trace ids, lifecycle stages, and the span ring.
//!
//! A [`TraceId`] is minted once at ingress (wire parse, CLI submit, or
//! job attempt) and rides the request through every layer via
//! `GenRequest.trace` / `Ticket::trace` / the durable job record.  Each
//! layer drops a [`SpanEvent`] — stage, monotonic start, duration,
//! backend, class — into the fixed-size [`SpanRing`], which is sharded
//! by trace id so concurrent workers rarely contend on the same lock
//! and old events are overwritten in place (constant memory).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Process-wide trace-id mint (0 is reserved for "no trace").
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Boot-time process epoch folded into every minted id (the 21 high
/// bits), so ids minted by different server incarnations are
/// vanishingly unlikely to collide — a job enqueued before a crash
/// keeps its persisted trace through replay and its pre-/post-restart
/// spans join on one id.
static EPOCH: OnceLock<u64> = OnceLock::new();

/// 21 epoch bits over a 32-bit counter = 53-bit ids: every id is an
/// exactly-representable f64 integer, so traces survive the job store's
/// JSON round-trip (and the stats exposition) bit-for-bit.
const COUNTER_BITS: u32 = 32;
const EPOCH_MASK: u64 = (1 << 21) - 1;

fn process_epoch() -> u64 {
    *EPOCH.get_or_init(|| {
        // boot nanos xor'd with the pid, run through a splitmix64
        // finalizer: the 21 retained bits draw on the whole timestamp
        // AND the process identity, so two incarnations whose boot
        // instants agree modulo the mask — or whose clock is too coarse
        // to tell them apart — still land in different epochs almost
        // surely
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1);
        let mut x = nanos ^ ((std::process::id() as u64) << 32);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        x
    })
}

/// Identity of one request across every serving layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

impl TraceId {
    /// The absent trace (internal/synthetic requests that skip ingress).
    pub const NONE: TraceId = TraceId(0);

    /// Mint a fresh id: 21 epoch bits (boot nanos + pid, mixed) over a
    /// 32-bit process-local counter.  Unique within a process for 2^32
    /// mints; across restarts two incarnations collide only when their
    /// mixed epochs agree in all 21 bits (~1 in 2M per restart, and
    /// only if the counter ranges also overlap) — vanishingly unlikely
    /// for the crash-replay window this guards, though not impossible.
    pub fn mint() -> TraceId {
        let counter =
            NEXT_TRACE.fetch_add(1, Ordering::Relaxed) & ((1 << COUNTER_BITS) - 1);
        let id = ((process_epoch() & EPOCH_MASK) << COUNTER_BITS) | counter;
        TraceId(if id == 0 { 1 } else { id })
    }

    pub fn is_none(&self) -> bool {
        self.0 == 0
    }
}

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Lifecycle stage of one request, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Wire/CLI ingress parsed and accepted the request line.
    Accept,
    /// `submit_nb` admitted it past the bounded-lane check.
    Admit,
    /// Time spent waiting in the batcher lane (duration = queue wait).
    Queue,
    /// The lane coalesced it into a batch (duration = oldest wait in
    /// the batch, i.e. how long the batch took to gather).
    BatchForm,
    /// The backend engine solved the batch (duration = solve wall).
    EngineSolve,
    /// Latents were decoded to pixels (only when requested).
    Decode,
    /// The response ticket was completed.
    Deliver,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::Accept,
        Stage::Admit,
        Stage::Queue,
        Stage::BatchForm,
        Stage::EngineSolve,
        Stage::Decode,
        Stage::Deliver,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::Admit => "admit",
            Stage::Queue => "queue",
            Stage::BatchForm => "batch_form",
            Stage::EngineSolve => "engine_solve",
            Stage::Decode => "decode",
            Stage::Deliver => "deliver",
        }
    }

    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// One recorded span: `stage` of `trace` started at `start_us`
/// (microseconds on the process-monotonic obs clock) and lasted
/// `dur_us`.  `backend`/`class` are interned label indices (see
/// [`super::Obs::label`]); `u16::MAX` / empty means "not yet routed".
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    pub trace: u64,
    pub stage: Stage,
    pub start_us: u64,
    pub dur_us: u64,
    pub backend: u16,
    pub class: u16,
}

struct Shard {
    events: Vec<SpanEvent>,
    /// Next overwrite position once the shard is full.
    next: usize,
    cap: usize,
}

/// Fixed-size, sharded span buffer.  `record` takes one short mutex on
/// the shard owned by the trace id; memory never grows past
/// `shards × per-shard capacity` events.
pub struct SpanRing {
    shards: Vec<Mutex<Shard>>,
}

const N_SHARDS: usize = 8;

impl SpanRing {
    /// `capacity` = total events retained across all shards.
    pub fn new(capacity: usize) -> SpanRing {
        let per = (capacity / N_SHARDS).max(8);
        SpanRing {
            shards: (0..N_SHARDS)
                .map(|_| Mutex::new(Shard {
                    events: Vec::with_capacity(per),
                    next: 0,
                    cap: per,
                }))
                .collect(),
        }
    }

    pub fn record(&self, ev: SpanEvent) {
        let shard = &self.shards[(ev.trace as usize) % N_SHARDS];
        let mut s = shard.lock().unwrap_or_else(|e| e.into_inner());
        if s.events.len() < s.cap {
            s.events.push(ev);
        } else {
            let at = s.next;
            s.events[at] = ev;
            s.next = (at + 1) % s.cap;
        }
    }

    /// Every retained event, sorted by (trace, start) — the raw material
    /// of timelines and breakdowns.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let s = shard.lock().unwrap_or_else(|e| e.into_inner());
            out.extend_from_slice(&s.events);
        }
        out.sort_by_key(|e| (e.trace, e.start_us, e.stage.index()));
        out
    }

    /// The retained spans of one trace, in start order.
    pub fn timeline(&self, trace: TraceId) -> Vec<SpanEvent> {
        let shard = &self.shards[(trace.0 as usize) % N_SHARDS];
        let s = shard.lock().unwrap_or_else(|e| e.into_inner());
        let mut out: Vec<SpanEvent> =
            s.events.iter().filter(|e| e.trace == trace.0).copied().collect();
        out.sort_by_key(|e| (e.start_us, e.stage.index()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_is_unique_and_nonzero() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        assert_ne!(a, b);
        assert!(!a.is_none() && !b.is_none());
        assert!(TraceId::NONE.is_none());
    }

    #[test]
    fn mint_folds_a_stable_process_epoch_into_the_high_bits() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        // same incarnation = same epoch bits, distinct counters
        assert_eq!(a.0 >> COUNTER_BITS, b.0 >> COUNTER_BITS);
        assert_ne!(a.0 & ((1 << COUNTER_BITS) - 1),
                   b.0 & ((1 << COUNTER_BITS) - 1));
        // the epoch is latched once: later mints can't drift
        assert_eq!(a.0 >> COUNTER_BITS, process_epoch() & EPOCH_MASK);
    }

    #[test]
    fn ring_is_bounded_and_overwrites_oldest() {
        let ring = SpanRing::new(64); // 8 per shard
        for i in 0..10_000u64 {
            ring.record(SpanEvent {
                trace: i,
                stage: Stage::Accept,
                start_us: i,
                dur_us: 0,
                backend: 0,
                class: 0,
            });
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 64, "capacity is a hard bound");
        // everything retained is from the recent tail
        assert!(snap.iter().all(|e| e.trace >= 10_000 - 8 * 8 * 2));
    }

    #[test]
    fn timeline_filters_and_sorts() {
        let ring = SpanRing::new(128);
        let t = TraceId(42);
        for (i, st) in Stage::ALL.iter().enumerate() {
            ring.record(SpanEvent {
                trace: t.0,
                stage: *st,
                start_us: 100 * (Stage::ALL.len() - i) as u64, // reversed
                dur_us: 5,
                backend: 1,
                class: 2,
            });
        }
        ring.record(SpanEvent {
            trace: 7,
            stage: Stage::Accept,
            start_us: 0,
            dur_us: 0,
            backend: 0,
            class: 0,
        });
        let tl = ring.timeline(t);
        assert_eq!(tl.len(), Stage::ALL.len());
        assert!(tl.windows(2).all(|w| w[0].start_us <= w[1].start_us),
                "timeline is monotone in start");
    }
}
