//! Analog health monitor: retention-drift tracking, self-test probes,
//! and quality-gate alerting over a running deployment.
//!
//! Three instruments over the obs substrate:
//!
//! * **Drift tracking** — every tick, each backend that exposes
//!   [`DeviceHealth`] reports its live conductances against the
//!   programmed-target baseline ([`crate::crossbar::LayerDrift`]), and
//!   the monitor exports per-backend / per-layer / per-bank drift
//!   gauges (`memdiff_drift_*`), stuck-cell gauges, and — after a
//!   reprogram — the write-verify residual histogram
//!   (`memdiff_program_error_ms`).  An optional retention clock
//!   (`[health] retention_dt_s`) ages the device by a fixed simulated
//!   interval per tick, so retention loss unfolds while serving.
//! * **Self-test probes** — on a configurable cadence the
//!   [`super::probe::ProbeRunner`] injects fixed-seed synthetic
//!   requests directly through every routed backend (bypassing the
//!   batcher lanes, so serving metrics never see them) and scores the
//!   clouds against the digital oracle (`memdiff_probe_kl`).
//! * **Alerting** — threshold + hysteresis rules
//!   ([`super::alert::AlertEngine`]) latch named alerts:
//!   `drift:<backend>` (mean |ΔG| over `drift_alert_ms`),
//!   `stuck:<backend>` (stuck-cell percentage), `probe:<backend>:<class>`
//!   (per-class KL budget), `probe_fail:<backend>:<class>` (probe
//!   error streaks).  `healthy()` is the `/healthz` truth; the full
//!   state renders as JSON for `{"op":"health"}` and the JSONL flush.
//!
//! With `reprogram_on_drift = true`, a firing drift alert triggers a
//! bank-by-bank write-verify re-program toward the stored baseline;
//! the achieved conductances are re-snapshotted as the new baseline
//! (residual write error lives in the program-error histogram, not the
//! drift gauges), so the alert clears on the same tick.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::alert::{AlertEngine, AlertRule, AlertSnapshot};
use super::flightrec::FlightRecorder;
use super::obs;
use super::probe::{ProbeConfig, ProbeResult, ProbeRunner};
use super::slo::{SloConfig, SloEngine};
use crate::coordinator::deploy::EngineRegistry;
use crate::coordinator::request::RequestClass;
use crate::coordinator::service::ModeGate;
use crate::crossbar::LayerDrift;
use crate::device::array::{DriftStats, ProgramStats};
use crate::util::json::Json;

/// Device-level maintenance surface an [`Engine`](crate::coordinator::service::Engine)
/// may expose to the health monitor.  All methods take `&self`: the
/// implementor owns its interior mutability (the analog engine guards
/// its net with a `RwLock`, so aging/reprogramming drains in-flight
/// solves like the PCB's programming mode).
pub trait DeviceHealth: Send + Sync {
    /// Apply retention drift for `dt_s` simulated seconds.
    fn age(&self, dt_s: f64);
    /// Live conductances vs the programmed baseline, per layer/bank.
    fn drift_report(&self) -> Vec<LayerDrift>;
    /// Re-run write-verify toward the baseline and re-snapshot it;
    /// returns the programming stats (pulses, residual errors).
    fn reprogram(&self, tol_ms: f32) -> ProgramStats;
}

/// The `[health]` config section.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Master switch: `false` skips monitor construction entirely.
    pub enabled: bool,
    /// Monitor tick period (drift refresh + rule evaluation).
    pub tick_ms: u64,
    /// Simulated seconds of retention drift applied per tick
    /// (0 = retention clock off; aging then only happens on explicit
    /// `--age-device` / wire `age` requests).
    pub retention_dt_s: f64,
    /// `drift:<backend>` fires when mean |ΔG| (mS) reaches this.
    pub drift_alert_ms: f64,
    /// Hysteresis: a firing rule clears below `threshold * clear_frac`.
    pub clear_frac: f64,
    /// `stuck:<backend>` fires at this stuck-cell percentage.
    pub stuck_cell_pct: f64,
    /// Probe cadence (0 = probes only on explicit request).
    pub probe_interval_ms: u64,
    /// Samples per probe request / oracle reference cloud.
    pub probe_samples: usize,
    /// Euler steps for digital probe and oracle solves.
    pub probe_steps: usize,
    /// Base seed of the deterministic probe streams.
    pub probe_seed: u64,
    /// Consecutive breaching probes before a probe alert latches.
    pub probe_streak: u32,
    /// Per-class KL budgets, indexed by [`RequestClass::index`]
    /// (`kl_budget_analog_uncond` ... keys in the config file).
    pub kl_budget: [f64; 4],
    /// Auto-heal: re-program a backend whose drift alert fires.
    pub reprogram_on_drift: bool,
    /// Write-verify tolerance (mS) for reprogramming.
    pub reprogram_tol_ms: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            enabled: true,
            tick_ms: 200,
            retention_dt_s: 0.0,
            // calibrated against the cell model: at dt = 1e9 s the mean
            // |ΔG| is ≈ 4.5e-4 mS, so a freshly-programmed array sits
            // well below this and a year-scale retention loss crosses it
            drift_alert_ms: 4.0e-4,
            clear_frac: 0.5,
            stuck_cell_pct: 1.0,
            probe_interval_ms: 30_000,
            probe_samples: 800,
            probe_steps: 100,
            probe_seed: 0x9E0B_E5EE,
            probe_streak: 2,
            // healthy engines score well under the end-to-end KL gate
            // (0.9 at 800 samples on this binning); a N(0,I) collapse
            // scores ~1.5.  Digital probes compare an engine against
            // the oracle family itself, so their floor is lower.
            kl_budget: [1.2, 1.2, 1.0, 1.0],
            reprogram_on_drift: false,
            reprogram_tol_ms: 1.5e-3,
        }
    }
}

/// Last drift view of one backend (for the health JSON).
#[derive(Debug, Clone)]
struct BackendDrift {
    backend: String,
    total: DriftStats,
    layers: Vec<LayerDrift>,
}

/// Summary of the last reprogram of one backend.
#[derive(Debug, Clone)]
struct ReprogramRecord {
    backend: String,
    cells: usize,
    failures: usize,
    mean_pulses: f64,
    max_error_ms: f32,
}

/// The monitor: owns the alert engine and probe runner, evaluates the
/// rules on every tick, and renders the health JSON.
pub struct HealthMonitor {
    cfg: HealthConfig,
    registry: Arc<EngineRegistry>,
    gate: Arc<ModeGate>,
    alerts: AlertEngine,
    probes: ProbeRunner,
    slo: SloEngine,
    /// Incident recorder: a newly-latched alert dumps `alert-<name>`.
    recorder: Option<Arc<FlightRecorder>>,
    /// Alerts firing after the previous tick (for the latch-edge diff).
    seen_firing: Mutex<BTreeSet<String>>,
    last_drift: Mutex<Vec<BackendDrift>>,
    last_probes: Mutex<Vec<ProbeResult>>,
    last_reprogram: Mutex<Vec<ReprogramRecord>>,
    last_probe_at: Mutex<Option<Instant>>,
    ticks: AtomicU64,
    reprograms: AtomicU64,
    stop: AtomicBool,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl HealthMonitor {
    pub fn new(cfg: HealthConfig, registry: Arc<EngineRegistry>,
               gate: Arc<ModeGate>) -> Arc<HealthMonitor> {
        Self::new_full(cfg, SloConfig::default(), registry, gate, None)
    }

    /// [`Self::new`] plus the deployment extras: the `[slo]` objectives
    /// and the flight recorder that captures newly-latched alerts.
    pub fn new_full(cfg: HealthConfig, slo_cfg: SloConfig,
                    registry: Arc<EngineRegistry>, gate: Arc<ModeGate>,
                    recorder: Option<Arc<FlightRecorder>>)
                    -> Arc<HealthMonitor> {
        let probes = ProbeRunner::new(
            ProbeConfig {
                samples: cfg.probe_samples,
                steps: cfg.probe_steps,
                seed: cfg.probe_seed,
            },
            Arc::clone(&registry));
        let slo = SloEngine::new(slo_cfg, Arc::clone(&registry));
        Arc::new(HealthMonitor {
            cfg,
            registry,
            gate,
            alerts: AlertEngine::new(),
            probes,
            slo,
            recorder,
            seen_firing: Mutex::new(BTreeSet::new()),
            last_drift: Mutex::new(Vec::new()),
            last_probes: Mutex::new(Vec::new()),
            last_reprogram: Mutex::new(Vec::new()),
            last_probe_at: Mutex::new(None),
            ticks: AtomicU64::new(0),
            reprograms: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            thread: Mutex::new(None),
        })
    }

    /// Start the background tick thread.  The thread holds only a weak
    /// reference, so dropping the last strong `Arc` also ends it.
    pub fn start(self: &Arc<Self>) {
        let weak: Weak<HealthMonitor> = Arc::downgrade(self);
        let tick_ms = self.cfg.tick_ms.max(10);
        let handle = std::thread::spawn(move || loop {
            let Some(mon) = weak.upgrade() else { return };
            if mon.stop.load(Ordering::Relaxed) {
                return;
            }
            mon.tick();
            drop(mon); // don't hold the strong ref across the sleep
            std::thread::sleep(Duration::from_millis(tick_ms));
        });
        *self.thread.lock().unwrap_or_else(|e| e.into_inner()) = Some(handle);
    }

    /// Stop and join the background thread (idempotent).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.thread.lock().unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = h.join();
        }
    }

    /// One synchronous monitor pass: retention clock → drift refresh +
    /// rules → SLO burn rates → due probes → optional drift-triggered
    /// reprogram → flight-record any alert that latched this tick.
    pub fn tick(&self) {
        self.ticks.fetch_add(1, Ordering::Relaxed);
        if self.cfg.retention_dt_s > 0.0 {
            self.age_all(self.cfg.retention_dt_s);
        }
        self.refresh_drift();
        self.slo.tick(&self.alerts);
        if self.cfg.probe_interval_ms > 0 && self.probe_due() {
            self.probe_now();
        }
        if self.cfg.reprogram_on_drift && self.any_drift_alert() {
            self.reprogram_all();
        }
        self.record_latched_alerts();
    }

    /// Dump a flight record for every alert that newly latched since the
    /// previous tick (edge-triggered; the recorder's own per-reason rate
    /// limit covers a rule flapping across ticks).
    fn record_latched_alerts(&self) {
        let Some(rec) = &self.recorder else { return };
        let firing: BTreeSet<String> =
            self.alerts.firing().into_iter().collect();
        let mut seen =
            self.seen_firing.lock().unwrap_or_else(|e| e.into_inner());
        for name in firing.difference(&seen) {
            let _ = rec.trigger(&format!("alert-{name}"));
        }
        *seen = firing;
    }

    fn probe_due(&self) -> bool {
        match *self.last_probe_at.lock().unwrap_or_else(|e| e.into_inner()) {
            None => true,
            Some(t) => {
                t.elapsed() >= Duration::from_millis(self.cfg.probe_interval_ms)
            }
        }
    }

    fn any_drift_alert(&self) -> bool {
        self.registry.backends().iter().any(|b| {
            b.engine.device_health().is_some()
                && self.alerts.is_firing(&format!("drift:{}", b.name))
        })
    }

    /// Apply `dt_s` simulated seconds of retention drift to every
    /// backend with device health, under exclusive programming mode.
    pub fn age_all(&self, dt_s: f64) {
        for backend in self.registry.backends() {
            let Some(dh) = backend.engine.device_health() else { continue };
            {
                let _prog = self.gate.programming();
                dh.age(dt_s);
            }
            obs().registry
                .counter("memdiff_device_age_ticks_total",
                         &[("backend", &backend.name)])
                .inc();
        }
    }

    /// Re-measure drift on every device backend, export the gauges, and
    /// feed the drift / stuck-cell rules.
    fn refresh_drift(&self) {
        let mut all = Vec::new();
        for backend in self.registry.backends() {
            let Some(dh) = backend.engine.device_health() else { continue };
            let layers = dh.drift_report();
            let mut total = DriftStats::default();
            for l in &layers {
                total.merge(&l.drift);
            }
            let r = &obs().registry;
            let bl = backend.name.as_str();
            r.gauge("memdiff_drift_mean_ms", &[("backend", bl)])
                .set(total.mean_abs_ms());
            r.gauge("memdiff_drift_max_ms", &[("backend", bl)])
                .set(total.max_abs_ms as f64);
            r.gauge("memdiff_stuck_cells", &[("backend", bl)])
                .set(total.stuck as f64);
            r.gauge("memdiff_stuck_cell_pct", &[("backend", bl)])
                .set(total.stuck_pct());
            for l in &layers {
                let ll = l.layer.to_string();
                r.gauge("memdiff_drift_layer_mean_ms",
                        &[("backend", bl), ("layer", &ll)])
                    .set(l.drift.mean_abs_ms());
                for b in &l.banks {
                    let bank = format!("r{}c{}", b.tile_row, b.tile_col);
                    r.gauge("memdiff_drift_bank_mean_ms",
                            &[("backend", bl), ("layer", &ll), ("bank", &bank)])
                        .set(b.drift.mean_abs_ms());
                }
            }
            self.alerts.observe(
                &AlertRule::new(
                    format!("drift:{bl}"),
                    self.cfg.drift_alert_ms,
                    self.cfg.drift_alert_ms * self.cfg.clear_frac,
                    1),
                total.mean_abs_ms());
            self.alerts.observe(
                &AlertRule::new(
                    format!("stuck:{bl}"),
                    self.cfg.stuck_cell_pct,
                    self.cfg.stuck_cell_pct * self.cfg.clear_frac,
                    1),
                total.stuck_pct());
            all.push(BackendDrift {
                backend: backend.name.clone(),
                total,
                layers,
            });
        }
        *self.last_drift.lock().unwrap_or_else(|e| e.into_inner()) = all;
    }

    /// Run the self-test probes now (also called by the tick when due)
    /// and feed the per-class quality-gate and failure-streak rules.
    pub fn probe_now(&self) {
        let results = {
            // probes are computation, not programming: share the gate's
            // read side with serving traffic
            let _compute = self.gate.compute();
            self.probes.run_all()
        };
        for res in &results {
            let class = res.class.name();
            if let Some(kl) = res.kl {
                let budget = self.cfg.kl_budget[res.class.index()];
                self.alerts.observe(
                    &AlertRule::new(
                        format!("probe:{}:{}", res.backend, class),
                        budget,
                        budget * self.cfg.clear_frac,
                        self.cfg.probe_streak),
                    kl);
            }
            self.alerts.observe(
                &AlertRule::new(
                    format!("probe_fail:{}:{}", res.backend, class),
                    1.0,
                    0.5,
                    self.cfg.probe_streak),
                if res.ok() { 0.0 } else { 1.0 });
        }
        *self.last_probes.lock().unwrap_or_else(|e| e.into_inner()) = results;
        *self.last_probe_at.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(Instant::now());
    }

    /// Re-program every device backend toward its baseline under
    /// exclusive programming mode, record the write-verify residuals,
    /// and re-evaluate the drift rules (which clears them — drift is
    /// zero against the re-snapshotted baseline).  Returns the number
    /// of backends reprogrammed.
    pub fn reprogram_all(&self) -> usize {
        let mut records = Vec::new();
        for backend in self.registry.backends() {
            let Some(dh) = backend.engine.device_health() else { continue };
            let stats = {
                let _prog = self.gate.programming();
                dh.reprogram(self.cfg.reprogram_tol_ms as f32)
            };
            let r = &obs().registry;
            let hist =
                r.hist("memdiff_program_error_ms", &[("backend", &backend.name)]);
            for &e in &stats.abs_errors_ms {
                hist.record(e as f64);
            }
            r.counter("memdiff_reprogram_total", &[("backend", &backend.name)])
                .inc();
            records.push(ReprogramRecord {
                backend: backend.name.clone(),
                cells: stats.abs_errors_ms.len(),
                failures: stats.failures,
                mean_pulses: stats.mean_pulses(),
                max_error_ms: stats.max_error_ms(),
            });
            self.reprograms.fetch_add(1, Ordering::Relaxed);
        }
        let n = records.len();
        *self.last_reprogram.lock().unwrap_or_else(|e| e.into_inner()) = records;
        self.refresh_drift();
        n
    }

    /// `/healthz` truth: no alert firing.
    pub fn healthy(&self) -> bool {
        !self.alerts.any_firing()
    }

    /// Names of the currently-firing alerts.
    pub fn firing(&self) -> Vec<String> {
        self.alerts.firing()
    }

    /// The alert engine (rule state machine) — exposed for tests.
    pub fn alerts(&self) -> &AlertEngine {
        &self.alerts
    }

    /// The SLO evaluator (burn-rate state), for direct inspection.
    pub fn slo(&self) -> &SloEngine {
        &self.slo
    }

    /// Full health state as JSON (the `{"op":"health"}` payload and the
    /// `"health"` key of the JSONL flush).
    pub fn health_json(&self) -> Json {
        let alerts: Vec<AlertSnapshot> = self.alerts.snapshot();
        let healthy = !alerts.iter().any(|a| a.firing);
        let drift = self.last_drift.lock().unwrap_or_else(|e| e.into_inner())
            .clone();
        let probes = self.last_probes.lock().unwrap_or_else(|e| e.into_inner())
            .clone();
        let reprog = self.last_reprogram.lock()
            .unwrap_or_else(|e| e.into_inner()).clone();
        jobj(vec![
            ("healthy", Json::Bool(healthy)),
            ("alerts",
             Json::Arr(alerts.iter().map(|a| a.to_json()).collect())),
            ("drift", Json::Arr(drift.iter().map(drift_json).collect())),
            ("probes", Json::Arr(probes.iter().map(probe_json).collect())),
            ("reprogram",
             Json::Arr(reprog.iter().map(reprogram_json).collect())),
            ("slo", self.slo.status_json()),
            ("ticks", Json::Num(self.ticks.load(Ordering::Relaxed) as f64)),
            ("reprograms",
             Json::Num(self.reprograms.load(Ordering::Relaxed) as f64)),
        ])
    }
}

impl Drop for HealthMonitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // the tick thread holds only a Weak: it exits on its next wake,
        // so joining here (possible deadlock-free — we are the last
        // strong ref) is unnecessary
    }
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn drift_stats_json(d: &DriftStats) -> Vec<(&'static str, Json)> {
    vec![
        ("cells", Json::Num(d.cells as f64)),
        ("mean_abs_ms", Json::Num(d.mean_abs_ms())),
        ("max_abs_ms", Json::Num(d.max_abs_ms as f64)),
        ("stuck", Json::Num(d.stuck as f64)),
        ("stuck_pct", Json::Num(d.stuck_pct())),
    ]
}

fn drift_json(b: &BackendDrift) -> Json {
    let mut pairs = vec![("backend", Json::Str(b.backend.clone()))];
    pairs.extend(drift_stats_json(&b.total));
    pairs.push((
        "layers",
        Json::Arr(b.layers.iter().map(|l| {
            let mut lp = vec![("layer", Json::Num(l.layer as f64))];
            lp.extend(drift_stats_json(&l.drift));
            lp.push((
                "banks",
                Json::Arr(l.banks.iter().map(|bank| {
                    let mut bp = vec![(
                        "bank",
                        Json::Str(format!("r{}c{}", bank.tile_row,
                                          bank.tile_col)),
                    )];
                    bp.extend(drift_stats_json(&bank.drift));
                    jobj(bp)
                }).collect()),
            ));
            jobj(lp)
        }).collect()),
    ));
    jobj(pairs)
}

fn probe_json(p: &ProbeResult) -> Json {
    jobj(vec![
        ("backend", Json::Str(p.backend.clone())),
        ("class", Json::Str(p.class.name().to_string())),
        ("kl", p.kl.map(Json::Num).unwrap_or(Json::Null)),
        ("ok", Json::Bool(p.ok())),
        ("error",
         p.error.clone().map(Json::Str).unwrap_or(Json::Null)),
    ])
}

fn reprogram_json(r: &ReprogramRecord) -> Json {
    jobj(vec![
        ("backend", Json::Str(r.backend.clone())),
        ("cells", Json::Num(r.cells as f64)),
        ("failures", Json::Num(r.failures as f64)),
        ("mean_pulses", Json::Num(r.mean_pulses)),
        ("max_error_ms", Json::Num(r.max_error_ms as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SolverChoice;
    use crate::coordinator::service::Engine;
    use crate::util::rng::Rng;

    /// Stub device engine: a scalar "drift level" stands in for the
    /// conductance residuals, so monitor logic tests run without the
    /// crossbar fixture.  `generate` serves any solver family with a
    /// unit Gaussian (probes score ~0 against a Gaussian oracle).
    struct FakeDevice {
        level: Mutex<f64>,
        stuck: usize,
    }

    impl FakeDevice {
        fn new() -> FakeDevice {
            FakeDevice { level: Mutex::new(0.0), stuck: 0 }
        }
    }

    impl Engine for FakeDevice {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, _onehot: &[f32], _g: f32,
                    n: usize, rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            Ok((0..n * 2).map(|_| rng.gaussian_f32()).collect())
        }
        fn device_health(&self) -> Option<&dyn DeviceHealth> {
            Some(self)
        }
    }

    impl DeviceHealth for FakeDevice {
        fn age(&self, dt_s: f64) {
            // same shape as the cell model's calibration point:
            // dt = 1e12 s pushes the level well past the default alert
            *self.level.lock().unwrap() += dt_s * 1e-15;
        }
        fn drift_report(&self) -> Vec<LayerDrift> {
            let level = *self.level.lock().unwrap();
            vec![LayerDrift {
                layer: 0,
                drift: DriftStats {
                    cells: 100,
                    sum_abs_ms: level * 100.0,
                    max_abs_ms: (level * 2.0) as f32,
                    stuck: self.stuck,
                },
                banks: Vec::new(),
            }]
        }
        fn reprogram(&self, _tol_ms: f32) -> ProgramStats {
            *self.level.lock().unwrap() = 0.0;
            ProgramStats {
                pulses: vec![3; 100],
                failures: 0,
                abs_errors_ms: vec![5e-4; 100],
            }
        }
    }

    /// Digital-only oracle stub with no device health.
    struct PlainDigital;

    impl Engine for PlainDigital {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, s: SolverChoice, _onehot: &[f32], _g: f32,
                    n: usize, rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            if s.is_analog() {
                return Err(anyhow::anyhow!("digital engine, analog solver"));
            }
            Ok((0..n * 2).map(|_| rng.gaussian_f32()).collect())
        }
    }

    fn monitor(cfg: HealthConfig) -> (Arc<HealthMonitor>, Arc<EngineRegistry>) {
        let mut reg = EngineRegistry::new();
        reg.add_backend("analog", Arc::new(FakeDevice::new()), 1).unwrap();
        reg.add_backend("rust", Arc::new(PlainDigital), 1).unwrap();
        for class in RequestClass::ALL {
            let name = if class.family
                == crate::coordinator::request::SolverFamily::Analog
            {
                "analog"
            } else {
                "rust"
            };
            reg.route_class(class, name).unwrap();
        }
        let reg = Arc::new(reg);
        let mon = HealthMonitor::new(cfg, Arc::clone(&reg),
                                     Arc::new(ModeGate::new()));
        (mon, reg)
    }

    fn quiet_cfg() -> HealthConfig {
        // probes off: these tests drive the drift instruments only
        HealthConfig { probe_interval_ms: 0, ..HealthConfig::default() }
    }

    #[test]
    fn drift_alert_lifecycle_age_fire_reprogram_clear() {
        let (mon, _reg) = monitor(quiet_cfg());
        mon.tick();
        assert!(mon.healthy(), "fresh device: no drift");
        assert_eq!(
            obs().registry.gauge("memdiff_drift_mean_ms",
                                 &[("backend", "analog")]).get(),
            0.0);

        mon.age_all(1e12);
        mon.tick();
        assert!(!mon.healthy());
        assert_eq!(mon.firing(), vec!["drift:analog".to_string()]);
        assert!(obs().registry.gauge("memdiff_drift_mean_ms",
                                     &[("backend", "analog")]).get()
                > 4e-4);
        let j = mon.health_json().to_string();
        assert!(j.contains("\"healthy\":false"), "{j}");
        assert!(j.contains("drift:analog"), "{j}");

        assert_eq!(mon.reprogram_all(), 1);
        assert!(mon.healthy(), "reprogram re-baselines: drift back to zero");
        assert!(mon.firing().is_empty());
        // write-verify residuals landed in the histogram, not the gauges
        let h = obs().registry.hist("memdiff_program_error_ms",
                                    &[("backend", "analog")]);
        assert!(h.count() >= 100);
        assert_eq!(
            obs().registry.gauge("memdiff_drift_mean_ms",
                                 &[("backend", "analog")]).get(),
            0.0);
        let j = mon.health_json().to_string();
        assert!(j.contains("\"healthy\":true"), "{j}");
        assert!(j.contains("\"reprograms\":1"), "{j}");
    }

    #[test]
    fn stuck_cell_rule_fires_on_census() {
        let mut reg = EngineRegistry::new();
        let dev = FakeDevice { level: Mutex::new(0.0), stuck: 5 };
        reg.add_backend("analog", Arc::new(dev), 1).unwrap();
        for class in RequestClass::ALL {
            reg.route_class(class, "analog").unwrap();
        }
        let mon = HealthMonitor::new(quiet_cfg(), Arc::new(reg),
                                     Arc::new(ModeGate::new()));
        mon.tick();
        // 5 of 100 cells = 5% ≥ the 1% default
        assert!(mon.alerts().is_firing("stuck:analog"));
        assert!(!mon.healthy());
    }

    #[test]
    fn retention_clock_ages_per_tick() {
        let (mon, _reg) = monitor(HealthConfig {
            retention_dt_s: 1e12, // absurd on purpose: one tick must alert
            ..quiet_cfg()
        });
        mon.tick();
        assert!(!mon.healthy(), "retention clock applied drift on tick");
        assert!(mon.alerts().is_firing("drift:analog"));
    }

    #[test]
    fn reprogram_on_drift_auto_heals_within_the_tick() {
        let (mon, _reg) = monitor(HealthConfig {
            reprogram_on_drift: true,
            ..quiet_cfg()
        });
        mon.age_all(1e12);
        mon.tick();
        assert!(mon.healthy(),
                "tick detected drift, reprogrammed, and cleared the alert");
        assert_eq!(mon.health_json().get("reprograms")
                       .and_then(|j| j.as_f64()),
                   Some(1.0));
        // the transition counters recorded fire AND clear
        let fired = obs().registry
            .counter("memdiff_alert_transitions_total",
                     &[("name", "drift:analog"), ("to", "firing")]).get();
        let cleared = obs().registry
            .counter("memdiff_alert_transitions_total",
                     &[("name", "drift:analog"), ("to", "clear")]).get();
        assert!(fired >= 1 && cleared >= 1, "fired={fired} cleared={cleared}");
    }

    #[test]
    fn probe_quality_gate_latches_after_streak() {
        // analog backend serves a unit Gaussian; oracle is the digital
        // Gaussian — healthy.  Drop the budget to force the breach.
        let (mon, _reg) = monitor(HealthConfig {
            probe_interval_ms: 0,
            probe_samples: 400,
            probe_steps: 4,
            probe_streak: 2,
            kl_budget: [1e-9; 4], // any nonzero KL breaches
            ..HealthConfig::default()
        });
        mon.probe_now();
        assert!(!mon.alerts().is_firing("probe:analog:analog_uncond"),
                "streak of 2: first breach arms only");
        mon.probe_now();
        assert!(mon.alerts().is_firing("probe:analog:analog_uncond"));
        assert!(!mon.healthy());
        let j = mon.health_json().to_string();
        assert!(j.contains("\"probes\":["), "{j}");
        assert!(j.contains("analog_uncond"), "{j}");
    }

    #[test]
    fn healthy_probes_stay_quiet_and_render_scores() {
        let (mon, _reg) = monitor(HealthConfig {
            probe_interval_ms: 0,
            probe_samples: 2000,
            probe_steps: 4,
            ..HealthConfig::default()
        });
        mon.probe_now();
        mon.probe_now();
        assert!(mon.healthy(), "same-distribution probes inside budget: {:?}",
                mon.firing());
        let last = mon.last_probes.lock().unwrap();
        assert_eq!(last.len(), 4, "every routed class probed");
        for p in last.iter() {
            assert!(p.ok(), "{}:{} -> {:?}", p.backend, p.class, p.error);
        }
    }

    #[test]
    fn latched_alert_writes_a_flight_record() {
        let dir = std::env::temp_dir().join(
            format!("memdiff_health_fr_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let rec = Arc::new(FlightRecorder::with_limits(
            &dir, Arc::new(crate::coordinator::Metrics::new()),
            "health-test".into(), 8, Duration::ZERO).unwrap());
        let mut reg = EngineRegistry::new();
        reg.add_backend("analog", Arc::new(FakeDevice::new()), 1).unwrap();
        for class in RequestClass::ALL {
            reg.route_class(class, "analog").unwrap();
        }
        let mon = HealthMonitor::new_full(
            quiet_cfg(), SloConfig::default(), Arc::new(reg),
            Arc::new(ModeGate::new()), Some(Arc::clone(&rec)));
        rec.attach_health(&mon);

        mon.tick();
        assert!(rec.dumps().is_empty(), "healthy tick: no dump");

        mon.age_all(1e12);
        mon.tick();
        let dumps = rec.dumps();
        assert_eq!(dumps.len(), 1, "latch edge dumped once: {dumps:?}");
        let fname = dumps[0].file_name().unwrap().to_str().unwrap();
        assert!(fname.contains("alert-drift_analog"), "{fname}");
        let body = std::fs::read_to_string(&dumps[0]).unwrap();
        assert!(body.contains("drift:analog"),
                "dump names the breaching rule");

        mon.tick();
        assert_eq!(rec.dumps().len(), 1,
                   "still-firing alert doesn't re-dump every tick");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn background_thread_ticks_and_stops() {
        let (mon, _reg) = monitor(HealthConfig {
            tick_ms: 10,
            ..quiet_cfg()
        });
        mon.start();
        let t0 = Instant::now();
        while mon.ticks.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(20), "monitor stalled");
            std::thread::sleep(Duration::from_millis(5));
        }
        mon.stop();
        let after = mon.ticks.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(mon.ticks.load(Ordering::Relaxed), after,
                   "no ticks after stop()");
    }
}
