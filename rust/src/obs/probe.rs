//! Self-test probes: fixed-seed synthetic requests injected through
//! every routed backend and scored against the digital oracle.
//!
//! A probe calls [`Engine::generate`] **directly** — it never enters a
//! batcher lane, so it is provably invisible to the serving metrics
//! (the worker loop is the only caller of `Metrics::record_batch`).
//! Each routed request class gets one probe: the backend serving that
//! class runs its own solver family with a deterministic per-(backend,
//! class) seed, and the sample cloud is scored with the paper's KL
//! metric ([`crate::util::stats::kl_points`]) against reference samples
//! from the **oracle** — the first registered backend that can execute
//! the digital solver (the quality baseline of the deployment).  Oracle
//! clouds are generated once per condition and cached, so steady-state
//! probing costs one `generate` per class.
//!
//! Results surface as `memdiff_probe_kl{backend,class}` gauges plus
//! `memdiff_probe_runs_total` / `memdiff_probe_failures_total`
//! counters; the [`super::health::HealthMonitor`] turns them into
//! per-class quality-gate alerts.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::obs;
use crate::coordinator::deploy::EngineRegistry;
use crate::coordinator::request::{RequestClass, SolverChoice, SolverFamily,
                                  TaskKind};
use crate::coordinator::service::Engine;
use crate::util::rng::Rng;
use crate::util::stats::kl_points;

/// Histogram binning of the probe score — matches the evaluation
/// convention used by the repo's quality gates.
const KL_BINS: usize = 24;
const KL_LIM: f64 = 2.0;
/// CFG guidance used for conditional probe requests (the serving
/// default).
const PROBE_GUIDANCE: f32 = 2.0;
/// Conditional probes always ask for the same class so the oracle cache
/// stays single-entry per condition arm.
const PROBE_LETTER: usize = 0;

/// Probe parameters (a slice of the `[health]` config).
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    /// Samples per probe request (and per oracle reference cloud).
    pub samples: usize,
    /// Euler steps for digital probe/oracle solves.
    pub steps: usize,
    /// Base seed; per-(backend, class) streams derive from it, so probe
    /// traffic is reproducible run to run.
    pub seed: u64,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        ProbeConfig { samples: 256, steps: 100, seed: 0x9E0B_E5EE }
    }
}

/// Outcome of one probe injection.
#[derive(Debug, Clone)]
pub struct ProbeResult {
    pub backend: String,
    pub class: RequestClass,
    /// KL(probe ‖ oracle); `None` when the engine errored.
    pub kl: Option<f64>,
    pub error: Option<String>,
}

impl ProbeResult {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Deterministic probe driver over a deployment's routing table.
pub struct ProbeRunner {
    cfg: ProbeConfig,
    registry: Arc<EngineRegistry>,
    /// Oracle reference clouds, keyed by conditional arm.
    oracle_cache: Mutex<BTreeMap<bool, Arc<Vec<f32>>>>,
}

impl ProbeRunner {
    pub fn new(cfg: ProbeConfig, registry: Arc<EngineRegistry>) -> ProbeRunner {
        ProbeRunner { cfg, registry, oracle_cache: Mutex::new(BTreeMap::new()) }
    }

    /// Solver a probe of `class` runs on its serving backend.
    fn solver_for(&self, class: RequestClass) -> SolverChoice {
        match class.family {
            SolverFamily::Analog => SolverChoice::AnalogOde,
            SolverFamily::Digital => {
                SolverChoice::DigitalOde { steps: self.cfg.steps }
            }
        }
    }

    fn task_for(class: RequestClass) -> TaskKind {
        if class.conditional {
            TaskKind::Letter(PROBE_LETTER)
        } else {
            TaskKind::Circle
        }
    }

    /// Deterministic per-(backend, class) probe stream.
    fn probe_rng(&self, backend_idx: usize, class: RequestClass) -> Rng {
        Rng::new(self.cfg.seed
                 ^ ((backend_idx as u64 + 1) << 32)
                 ^ class.index() as u64)
    }

    /// Reference cloud for one conditional arm, from the digital oracle
    /// (generated once, cached).  `None` when no registered backend can
    /// execute the digital solver.
    fn oracle_cloud(&self, conditional: bool) -> Option<Arc<Vec<f32>>> {
        if let Some(c) = self.oracle_cache.lock()
            .unwrap_or_else(|e| e.into_inner()).get(&conditional)
        {
            return Some(Arc::clone(c));
        }
        let solver = SolverChoice::DigitalOde { steps: self.cfg.steps };
        let task = Self::task_for(RequestClass {
            family: SolverFamily::Digital,
            conditional,
        });
        for (b, backend) in self.registry.backends().iter().enumerate() {
            let onehot = task.onehot(backend.engine.n_classes());
            let guidance = if conditional { PROBE_GUIDANCE } else { 0.0 };
            // oracle stream is distinct from every probe stream
            let mut rng = Rng::new(self.cfg.seed
                                   ^ 0x0AC1_E000_0000_0000
                                   ^ ((b as u64) << 8)
                                   ^ conditional as u64);
            match backend.engine.generate(solver, &onehot, guidance,
                                          self.cfg.samples, &mut rng) {
                Ok(cloud) => {
                    let cloud = Arc::new(cloud);
                    self.oracle_cache.lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .insert(conditional, Arc::clone(&cloud));
                    return Some(cloud);
                }
                Err(_) => continue, // wrong family / broken backend: next
            }
        }
        None
    }

    /// Probe one routed class through its serving backend.
    fn probe_class(&self, class: RequestClass) -> Option<ProbeResult> {
        let idx = self.registry.backend_index(class)?;
        let backend = self.registry.backend(idx);
        let engine: &dyn Engine = &*backend.engine;
        let solver = self.solver_for(class);
        let task = Self::task_for(class);
        let onehot = task.onehot(engine.n_classes());
        let guidance = if class.conditional { PROBE_GUIDANCE } else { 0.0 };
        let mut rng = self.probe_rng(idx, class);
        let labels: [(&str, &str); 2] =
            [("backend", &backend.name), ("class", class.name())];
        obs().registry.counter("memdiff_probe_runs_total", &labels).inc();
        let outcome =
            engine.generate(solver, &onehot, guidance, self.cfg.samples,
                            &mut rng);
        let result = match outcome {
            Ok(cloud) => {
                let kl = self.oracle_cloud(class.conditional)
                    .map(|oracle| kl_points(&cloud, &oracle, KL_BINS, KL_LIM));
                if let Some(kl) = kl {
                    obs().registry.gauge("memdiff_probe_kl", &labels).set(kl);
                }
                ProbeResult {
                    backend: backend.name.clone(),
                    class,
                    kl,
                    error: if kl.is_some() {
                        None
                    } else {
                        Some("no digital oracle available".into())
                    },
                }
            }
            Err(e) => ProbeResult {
                backend: backend.name.clone(),
                class,
                kl: None,
                error: Some(format!("{e:#}")),
            },
        };
        if !result.ok() {
            obs().registry
                .counter("memdiff_probe_failures_total", &labels)
                .inc();
        }
        Some(result)
    }

    /// Probe every routed class once, in class order.
    pub fn run_all(&self) -> Vec<ProbeResult> {
        RequestClass::ALL
            .into_iter()
            .filter_map(|c| self.probe_class(c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::Engine;
    use anyhow::anyhow;

    /// Digital-only stand-in: unit Gaussian scaled by `spread`, errors on
    /// analog solver choices like the real digital engines.
    struct GaussEngine {
        spread: f32,
    }

    impl Engine for GaussEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, s: SolverChoice, _onehot: &[f32], _g: f32,
                    n: usize, rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            if s.is_analog() {
                return Err(anyhow!("digital engine got an analog solver"));
            }
            Ok((0..n * 2).map(|_| self.spread * rng.gaussian_f32()).collect())
        }
    }

    fn registry(spread_analog_arm: f32) -> Arc<EngineRegistry> {
        // both families routed to digital-capable engines so probes run
        // without the heavy analog fixture; the "analog" arm is just a
        // second engine with its own spread
        let mut reg = EngineRegistry::new();
        reg.add_backend("oracle", Arc::new(GaussEngine { spread: 1.0 }), 1)
            .unwrap();
        reg.add_backend("suspect",
                        Arc::new(GaussEngine { spread: spread_analog_arm }), 1)
            .unwrap();
        for class in RequestClass::ALL {
            let name = if class.family == SolverFamily::Digital {
                "oracle"
            } else {
                "suspect"
            };
            reg.route_class(class, name).unwrap();
        }
        Arc::new(reg)
    }

    #[test]
    fn probes_are_deterministic_and_score_against_the_oracle() {
        // the "suspect" engine cannot execute analog solvers, so its
        // probes fail; the digital classes probe the oracle against
        // itself (different stream, same distribution → small KL)
        let reg = registry(1.0);
        let cfg = ProbeConfig { samples: 4000, steps: 4, seed: 7 };
        let runner = ProbeRunner::new(cfg.clone(), Arc::clone(&reg));
        let a = runner.run_all();
        assert_eq!(a.len(), 4, "every routed class probed");
        for r in &a {
            match r.class.family {
                SolverFamily::Analog => {
                    assert!(!r.ok(), "digital stand-in rejects analog probes");
                }
                SolverFamily::Digital => {
                    let kl = r.kl.expect("scored");
                    // the estimator floor at this sample count / binning
                    // is ~0.2; well-separated distributions score > 1
                    assert!(kl < 0.5, "same distribution, small KL: {kl}");
                }
            }
        }
        // identical config → identical scores (fixed seeds, cached oracle)
        let runner2 = ProbeRunner::new(cfg, reg);
        let b = runner2.run_all();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kl, y.kl, "{}:{}", x.backend, x.class);
        }
    }

    #[test]
    fn probe_kl_detects_a_degraded_backend() {
        // route the digital classes to a narrow-spread engine with a
        // unit-spread oracle ahead of it in the registry
        let mut reg = EngineRegistry::new();
        reg.add_backend("oracle", Arc::new(GaussEngine { spread: 1.0 }), 1)
            .unwrap();
        reg.add_backend("narrow", Arc::new(GaussEngine { spread: 0.3 }), 1)
            .unwrap();
        for class in RequestClass::ALL
            .into_iter()
            .filter(|c| c.family == SolverFamily::Digital)
        {
            reg.route_class(class, "narrow").unwrap();
        }
        let runner = ProbeRunner::new(
            ProbeConfig { samples: 2000, steps: 4, seed: 11 },
            Arc::new(reg));
        let results = runner.run_all();
        assert_eq!(results.len(), 2, "only the routed (digital) classes");
        for r in &results {
            assert!(r.kl.expect("scored") > 0.3,
                    "narrow vs unit spread must blow the KL: {:?}", r.kl);
        }
    }

    #[test]
    fn probe_failure_counter_increments() {
        let reg = registry(1.0);
        let runner = ProbeRunner::new(
            ProbeConfig { samples: 64, steps: 4, seed: 3 }, reg);
        let before = obs().registry
            .counter("memdiff_probe_failures_total",
                     &[("backend", "suspect"), ("class", "analog_uncond")])
            .get();
        runner.run_all();
        let after = obs().registry
            .counter("memdiff_probe_failures_total",
                     &[("backend", "suspect"), ("class", "analog_uncond")])
            .get();
        assert_eq!(after, before + 1);
    }
}
