//! Latency SLO engine: per-class p99 objectives tracked as
//! multi-window burn rates over the cumulative request-latency
//! histograms, feeding the shared [`AlertEngine`].
//!
//! ## Burn-rate semantics
//!
//! An objective says "`target_frac` of `<class>` requests finish inside
//! `p99_ms_<class>`".  The error budget over any window is therefore
//! `1 - target_frac` of its traffic; the **burn rate** is how fast the
//! deployment is spending it:
//!
//! ```text
//! burn(window) = bad_fraction(window) / (1 - target_frac)
//! ```
//!
//! `1.0` means spending exactly the budget; `2.0` means the budget is
//! gone in half the window.  Following the multi-window pattern, the
//! alert observes `min(burn_fast, burn_slow)`: the fast window makes
//! the alert respond quickly and clear quickly, the slow window keeps
//! one short spike from latching it.  Both windows are computed as
//! **deltas of the cumulative histogram counters** against a
//! time-stamped snapshot ring — there is no second recording path on
//! the hot path, the engine only reads what the delivery loop already
//! records into `memdiff_request_latency_class_seconds`.
//!
//! Until the ring actually spans a window (the first `slow_window`
//! after every (re)start), its burn is scaled by the covered fraction
//! of the window — missing history counts as in-budget traffic — so a
//! brief spike right after boot cannot impersonate a sustained
//! slow-window breach and spuriously latch `slo:*` alerts.
//!
//! Rules are named `slo:<backend>:<class>` (e.g. `slo:rust:digital_uncond`)
//! and run through the same threshold + hysteresis + streak latch as
//! every other alert, so `/healthz`, `{"op":"health"}`, and
//! `memdiff_alert{name=}` report SLO breaches with no extra wiring.
//!
//! Exported gauges, refreshed every tick:
//!
//! * `memdiff_slo_burn_rate{class=,window="fast"|"slow"}`
//! * `memdiff_slo_budget_remaining{class=}` — the slow window's budget
//!   left as a fraction (1 = untouched, 0 = exhausted, negative =
//!   overspent).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::deploy::EngineRegistry;
use crate::coordinator::request::RequestClass;
use crate::util::json::Json;
use crate::util::stats::log_bucket_upper;

use super::alert::{AlertEngine, AlertRule};
use super::obs;

/// Histogram the delivery loop records end-to-end request latency into
/// (queue wait + solve wall, seconds) — the series the SLO engine reads.
pub const REQUEST_LATENCY_HIST: &str = "memdiff_request_latency_class_seconds";

/// The `[slo]` config section.
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    /// Master switch: off = no rules evaluated, no gauges exported.
    pub enabled: bool,
    /// Per-class latency objective in milliseconds, indexed by
    /// [`RequestClass::index`].  The default is deliberately generous
    /// (30 s) so an unconfigured deployment exports the series without
    /// ever firing.
    pub p99_ms: [f64; 4],
    /// Fraction of requests that must finish inside the objective.
    pub target_frac: f64,
    /// Fast burn window (responsiveness; 1 min by default).
    pub fast_window_ms: u64,
    /// Slow burn window (sustained-breach confirmation; 30 min).
    pub slow_window_ms: u64,
    /// Burn rate that latches the alert (both windows must exceed it).
    pub burn_threshold: f64,
    /// Hysteresis: the alert clears below `burn_threshold * clear_frac`.
    pub clear_frac: f64,
    /// Consecutive breaching ticks before the alert latches.
    pub streak: u32,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            enabled: true,
            p99_ms: [30_000.0; 4],
            target_frac: 0.99,
            fast_window_ms: 60_000,
            slow_window_ms: 1_800_000,
            burn_threshold: 2.0,
            clear_frac: 0.5,
            streak: 1,
        }
    }
}

/// One class's last evaluation — the `"slo"` block of the health report
/// and the flight recorder's breach context.
#[derive(Debug, Clone)]
pub struct SloClassState {
    pub class: RequestClass,
    pub backend: String,
    /// The alert rule this class feeds (`slo:<backend>:<class>`).
    pub rule: String,
    pub p99_ms: f64,
    pub burn_fast: f64,
    pub burn_slow: f64,
    pub budget_remaining: f64,
    /// Cumulative requests / budget breaches since boot.
    pub total: u64,
    pub bad: u64,
    pub firing: bool,
}

impl SloClassState {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("class".into(), Json::Str(self.class.name().into()));
        m.insert("backend".into(), Json::Str(self.backend.clone()));
        m.insert("rule".into(), Json::Str(self.rule.clone()));
        m.insert("p99_ms".into(), Json::Num(self.p99_ms));
        m.insert("burn_fast".into(), Json::Num(self.burn_fast));
        m.insert("burn_slow".into(), Json::Num(self.burn_slow));
        m.insert("budget_remaining".into(), Json::Num(self.budget_remaining));
        m.insert("total".into(), Json::Num(self.total as f64));
        m.insert("bad".into(), Json::Num(self.bad as f64));
        m.insert("firing".into(), Json::Bool(self.firing));
        Json::Obj(m)
    }
}

/// One time-stamped cumulative reading: (when, total, bad).
type Reading = (Instant, u64, u64);

/// The SLO evaluator.  Owns no alert state — it feeds whichever
/// [`AlertEngine`] the caller passes to [`Self::tick`] (the health
/// monitor's, so every export path agrees).
pub struct SloEngine {
    cfg: SloConfig,
    registry: Arc<EngineRegistry>,
    /// When the engine came up — the coverage floor for burn scaling
    /// while the snapshot ring is younger than a window.
    born: Instant,
    /// Per-class snapshot ring, pruned to the slow window.
    windows: Mutex<[Vec<Reading>; 4]>,
    /// Last evaluation per class, for the JSON report.
    last: Mutex<Vec<SloClassState>>,
}

impl SloEngine {
    pub fn new(cfg: SloConfig, registry: Arc<EngineRegistry>) -> SloEngine {
        SloEngine {
            cfg,
            registry,
            born: Instant::now(),
            windows: Mutex::new(std::array::from_fn(|_| Vec::new())),
            last: Mutex::new(Vec::new()),
        }
    }

    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// Read one class's cumulative (total, bad) from its latency
    /// histogram: bad = samples landing in buckets above the budget.
    fn cumulative(&self, backend: &str, class: RequestClass) -> (u64, u64) {
        let budget_s = self.cfg.p99_ms[class.index()] / 1e3;
        let h = obs().registry.hist(
            REQUEST_LATENCY_HIST,
            &[("backend", backend), ("class", class.name())]);
        let buckets = h.buckets();
        let mut total = 0u64;
        let mut good = 0u64;
        for (i, &c) in buckets.iter().enumerate() {
            total += c;
            // tolerance keeps a sample exactly on the budget "good"
            // despite the log-bucket edge falling a hair above it
            if log_bucket_upper(i) <= budget_s * 1.000_001 {
                good += c;
            }
        }
        (total, total - good)
    }

    /// Burn rate over `window`, as a delta against the snapshot ring:
    /// baseline is the newest reading at least `window` old.  When no
    /// reading is old enough — the window is not yet established after
    /// a (re)start — the oldest retained reading (or boot itself)
    /// serves instead and the burn is scaled by `covered / window`:
    /// the un-covered remainder counts as in-budget traffic, so a
    /// short post-boot spike cannot impersonate a sustained breach of
    /// the full window.  No traffic in the window = burn 0.
    fn burn(ring: &[Reading], born: Instant, now: Instant, window: Duration,
            cur: (u64, u64), target_frac: f64) -> (f64, f64) {
        let (t0, b0, covered) = match ring
            .iter()
            .rev()
            .find(|(t, _, _)| now.duration_since(*t) >= window)
        {
            Some(&(_, t0, b0)) => (t0, b0, window),
            None => match ring.first() {
                Some(&(t, t0, b0)) => (t0, b0, now.duration_since(t)),
                None => (0, 0, now.duration_since(born)),
            },
        };
        let d_total = cur.0.saturating_sub(t0);
        let d_bad = cur.1.saturating_sub(b0);
        if d_total == 0 {
            return (0.0, 0.0);
        }
        let frac =
            (covered.as_secs_f64() / window.as_secs_f64()).clamp(0.0, 1.0);
        let bad_frac = d_bad as f64 / d_total as f64 * frac;
        (bad_frac / (1.0 - target_frac).max(1e-9), bad_frac)
    }

    /// Evaluate every routed class once: refresh the gauges, feed the
    /// `slo:` rules into `alerts`, and return the per-class states.
    /// Call from the health monitor's tick (or directly in tests).
    pub fn tick(&self, alerts: &AlertEngine) -> Vec<SloClassState> {
        if !self.cfg.enabled {
            return Vec::new();
        }
        let now = Instant::now();
        let slow = Duration::from_millis(self.cfg.slow_window_ms.max(1));
        let fast = Duration::from_millis(self.cfg.fast_window_ms.max(1));
        let reg = &obs().registry;
        let mut states = Vec::new();
        let mut windows =
            self.windows.lock().unwrap_or_else(|e| e.into_inner());
        for class in RequestClass::ALL {
            let Some(bi) = self.registry.backend_index(class) else {
                continue;
            };
            let backend = self.registry.backend(bi).name.clone();
            let cur = self.cumulative(&backend, class);
            let ring = &mut windows[class.index()];
            let (burn_fast, _) = Self::burn(ring, self.born, now, fast, cur,
                                            self.cfg.target_frac);
            let (burn_slow, bad_frac_slow) =
                Self::burn(ring, self.born, now, slow, cur,
                           self.cfg.target_frac);
            ring.push((now, cur.0, cur.1));
            ring.retain(|(t, _, _)| now.duration_since(*t) <= slow);
            let budget_remaining =
                1.0 - bad_frac_slow / (1.0 - self.cfg.target_frac).max(1e-9);
            reg.gauge("memdiff_slo_burn_rate",
                      &[("class", class.name()), ("window", "fast")])
                .set(burn_fast);
            reg.gauge("memdiff_slo_burn_rate",
                      &[("class", class.name()), ("window", "slow")])
                .set(burn_slow);
            reg.gauge("memdiff_slo_budget_remaining",
                      &[("class", class.name())])
                .set(budget_remaining);
            // multi-window: only a burn sustained across BOTH windows
            // latches, and the faster decay of min() clears it sooner
            let rule = AlertRule::new(
                format!("slo:{}:{}", backend, class.name()),
                self.cfg.burn_threshold,
                self.cfg.burn_threshold * self.cfg.clear_frac,
                self.cfg.streak,
            );
            let firing = alerts.observe(&rule, burn_fast.min(burn_slow));
            states.push(SloClassState {
                class,
                backend,
                rule: rule.name.clone(),
                p99_ms: self.cfg.p99_ms[class.index()],
                burn_fast,
                burn_slow,
                budget_remaining,
                total: cur.0,
                bad: cur.1,
                firing,
            });
        }
        *self.last.lock().unwrap_or_else(|e| e.into_inner()) =
            states.clone();
        states
    }

    /// The last evaluation, as the health report's `"slo"` array.
    pub fn status_json(&self) -> Json {
        Json::Arr(
            self.last
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .iter()
                .map(|s| s.to_json())
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SolverFamily;
    use crate::coordinator::service::Engine;
    use crate::coordinator::SolverChoice;
    use crate::util::rng::Rng;

    // the SLO gauges are keyed by class only — serialize tests that set
    // and assert them on the shared global registry
    static GAUGE_LOCK: Mutex<()> = Mutex::new(());

    struct NullEngine;

    impl Engine for NullEngine {
        fn dim(&self) -> usize {
            2
        }
        fn n_classes(&self) -> usize {
            3
        }
        fn generate(&self, _s: SolverChoice, _oh: &[f32], _g: f32,
                    n: usize, _rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
            Ok(vec![0.0; n * 2])
        }
    }

    fn registry() -> Arc<EngineRegistry> {
        let mut reg = EngineRegistry::new();
        reg.add_backend("rust", Arc::new(NullEngine), 1).unwrap();
        reg.route_family(SolverFamily::Analog, "rust").unwrap();
        reg.route_family(SolverFamily::Digital, "rust").unwrap();
        Arc::new(reg)
    }

    /// Tight windows so the test drives a full latch → clear cycle in
    /// tens of milliseconds.
    fn cfg(p99_ms: f64) -> SloConfig {
        SloConfig {
            p99_ms: [p99_ms; 4],
            target_frac: 0.9,
            fast_window_ms: 40,
            slow_window_ms: 120,
            burn_threshold: 1.0,
            clear_frac: 0.5,
            streak: 1,
            ..SloConfig::default()
        }
    }

    fn feed(class: RequestClass, secs: f64, n: usize) {
        let h = obs().registry.hist(
            REQUEST_LATENCY_HIST,
            &[("backend", "rust"), ("class", class.name())]);
        for _ in 0..n {
            h.record_traced(secs, crate::obs::TraceId::mint().0);
        }
    }

    #[test]
    fn sustained_breach_latches_and_clears_through_hysteresis() {
        let _g = GAUGE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::set_enabled(true);
        let class = RequestClass {
            family: SolverFamily::Digital,
            conditional: false,
        };
        let slo = SloEngine::new(cfg(1.0), registry());
        let alerts = AlertEngine::new();
        let rule = "slo:rust:digital_uncond";

        // healthy traffic: well inside the 1 ms budget
        feed(class, 1e-4, 50);
        slo.tick(&alerts);
        assert!(!alerts.is_firing(rule), "{:?}", alerts.firing());

        // sustained breach: every request blows the budget; the sleep
        // covers the whole fast window and half the slow one, so even
        // the coverage-scaled slow burn clears the threshold
        feed(class, 0.05, 50);
        std::thread::sleep(Duration::from_millis(60));
        let states = slo.tick(&alerts);
        assert!(alerts.is_firing(rule), "burn should latch: {states:?}");
        let st = states
            .iter()
            .find(|s| s.class == class)
            .expect("digital_uncond evaluated");
        assert!(st.firing && st.burn_fast > 1.0 && st.burn_slow > 1.0,
                "{st:?}");
        assert!(st.budget_remaining < 1.0);

        // load stops; once both windows roll past the breach the burn
        // decays to 0 and the latch clears through the hysteresis band
        std::thread::sleep(Duration::from_millis(150));
        slo.tick(&alerts);
        std::thread::sleep(Duration::from_millis(10));
        slo.tick(&alerts);
        assert!(!alerts.is_firing(rule),
                "burn 0 after the windows roll: {:?}", alerts.firing());
    }

    #[test]
    fn idle_classes_export_gauges_without_firing() {
        let _g = GAUGE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::set_enabled(true);
        let slo = SloEngine::new(SloConfig::default(), registry());
        let alerts = AlertEngine::new();
        let states = slo.tick(&alerts);
        assert_eq!(states.len(), 4, "every routed class evaluated");
        assert!(!alerts.any_firing());
        for class in RequestClass::ALL {
            let g = obs().registry.gauge(
                "memdiff_slo_budget_remaining", &[("class", class.name())]);
            // other tests may have fed the shared global histograms, but
            // a just-born engine covers ~none of the slow window, so its
            // scaled spend stays negligible
            assert!((g.get() - 1.0).abs() < 1e-3,
                    "idle budget untouched for {class}: {}", g.get());
        }
        // and the report names every rule
        let j = slo.status_json().to_string();
        assert!(j.contains("slo:rust:digital_uncond"), "{j}");
    }

    #[test]
    fn boot_spike_is_scaled_by_coverage_and_does_not_latch() {
        let _g = GAUGE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::set_enabled(true);
        let class = RequestClass {
            family: SolverFamily::Digital,
            conditional: true,
        };
        // slow window far wider than the engine's lifetime: a breaching
        // burst right after boot must not latch, because the un-covered
        // remainder of the slow window counts as in-budget traffic
        let slo = SloEngine::new(
            SloConfig {
                p99_ms: [1.0; 4],
                target_frac: 0.9,
                fast_window_ms: 20,
                slow_window_ms: 60_000,
                burn_threshold: 1.0,
                clear_frac: 0.5,
                streak: 1,
                ..SloConfig::default()
            },
            registry());
        let alerts = AlertEngine::new();
        slo.tick(&alerts); // baseline reading before the spike
        feed(class, 0.05, 50);
        std::thread::sleep(Duration::from_millis(25));
        let states = slo.tick(&alerts);
        let st = states
            .iter()
            .find(|s| s.class == class)
            .expect("digital_cond evaluated");
        assert!(st.burn_fast > 1.0,
                "fast window is fully covered and burns: {st:?}");
        assert!(st.burn_slow < 1.0,
                "slow burn scaled by its tiny coverage: {st:?}");
        assert!(!alerts.is_firing("slo:rust:digital_cond"),
                "{:?}", alerts.firing());
    }

    #[test]
    fn disabled_engine_is_inert() {
        let slo = SloEngine::new(
            SloConfig { enabled: false, ..SloConfig::default() }, registry());
        let alerts = AlertEngine::new();
        assert!(slo.tick(&alerts).is_empty());
        assert!(!alerts.any_firing());
    }
}
