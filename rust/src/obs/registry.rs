//! Metrics registry: named counters, gauges, and atomic log-bucketed
//! histograms with constant memory per series.
//!
//! Series are keyed by `(name, sorted label pairs)`.  Handle lookup
//! takes one short mutex on the registry map; recording through a held
//! handle is lock-free (atomics only).  Histograms share their bucket
//! geometry with [`crate::util::stats`] (`log_bucket_*`), so quantiles
//! read here carry the same ±4.4% relative-error bound and the
//! Prometheus `le` edges match the in-process `Summary` everywhere.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::stats::{log_bucket_repr, LOG_BUCKETS};

/// A series key: metric name + sorted `label=value` pairs.
pub type Key = (String, Vec<(String, String)>);

/// Monotone counter handle (clone-cheap; record is one atomic add).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge holding an `f64` (stored as bits).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lock-free log-bucketed histogram (the atomic sibling of
/// [`crate::util::stats::Summary`]): fixed bucket array + exact
/// count/sum, bounded memory regardless of sample volume.
pub struct AtomicHist {
    buckets: Box<[AtomicU64; LOG_BUCKETS]>,
    count: AtomicU64,
    /// Σ values, accumulated as f64 bits via CAS (contention here is one
    /// batch completion at a time — negligible).
    sum_bits: AtomicU64,
    /// Per-bucket exemplar: the trace id of the most recent traced
    /// sample that landed in the bucket (0 = none yet).  Two relaxed
    /// stores per traced record; a torn trace/value pair across the two
    /// arrays only mislabels one exemplar, never corrupts the counts.
    exemplar_trace: Box<[AtomicU64; LOG_BUCKETS]>,
    /// The exemplar sample's value, as f64 bits.
    exemplar_bits: Box<[AtomicU64; LOG_BUCKETS]>,
}

impl AtomicHist {
    fn new() -> AtomicHist {
        AtomicHist {
            buckets: Box::new(std::array::from_fn(|_| AtomicU64::new(0))),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            exemplar_trace: Box::new(std::array::from_fn(|_| AtomicU64::new(0))),
            exemplar_bits: Box::new(
                std::array::from_fn(|_| AtomicU64::new(0f64.to_bits()))),
        }
    }

    pub fn record(&self, v: f64) {
        self.record_traced(v, 0);
    }

    /// Record `v` and, when `trace` is nonzero, retain it as the
    /// bucket's exemplar — the answer to "which request was the p99".
    pub fn record_traced(&self, v: f64, trace: u64) {
        let i = crate::util::stats::log_bucket_index(v);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        if trace != 0 {
            self.exemplar_bits[i].store(v.to_bits(), Ordering::Relaxed);
            self.exemplar_trace[i].store(trace, Ordering::Relaxed);
        }
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Per-bucket `(trace, value)` exemplars (trace 0 = none recorded).
    pub fn exemplars(&self) -> Vec<(u64, f64)> {
        (0..LOG_BUCKETS)
            .map(|i| (self.exemplar_trace[i].load(Ordering::Relaxed),
                      f64::from_bits(
                          self.exemplar_bits[i].load(Ordering::Relaxed))))
            .collect()
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Snapshot of the raw (non-cumulative) bucket counts.
    pub fn buckets(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Approximate percentile (±4.4%), q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        let counts = self.buckets();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return f64::NAN;
        }
        let rank = ((q / 100.0) * (total as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return log_bucket_repr(i);
            }
        }
        log_bucket_repr(LOG_BUCKETS - 1)
    }
}

/// Hot-path phase timers: fixed atomic (Σns, count) slots — no map
/// lookup, no allocation, safe to hit from the GEMM inner loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Matrix-multiply microkernels (`util::tensor`).
    Gemm,
    /// Gaussian/noise-DAC generation passes.
    NoisePass,
    /// One stepper substep (analog RC loop or digital Euler step).
    Substep,
    /// Durable-log fsync (`jobs::store`).
    Fsync,
}

impl Phase {
    pub const ALL: [Phase; 4] =
        [Phase::Gemm, Phase::NoisePass, Phase::Substep, Phase::Fsync];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Gemm => "gemm",
            Phase::NoisePass => "noise_pass",
            Phase::Substep => "substep",
            Phase::Fsync => "fsync",
        }
    }

    pub fn index(&self) -> usize {
        *self as usize
    }
}

#[derive(Default)]
pub struct PhaseSlot {
    pub sum_ns: AtomicU64,
    pub count: AtomicU64,
}

pub struct PhaseTimers {
    pub slots: [PhaseSlot; Phase::ALL.len()],
}

impl PhaseTimers {
    pub fn new() -> PhaseTimers {
        PhaseTimers { slots: std::array::from_fn(|_| PhaseSlot::default()) }
    }

    pub fn record(&self, phase: Phase, ns: u64) {
        let slot = &self.slots[phase.index()];
        slot.sum_ns.fetch_add(ns, Ordering::Relaxed);
        slot.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn read(&self, phase: Phase) -> (u64, u64) {
        let slot = &self.slots[phase.index()];
        (slot.sum_ns.load(Ordering::Relaxed), slot.count.load(Ordering::Relaxed))
    }
}

/// The series registry.  Get-or-create returns a shared handle the call
/// site caches (or re-looks-up — one mutexed BTreeMap probe).
pub struct Registry {
    counters: Mutex<BTreeMap<Key, Counter>>,
    gauges: Mutex<BTreeMap<Key, Gauge>>,
    hists: Mutex<BTreeMap<Key, Arc<AtomicHist>>>,
}

fn key(name: &str, labels: &[(&str, &str)]) -> Key {
    let mut ls: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    ls.sort();
    (name.to_string(), ls)
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let mut m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(key(name, labels))
            .or_insert_with(|| Counter(Arc::new(AtomicU64::new(0))))
            .clone()
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let mut m = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(key(name, labels))
            .or_insert_with(|| Gauge(Arc::new(AtomicU64::new(0f64.to_bits()))))
            .clone()
    }

    pub fn hist(&self, name: &str, labels: &[(&str, &str)]) -> Arc<AtomicHist> {
        let mut m = self.hists.lock().unwrap_or_else(|e| e.into_inner());
        Arc::clone(m.entry(key(name, labels)).or_insert_with(|| Arc::new(AtomicHist::new())))
    }

    /// Snapshot every series for export (counters, gauges, histograms).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let counters = self.counters.lock().unwrap_or_else(|e| e.into_inner())
            .iter().map(|(k, c)| (k.clone(), c.get())).collect();
        let gauges = self.gauges.lock().unwrap_or_else(|e| e.into_inner())
            .iter().map(|(k, g)| (k.clone(), g.get())).collect();
        let hists = self.hists.lock().unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, h)| (k.clone(), HistSnapshot {
                buckets: h.buckets(),
                count: h.count(),
                sum: h.sum(),
                p50: h.percentile(50.0),
                p90: h.percentile(90.0),
                p99: h.percentile(99.0),
                exemplars: h.exemplars(),
            }))
            .collect();
        RegistrySnapshot { counters, gauges, hists }
    }
}

/// Point-in-time copy of every registered series.
pub struct RegistrySnapshot {
    pub counters: Vec<(Key, u64)>,
    pub gauges: Vec<(Key, f64)>,
    pub hists: Vec<(Key, HistSnapshot)>,
}

pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// Per-bucket `(trace, value)` exemplars — trace 0 = none.
    pub exemplars: Vec<(u64, f64)>,
}

impl HistSnapshot {
    /// The exemplar nearest (from above) to the quantile `q`'s bucket:
    /// the concrete request behind an approximate percentile.  Walks
    /// from the quantile's bucket upward so a tail exemplar wins when
    /// the exact bucket never saw a traced sample.  Never reaches
    /// *below* the quantile bucket — labeling a fast request as the
    /// p99 would misattribute the tail — so when no bucket at or above
    /// the quantile holds a traced sample there is no exemplar.
    pub fn exemplar_at(&self, q: f64) -> Option<(u64, f64)> {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return None;
        }
        let rank = ((q / 100.0) * (total as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        let mut at = self.buckets.len() - 1;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                at = i;
                break;
            }
        }
        self.exemplars[at..].iter().find(|(t, _)| *t != 0).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("memdiff_test_total", &[("lane", "a")]);
        c.inc();
        c.add(4);
        // same key → same series, regardless of label order
        assert_eq!(r.counter("memdiff_test_total", &[("lane", "a")]).get(), 5);
        let g = r.gauge("memdiff_depth", &[]);
        g.set(3.5);
        assert_eq!(r.gauge("memdiff_depth", &[]).get(), 3.5);
    }

    #[test]
    fn hist_counts_sum_and_quantiles() {
        let r = Registry::new();
        let h = r.hist("memdiff_lat", &[("stage", "queue")]);
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 5.05).abs() < 1e-9);
        let p50 = h.percentile(50.0);
        assert!((p50 / 0.050 - 1.0).abs() < 0.125, "p50={p50}");
        let p99 = h.percentile(99.0);
        assert!(p99 >= p50);
    }

    #[test]
    fn traced_records_keep_bucket_exemplars() {
        let r = Registry::new();
        let h = r.hist("memdiff_req_lat", &[("class", "digital_uncond")]);
        // bulk of fast untraced samples, one slow traced outlier
        for _ in 0..99 {
            h.record(1e-3);
        }
        h.record_traced(5.0, 0xABCD);
        let snap = r.snapshot();
        let (_, hs) = snap
            .hists
            .iter()
            .find(|(k, _)| k.0 == "memdiff_req_lat")
            .expect("series snapshotted");
        let (trace, val) = hs.exemplar_at(99.0).expect("tail exemplar");
        assert_eq!(trace, 0xABCD);
        assert!((val - 5.0).abs() < 1e-9);
        // untraced records never install an exemplar
        let h2 = r.hist("memdiff_untraced", &[]);
        h2.record(0.5);
        assert!(h2.exemplars().iter().all(|(t, _)| *t == 0));
    }

    #[test]
    fn exemplar_never_reaches_below_the_quantile_bucket() {
        let r = Registry::new();
        let h = r.hist("memdiff_fallback", &[]);
        // traced sample in a low bucket, untraced mass above it:
        // reporting the 1 ms request as "the p99" would mislabel the
        // tail, so the p99 carries no exemplar at all
        h.record_traced(1e-3, 7);
        for _ in 0..50 {
            h.record(1.0);
        }
        let snap = r.snapshot();
        let (_, hs) = snap.hists.iter()
            .find(|(k, _)| k.0 == "memdiff_fallback").unwrap();
        assert_eq!(hs.exemplar_at(99.0), None);
        // but the traced request still stands for its own quantile
        assert_eq!(hs.exemplar_at(0.0).map(|(t, _)| t), Some(7));
    }

    #[test]
    fn phase_timers_accumulate() {
        let t = PhaseTimers::new();
        t.record(Phase::Gemm, 100);
        t.record(Phase::Gemm, 50);
        t.record(Phase::Fsync, 7);
        assert_eq!(t.read(Phase::Gemm), (150, 2));
        assert_eq!(t.read(Phase::Fsync), (7, 1));
        assert_eq!(t.read(Phase::Substep), (0, 0));
    }
}
