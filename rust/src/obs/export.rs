//! Exporters: Prometheus text exposition and JSON rendering of the
//! whole observability surface — the coordinator metrics snapshot
//! (counters, per-backend gauges, bank read counters, pool stats, job
//! gauges), the [`super::Registry`] series (per-stage latency
//! histograms), the hot-path phase timers, and recent trace timelines
//! (JSON only).
//!
//! Histograms render with cumulative `le` buckets on the shared
//! log-bucket edges ([`crate::util::stats::log_bucket_upper`]); only
//! non-empty buckets are emitted (cumulativity still holds at every
//! emitted edge), plus the mandatory `+Inf`, `_sum`, and `_count`.
//!
//! Two text flavors share one renderer: [`render_prometheus`] is the
//! classic `text/plain; version=0.0.4` exposition — no exemplar
//! suffixes, because the classic parser treats anything after the
//! value as a timestamp and a `#` there is a parse error — and
//! [`render_openmetrics`] is the OpenMetrics flavor (exemplars on
//! traced buckets, counter families without the `_total` sample
//! suffix, trailing `# EOF`), served only to scrapers that negotiate
//! `application/openmetrics-text` via `Accept`.

use std::collections::BTreeMap;

use crate::coordinator::metrics::MetricsSnapshot;
use crate::util::json::Json;
use crate::util::stats::{log_bucket_upper, Summary};

use super::registry::{HistSnapshot, Key};
use super::trace::SpanEvent;
use super::{obs, Phase};

/// Escape a label value per the Prometheus text exposition rules.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn labels_text(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

fn line(out: &mut String, name: &str, labels: &[(String, String)], v: f64) {
    out.push_str(name);
    out.push_str(&labels_text(labels));
    if v.fract() == 0.0 && v.abs() < 1e15 {
        out.push_str(&format!(" {}\n", v as i64));
    } else {
        out.push_str(&format!(" {v}\n"));
    }
}

fn header(out: &mut String, om: bool, name: &str, kind: &str, help: &str) {
    // OpenMetrics names a counter family without the `_total` sample
    // suffix; the classic text format keeps the full sample name.
    let family = if om && kind == "counter" {
        name.strip_suffix("_total").unwrap_or(name)
    } else {
        name
    };
    out.push_str(&format!("# HELP {family} {help}\n# TYPE {family} {kind}\n"));
}

fn owned(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

/// Render one histogram family series: cumulative buckets at the
/// non-empty log-bucket edges, then `+Inf`, `_sum`, `_count`.  In
/// OpenMetrics mode (`om`), a non-empty `exemplars` slice (per-bucket
/// `(trace, value)` pairs, 0 = none) appends exemplar suffixes —
/// `# {trace_id="T"} value` — to the bucket lines that retained one;
/// the classic format never carries them (its parser reads anything
/// after the value as a timestamp, so a `#` there is a parse error).
fn render_hist(out: &mut String, om: bool, name: &str,
               labels: &[(String, String)], buckets: &[u64], sum: f64,
               exemplars: &[(u64, f64)]) {
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        let upper = log_bucket_upper(i);
        if upper.is_finite() {
            let mut ls = labels.to_vec();
            ls.push(("le".to_string(), format!("{upper:.6e}")));
            match exemplars.get(i) {
                Some(&(t, v)) if t != 0 && om => {
                    out.push_str(&format!(
                        "{name}_bucket{} {cum} # {{trace_id=\"{t}\"}} {v}\n",
                        labels_text(&ls)));
                }
                _ => line(out, &format!("{name}_bucket"), &ls, cum as f64),
            }
        }
    }
    let mut ls = labels.to_vec();
    ls.push(("le".to_string(), "+Inf".to_string()));
    line(out, &format!("{name}_bucket"), &ls, cum as f64);
    line(out, &format!("{name}_sum"), labels, sum);
    line(out, &format!("{name}_count"), labels, cum as f64);
}

fn render_summary_hist(out: &mut String, om: bool, name: &str,
                       labels: &[(String, String)], s: &Summary) {
    render_hist(out, om, name, labels, s.buckets(), s.sum(), &[]);
}

/// The classic Prometheus text exposition (`text/plain; version=0.0.4`):
/// coordinator snapshot + registry + phase timers, with no exemplar
/// suffixes so any vanilla scraper parses it.  This is what
/// `--metrics-listen` serves by default and what the `stats` wire op
/// embeds.
pub fn render_prometheus(snap: &MetricsSnapshot) -> String {
    render_exposition(snap, false)
}

/// The OpenMetrics flavor (`application/openmetrics-text`): same
/// families, counter families named without the `_total` sample suffix,
/// exemplar suffixes on traced histogram buckets, and the mandatory
/// trailing `# EOF`.  Serve it only to scrapers whose `Accept` header
/// negotiated it.
pub fn render_openmetrics(snap: &MetricsSnapshot) -> String {
    render_exposition(snap, true)
}

fn render_exposition(snap: &MetricsSnapshot, om: bool) -> String {
    let mut out = String::with_capacity(4096);
    let o = obs();

    header(&mut out, om, "memdiff_requests_total", "counter",
           "Requests served by the coordinator.");
    line(&mut out, "memdiff_requests_total", &[], snap.requests as f64);
    header(&mut out, om, "memdiff_samples_total", "counter",
           "Samples generated.");
    line(&mut out, "memdiff_samples_total", &[], snap.samples as f64);
    header(&mut out, om, "memdiff_batches_total", "counter",
           "Batches executed.");
    line(&mut out, "memdiff_batches_total", &[], snap.batches as f64);
    header(&mut out, om, "memdiff_rejected_total", "counter",
           "Admission rejects (bounded-lane sheds).");
    line(&mut out, "memdiff_rejected_total", &[], snap.rejected as f64);
    header(&mut out, om, "memdiff_worker_panics_total", "counter",
           "Engine panics contained by worker catch_unwind.");
    line(&mut out, "memdiff_worker_panics_total", &[],
         snap.worker_panics as f64);
    header(&mut out, om, "memdiff_batch_fill_ratio", "gauge",
           "Mean batch fill (coalesced samples / max batch).");
    line(&mut out, "memdiff_batch_fill_ratio", &[], zero_nan(snap.mean_batch_fill));

    header(&mut out, om, "memdiff_request_latency_seconds", "histogram",
           "Batch wall latency, service-wide.");
    render_summary_hist(&mut out, om, "memdiff_request_latency_seconds", &[],
                        &snap.wall_latency);

    if !snap.backends.is_empty() {
        header(&mut out, om, "memdiff_backend_requests_total", "counter",
               "Requests served per backend.");
        for b in &snap.backends {
            line(&mut out, "memdiff_backend_requests_total",
                 &owned(&[("backend", &b.name)]), b.requests as f64);
        }
        header(&mut out, om, "memdiff_backend_samples_total", "counter",
               "Samples generated per backend.");
        for b in &snap.backends {
            line(&mut out, "memdiff_backend_samples_total",
                 &owned(&[("backend", &b.name)]), b.samples as f64);
        }
        header(&mut out, om, "memdiff_backend_rejected_total", "counter",
               "Bounded-lane sheds per backend.");
        for b in &snap.backends {
            line(&mut out, "memdiff_backend_rejected_total",
                 &owned(&[("backend", &b.name)]), b.rejected as f64);
        }
        header(&mut out, om, "memdiff_lane_queue_depth", "gauge",
               "Samples queued in the backend's lane.");
        for b in &snap.backends {
            line(&mut out, "memdiff_lane_queue_depth",
                 &owned(&[("backend", &b.name)]), b.queue_depth as f64);
        }
        header(&mut out, om, "memdiff_hw_energy_joules_total", "counter",
               "Modeled hardware energy served per backend.");
        for b in &snap.backends {
            line(&mut out, "memdiff_hw_energy_joules_total",
                 &owned(&[("backend", &b.name)]), b.hw_energy_j);
        }
        header(&mut out, om, "memdiff_backend_latency_seconds", "histogram",
               "Batch wall latency per backend.");
        for b in &snap.backends {
            render_summary_hist(&mut out, om, "memdiff_backend_latency_seconds",
                                &owned(&[("backend", &b.name)]),
                                &b.wall_latency);
        }
    }

    if !snap.banking.is_empty() {
        header(&mut out, om, "memdiff_bank_reads_total", "counter",
               "MVM read sweeps per crossbar layer (and per bank tile).");
        for r in &snap.banking {
            let layer = r.layer.to_string();
            line(&mut out, "memdiff_bank_reads_total",
                 &owned(&[("layer", &layer)]), r.reads as f64);
            for b in &r.banks {
                let tile = format!("r{}c{}", b.tile_row, b.tile_col);
                line(&mut out, "memdiff_bank_reads_total",
                     &owned(&[("layer", &layer), ("bank", &tile)]),
                     b.reads as f64);
            }
        }
    }

    if let Some(p) = &snap.pool {
        header(&mut out, om, "memdiff_pool_threads", "gauge",
               "Intra-op pool thread count.");
        line(&mut out, "memdiff_pool_threads", &[], p.threads as f64);
        header(&mut out, om, "memdiff_pool_scopes_total", "counter",
               "Fork-join scopes run.");
        line(&mut out, "memdiff_pool_scopes_total", &[], p.scopes_run as f64);
        header(&mut out, om, "memdiff_pool_tasks_total", "counter",
               "Pool tasks run.");
        line(&mut out, "memdiff_pool_tasks_total", &[], p.tasks_run as f64);
    }

    if let Some(j) = &snap.jobs {
        header(&mut out, om, "memdiff_jobs", "gauge",
               "Durable jobs by lifecycle state.");
        for (state, v) in [("queued", j.queued), ("running", j.running),
                           ("failed", j.failed), ("done", j.done),
                           ("dead", j.dead), ("cancelled", j.cancelled)] {
            line(&mut out, "memdiff_jobs", &owned(&[("state", state)]),
                 v as f64);
        }
        header(&mut out, om, "memdiff_jobs_enqueued_total", "counter",
               "Jobs durably enqueued.");
        line(&mut out, "memdiff_jobs_enqueued_total", &[],
             j.enqueued_total as f64);
        header(&mut out, om, "memdiff_jobs_retries_total", "counter",
               "Job attempts retried.");
        line(&mut out, "memdiff_jobs_retries_total", &[],
             j.retries_total as f64);
    }

    if !snap.degraded.is_empty() {
        header(&mut out, om, "memdiff_degraded_routes", "gauge",
               "Classes rerouted off their planned backend at startup.");
        line(&mut out, "memdiff_degraded_routes", &[],
             snap.degraded.len() as f64);
    }

    // dynamic registry series (per-stage latency histograms and any
    // counters/gauges instrumented sites registered)
    let reg = o.registry.snapshot();
    render_registry_counters(&mut out, om, &reg.counters);
    render_registry_gauges(&mut out, om, &reg.gauges);
    render_registry_hists(&mut out, om, &reg.hists);

    header(&mut out, om, "memdiff_phase_seconds_total", "counter",
           "Time spent in instrumented hot-path phases.");
    for p in Phase::ALL {
        let (ns, _) = o.phases.read(p);
        line(&mut out, "memdiff_phase_seconds_total",
             &owned(&[("phase", p.name())]), ns as f64 * 1e-9);
    }
    header(&mut out, om, "memdiff_phase_invocations_total", "counter",
           "Invocations of instrumented hot-path phases.");
    for p in Phase::ALL {
        let (_, n) = o.phases.read(p);
        line(&mut out, "memdiff_phase_invocations_total",
             &owned(&[("phase", p.name())]), n as f64);
    }
    if om {
        out.push_str("# EOF\n");
    }
    out
}

fn zero_nan(v: f64) -> f64 {
    if v.is_nan() {
        0.0
    } else {
        v
    }
}

fn render_registry_counters(out: &mut String, om: bool,
                            counters: &[(Key, u64)]) {
    let mut last = "";
    for ((name, labels), v) in counters {
        if name != last {
            header(out, om, name, "counter", "Registered counter.");
            last = name;
        }
        line(out, name, labels, *v as f64);
    }
}

fn render_registry_gauges(out: &mut String, om: bool, gauges: &[(Key, f64)]) {
    let mut last = "";
    for ((name, labels), v) in gauges {
        if name != last {
            header(out, om, name, "gauge", "Registered gauge.");
            last = name;
        }
        line(out, name, labels, *v);
    }
}

fn render_registry_hists(out: &mut String, om: bool,
                         hists: &[(Key, HistSnapshot)]) {
    let mut last = "";
    for ((name, labels), h) in hists {
        if name != last {
            header(out, om, name, "histogram", "Registered histogram.");
            last = name;
        }
        render_hist(out, om, name, labels, &h.buckets, h.sum, &h.exemplars);
    }
}

// ---------------------------------------------------------------------
// JSON rendering (the `stats` wire op and the periodic JSONL flush)

fn jnum(v: f64) -> Json {
    Json::Num(zero_nan(v))
}

fn jobj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// The whole observability surface as one JSON object: coordinator
/// counters/gauges, per-stage latency breakdowns, phase timers, and the
/// most recent trace timelines.
pub fn stats_json(snap: &MetricsSnapshot) -> Json {
    let o = obs();
    let mut top: Vec<(&str, Json)> = vec![
        ("requests", jnum(snap.requests as f64)),
        ("samples", jnum(snap.samples as f64)),
        ("batches", jnum(snap.batches as f64)),
        ("rejected", jnum(snap.rejected as f64)),
        ("worker_panics", jnum(snap.worker_panics as f64)),
        ("mean_latency_s", jnum(snap.mean_latency_s)),
        ("p99_latency_s", jnum(snap.p99_latency_s)),
        ("mean_batch_fill", jnum(snap.mean_batch_fill)),
    ];

    top.push(("backends", Json::Arr(snap.backends.iter().map(|b| jobj(vec![
        ("name", Json::Str(b.name.clone())),
        ("requests", jnum(b.requests as f64)),
        ("samples", jnum(b.samples as f64)),
        ("batches", jnum(b.batches as f64)),
        ("rejected", jnum(b.rejected as f64)),
        ("queue_depth", jnum(b.queue_depth as f64)),
        ("hw_energy_j", jnum(b.hw_energy_j)),
        ("mean_latency_s", jnum(b.mean_latency_s)),
        ("p50_latency_s", jnum(b.wall_latency.p50())),
        ("p99_latency_s", jnum(b.wall_latency.p99())),
    ])).collect())));

    top.push(("banks", Json::Arr(snap.banking.iter().map(|r| jobj(vec![
        ("layer", jnum(r.layer as f64)),
        ("rows", jnum(r.rows as f64)),
        ("cols", jnum(r.cols as f64)),
        ("tile_rows", jnum(r.tile_rows as f64)),
        ("tile_cols", jnum(r.tile_cols as f64)),
        ("reads", jnum(r.reads as f64)),
        ("banks", Json::Arr(r.banks.iter().map(|b| jobj(vec![
            ("tile_row", jnum(b.tile_row as f64)),
            ("tile_col", jnum(b.tile_col as f64)),
            ("reads", jnum(b.reads as f64)),
        ])).collect())),
    ])).collect())));

    if let Some(p) = &snap.pool {
        top.push(("pool", jobj(vec![
            ("threads", jnum(p.threads as f64)),
            ("scopes_run", jnum(p.scopes_run as f64)),
            ("tasks_run", jnum(p.tasks_run as f64)),
            ("max_queue_depth", jnum(p.max_queue_depth as f64)),
        ])));
    }

    if let Some(j) = &snap.jobs {
        top.push(("jobs", jobj(vec![
            ("queued", jnum(j.queued as f64)),
            ("running", jnum(j.running as f64)),
            ("failed", jnum(j.failed as f64)),
            ("done", jnum(j.done as f64)),
            ("dead", jnum(j.dead as f64)),
            ("cancelled", jnum(j.cancelled as f64)),
            ("enqueued_total", jnum(j.enqueued_total as f64)),
            ("retries_total", jnum(j.retries_total as f64)),
        ])));
    }

    if !snap.degraded.is_empty() {
        top.push(("degraded", Json::Arr(
            snap.degraded.iter().map(|d| Json::Str(d.clone())).collect())));
    }

    // per-stage latency breakdowns (per backend, per class)
    let reg = o.registry.snapshot();
    top.push(("stages", Json::Arr(reg.hists.iter()
        .filter(|((name, _), _)| name == "memdiff_stage_latency_seconds")
        .map(|((_, labels), h)| {
            let get = |k: &str| labels.iter().find(|(lk, _)| lk == k)
                .map(|(_, v)| v.clone()).unwrap_or_default();
            jobj(vec![
                ("stage", Json::Str(get("stage"))),
                ("backend", Json::Str(get("backend"))),
                ("class", Json::Str(get("class"))),
                ("count", jnum(h.count as f64)),
                ("sum_s", jnum(h.sum)),
                ("p50_s", jnum(h.p50)),
                ("p90_s", jnum(h.p90)),
                ("p99_s", jnum(h.p99)),
            ])
        })
        .collect())));

    // per-class request latency with the p99 exemplar: which request
    // was the tail, and where its time went (span-ring breakdown)
    top.push(("class_latency", Json::Arr(reg.hists.iter()
        .filter(|((name, _), _)| name == super::slo::REQUEST_LATENCY_HIST)
        .map(|((_, labels), h)| {
            let get = |k: &str| labels.iter().find(|(lk, _)| lk == k)
                .map(|(_, v)| v.clone()).unwrap_or_default();
            let mut fields = vec![
                ("backend", Json::Str(get("backend"))),
                ("class", Json::Str(get("class"))),
                ("count", jnum(h.count as f64)),
                ("p50_s", jnum(h.p50)),
                ("p99_s", jnum(h.p99)),
            ];
            if let Some((trace, v)) = h.exemplar_at(99.0) {
                fields.push(("p99_exemplar_trace", jnum(trace as f64)));
                fields.push(("p99_exemplar_s", jnum(v)));
                let tl = o.ring.timeline(super::TraceId(trace));
                fields.push(("p99_exemplar_stages",
                    Json::Arr(tl.iter().map(|e| jobj(vec![
                        ("stage", Json::Str(e.stage.name().to_string())),
                        ("dur_us", jnum(e.dur_us as f64)),
                    ])).collect())));
            }
            jobj(fields)
        }).collect())));

    top.push(("phases", Json::Arr(Phase::ALL.iter().map(|p| {
        let (ns, n) = o.phases.read(*p);
        jobj(vec![
            ("phase", Json::Str(p.name().to_string())),
            ("total_s", jnum(ns as f64 * 1e-9)),
            ("count", jnum(n as f64)),
        ])
    }).collect())));

    top.push(("traces", traces_json(&o.ring.snapshot())));

    jobj(top)
}

/// Most recent trace timelines (up to 32), newest first.
fn traces_json(events: &[SpanEvent]) -> Json {
    let o = obs();
    let mut by_trace: BTreeMap<u64, Vec<&SpanEvent>> = BTreeMap::new();
    for e in events {
        by_trace.entry(e.trace).or_default().push(e);
    }
    let mut traces: Vec<(u64, Vec<&SpanEvent>)> = by_trace.into_iter().collect();
    // newest first, by the trace's latest span
    traces.sort_by_key(|(_, evs)|
        std::cmp::Reverse(evs.iter().map(|e| e.start_us).max().unwrap_or(0)));
    traces.truncate(32);
    Json::Arr(traces.into_iter().map(|(t, mut evs)| {
        evs.sort_by_key(|e| (e.start_us, e.stage.index()));
        jobj(vec![
            ("trace", jnum(t as f64)),
            ("spans", Json::Arr(evs.into_iter().map(|e| jobj(vec![
                ("stage", Json::Str(e.stage.name().to_string())),
                ("start_us", jnum(e.start_us as f64)),
                ("dur_us", jnum(e.dur_us as f64)),
                ("backend", Json::Str(o.label_name(e.backend))),
                ("class", Json::Str(o.label_name(e.class))),
            ])).collect())),
        ])
    }).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    fn snap_with_traffic() -> MetricsSnapshot {
        let m = Metrics::new();
        m.set_backends(&["analog".to_string(), "rust".to_string()]);
        m.record_batch(2, 32, 0.5, Duration::from_millis(3));
        m.record_backend_batch(0, 1, 16, 1e-5, Duration::from_millis(3));
        m.record_backend_batch(1, 1, 16, 2e-3, Duration::from_millis(7));
        m.set_backend_queue(0, 12);
        m.snapshot()
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
        // escaped output embeds in a well-formed label
        let t = labels_text(&[("k".into(), "v\"\\\n".into())]);
        assert_eq!(t, "{k=\"v\\\"\\\\\\n\"}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_count() {
        let mut s = Summary::new();
        for v in [0.001, 0.002, 0.002, 0.004, 0.050, 1.5] {
            s.record(v);
        }
        let mut out = String::new();
        render_summary_hist(&mut out, false, "t_seconds", &[], &s);
        let mut prev = 0i64;
        let mut last_bucket = 0i64;
        let mut count = -1i64;
        for l in out.lines() {
            let (name, val) = l.rsplit_once(' ').unwrap();
            let v: f64 = val.parse().unwrap();
            if name.starts_with("t_seconds_bucket") {
                assert!(v as i64 >= prev, "cumulativity violated: {l}");
                prev = v as i64;
                last_bucket = v as i64;
                if name.contains("+Inf") {
                    assert_eq!(v as i64, 6, "+Inf bucket counts everything");
                }
            } else if name == "t_seconds_count" {
                count = v as i64;
            }
        }
        assert_eq!(last_bucket, count, "_count equals the +Inf bucket");
        assert!(out.contains("t_seconds_sum"));
    }

    #[test]
    fn prometheus_lines_are_well_formed() {
        super::super::set_enabled(true);
        super::super::span(super::super::TraceId::mint(),
                           super::super::Stage::Queue, "rust",
                           "digital_uncond", Duration::from_millis(2));
        let text = render_prometheus(&snap_with_traffic());
        assert!(text.contains("memdiff_requests_total 2"));
        assert!(text.contains(
            "memdiff_lane_queue_depth{backend=\"analog\"} 12"));
        assert!(text.contains("memdiff_backend_latency_seconds_bucket"));
        assert!(text.contains("memdiff_stage_latency_seconds"));
        assert!(text.contains("memdiff_phase_seconds_total{phase=\"gemm\"}"));
        for l in text.lines() {
            if l.starts_with('#') || l.is_empty() {
                continue;
            }
            let (name, val) = l.rsplit_once(' ').expect("name value");
            assert!(val.parse::<f64>().is_ok(), "unparseable value: {l}");
            assert!(name.starts_with("memdiff_") || name.starts_with("t_"),
                    "unexpected family: {l}");
        }
    }

    #[test]
    fn traced_buckets_render_openmetrics_exemplars() {
        super::super::set_enabled(true);
        let o = super::super::obs();
        let t = super::super::TraceId::mint();
        o.registry
            .hist(super::super::slo::REQUEST_LATENCY_HIST,
                  &[("backend", "rust"), ("class", "analog_cond")])
            .record_traced(0.125, t.0);
        let text = render_openmetrics(&snap_with_traffic());
        let needle = format!("# {{trace_id=\"{}\"}} 0.125", t.0);
        assert!(text.contains(&needle), "exemplar suffix missing:\n{text}");
        // exemplar lines still end in a parseable value
        for l in text.lines().filter(|l| l.contains("trace_id")) {
            let (_, val) = l.rsplit_once(' ').unwrap();
            assert!(val.parse::<f64>().is_ok(), "bad exemplar line: {l}");
        }
        // OpenMetrics requirements: counter families drop the `_total`
        // sample suffix, and the exposition ends with the EOF marker
        assert!(text.contains("# TYPE memdiff_requests counter"), "{text}");
        assert!(!text.contains("# TYPE memdiff_requests_total counter"));
        assert!(text.ends_with("# EOF\n"), "missing EOF marker");
    }

    #[test]
    fn classic_text_never_carries_exemplar_suffixes() {
        super::super::set_enabled(true);
        let o = super::super::obs();
        let t = super::super::TraceId::mint();
        o.registry
            .hist(super::super::slo::REQUEST_LATENCY_HIST,
                  &[("backend", "rust"), ("class", "analog_uncond")])
            .record_traced(0.25, t.0);
        // the classic parser reads anything after the value as a
        // timestamp: a retained exemplar must not leak a `#` suffix
        let text = render_prometheus(&snap_with_traffic());
        assert!(!text.contains("trace_id"), "exemplar leaked:\n{text}");
        assert!(!text.contains("# EOF"), "EOF is OpenMetrics-only");
        assert!(text.contains("# TYPE memdiff_requests_total counter"),
                "classic keeps full counter family names");
    }

    #[test]
    fn stats_json_names_the_p99_exemplar_with_stage_breakdown() {
        super::super::set_enabled(true);
        let o = super::super::obs();
        let t = super::super::TraceId::mint();
        let h = o.registry.hist(
            super::super::slo::REQUEST_LATENCY_HIST,
            &[("backend", "rust"), ("class", "digital_cond")]);
        for _ in 0..99 {
            h.record(1e-3);
        }
        h.record_traced(2.0, t.0); // the tail request, traced
        super::super::span(t, super::super::Stage::EngineSolve, "rust",
                           "digital_cond", Duration::from_millis(1900));
        let j = stats_json(&snap_with_traffic());
        let classes = j.get("class_latency").and_then(|v| v.as_arr()).unwrap();
        let mine = classes.iter().find(|c|
            c.get("class").and_then(|v| v.as_str()) == Some("digital_cond"))
            .expect("class entry present");
        assert_eq!(mine.get("p99_exemplar_trace").and_then(|v| v.as_f64()),
                   Some(t.0 as f64));
        let stages =
            mine.get("p99_exemplar_stages").and_then(|v| v.as_arr()).unwrap();
        assert!(stages.iter().any(|s|
            s.get("stage").and_then(|v| v.as_str()) == Some("engine_solve")),
            "breakdown names the dominant stage");
    }

    #[test]
    fn stats_json_has_stage_breakdown_and_traces() {
        super::super::set_enabled(true);
        let t = super::super::TraceId::mint();
        for st in super::super::Stage::ALL {
            super::super::span(t, st, "rust", "digital_uncond",
                               Duration::from_micros(40));
        }
        let j = stats_json(&snap_with_traffic());
        let stages = j.get("stages").and_then(|v| v.as_arr()).unwrap();
        assert!(stages.iter().any(|s|
            s.get("stage").and_then(|v| v.as_str()) == Some("engine_solve")));
        let traces = j.get("traces").and_then(|v| v.as_arr()).unwrap();
        let mine = traces.iter().find(|tr|
            tr.get("trace").and_then(|v| v.as_f64()) == Some(t.0 as f64))
            .expect("trace present");
        let spans = mine.get("spans").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(spans.len(), super::super::Stage::ALL.len());
        // round-trips through the hand-rolled serializer
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok());
    }
}
