//! Incident flight recorder: an atomically-written black-box dump of
//! the whole observability surface, captured at the moment something
//! goes wrong.
//!
//! A dump is one JSON file under `<state-dir>/flightrec/` named
//! `<ts_ms>-<seq>-<reason>.json` (`seq` is a process-wide atomic
//! sequence, so concurrent dumps in the same millisecond can never
//! choose the same path), carrying the full stats object (coordinator
//! snapshot, per-lane queue/job gauges, registry series with their
//! trace exemplars, phase timers, recent span-ring timelines), the
//! health/SLO report when a monitor is attached, the alert states, and
//! the deployment's config fingerprint — everything an operator needs
//! to answer "what was the server doing when it broke" after the
//! process (and its in-memory ring) is long gone.
//!
//! Triggers:
//!
//! * **alert latch** — the health monitor dumps `alert-<name>` when a
//!   rule transitions to firing (drift, probe, or `slo:` burn rules);
//! * **worker panic** — the coordinator's `catch_unwind` arm dumps
//!   `worker-panic` after containing an engine panic;
//! * **sustained overload** — [`note_shed`] counts bounded-lane sheds
//!   and dumps `overload-shed` when a burst overruns
//!   [`SHED_BURST`] sheds inside [`SHED_WINDOW`];
//! * **manual** — the `{"op":"dump"}` wire op / `memdiff client --dump`.
//!
//! Writes use the same atomic pattern as the job store's checkpoint
//! (tmp + fsync + rename + dir fsync), a per-reason rate limit keeps a
//! flapping alert from milling the disk, and a retention cap prunes the
//! oldest dumps so the directory is bounded.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::coordinator::Metrics;
use crate::util::json::Json;

use super::health::HealthMonitor;
use super::registry::Phase;

/// Default retained dump files.
pub const DEFAULT_CAP: usize = 16;
/// Default per-reason rate limit.
pub const DEFAULT_MIN_INTERVAL: Duration = Duration::from_secs(10);
/// Sheds inside [`SHED_WINDOW`] that count as sustained overload.
pub const SHED_BURST: u32 = 32;
/// The overload-shed counting window.
pub const SHED_WINDOW: Duration = Duration::from_secs(10);

/// The recorder.  Constructed once per `--state-dir` deployment and
/// shared (`Arc`) between the front-end (`dump` op), the health
/// monitor (alert-latch trigger), and the global trigger sites.
pub struct FlightRecorder {
    dir: PathBuf,
    cap: usize,
    min_interval: Duration,
    metrics: Arc<Metrics>,
    /// Weak: the monitor holds a strong `Arc<FlightRecorder>` for its
    /// alert-latch trigger, so a strong pointer back would leak both.
    health: Mutex<Weak<HealthMonitor>>,
    /// One-line deployment description, stamped into every dump.
    fingerprint: String,
    last_by_reason: Mutex<BTreeMap<String, Instant>>,
}

fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Reasons become filename components: keep alphanumerics and `-`/`_`,
/// fold everything else (alert names carry `:`) to `_`.
fn sanitize(reason: &str) -> String {
    let mut s: String = reason
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
            c
        } else {
            '_'
        })
        .collect();
    s.truncate(64);
    if s.is_empty() {
        s.push_str("unknown");
    }
    s
}

impl FlightRecorder {
    /// Open (creating) `<state_dir>/flightrec` with default limits.
    pub fn new(state_dir: impl AsRef<Path>, metrics: Arc<Metrics>,
               fingerprint: String) -> anyhow::Result<FlightRecorder> {
        Self::with_limits(state_dir, metrics, fingerprint, DEFAULT_CAP,
                          DEFAULT_MIN_INTERVAL)
    }

    /// [`Self::new`] with explicit retention cap and per-reason rate
    /// limit (tests use a tiny cap and a zero interval).
    pub fn with_limits(state_dir: impl AsRef<Path>, metrics: Arc<Metrics>,
                       fingerprint: String, cap: usize,
                       min_interval: Duration)
                       -> anyhow::Result<FlightRecorder> {
        let dir = state_dir.as_ref().join("flightrec");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        // a crash between create and rename can strand a `*.json.tmp`
        // that dumps()/prune() would never see — sweep it on open
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let p = e.path();
                if p.extension().and_then(|x| x.to_str()) == Some("tmp") {
                    let _ = std::fs::remove_file(&p);
                }
            }
        }
        Ok(FlightRecorder {
            dir,
            cap: cap.max(1),
            min_interval,
            metrics,
            health: Mutex::new(Weak::new()),
            fingerprint,
            last_by_reason: Mutex::new(BTreeMap::new()),
        })
    }

    /// Where dumps land (`<state-dir>/flightrec`).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Attach the health monitor after construction (the monitor holds
    /// the recorder, so the back-pointer must be weak).
    pub fn attach_health(&self, mon: &Arc<HealthMonitor>) {
        *self.health.lock().unwrap_or_else(|e| e.into_inner()) =
            Arc::downgrade(mon);
    }

    /// Rate-limited trigger: dump unless `reason` dumped inside the
    /// recorder's `min_interval`.  `None` = suppressed (or the write
    /// failed — a black box must never take the server down with it).
    pub fn trigger(&self, reason: &str) -> Option<PathBuf> {
        {
            let mut last =
                self.last_by_reason.lock().unwrap_or_else(|e| e.into_inner());
            let now = Instant::now();
            if let Some(prev) = last.get(reason) {
                if now.duration_since(*prev) < self.min_interval {
                    return None;
                }
            }
            last.insert(reason.to_string(), now);
        }
        self.dump(reason).ok()
    }

    /// Capture and atomically write one dump, pruning to the retention
    /// cap.  Unconditional — the wire op uses this directly.
    pub fn dump(&self, reason: &str) -> anyhow::Result<PathBuf> {
        let body = self.capture(reason).to_string();
        let name = sanitize(reason);
        let ts = now_ms();
        // the process-wide sequence makes the path (and the tmp name
        // derived from it) unique without a racy exists() probe, even
        // for concurrent same-reason dumps in the same millisecond
        let seq = DUMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = self.dir.join(format!("{ts}-{seq:06}-{name}.json"));
        let tmp = path.with_extension("json.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(body.as_bytes())?;
            f.write_all(b"\n")?;
            let _fsync = super::phase(Phase::Fsync);
            f.sync_data()
                .with_context(|| format!("fsync {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        #[cfg(unix)]
        if let Ok(d) = std::fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        self.prune();
        Ok(path)
    }

    /// The dump body: reason + fingerprint + alert/health/SLO state +
    /// the full stats object (which carries the lane gauges, registry
    /// exemplars, and span-ring timelines).
    fn capture(&self, reason: &str) -> Json {
        let mut m = BTreeMap::new();
        m.insert("kind".into(), Json::Str("memdiff_flight_record".into()));
        m.insert("ts_ms".into(), Json::Num(now_ms() as f64));
        m.insert("reason".into(), Json::Str(reason.to_string()));
        m.insert("fingerprint".into(), Json::Str(self.fingerprint.clone()));
        if let Some(mon) =
            self.health.lock().unwrap_or_else(|e| e.into_inner()).upgrade()
        {
            m.insert("health".into(), mon.health_json());
            m.insert(
                "firing".into(),
                Json::Arr(mon.firing().into_iter().map(Json::Str).collect()),
            );
        }
        m.insert("stats".into(),
                 super::export::stats_json(&self.metrics.snapshot()));
        Json::Obj(m)
    }

    /// Every retained dump path, oldest first.
    pub fn dumps(&self) -> Vec<PathBuf> {
        let mut files: Vec<PathBuf> = std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| {
                        p.extension().and_then(|x| x.to_str()) == Some("json")
                    })
                    .collect()
            })
            .unwrap_or_default();
        // <ts_ms>-<seq>- prefixes sort chronologically as strings
        // (13-digit millisecond stamps until the year 2286; the
        // zero-padded sequence breaks same-millisecond ties in write
        // order)
        files.sort();
        files
    }

    fn prune(&self) {
        let files = self.dumps();
        if files.len() > self.cap {
            for old in &files[..files.len() - self.cap] {
                let _ = std::fs::remove_file(old);
            }
        }
    }
}

/// Process-wide dump sequence: folded into every dump filename so
/// concurrent dumps (the unratelimited wire op racing a trigger, or
/// each other) can never pick the same tmp/final path.
static DUMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// The process-global recorder, for trigger sites too deep to thread an
/// `Arc` into (worker panic containment, overload shedding).
static GLOBAL: OnceLock<Arc<FlightRecorder>> = OnceLock::new();

/// Install the deployment's recorder as the global trigger target
/// (first install wins; later calls are ignored).
pub fn install(rec: Arc<FlightRecorder>) {
    let _ = GLOBAL.set(rec);
}

/// The installed recorder, if any.
pub fn global() -> Option<&'static Arc<FlightRecorder>> {
    GLOBAL.get()
}

/// Fire-and-forget trigger through the global recorder (no-op when no
/// `--state-dir` deployment installed one).
pub fn trigger_global(reason: &str) {
    if let Some(rec) = GLOBAL.get() {
        let _ = rec.trigger(reason);
    }
}

static SHED: Mutex<Option<(Instant, u32)>> = Mutex::new(None);

/// Count one bounded-lane overload shed; a sustained burst
/// ([`SHED_BURST`] sheds inside [`SHED_WINDOW`]) triggers an
/// `overload-shed` dump.  Cheap when no recorder is installed.
pub fn note_shed() {
    if GLOBAL.get().is_none() {
        return;
    }
    let fire = {
        let mut w = SHED.lock().unwrap_or_else(|e| e.into_inner());
        let now = Instant::now();
        match &mut *w {
            Some((t0, n)) if now.duration_since(*t0) <= SHED_WINDOW => {
                *n += 1;
                if *n >= SHED_BURST {
                    *w = None; // reset the window after firing
                    true
                } else {
                    false
                }
            }
            _ => {
                *w = Some((now, 1));
                false
            }
        }
    };
    if fire {
        trigger_global("overload-shed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("memdiff_flightrec_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn recorder(dir: &Path, cap: usize, min: Duration) -> FlightRecorder {
        FlightRecorder::with_limits(
            dir, Arc::new(Metrics::new()), "test-deployment".into(), cap, min)
            .unwrap()
    }

    #[test]
    fn dump_is_atomic_wellformed_and_reason_tagged() {
        let dir = tmp("atomic");
        let rec = recorder(&dir, 8, Duration::ZERO);
        let path = rec.dump("alert-slo:rust:digital_uncond").unwrap();
        // reason sanitized into the filename, raw in the body
        let fname = path.file_name().unwrap().to_str().unwrap();
        assert!(fname.ends_with("-alert-slo_rust_digital_uncond.json"),
                "{fname}");
        let body = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(body.trim()).expect("dump parses as JSON");
        assert_eq!(j.get("reason").and_then(|r| r.as_str()),
                   Some("alert-slo:rust:digital_uncond"));
        assert_eq!(j.get("fingerprint").and_then(|r| r.as_str()),
                   Some("test-deployment"));
        assert!(j.get("stats").is_some(), "full stats object embedded");
        // the atomic write leaves no tmp litter behind
        let litter: Vec<_> = std::fs::read_dir(rec.dir())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().and_then(|x| x.to_str())
                        != Some("json"))
            .collect();
        assert!(litter.is_empty(), "{litter:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_cap_prunes_oldest() {
        let dir = tmp("retention");
        let rec = recorder(&dir, 3, Duration::ZERO);
        let mut paths = Vec::new();
        for i in 0..6 {
            paths.push(rec.dump(&format!("r{i}")).unwrap());
        }
        let kept = rec.dumps();
        assert_eq!(kept.len(), 3, "cap enforced: {kept:?}");
        for old in &paths[..3] {
            assert!(!old.exists(), "oldest pruned: {}", old.display());
        }
        for new in &paths[3..] {
            assert!(new.exists(), "newest kept: {}", new.display());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn same_millisecond_dumps_get_distinct_paths() {
        let dir = tmp("seq");
        let rec = recorder(&dir, 8, Duration::ZERO);
        // the wire op bypasses the rate limit: back-to-back dumps for
        // one reason land in the same millisecond and must not clobber
        let a = rec.dump("manual").unwrap();
        let b = rec.dump("manual").unwrap();
        assert_ne!(a, b);
        assert!(a.exists() && b.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_tmp_litter_is_swept_on_open() {
        let dir = tmp("tmpsweep");
        let frdir = dir.join("flightrec");
        std::fs::create_dir_all(&frdir).unwrap();
        // a crash between create and rename strands a half-written tmp
        let stale = frdir.join("123-000000-crash.json.tmp");
        std::fs::write(&stale, b"{\"trunc").unwrap();
        let rec = recorder(&dir, 8, Duration::ZERO);
        assert!(!stale.exists(), "stale tmp swept on open");
        assert!(rec.dumps().is_empty(), "tmp never counted as a dump");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trigger_rate_limits_per_reason() {
        let dir = tmp("ratelimit");
        let rec = recorder(&dir, 8, Duration::from_secs(60));
        assert!(rec.trigger("flappy").is_some(), "first dump goes through");
        assert!(rec.trigger("flappy").is_none(), "second suppressed");
        assert!(rec.trigger("different").is_some(),
                "limit is per reason, not global");
        assert_eq!(rec.dumps().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
