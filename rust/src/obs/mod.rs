//! Observability: request tracing, a metrics registry, and hot-path
//! phase timers for the whole serving stack.
//!
//! Three pieces (see the ISSUE-7 tentpole):
//!
//! * [`trace`] — [`TraceId`]s minted at ingress and propagated through
//!   `GenRequest`/`Ticket`/job records; [`SpanEvent`]s for every
//!   lifecycle stage (accept → admit → queue → batch-form →
//!   engine-solve → decode → deliver) in a fixed-size sharded
//!   [`SpanRing`].
//! * [`registry`] — counters / gauges / log-bucketed bounded histograms
//!   ([`Registry`]); per-stage latency is recorded here per backend and
//!   per request class.
//! * [`export`] — Prometheus text exposition + JSON rendering of the
//!   registry, the coordinator metrics snapshot, the phase timers, and
//!   recent trace timelines (served by `{"op":"stats"}`,
//!   `--metrics-listen`, and the periodic JSONL flush).
//!
//! ## Overhead contract
//!
//! Every instrumentation point is gated on one process-global flag:
//!
//! * **Disabled** (`[obs] enabled = false`): each site reduces to a
//!   single relaxed atomic load — no clock read, no lock, no
//!   allocation.  Phase guards are a `None` and spans return
//!   immediately.
//! * **Enabled** (the default): a stage span costs one monotonic clock
//!   read, one atomic histogram add, and one short sharded-mutex push
//!   into the ring; a phase timer costs two clock reads and two atomic
//!   adds.  Memory is constant: the ring overwrites its oldest events
//!   and every histogram is a fixed bucket array
//!   ([`crate::util::stats::LOG_BUCKETS`] buckets).
//!
//! The end-to-end budget is **< 3% throughput cost** on the serving
//! path with obs enabled, tracked as `obs_overhead_pct` in
//! `BENCH_sampler_throughput.json`.
//!
//! ## Health monitoring
//!
//! On top of the substrate sits the analog health monitor (the ISSUE-8
//! tentpole):
//!
//! * [`health`] — [`HealthMonitor`]: a background retention clock and
//!   drift tracker comparing live conductances against the programmed
//!   baseline (per-backend / per-layer / per-bank `memdiff_drift_*`
//!   gauges, stuck-cell census, write-verify residual histograms), plus
//!   the [`DeviceHealth`] trait engines implement to expose
//!   age / drift-report / reprogram.
//! * [`probe`] — [`ProbeRunner`]: fixed-seed self-test requests injected
//!   directly through every routed backend (never through the batcher
//!   lanes, so serving metrics exclude them) and scored against the
//!   digital oracle with the paper's KL metric (`memdiff_probe_kl`).
//! * [`alert`] — [`AlertEngine`]: threshold + hysteresis + streak rules
//!   that latch named alerts (`memdiff_alert{name=}`), driving
//!   `/healthz`, `{"op":"health"}`, `memdiff client --health`, and the
//!   JSONL flush.
//!
//! ## Latency SLOs and incident capture
//!
//! The ISSUE-10 tentpole turns the telemetry into operable objectives:
//!
//! * [`slo`] — [`SloEngine`]: per-[`RequestClass`] p99 latency
//!   objectives from the `[slo]` config section, evaluated as
//!   multi-window burn rates (fast/slow windows over the cumulative
//!   request-latency histograms) that feed `slo:<backend>:<class>`
//!   rules into the same [`AlertEngine`], plus the
//!   `memdiff_slo_budget_remaining{class=}` /
//!   `memdiff_slo_burn_rate{class=,window=}` gauges.
//! * **Trace exemplars** — tail histogram buckets retain the most
//!   recent [`TraceId`] that landed there
//!   ([`registry::AtomicHist::record_traced`]); the Prometheus
//!   exposition renders OpenMetrics exemplars and `{"op":"stats"}`
//!   names the p99 request with its stage breakdown.
//! * [`flightrec`] — [`FlightRecorder`]: an atomic black-box dump
//!   (span ring, metrics snapshot, health/SLO state, config
//!   fingerprint) written to `<state-dir>/flightrec/<ts>-<reason>.json`
//!   on alert latch, worker panic, or sustained overload shed, with a
//!   retention cap, the `{"op":"dump"}` wire op, and
//!   `memdiff client --dump`.
//!
//! [`RequestClass`]: crate::coordinator::request::RequestClass

pub mod alert;
pub mod export;
pub mod flightrec;
pub mod health;
pub mod probe;
pub mod registry;
pub mod slo;
pub mod trace;

pub use alert::{AlertEngine, AlertRule, AlertSnapshot};
pub use flightrec::FlightRecorder;
pub use health::{DeviceHealth, HealthConfig, HealthMonitor};
pub use probe::{ProbeConfig, ProbeResult, ProbeRunner};
pub use registry::{AtomicHist, Counter, Gauge, Phase, PhaseTimers, Registry};
pub use slo::{SloConfig, SloEngine};
pub use trace::{SpanEvent, SpanRing, Stage, TraceId};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// The `[obs]` config section.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Master switch for spans, stage histograms, and phase timers
    /// (default on; the exporters keep working either way).
    pub enabled: bool,
    /// Total span events retained across the ring's shards.
    pub ring_capacity: usize,
    /// Period of the metrics JSONL flush under `--state-dir` (0 = off).
    pub jsonl_flush_ms: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: true, ring_capacity: 4096, jsonl_flush_ms: 10_000 }
    }
}

/// Global enable flag, readable with one relaxed load from any hot path.
static ENABLED: AtomicBool = AtomicBool::new(true);

static OBS: OnceLock<Obs> = OnceLock::new();

/// The process-wide observability state.
pub struct Obs {
    epoch: Instant,
    pub ring: SpanRing,
    pub registry: Registry,
    pub phases: PhaseTimers,
    /// Interned label strings (backend / class names) for compact
    /// [`SpanEvent`]s.
    labels: Mutex<Vec<String>>,
}

/// Install the configuration.  Call once at startup, before traffic:
/// the ring capacity is fixed at first use (later calls still update
/// the enable flag).
pub fn init(cfg: &ObsConfig) {
    ENABLED.store(cfg.enabled, Ordering::Relaxed);
    let _ = OBS.set(Obs::with_capacity(cfg.ring_capacity));
}

/// The global instance (created with defaults on first use).
pub fn obs() -> &'static Obs {
    OBS.get_or_init(|| Obs::with_capacity(ObsConfig::default().ring_capacity))
}

/// Whether instrumentation is live (one relaxed atomic load).
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip instrumentation at runtime (used by the overhead bench).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

impl Obs {
    fn with_capacity(ring_capacity: usize) -> Obs {
        Obs {
            epoch: Instant::now(),
            ring: SpanRing::new(ring_capacity),
            registry: Registry::new(),
            phases: PhaseTimers::new(),
            labels: Mutex::new(Vec::new()),
        }
    }

    /// Microseconds on the process-monotonic obs clock.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Intern a label string, returning its stable index.
    pub fn label(&self, s: &str) -> u16 {
        let mut ls = self.labels.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(i) = ls.iter().position(|l| l == s) {
            return i as u16;
        }
        if ls.len() >= u16::MAX as usize {
            return u16::MAX;
        }
        ls.push(s.to_string());
        (ls.len() - 1) as u16
    }

    /// Resolve an interned label (empty string when unknown).
    pub fn label_name(&self, i: u16) -> String {
        self.labels
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(i as usize)
            .cloned()
            .unwrap_or_default()
    }
}

/// Record one lifecycle span: a ring event on the request's trace plus
/// a sample in the per-(stage, backend, class) latency histogram.
/// No-op when obs is disabled.
pub fn span(trace: TraceId, stage: Stage, backend: &str, class: &str,
            dur: Duration) {
    if !enabled() {
        return;
    }
    let o = obs();
    let secs = dur.as_secs_f64();
    o.registry
        .hist("memdiff_stage_latency_seconds",
              &[("stage", stage.name()), ("backend", backend), ("class", class)])
        .record_traced(secs, trace.0);
    if !trace.is_none() {
        let dur_us = dur.as_micros() as u64;
        let now = o.now_us();
        o.ring.record(SpanEvent {
            trace: trace.0,
            stage,
            start_us: now.saturating_sub(dur_us),
            dur_us,
            backend: o.label(backend),
            class: o.label(class),
        });
    }
}

/// RAII hot-path phase timer: measures from construction to drop.
/// When obs is disabled the guard is inert (no clock read at all).
pub struct PhaseGuard(Option<(Phase, Instant)>);

/// Start timing `phase` (see [`PhaseGuard`]).
#[inline]
pub fn phase(p: Phase) -> PhaseGuard {
    if enabled() {
        PhaseGuard(Some((p, Instant::now())))
    } else {
        PhaseGuard(None)
    }
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        if let Some((p, t0)) = self.0.take() {
            obs().phases.record(p, t0.elapsed().as_nanos() as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // serialize tests that toggle the global enable flag
    static FLAG_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn span_records_histogram_and_ring() {
        let _g = FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        let t = TraceId::mint();
        span(t, Stage::Queue, "rust", "digital_uncond",
             Duration::from_millis(3));
        span(t, Stage::EngineSolve, "rust", "digital_uncond",
             Duration::from_millis(5));
        let tl = obs().ring.timeline(t);
        assert_eq!(tl.len(), 2);
        assert!(tl[0].start_us <= tl[1].start_us);
        let h = obs().registry.hist(
            "memdiff_stage_latency_seconds",
            &[("stage", "queue"), ("backend", "rust"),
              ("class", "digital_uncond")]);
        assert!(h.count() >= 1);
    }

    #[test]
    fn disabled_obs_is_inert() {
        let _g = FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        let t = TraceId::mint();
        span(t, Stage::Accept, "x", "y", Duration::from_millis(1));
        assert!(obs().ring.timeline(t).is_empty());
        let g = phase(Phase::Gemm);
        drop(g); // must not record
        set_enabled(true);
    }

    #[test]
    fn labels_intern_stably() {
        let a = obs().label("analog");
        let b = obs().label("rust-x");
        assert_eq!(obs().label("analog"), a);
        assert_ne!(a, b);
        assert_eq!(obs().label_name(a), "analog");
    }
}
